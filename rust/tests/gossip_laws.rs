//! PR 8 law suite (see `util::prop` for harness/replay mechanics):
//!
//! * SWIM digest merge laws — commutative, idempotent, associative, and
//!   therefore *order-convergent*: every delivery order of the same gossip
//!   events produces the same final view;
//! * incarnation refutation — a higher incarnation always beats stale
//!   suspicion, and stale suspicion can never re-convict;
//! * the byte-granular fault model — truncation/corruption of a stored
//!   chunk is rejected chunk-granularly by the ECS3 crc index and never
//!   commits a row into the `StateAssembler`; the restored prefix stays
//!   bit-exact once pristine bytes arrive.

use edgecache::coordinator::membership::{
    HealthPolicy, Membership, MembershipDigest, Outcome, PeerHealth, PeerView,
};
use edgecache::model::state::{BlobLayout, Compression, KvState, StateAssembler};
use edgecache::netsim::{apply_byte_fault, Fault};
use edgecache::util::prop::{run_prop_n, Gen};
use edgecache::util::rng::Rng;

const HASH: &str = "gossip-law";
const DIMS: (usize, usize, usize, usize) = (2, 64, 1, 8); // 128 B/token

// ---------------------------------------------------------------- gossip --

const ADDRS: [&str; 5] = ["10.0.0.1:7", "10.0.0.2:7", "10.0.0.3:7", "10.0.0.4:7", "10.0.0.5:7"];

fn gen_view(g: &mut Gen) -> PeerView {
    let state = match g.usize_in(0, 3) {
        0 => PeerHealth::Up,
        1 => PeerHealth::Recovering,
        2 => PeerHealth::Suspect,
        _ => PeerHealth::Dead,
    };
    PeerView::new(g.usize_in(0, 4) as u64, state)
}

fn gen_digest(g: &mut Gen) -> MembershipDigest {
    let mut d = MembershipDigest::new(g.usize_in(0, 9) as u64);
    for addr in ADDRS {
        if g.bool() {
            d.merge_entry(addr, gen_view(g));
        }
    }
    d
}

#[test]
fn prop_view_merge_is_commutative_idempotent_associative() {
    run_prop_n("view-merge-laws", 400, |g: &mut Gen| {
        let (a, b, c) = (gen_view(g), gen_view(g), gen_view(g));
        assert_eq!(PeerView::merge(a, b), PeerView::merge(b, a), "commutative");
        assert_eq!(PeerView::merge(a, a), a, "idempotent");
        assert_eq!(
            PeerView::merge(PeerView::merge(a, b), c),
            PeerView::merge(a, PeerView::merge(b, c)),
            "associative"
        );
        // the winner is always one of the operands — merge invents nothing
        let w = PeerView::merge(a, b);
        assert!(w == a || w == b, "merge must pick an operand");
    });
}

#[test]
fn prop_digest_merge_converges_across_delivery_orders() {
    run_prop_n("digest-order-convergence", 150, |g: &mut Gen| {
        let events: Vec<MembershipDigest> =
            (0..g.usize_in(2, 6)).map(|_| gen_digest(g)).collect();
        // two independently seeded delivery orders of the same events
        let mut order_a: Vec<usize> = (0..events.len()).collect();
        let mut order_b = order_a.clone();
        let mut rng = Rng::new(g.rng.next_u64());
        for i in (1..order_a.len()).rev() {
            order_a.swap(i, (rng.next_u64() as usize) % (i + 1));
        }
        for i in (1..order_b.len()).rev() {
            order_b.swap(i, (rng.next_u64() as usize) % (i + 1));
        }
        let fold = |order: &[usize]| {
            let mut board = MembershipDigest::default();
            for &i in order {
                board.merge_from(&events[i]);
            }
            board
        };
        let (a, b) = (fold(&order_a), fold(&order_b));
        assert_eq!(a, b, "delivery order must not change the converged view");
        // re-delivering everything is a no-op (idempotent union)
        let mut again = a.clone();
        for e in &events {
            again.merge_from(e);
        }
        assert_eq!(again, a, "re-delivery must be a no-op");
    });
}

#[test]
fn prop_digest_wire_roundtrip_is_exact() {
    run_prop_n("digest-roundtrip", 200, |g: &mut Gen| {
        let d = gen_digest(g);
        let decoded = MembershipDigest::decode(&d.encode()).expect("own encoding must parse");
        assert_eq!(decoded, d);
    });
}

#[test]
fn higher_incarnation_refutes_stale_suspicion() {
    // law level: suspicion at incarnation i loses to Up at i+1, in both
    // argument orders; and Up at i+1 is immune to re-conviction by i
    let sus = PeerView::new(3, PeerHealth::Suspect);
    let up = PeerView::new(4, PeerHealth::Up);
    assert_eq!(PeerView::merge(sus, up), up);
    assert_eq!(PeerView::merge(up, sus), up);

    // membership level: a first-hand Suspect is overturned by a gossiped
    // higher incarnation (the subject refuted itself through some box)
    let m = Membership::with_addrs(
        vec!["10.0.0.1:7".into(), "10.0.0.2:7".into()],
        HealthPolicy::default(),
    );
    m.report(1, Outcome::IoTimeout);
    assert_eq!(m.state(1), PeerHealth::Suspect);
    let mut d = MembershipDigest::new(0);
    d.merge_entry("10.0.0.2:7", PeerView::new(m.incarnation(1) + 1, PeerHealth::Up));
    assert_eq!(m.apply_digest(&d), 1, "the refutation must be adopted");
    assert_eq!(m.state(1), PeerHealth::Up);
    assert!(m.refutations() >= 1);

    // stale suspicion (the old incarnation) bounces off the refuted view
    let mut stale = MembershipDigest::new(0);
    stale.merge_entry("10.0.0.2:7", PeerView::new(0, PeerHealth::Suspect));
    assert_eq!(m.apply_digest(&stale), 0, "stale gossip must not re-convict");
    assert_eq!(m.state(1), PeerHealth::Up);
}

// ----------------------------------------------------------- byte faults --

fn filled_state(n: usize, seed: u64) -> KvState {
    let (l, s, kh, d) = DIMS;
    let mut st = KvState::zeroed(l, s, kh, d);
    st.n_tokens = n;
    let mut rng = Rng::new(seed);
    let row = kh * d;
    let le = s * row;
    for li in 0..l {
        for e in 0..n * row {
            st.k[li * le + e] = rng.f64() as f32;
            st.v[li * le + e] = rng.f64() as f32 - 0.5;
        }
    }
    st
}

/// Byte spans `(offset, len)` of the stored chunks, from the verified index.
fn chunk_spans(asm: &StateAssembler, head_len: usize) -> Vec<(usize, usize)> {
    let mut off = head_len;
    (0..asm.expected_chunks())
        .map(|c| {
            let span = (off, asm.chunk_len(c));
            off += asm.chunk_len(c);
            span
        })
        .collect()
}

#[test]
fn prop_byte_faults_are_rejected_chunk_granularly_and_never_commit_a_row() {
    run_prop_n("byte-faults-chunk-granular", 40, |g: &mut Gen| {
        let comp = if g.bool() { Compression::Deflate } else { Compression::None };
        let ct = 4;
        let n = g.usize_in(9, 32);
        let st = filled_state(n, g.rng.next_u64());
        let blob = st.serialize_prefix_opts(n, HASH, comp, ct);
        let (l, _, kh, d) = DIMS;
        let head_len = BlobLayout::new(HASH, l, kh, d)
            .with_chunk_tokens(ct)
            .payload_off(n);
        let mut asm = StateAssembler::new(&blob[..head_len], n, HASH, DIMS).unwrap();
        let k = asm.expected_chunks();
        let spans = chunk_spans(&asm, head_len);
        let victim = g.usize_in(0, k - 1);
        for c in 0..k {
            let (off, len) = spans[c];
            let pristine = &blob[off..off + len];
            if c == victim {
                let mut damaged = pristine.to_vec();
                let fault = if g.bool() {
                    Fault::TruncateAt(g.usize_in(0, len - 1))
                } else {
                    Fault::CorruptByteAt(g.usize_in(0, len - 1))
                };
                apply_byte_fault(fault, &mut damaged).unwrap();
                let fed_before = asm.fed_chunks();
                assert!(
                    asm.feed_chunk_at(c, &damaged).is_err(),
                    "damaged chunk {c} must be rejected ({fault:?})"
                );
                assert_eq!(asm.fed_chunks(), fed_before, "rejection must not count as fed");
                assert!(!asm.fed_at(c), "rejection must not mark the chunk fed");
                // chunk-granular: the same slot still accepts pristine bytes
                asm.feed_chunk_at(c, pristine).unwrap();
            } else {
                asm.feed_chunk_at(c, pristine).unwrap();
            }
        }
        let out = asm.finish().expect("all chunks pristine-fed");
        let want = KvState::restore(&blob, HASH, DIMS).unwrap();
        assert_eq!(out, want, "restored prefix must be bit-exact after the fault");
    });
}

#[test]
fn prop_seeded_rows_track_the_contiguous_fed_prefix() {
    run_prop_n("seeded-rows-oracle", 60, |g: &mut Gen| {
        let ct = 4;
        let n = g.usize_in(9, 32);
        let st = filled_state(n, g.rng.next_u64());
        let blob = st.serialize_prefix_opts(n, HASH, Compression::None, ct);
        let (l, _, kh, d) = DIMS;
        let head_len = BlobLayout::new(HASH, l, kh, d)
            .with_chunk_tokens(ct)
            .payload_off(n);
        let mut asm = StateAssembler::new(&blob[..head_len], n, HASH, DIMS).unwrap();
        let k = asm.expected_chunks();
        let spans = chunk_spans(&asm, head_len);
        // feed a random subset in a random order
        let mut order: Vec<usize> = (0..k).collect();
        let mut rng = Rng::new(g.rng.next_u64());
        for i in (1..order.len()).rev() {
            order.swap(i, (rng.next_u64() as usize) % (i + 1));
        }
        let keep = g.usize_in(0, k);
        let mut fed = vec![false; k];
        for &c in order.iter().take(keep) {
            let (off, len) = spans[c];
            asm.feed_chunk_at(c, &blob[off..off + len]).unwrap();
            fed[c] = true;
            let lead = fed.iter().take_while(|&&f| f).count();
            let want_rows = (lead * ct).min(n);
            assert_eq!(asm.seeded_rows(), want_rows, "seeded_rows oracle");
            match asm.seed_state() {
                Some(seed) => {
                    assert!(want_rows > 0);
                    assert_eq!(seed.n_tokens, want_rows);
                    // the seed's leading rows are bit-exact truth rows
                    assert_eq!(
                        seed.chunk_payload(0, want_rows),
                        st.chunk_payload(0, want_rows),
                        "seed rows must match the stored truth"
                    );
                }
                None => assert_eq!(want_rows, 0, "no seed only when nothing contiguous"),
            }
        }
    });
}

#[test]
fn reset_fault_truncates_and_surfaces_a_connection_reset() {
    let mut bytes = (0u8..200).collect::<Vec<u8>>();
    let err = apply_byte_fault(Fault::ResetAfter(37), &mut bytes)
        .expect_err("an injected reset must surface as an error");
    assert_eq!(err.kind(), std::io::ErrorKind::ConnectionReset);
    assert_eq!(bytes.len(), 37, "only the bytes before the reset survive");

    let mut bytes = vec![0xFFu8; 64];
    apply_byte_fault(Fault::CorruptByteAt(70), &mut bytes).unwrap();
    assert_eq!(bytes[63], 0xFF ^ 0xA5, "offset past the end clamps to the last byte");
}
