//! Integration: the fleet-scale serving core — poll loop, sharded store,
//! admission shedding — under genuinely concurrent client load.
//!
//! The loom-free concurrency discipline here is observational: many OS
//! threads hammer one box with mixed `SET`/`GETRANGE`/`SPLICE` traffic
//! whose every value is a *uniform byte fill*, so any torn read — bytes
//! from two generations of a key stitched together — is detectable as a
//! mixed-byte payload no matter how the race interleaved.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use edgecache::kvstore::{KvClient, KvServer, ServeMode, Value};

fn spawn(mode: ServeMode, shards: usize, max_pending: usize) -> edgecache::kvstore::ServerHandle {
    KvServer::configure(usize::MAX, shards, max_pending)
        .serve_with("127.0.0.1:0", mode)
        .unwrap()
}

/// Assert a payload is a uniform byte fill (the torn-read detector).
fn assert_uniform(b: &[u8], ctx: &str) {
    if let Some(&first) = b.first() {
        assert!(
            b.iter().all(|&x| x == first),
            "torn read ({ctx}): mixed bytes in a uniform-fill value"
        );
    }
}

#[test]
fn shard_stress_no_torn_reads_and_honest_accounting() {
    // a real (finite) budget so eviction accounting is part of the check
    let server = KvServer::configure(64 << 10, 4, 0);
    let h = server.serve_with("127.0.0.1:0", ServeMode::Poll).unwrap();
    let addr = h.addr_string();

    let writers = 6usize;
    let ops = 120usize;
    thread::scope(|s| {
        for t in 0..writers {
            let addr = addr.clone();
            s.spawn(move || {
                let mut c = KvClient::connect(&addr).unwrap();
                for i in 0..ops {
                    let key = format!("k{}:{}", t, i % 7);
                    let byte = (17 * t + i) as u8;
                    let len = 64 + (i * 37) % 512;
                    match i % 4 {
                        0 | 1 => {
                            c.set(key.as_bytes(), &vec![byte; len]).unwrap();
                        }
                        2 => {
                            if let Some(got) = c.get(key.as_bytes()).unwrap() {
                                assert_uniform(&got, &key);
                            }
                            if let Some(got) =
                                c.getrange(key.as_bytes(), 5, 40).unwrap()
                            {
                                assert_uniform(&got, &key);
                            }
                        }
                        _ => {
                            // cross-shard splice: new key and base key hash
                            // to different shards; head/tail reuse the base
                            // byte so the result stays uniform
                            let new = format!("s{}:{}", t, i % 5);
                            if let Ok(n) = c.splice(
                                new.as_bytes(),
                                key.as_bytes(),
                                0,
                                10,
                                vec![byte; 3].into(),
                                vec![byte; 3].into(),
                            ) {
                                assert!(n >= 6, "splice result too short");
                                if let Some(got) = c.get(new.as_bytes()).unwrap() {
                                    assert_uniform(&got, &new);
                                }
                            }
                        }
                    }
                }
            });
        }
    });

    // honest accounting after the dust settles: the aggregate view must
    // equal the sum of what the keys actually hold, per shard and globally
    let store = &h.server.store;
    let mut global = 0usize;
    for i in 0..store.n_shards() {
        let s = store.shard_at(i).lock().unwrap();
        let by_keys: usize = s
            .keys()
            .map(|k| s.strlen(k).expect("listed key present"))
            .sum();
        assert_eq!(s.used_bytes(), by_keys, "shard {i} accounting drifted");
        assert!(
            s.used_bytes() <= s.max_bytes,
            "shard {i} over its partitioned budget"
        );
        global += s.used_bytes();
    }
    assert_eq!(store.used_bytes(), global, "global used_bytes not the shard sum");
    assert!(store.used_bytes() <= 64 << 10, "global budget violated");
    h.shutdown();
}

#[test]
fn poll_and_threads_answer_identically() {
    // one scripted mixed pipeline, replayed against both serving cores:
    // the replies must be value-identical (the core is an implementation
    // choice, never a protocol change)
    let script: Vec<Vec<Vec<u8>>> = vec![
        vec![b"PING".to_vec()],
        vec![b"SET".to_vec(), b"a".to_vec(), vec![9u8; 100]],
        vec![b"STRLEN".to_vec(), b"a".to_vec()],
        vec![b"GETRANGE".to_vec(), b"a".to_vec(), b"10".to_vec(), b"20".to_vec()],
        vec![b"EXISTS".to_vec(), b"a".to_vec()],
        vec![b"GET".to_vec(), b"missing".to_vec()],
        vec![b"DEL".to_vec(), b"a".to_vec()],
        vec![b"DBSIZE".to_vec()],
        vec![b"BOGUS".to_vec()],
    ];
    let mut replies = Vec::new();
    for mode in [ServeMode::Threads, ServeMode::Poll] {
        let h = spawn(mode, 4, 0);
        let mut c = KvClient::connect(&h.addr_string()).unwrap();
        replies.push(c.pipeline(&script).unwrap());
        h.shutdown();
    }
    assert_eq!(replies[0], replies[1], "threads vs poll replies diverged");
}

#[test]
fn admission_sheds_deterministically_and_recovers() {
    let server = KvServer::configure(usize::MAX, 1, 1);
    let mut server = server;
    // slow each op down so a pipelined burst genuinely overlaps the gate
    Arc::get_mut(&mut server).unwrap().op_delay = Duration::from_millis(2);
    let h = server.serve_with("127.0.0.1:0", ServeMode::Poll).unwrap();
    let mut c = KvClient::connect(&h.addr_string()).unwrap();

    let burst: Vec<Vec<Vec<u8>>> = (0..24).map(|_| vec![b"PING".to_vec()]).collect();
    let replies = c.pipeline(&burst).unwrap();
    assert_eq!(replies.len(), 24, "no reply may go missing under shedding");
    let busy = replies
        .iter()
        .filter(|v| matches!(v, Value::Error(e) if e.starts_with("BUSY")))
        .count();
    let pong = replies
        .iter()
        .filter(|v| matches!(v, Value::Simple(s) if s == "PONG"))
        .count();
    assert_eq!(busy + pong, 24, "every reply is either a PONG or a BUSY");
    assert!(busy >= 1, "a 24-deep burst over a 1-slot gate must shed");
    assert!(pong >= 1, "the admitted head of the burst must still answer");

    // the gate's own books agree with what went over the wire
    assert_eq!(h.server.admission.sheds(), busy as u64);
    assert!(h.server.admission.peak_pending() >= 1);

    // shedding is per-op, not per-connection: the same socket serves again
    c.ping().unwrap();
    assert!(c.set(b"after", b"ok").is_ok());
    assert_eq!(c.get(b"after").unwrap().unwrap().as_ref(), b"ok");

    // and the INFO telemetry carries the shed counters for probes
    let info = c.info().unwrap();
    let sheds =
        edgecache::kvstore::client::parse_info_field(&info, "sheds").expect("sheds line");
    assert_eq!(sheds as u64, h.server.admission.sheds());
    assert!(
        edgecache::kvstore::client::parse_info_field(&info, "pending_peak").is_some(),
        "pending_peak line missing from INFO"
    );
    h.shutdown();
}

#[test]
fn poll_core_survives_many_connections_with_zero_wedged_clients() {
    // more simultaneous connections than worker threads: every client must
    // make progress (readiness multiplexing), none may wedge
    let h = spawn(ServeMode::Poll, 4, 0);
    let addr = h.addr_string();
    let clients = 32usize;
    thread::scope(|s| {
        for t in 0..clients {
            let addr = addr.clone();
            s.spawn(move || {
                let mut c = KvClient::connect(&addr).unwrap();
                let key = format!("conn{t}");
                for i in 0..20 {
                    c.set(key.as_bytes(), &vec![t as u8; 50 + i]).unwrap();
                    let got = c.get(key.as_bytes()).unwrap().unwrap();
                    assert_eq!(got.len(), 50 + i);
                    assert_uniform(&got, &key);
                }
            });
        }
    });
    assert_eq!(h.server.store.len(), clients);
    h.shutdown();
}
