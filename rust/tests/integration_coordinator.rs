//! Integration: the full distributed-prompt-caching system — multi-client
//! traces, policy ablations, and the paper's qualitative claims end to end.

use std::sync::Arc;
use std::time::Duration;

use edgecache::coordinator::{
    CacheBox, EdgeClient, EdgeClientConfig, FetchPolicy, HitCase,
};
use edgecache::engine::Engine;
use edgecache::model::state::Compression;
use edgecache::workload::{Generator, Trace};

fn engine() -> Option<Arc<Engine>> {
    if !edgecache::artifacts_dir().join("tiny/meta.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return None;
    }
    Some(Arc::new(Engine::load_preset("tiny").unwrap()))
}

fn cfg(name: &str, server: Option<String>) -> EdgeClientConfig {
    EdgeClientConfig {
        name: name.into(),
        max_new_tokens: Some(2),
        sync_interval: None,
        ..EdgeClientConfig::native(server)
    }
}

#[test]
fn multi_client_trace_distribution() {
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut clients: Vec<EdgeClient> = (0..3)
        .map(|i| {
            EdgeClient::new(Arc::clone(&eng), cfg(&format!("c{i}"), Some(cb.addr()))).unwrap()
        })
        .collect();
    let gen = Generator::new(11);
    let trace = Trace::generate(11, 3, 4, 4, 1);
    let mut cases = [0usize; 5];
    for q in &trace.queries {
        let c = &mut clients[q.client];
        c.sync_catalog_now().unwrap();
        let p = gen.prompt(&q.domain, q.question_index, q.n_shots);
        let r = c.query(&p).unwrap();
        cases[r.case.number() - 1] += 1;
    }
    // the first query of a domain misses; later same-domain queries hit
    // at least the instruction+examples prefix
    assert!(cases[0] >= 4, "one miss per domain minimum: {cases:?}");
    assert!(
        cases[3] + cases[4] >= 8,
        "most repeat-domain queries must hit cases 4/5: {cases:?}"
    );
    let total: usize = cases.iter().sum();
    assert_eq!(total, 16);
    for c in clients {
        c.shutdown();
    }
    cb.shutdown();
}

#[test]
fn deflated_partial_hit_streams_and_credits_overlap() {
    // Acceptance pin for the streaming assembly pipeline: a deflated
    // partial hit must ride the per-chunk range path, and the decode of
    // early chunks must demonstrably overlap the modelled wire time of
    // later chunks — overlap_saved > 0 on the hit query's breakdown.
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut k = cfg("overlap", Some(cb.addr()));
    k.compression = Compression::Deflate;
    k.chunk_tokens = 2; // many chunks -> many arrivals to overlap
    k.link = edgecache::netsim::LinkModel {
        name: "test-lan",
        // slow enough that each chunk has real modelled flight time to hide
        // decode inside, fast enough to keep the test well under a second
        goodput_bps: 2e6,
        rtt: Duration::from_millis(2),
        jitter_frac: 0.0,
    };
    let mut c = EdgeClient::new(Arc::clone(&eng), k).unwrap();
    let gen = Generator::new(21);
    let p0 = gen.prompt("anatomy", 0, 2);
    let p1 = gen.prompt("anatomy", 1, 2);

    let r0 = c.query(&p0).unwrap();
    assert_eq!(r0.case, HitCase::Miss);
    assert_eq!(r0.breakdown.overlap_saved, Duration::ZERO, "miss streams nothing");

    let r1 = c.query(&p1).unwrap();
    assert_eq!(r1.case, HitCase::AllExamples);
    assert_eq!(c.stats.range_fetches, 1, "deflated alias hit must range-fetch");
    assert_eq!(c.stats.full_fetch_fallbacks, 0);
    assert!(
        r1.breakdown.overlap_saved > Duration::ZERO,
        "chunk decode must overlap wire time (saved {:?})",
        r1.breakdown.overlap_saved
    );
    // the credit can never exceed the Redis phase it was hidden inside
    assert!(
        r1.breakdown.overlap_saved <= r1.breakdown.get(edgecache::metrics::Phase::Redis),
        "overlap credit {:?} must be bounded by Redis time {:?}",
        r1.breakdown.overlap_saved,
        r1.breakdown.get(edgecache::metrics::Phase::Redis)
    );
    assert_eq!(c.link_overlap_saved(), r1.breakdown.overlap_saved);
    c.shutdown();
    cb.shutdown();
}

#[test]
fn adaptive_chunk_size_roundtrips_through_the_range_path() {
    // Adaptive sizing records the chosen chunk size per entry (header +
    // alias), so a partial hit still chunk-aligns its GETRANGEs and the
    // range path completes without fallback.
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut k = cfg("adaptive", Some(cb.addr()));
    k.compression = Compression::Deflate;
    k.adaptive_chunk = true;
    k.link = edgecache::netsim::LinkModel {
        name: "test-lan",
        goodput_bps: 25e6,
        rtt: Duration::from_millis(2),
        jitter_frac: 0.0,
    };
    let mut c = EdgeClient::new(Arc::clone(&eng), k).unwrap();
    let gen = Generator::new(23);
    let p0 = gen.prompt("virology", 0, 2);
    let p1 = gen.prompt("virology", 1, 2);

    let r0 = c.query(&p0).unwrap();
    assert_eq!(r0.case, HitCase::Miss);
    let r1 = c.query(&p1).unwrap();
    assert_eq!(r1.case, HitCase::AllExamples);
    assert_eq!(c.stats.range_fetches, 1, "adaptive entries must range-fetch");
    assert_eq!(c.stats.full_fetch_fallbacks, 0, "no stale-geometry fallback");
    assert!(r1.saved_bytes > 0);
    // identical repeat fully hits and reproduces through the adaptive entry
    let r2 = c.query(&p0).unwrap();
    assert_eq!(r2.case, HitCase::Full);
    assert_eq!(r0.response_tokens, r2.response_tokens);
    c.shutdown();
    cb.shutdown();
}

#[test]
fn cross_client_correctness_identical_outputs() {
    // The headline correctness property: the same prompt produces the same
    // tokens whether answered locally, via own-cache hit, or via a state
    // another client uploaded.
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut a = EdgeClient::new(Arc::clone(&eng), cfg("a", Some(cb.addr()))).unwrap();
    let mut b = EdgeClient::new(Arc::clone(&eng), cfg("b", Some(cb.addr()))).unwrap();
    let mut solo = EdgeClient::new(Arc::clone(&eng), cfg("solo", None)).unwrap();

    let p = Generator::new(3).prompt("college_physics", 2, 1);
    let r_solo = solo.query(&p).unwrap();
    let r_a1 = a.query(&p).unwrap(); // miss + upload
    let r_a2 = a.query(&p).unwrap(); // own full hit
    b.sync_catalog_now().unwrap();
    let r_b = b.query(&p).unwrap(); // cross-client full hit

    assert_eq!(r_a1.case, HitCase::Miss);
    assert_eq!(r_a2.case, HitCase::Full);
    assert_eq!(r_b.case, HitCase::Full);
    assert_eq!(r_solo.response_tokens, r_a1.response_tokens);
    assert_eq!(r_a1.response_tokens, r_a2.response_tokens);
    assert_eq!(r_a1.response_tokens, r_b.response_tokens);
    for c in [a, b, solo] {
        c.shutdown();
    }
    cb.shutdown();
}

#[test]
fn partial_matching_off_means_full_or_nothing() {
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut c = {
        let mut k = cfg("nopartial", Some(cb.addr()));
        k.partial_matching = false;
        EdgeClient::new(Arc::clone(&eng), k).unwrap()
    };
    let gen = Generator::new(5);
    let p0 = gen.prompt("marketing", 0, 1);
    let p1 = gen.prompt("marketing", 1, 1); // shares instruction+examples

    let r0 = c.query(&p0).unwrap();
    assert_eq!(r0.case, HitCase::Miss);
    let r1 = c.query(&p1).unwrap();
    assert_eq!(
        r1.case,
        HitCase::Miss,
        "without partial matching, shared prefixes cannot hit"
    );
    let r2 = c.query(&p0).unwrap();
    assert_eq!(r2.case, HitCase::Full, "exact repeats still hit");
    c.shutdown();
    cb.shutdown();
}

#[test]
fn break_even_policy_declines_on_slow_tradeoffs() {
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    // device so fast that fetching can never win (prefill is ~free)
    let mut k = cfg("breakeven", Some(cb.addr()));
    k.fetch_policy = FetchPolicy::BreakEven;
    k.link = edgecache::netsim::LinkModel {
        name: "slow-test",
        goodput_bps: 1e6, // 1 MB/s: fetching a state is slower than prefill
        rtt: Duration::from_millis(200),
        jitter_frac: 0.0,
    };
    let mut c = EdgeClient::new(Arc::clone(&eng), k).unwrap();
    let p = Generator::new(9).prompt("jurisprudence", 0, 1);
    let _ = c.query(&p).unwrap(); // seed (upload still happens, shaped)
    let r = c.query(&p).unwrap();
    assert_eq!(
        r.case,
        HitCase::Miss,
        "break-even must decline the fetch on a host-speed device"
    );
    assert_eq!(c.stats.fetches_declined, 1);
    c.shutdown();
    cb.shutdown();
}

#[test]
fn compression_reduces_uploaded_bytes() {
    let Some(eng) = engine() else { return };
    let gen = Generator::new(13);
    let p = gen.prompt("nutrition", 0, 1);

    let cb1 = CacheBox::start_local().unwrap();
    let mut plain = EdgeClient::new(Arc::clone(&eng), cfg("plain", Some(cb1.addr()))).unwrap();
    let r_plain = plain.query(&p).unwrap();

    let cb2 = CacheBox::start_local().unwrap();
    let mut comp = {
        let mut k = cfg("deflate", Some(cb2.addr()));
        k.compression = Compression::Deflate;
        EdgeClient::new(Arc::clone(&eng), k).unwrap()
    };
    let r_comp = comp.query(&p).unwrap();

    assert!(r_comp.uploaded_bytes > 0);
    assert!(
        r_comp.uploaded_bytes < r_plain.uploaded_bytes,
        "deflate must shrink uploads: {} vs {}",
        r_comp.uploaded_bytes,
        r_plain.uploaded_bytes
    );
    // and the compressed path still hits + reproduces
    let r2 = comp.query(&p).unwrap();
    assert_eq!(r2.case, HitCase::Full);
    assert_eq!(r_comp.response_tokens, r2.response_tokens);
    plain.shutdown();
    comp.shutdown();
    cb1.shutdown();
    cb2.shutdown();
}

#[test]
fn min_hit_tokens_suppresses_short_fetches() {
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut k = cfg("minhit", Some(cb.addr()));
    k.min_hit_tokens = 100_000; // nothing is ever long enough
    let mut c = EdgeClient::new(Arc::clone(&eng), k).unwrap();
    let p = Generator::new(17).prompt("sociology", 0, 1);
    let _ = c.query(&p).unwrap();
    let r = c.query(&p).unwrap();
    assert_eq!(r.case, HitCase::Miss, "threshold filters all hits");
    assert_eq!(r.downloaded_bytes, 0);
    c.shutdown();
    cb.shutdown();
}

#[test]
fn delta_upload_and_range_download_shrink_wire_bytes() {
    // The zero-copy/suffix-delta acceptance: a miss publishes ~one blob
    // (plus tiny aliases) instead of one full nested blob per range, and a
    // partial match downloads only the matched token rows plus the blob
    // head — both visibly smaller than the full-blob-per-range pipeline.
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("delta", Some(cb.addr()))).unwrap();
    let gen = Generator::new(23);
    let p0 = gen.prompt("astronomy", 0, 2);
    let p1 = gen.prompt("astronomy", 1, 2); // shares instruction + examples

    let mcfg = &eng.model.config;
    let lo = edgecache::model::state::BlobLayout::new(
        eng.model_hash(),
        mcfg.n_layers,
        mcfg.n_kv_heads,
        mcfg.head_dim,
    );

    let r0 = c.query(&p0).unwrap();
    assert_eq!(r0.case, HitCase::Miss);
    let one_blob = lo.blob_len(r0.prompt_tokens);
    assert!(r0.uploaded_bytes > 0);
    assert!(
        r0.uploaded_bytes < one_blob + one_blob / 4,
        "miss upload must ship ~one blob + aliases, not nested blobs: {} vs {}",
        r0.uploaded_bytes,
        one_blob
    );
    assert!(r0.saved_bytes > 0, "alias scheme must beat the per-range model");

    let r1 = c.query(&p1).unwrap();
    assert_eq!(r1.case, HitCase::AllExamples);
    assert!(r1.matched_tokens > 0 && r1.matched_tokens < r1.prompt_tokens);
    // download: alias + head/index + matched rows only — strictly less than
    // the stored full-prompt entry it resolves into
    assert!(r1.downloaded_bytes > 0);
    assert!(
        r1.downloaded_bytes < lo.blob_len(r0.prompt_tokens),
        "partial match must not move the whole entry: {} vs {}",
        r1.downloaded_bytes,
        lo.blob_len(r0.prompt_tokens)
    );
    assert!(r1.downloaded_bytes >= r1.matched_tokens * lo.token_stride());
    // upload: only the suffix rows past the matched prefix (via SPLICE)
    let suffix_rows = r1.prompt_tokens - r1.matched_tokens;
    assert!(r1.uploaded_bytes > 0);
    assert!(
        r1.uploaded_bytes < lo.blob_len(r1.prompt_tokens),
        "delta upload must beat a full blob: {} vs {}",
        r1.uploaded_bytes,
        lo.blob_len(r1.prompt_tokens)
    );
    assert!(r1.uploaded_bytes >= suffix_rows * lo.token_stride());
    assert!(r1.saved_bytes > 0);

    // the spliced entry is complete: an exact repeat of p1 is a full hit
    // that reproduces the same response
    let r2 = c.query(&p1).unwrap();
    assert_eq!(r2.case, HitCase::Full);
    assert_eq!(r1.response_tokens, r2.response_tokens);
    c.shutdown();
    cb.shutdown();
}

#[test]
fn compressed_partial_hit_uses_range_path() {
    // The ECS3 acceptance: with Compression::Deflate, a partial match moves
    // only the matched chunks' bytes — no full-blob fallback — and the
    // SPLICE suffix-delta composes with the deflated base entry.
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut k = cfg("comp-range", Some(cb.addr()));
    k.compression = Compression::Deflate;
    k.chunk_tokens = 2; // small chunks: tight over-fetch bound for the test
    let mut c = EdgeClient::new(Arc::clone(&eng), k).unwrap();
    let gen = Generator::new(29);
    let p0 = gen.prompt("astronomy", 0, 2);
    let p1 = gen.prompt("astronomy", 1, 2); // shares instruction + examples

    let r0 = c.query(&p0).unwrap();
    assert_eq!(r0.case, HitCase::Miss);

    // the largest stored entry is p0's full-prompt deflated blob — the
    // old pipeline moved at least this much on a compressed partial hit
    let full_entry_len = {
        let store = cb.handle.server.store.lock().unwrap();
        let mut max = 0usize;
        for key in store.keys() {
            max = max.max(store.strlen(key).unwrap_or(0));
        }
        max
    };
    assert!(full_entry_len > 0);

    let moved0 = c.link_moved_bytes();
    edgecache::util::bytes::copymeter::reset();
    let r1 = c.query(&p1).unwrap();
    let copied = edgecache::util::bytes::copymeter::get();
    let moved = (c.link_moved_bytes() - moved0) as usize;

    assert_eq!(r1.case, HitCase::AllExamples);
    assert!(r1.matched_tokens > 0 && r1.matched_tokens < r1.prompt_tokens);
    // the path taken, exactly: one chunk-aligned range fetch, no fallback
    assert_eq!(c.stats.range_fetches, 1);
    assert_eq!(c.stats.full_fetch_fallbacks, 0);
    // moved_bytes bound: the download (alias + head + matched chunks) must
    // undercut the full deflated entry the old fallback re-shipped
    assert!(
        r1.downloaded_bytes < full_entry_len,
        "partial fetch {} must move less than the {}-byte entry",
        r1.downloaded_bytes,
        full_entry_len
    );
    // ...and the Shaper ledger agrees with the per-query accounting
    assert_eq!(moved, r1.downloaded_bytes + r1.uploaded_bytes);
    // the SPLICE suffix-delta also undercuts re-shipping a whole entry
    assert!(r1.uploaded_bytes > 0);
    assert!(
        r1.uploaded_bytes < full_entry_len,
        "deflated suffix splice {} vs full entry {}",
        r1.uploaded_bytes,
        full_entry_len
    );
    assert!(r1.saved_bytes > 0, "range + delta must beat the old pipeline");
    // copymeter bound: client and in-process server together may move the
    // state through a small constant number of payload-sized copies
    // (gather, wire write, inflate, scatter, ...), but never the old
    // download-whole-blob-then-truncate pipeline's worth per side
    let logical = r1.breakdown.inflated_bytes;
    assert!(logical > 0, "inflated accounting must be populated");
    let state_size = eng.model.config.kv_bytes_per_token() * r1.prompt_tokens;
    assert!(
        (copied as usize) < 12 * state_size + (4 << 20),
        "copy budget blown: {copied} bytes copied vs state {state_size}"
    );

    // the spliced deflated entry is complete and valid: an exact repeat is
    // a full hit that reproduces the same response
    let r2 = c.query(&p1).unwrap();
    assert_eq!(r2.case, HitCase::Full);
    assert_eq!(r1.response_tokens, r2.response_tokens);
    c.shutdown();
    cb.shutdown();
}

#[test]
fn upload_dedup_across_queries() {
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("dedup", Some(cb.addr()))).unwrap();
    let gen = Generator::new(21);
    let p0 = gen.prompt("virology", 0, 1);
    let p1 = gen.prompt("virology", 1, 1);

    let r0 = c.query(&p0).unwrap();
    assert!(r0.uploaded_bytes > 0);
    let r1 = c.query(&p1).unwrap();
    // shared instruction+examples ranges are already cached: only the new
    // full-prompt range uploads
    assert!(r1.uploaded_bytes > 0);
    assert!(
        r1.uploaded_bytes < r0.uploaded_bytes,
        "prefix ranges must not re-upload: {} vs {}",
        r1.uploaded_bytes,
        r0.uploaded_bytes
    );
    c.shutdown();
    cb.shutdown();
}
