//! Integration: the inference engine against the real AOT artifacts —
//! numerics, chunking equivalence, state snapshot fidelity.
//!
//! Tests skip (with a note) when `artifacts/tiny` is absent; run
//! `make artifacts` first.

use std::sync::Arc;

use edgecache::devicemodel::{DeviceProfile, Pacer};
use edgecache::engine::Engine;
use edgecache::metrics::PhaseBreakdown;
use edgecache::model::sampler::Sampler;
use edgecache::model::state::{Compression, KvState};

fn engine() -> Option<Arc<Engine>> {
    if !edgecache::artifacts_dir().join("tiny/meta.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return None;
    }
    Some(Arc::new(Engine::load_preset("tiny").unwrap()))
}

fn pacer() -> Pacer {
    Pacer::new(DeviceProfile::host())
}

#[test]
fn chunking_is_transparent() {
    // prefill through different chunk paths must give identical logits:
    // the engine picks chunks by remaining length, so prompts of different
    // lengths exercise different chunk sequences — drive them explicitly.
    let Some(e) = engine() else { return };
    let text = "In astronomy, the standard model directly determines the rate \
                of change observed in the system? Answer:";
    let tokens = e.tokenize_prompt(text);
    let mut p = pacer();

    // path 1: engine-chosen chunking over the whole prompt
    let mut s1 = e.fresh_state();
    let mut bd = PhaseBreakdown::default();
    let l1 = e.prefill_suffix(&mut s1, &tokens, &mut p, &mut bd).unwrap().unwrap();

    // path 2: two stages — first half, then the rest (different chunk seq)
    let mut s2 = e.fresh_state();
    let half = tokens.len() / 2;
    e.prefill_suffix(&mut s2, &tokens[..half], &mut p, &mut bd).unwrap();
    let l2 = e.prefill_suffix(&mut s2, &tokens, &mut p, &mut bd).unwrap().unwrap();

    assert_eq!(s1.n_tokens, s2.n_tokens);
    for (a, b) in l1.iter().zip(&l2) {
        assert!((a - b).abs() < 2e-3, "{a} vs {b}");
    }
}

#[test]
fn greedy_continuations_agree_after_blob_roundtrip_with_compression() {
    let Some(e) = engine() else { return };
    let mut p = pacer();
    let text = "The following are multiple choice questions about physics.";
    let tokens = e.tokenize_prompt(text);
    let cfg = &e.model.config;
    let dims = (cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim);

    let mut bd = PhaseBreakdown::default();
    let mut s = e.fresh_state();
    let logits = e.prefill_suffix(&mut s, &tokens, &mut p, &mut bd).unwrap().unwrap();

    for comp in [Compression::None, Compression::Deflate] {
        let blob = s.serialize(e.model_hash(), comp);
        let mut restored = KvState::restore(&blob, e.model_hash(), dims).unwrap();
        assert_eq!(restored.n_tokens, s.n_tokens);
        // the valid K/V prefix must be bit-identical (rows beyond n_tokens
        // hold chunk-padding junk in the live state and are never shipped)
        let row = cfg.n_kv_heads * cfg.head_dim;
        let le = cfg.max_seq * row;
        for li in 0..cfg.n_layers {
            let take = s.n_tokens * row;
            assert_eq!(restored.k[li * le..li * le + take], s.k[li * le..li * le + take]);
            assert_eq!(restored.v[li * le..li * le + take], s.v[li * le..li * le + take]);
        }

        let mut sm = Sampler::greedy();
        let mut sm2 = Sampler::greedy();
        let mut bd2 = PhaseBreakdown::default();
        let mut s_live = s.clone();
        let a = e
            .decode_loop(&mut s_live, logits.clone(), 4, &mut sm, &mut p, &mut bd)
            .unwrap();
        let b = e
            .decode_loop(&mut restored, logits.clone(), 4, &mut sm2, &mut p, &mut bd2)
            .unwrap();
        assert_eq!(a, b, "continuation must match after {comp:?} roundtrip");
    }
}

#[test]
fn logits_are_sane_probability_material() {
    let Some(e) = engine() else { return };
    let mut p = pacer();
    let out = e.generate("What is gravity? Answer:", 3, &mut p).unwrap();
    assert_eq!(out.response_tokens_len(), out.tokens.len());
    assert!(out.tokens.iter().all(|&t| t < e.model.config.vocab as u32));
}

// helper so the assertion above reads naturally
trait GenOutputExt {
    fn response_tokens_len(&self) -> usize;
}
impl GenOutputExt for edgecache::engine::GenOutput {
    fn response_tokens_len(&self) -> usize {
        self.breakdown.response_tokens
    }
}

#[test]
fn prefix_state_of_longer_prefill_equals_direct_prefill() {
    // serialize_prefix(m) of a long prefill == serialize() of a prefill of
    // exactly m tokens — the invariant that lets one prefill feed all four
    // catalog ranges (§3.2).
    let Some(e) = engine() else { return };
    let mut p = pacer();
    let text = "In physics, an equilibrium state is measured relative to the \
                marginal cost of one additional unit, in the general case?";
    let tokens = e.tokenize_prompt(text);
    let m = tokens.len() / 2;

    let mut bd = PhaseBreakdown::default();
    let mut s_full = e.fresh_state();
    e.prefill_suffix(&mut s_full, &tokens, &mut p, &mut bd).unwrap();
    let blob_prefix = s_full.serialize_prefix(m, e.model_hash(), Compression::None);

    let mut s_m = e.fresh_state();
    e.prefill_suffix(&mut s_m, &tokens[..m], &mut p, &mut bd).unwrap();
    let blob_direct = s_m.serialize(e.model_hash(), Compression::None);

    let cfg = &e.model.config;
    let dims = (cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim);
    let a = KvState::restore(&blob_prefix, e.model_hash(), dims).unwrap();
    let b = KvState::restore(&blob_direct, e.model_hash(), dims).unwrap();
    assert_eq!(a.n_tokens, b.n_tokens);
    let max_diff = a
        .k
        .iter()
        .zip(&b.k)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-4, "K prefixes diverge by {max_diff}");
}

#[test]
fn state_size_matches_config_closed_form() {
    let Some(e) = engine() else { return };
    let mut p = pacer();
    let tokens = e.tokenize_prompt("short prompt here");
    let mut bd = PhaseBreakdown::default();
    let mut s = e.fresh_state();
    e.prefill_suffix(&mut s, &tokens, &mut p, &mut bd).unwrap();
    let blob = s.serialize(e.model_hash(), Compression::None);
    let payload = e.model.config.kv_bytes_per_token() * tokens.len();
    let overhead = blob.len() - payload;
    // fixed header plus the 4-byte-per-token crc32 row index (the price of
    // range-served prefixes; <0.5% of a real token's KV rows)
    let budget = 128 + 4 * tokens.len();
    assert!(
        overhead < budget,
        "header+index overhead {overhead} B exceeds {budget} B (payload {payload} B)"
    );
}

#[test]
fn cross_preset_blobs_rejected() {
    let Some(e) = engine() else { return };
    let mut p = pacer();
    let tokens = e.tokenize_prompt("hello");
    let mut bd = PhaseBreakdown::default();
    let mut s = e.fresh_state();
    e.prefill_suffix(&mut s, &tokens, &mut p, &mut bd).unwrap();
    let blob = s.serialize("some-other-model-hash", Compression::None);
    let cfg = &e.model.config;
    let dims = (cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim);
    assert!(KvState::restore(&blob, e.model_hash(), dims).is_err());
}
