//! Oracle suite for the per-chunk fetch planner (`coordinator::plan`).
//!
//! The planner's claim is *optimality under its own cost model*, so the
//! tests pin it against an oracle that cannot be wrong by construction:
//! brute-force enumeration of every `2^k` fetch/recompute assignment
//! through the same public [`cost_of`] function.  On top of the oracle:
//!
//! * seeded property sweeps over heterogeneous chunk sizes, link sets and
//!   device rates (the harness prints a replayable seed on failure);
//! * monotonicity laws on homogeneous-chunk single-link grids (the
//!   restriction keeps the laws exact — with heterogeneous chunks a faster
//!   link can legitimately swap *which* chunks it fetches): a faster link
//!   only grows the fetch set, a faster device only grows the recompute
//!   set;
//! * dominance everywhere: a plan's cost never exceeds the cheaper of
//!   all-fetch and all-recompute.

use edgecache::coordinator::plan::{
    cost_of, plan_exhaustive, plan_split, ChunkCost, ChunkSource, LinkCost,
};
use edgecache::devicemodel::DeviceProfile;
use edgecache::netsim::LinkModel;
use edgecache::util::prop::{run_prop, Gen};

/// Relative-tolerance comparison for modelled seconds.
fn leq(a: f64, b: f64) -> bool {
    a <= b * (1.0 + 1e-9) + 1e-12
}

fn close(a: f64, b: f64) -> bool {
    leq(a, b) && leq(b, a)
}

/// The oracle: argmin over every possible assignment, priced through the
/// same public cost function the planners use.
fn brute_force_min(chunks: &[ChunkCost], links: &[LinkCost], rate: f64) -> f64 {
    let k = chunks.len();
    assert!(k <= 16, "oracle enumeration is 2^k");
    let mut best = f64::INFINITY;
    for mask in 0u32..(1 << k) {
        let sources: Vec<ChunkSource> = (0..k)
            .map(|i| {
                if mask & (1 << i) != 0 { ChunkSource::Fetch } else { ChunkSource::Recompute }
            })
            .collect();
        best = best.min(cost_of(chunks, links, rate, &sources).total_s);
    }
    best
}

fn gen_chunks(g: &mut Gen, k: usize) -> Vec<ChunkCost> {
    (0..k)
        .map(|_| ChunkCost {
            wire_bytes: g.usize_in(64, 2_000_000),
            tokens: g.usize_in(1, 64),
        })
        .collect()
}

fn gen_links(g: &mut Gen) -> Vec<LinkCost> {
    let n = g.usize_in(1, 3);
    (0..n)
        .map(|_| LinkCost {
            goodput_bps: g.usize_in(10_000, 200_000_000) as f64,
            rtt_s: g.usize_in(0, 500) as f64 / 1e3,
        })
        .collect()
}

/// ms/token prefill rate: spans sub-ms hosts to Pi-Zero-class devices.
fn gen_rate(g: &mut Gen) -> f64 {
    g.usize_in(1, 250_000) as f64 / 1e3
}

#[test]
fn exhaustive_planner_matches_brute_force_oracle() {
    run_prop("plan-exhaustive-oracle", |g| {
        let k = g.usize_in(1, 10);
        let chunks = gen_chunks(g, k);
        let links = gen_links(g);
        let rate = gen_rate(g);
        let plan = plan_exhaustive(&chunks, &links, rate);
        // the plan's reported cost is its own sources re-priced...
        let repriced = cost_of(&chunks, &links, rate, &plan.sources).total_s;
        assert!(
            close(plan.cost.total_s, repriced),
            "reported {} != repriced {repriced}",
            plan.cost.total_s
        );
        // ...and no assignment whatsoever is cheaper
        let oracle = brute_force_min(&chunks, &links, rate);
        assert!(
            close(plan.cost.total_s, oracle),
            "planner {} vs oracle {oracle} (k={k}, rate={rate})",
            plan.cost.total_s
        );
    });
}

#[test]
fn split_planner_is_prefix_shaped_and_dominates_extremes() {
    run_prop("plan-split-dominates", |g| {
        let k = g.usize_in(1, 12);
        let chunks = gen_chunks(g, k);
        let links = gen_links(g);
        let rate = gen_rate(g);
        let plan = plan_split(&chunks, &links, rate);
        // executable shape: causal prefill means recompute is a prefix
        let s = plan.split_point();
        for (i, src) in plan.sources.iter().enumerate() {
            let want = if i < s { ChunkSource::Recompute } else { ChunkSource::Fetch };
            assert_eq!(*src, want, "split plan must be recompute-prefix shaped");
        }
        // law: plan cost <= min(all-fetch, all-recompute)
        let fetch = cost_of(&chunks, &links, rate, &vec![ChunkSource::Fetch; k]).total_s;
        let rec = cost_of(&chunks, &links, rate, &vec![ChunkSource::Recompute; k]).total_s;
        assert!(
            leq(plan.cost.total_s, fetch.min(rec)),
            "split plan {} must not lose to an extreme (fetch {fetch}, recompute {rec})",
            plan.cost.total_s
        );
        // the split restriction can only cost, never gain, vs the oracle
        let oracle = plan_exhaustive(&chunks, &links, rate);
        assert!(
            leq(oracle.cost.total_s, plan.cost.total_s),
            "oracle {} cannot be worse than restricted split {}",
            oracle.cost.total_s,
            plan.cost.total_s
        );
    });
}

#[test]
fn split_matches_exhaustive_on_homogeneous_chunks() {
    // with identical chunks the cost depends only on *how many* are
    // fetched, so the prefix restriction loses nothing: the split planner
    // must reach the unrestricted optimum exactly
    run_prop("plan-split-homogeneous-optimal", |g| {
        let k = g.usize_in(1, 12);
        let chunk = ChunkCost {
            wire_bytes: g.usize_in(64, 2_000_000),
            tokens: g.usize_in(1, 64),
        };
        let chunks = vec![chunk; k];
        let links = vec![LinkCost {
            goodput_bps: g.usize_in(10_000, 200_000_000) as f64,
            rtt_s: g.usize_in(0, 500) as f64 / 1e3,
        }];
        let rate = gen_rate(g);
        let split = plan_split(&chunks, &links, rate);
        let oracle = plan_exhaustive(&chunks, &links, rate);
        assert!(
            close(split.cost.total_s, oracle.cost.total_s),
            "homogeneous split {} != oracle {} (k={k}, rate={rate})",
            split.cost.total_s,
            oracle.cost.total_s
        );
    });
}

#[test]
fn faster_link_only_grows_the_fetch_set() {
    // homogeneous grid law: sweep goodput upward with everything else
    // fixed — the number of fetched chunks must be non-decreasing
    let chunks = vec![ChunkCost { wire_bytes: 551_584, tokens: 16 }; 12];
    for rate in [2.0, 8.046, 50.0, 192.75] {
        let mut last = 0usize;
        for exp in 0..24 {
            let goodput = 10_000.0 * 1.8f64.powi(exp);
            let links = [LinkCost { goodput_bps: goodput, rtt_s: 0.27 }];
            let f = plan_split(&chunks, &links, rate).fetched();
            assert!(
                f >= last,
                "rate {rate}: goodput {goodput:.0} fetched {f} < previous {last}"
            );
            last = f;
        }
        // a slow device must end up fetching everything; a fast one may
        // keep recomputing chunks the link's RTT floor makes free anyway
        if rate > 100.0 {
            assert_eq!(last, 12, "pi-zero-class prefill never beats a fast link");
        }
    }
}

#[test]
fn faster_device_only_grows_the_recompute_set() {
    // dual law: sweep the prefill rate downward (device gets faster) with
    // the link fixed — the recompute set must be non-decreasing
    let chunks = vec![ChunkCost { wire_bytes: 551_584, tokens: 16 }; 12];
    for (_, link) in [
        ("wifi", LinkCost::from_link(&LinkModel::wifi4_2g4())),
        ("slow", LinkCost { goodput_bps: 250_000.0, rtt_s: 0.05 }),
    ] {
        let mut last = 0usize;
        for exp in 0..24 {
            let rate = 500.0 / 1.6f64.powi(exp); // ms/token, decreasing
            let r = plan_split(&chunks, &[link], rate).recomputed();
            assert!(
                r >= last,
                "rate {rate:.3} ms/tok recomputed {r} < previous {last}"
            );
            last = r;
        }
    }
}

#[test]
fn no_links_forces_all_recompute() {
    let chunks = vec![ChunkCost { wire_bytes: 1_000, tokens: 8 }; 6];
    for plan in [
        plan_split(&chunks, &[], 10.0),
        plan_exhaustive(&chunks, &[], 10.0),
    ] {
        assert_eq!(plan.fetched(), 0, "fetching over no links costs +inf");
        assert_eq!(plan.recomputed(), 6);
        assert!(plan.cost.total_s.is_finite());
    }
}

#[test]
fn paper_cells_behave_as_the_ablation_claims() {
    // the bench's headline cells, pinned: slow link + fast device mixes,
    // slow device all-fetches, fast link all-fetches
    let chunks = vec![ChunkCost { wire_bytes: 551_584, tokens: 16 }; 16];
    let wifi = [LinkCost::from_link(&LinkModel::wifi4_2g4())];
    let eth = [LinkCost::from_link(&LinkModel::ethernet_1g())];

    let mixed = plan_split(&chunks, &wifi, DeviceProfile::pi5_4gb().prefill_ms_per_tok);
    assert!(mixed.is_mixed(), "pi5 over wifi must split: {mixed:?}");
    let fetch_all =
        cost_of(&chunks, &wifi, DeviceProfile::pi5_4gb().prefill_ms_per_tok, &vec![
            ChunkSource::Fetch;
            16
        ])
        .total_s;
    assert!(
        mixed.cost.total_s < fetch_all * 0.99,
        "the mixed plan must strictly beat all-fetch here"
    );

    let slow_dev =
        plan_split(&chunks, &wifi, DeviceProfile::pi_zero_2w().prefill_ms_per_tok);
    assert_eq!(slow_dev.recomputed(), 0, "pi-zero recompute never pays on wifi");

    let fast_link = plan_split(&chunks, &eth, DeviceProfile::pi5_4gb().prefill_ms_per_tok);
    assert_eq!(fast_link.recomputed(), 0, "gigabit fetch always pays");
}
