//! Integration: the semantic similarity tier (`edgecache::sketch`) —
//! sketch-section wire roundtrip, legacy-peer degradation, the
//! verification gate (a close sketch NEVER causes reuse without a real
//! token-prefix overlap), cross-client paraphrase recovery, and the
//! timer-driven proactive repair sweep.

use std::sync::Arc;
use std::time::Duration;

use edgecache::coordinator::{
    CacheBox, CatalogSync, EdgeClient, EdgeClientConfig, HitCase, PeerConfig,
    PlacementKind,
};
use edgecache::engine::Engine;
use edgecache::kvstore::KvClient;
use edgecache::sketch::{
    common_prefix_len, encode_section, encode_token_ids, sketch_tokens,
    SketchRecord, SketchTable,
};
use edgecache::workload::perturb::Perturber;
use edgecache::workload::{Generator, Prompt};

fn engine() -> Option<Arc<Engine>> {
    if !edgecache::artifacts_dir().join("tiny/meta.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return None;
    }
    Some(Arc::new(Engine::load_preset("tiny").unwrap()))
}

fn cfg(name: &str, server: Option<String>) -> EdgeClientConfig {
    EdgeClientConfig {
        name: name.into(),
        max_new_tokens: Some(2),
        sync_interval: None,
        ..EdgeClientConfig::native(server)
    }
}

/// A long shared instruction whose final words differ — the paraphrase
/// shape exact range matching cannot see (every range hash differs) but
/// the semantic tier recovers: the common token prefix is most of the
/// prompt.
fn manual_prompt(tail: &str, target: &str) -> Prompt {
    let instruction = format!(
        "You are assisting with a careful multi step reasoning exercise. \
         Read the shared background closely, weigh every stated constraint, \
         and keep the working consistent across steps. The background \
         covers resource budgets, timing margins, placement rules, and \
         recovery behaviour for a small fleet of cooperating cache boxes \
         that serve key value traffic under churn and partial failure. \
         When the steps disagree, prefer the reading that keeps the whole \
         account consistent. {tail}\n\n"
    );
    Prompt {
        domain: "manual".into(),
        instruction,
        examples: Vec::new(),
        target: format!("State the {target} in one word.\nAnswer:"),
        answer: 'A',
    }
}

#[test]
fn sketch_section_roundtrips_over_the_wire() {
    let cb = CacheBox::start_local().unwrap();
    let mut c = KvClient::connect(&cb.addr()).unwrap();

    let rec = SketchRecord {
        key: [0x42; 16],
        sketch: 0xDEAD_BEEF_0BAD_F00D,
        token_len: 321,
        chunk_tokens: 8,
        compressed: true,
    };
    let v1 = c.sketch_register(&encode_section(&[rec])).unwrap();
    assert_eq!(v1, 1, "first section is version 1");

    let (ver, sections) = c.sketch_delta(0).unwrap();
    assert_eq!(ver, 1);
    assert_eq!(sections.len(), 1);
    let mut table = SketchTable::new();
    table.apply_delta(ver, &sections);
    assert_eq!(table.get(&rec.key), Some(&rec), "record survives the wire");
    assert_eq!(table.synced_version, 1);

    // a second register bumps the version; an incremental delta returns
    // only the new section
    let rec2 = SketchRecord { key: [0x43; 16], sketch: 1, ..rec };
    let v2 = c.sketch_register(&encode_section(&[rec2])).unwrap();
    assert_eq!(v2, 2);
    let (ver2, tail) = c.sketch_delta(v1).unwrap();
    assert_eq!(ver2, 2);
    assert_eq!(tail.len(), 1, "incremental sync ships only the delta");
    table.apply_delta(ver2, &tail);
    assert_eq!(table.len(), 2);
    assert_eq!(table.get(&rec2.key), Some(&rec2));
    cb.shutdown();
}

#[test]
fn legacy_box_degrades_sketch_sync_not_state() {
    // A pre-sketch box answers the new verbs with `-ERR unknown command`
    // on a healthy connection; the sync helper surfaces the error and the
    // table stays empty — the tier degrades to exact-only, nothing dies.
    use std::io::{Read, Write};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut buf = [0u8; 4096];
        while let Ok(n) = s.read(&mut buf) {
            if n == 0 || s.write_all(b"-ERR unknown command\r\n").is_err() {
                break;
            }
        }
    });
    let mut c = KvClient::connect(&addr).unwrap();
    assert!(c.sketch_delta(0).is_err(), "legacy box lacks CAT.SDELTA");
    let table = Arc::new(std::sync::Mutex::new(SketchTable::new()));
    assert!(CatalogSync::sketch_once(&mut c, &table).is_err());
    assert_eq!(table.lock().unwrap().len(), 0, "no partial merge on error");
    assert!(c.scan_keys(0, 8).is_err(), "legacy box lacks SCAN");
    drop(c);
    server.join().unwrap();
}

#[test]
fn semantic_never_engages_on_exact_hits() {
    // The zero-regression guarantee for exact workloads: any exact
    // catalog hit — full or partial — bypasses the semantic tier
    // entirely.  Probe counters must stay at zero.
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("exact", Some(cb.addr()))).unwrap();
    let gen = Generator::new(31);
    let p0 = gen.prompt("astronomy", 0, 2);
    let p1 = gen.prompt("astronomy", 1, 2); // shares instruction + examples

    let r0 = c.query(&p0).unwrap();
    assert_eq!(r0.case, HitCase::Miss);
    let r1 = c.query(&p0).unwrap();
    assert_eq!(r1.case, HitCase::Full);
    let r2 = c.query(&p1).unwrap();
    assert_eq!(r2.case, HitCase::AllExamples);
    assert_eq!(c.stats.semantic_probes, 0, "exact hits never probe");
    assert_eq!(c.stats.semantic_hits, 0);
    assert_eq!(c.stats.semantic_false_probes, 0);
    c.shutdown();
    cb.shutdown();
}

#[test]
fn verification_gate_blocks_zero_overlap_donor() {
    // The adversarial case the gate exists for: a donor whose sketch is
    // IDENTICAL to the query's (Hamming distance 0) but whose real token
    // ids share nothing.  The cheap-header verification must expose it as
    // a false probe; no state is ever fetched, let alone reused.
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let gen = Generator::new(37);
    let victim = gen.prompt("virology", 0, 2);
    let vtokens = eng.tokenize_prompt(&victim.full_text());
    assert!(!vtokens.is_empty());

    // plant the malicious donor: perfect sketch, zero-overlap header
    let mal_key = [0xAB; 16];
    let rec = SketchRecord {
        key: mal_key,
        sketch: sketch_tokens(&vtokens),
        token_len: vtokens.len() as u32,
        chunk_tokens: 4,
        compressed: false,
    };
    let mut kv = KvClient::connect(&cb.addr()).unwrap();
    kv.sketch_register(&encode_section(&[rec])).unwrap();
    let disjoint: Vec<u32> = vtokens.iter().map(|t| t + 100_000).collect();
    assert_eq!(common_prefix_len(&vtokens, &disjoint), 0);
    kv.set(
        &edgecache::catalog::token_store_key(&mal_key),
        &encode_token_ids(&disjoint),
    )
    .unwrap();

    let mut solo = EdgeClient::new(Arc::clone(&eng), cfg("solo", None)).unwrap();
    let expected = solo.query(&victim).unwrap();

    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("gate", Some(cb.addr()))).unwrap();
    c.sync_catalog_now().unwrap(); // pulls the malicious sketch section
    let r = c.query(&victim).unwrap();

    assert_eq!(c.stats.semantic_probes, 1, "the close sketch is probed");
    assert_eq!(c.stats.semantic_false_probes, 1, "...and exposed");
    assert_eq!(c.stats.semantic_hits, 0, "never reused");
    assert_eq!(c.stats.semantic_tokens_recovered, 0);
    assert_eq!(r.matched_tokens, 0);
    assert_eq!(r.case, HitCase::Miss);
    // correctness untouched: same output as a cache-less client
    assert_eq!(r.response_tokens, expected.response_tokens);
    c.shutdown();
    solo.shutdown();
    cb.shutdown();
}

#[test]
fn paraphrase_recovers_verified_prefix_cross_client() {
    // The headline semantic win: a paraphrase that changes words near the
    // END of a long shared prefix defeats every exact range hash (total
    // miss) yet shares almost the whole token prefix with the donor.  The
    // tier must find the donor by sketch, verify the real LCP from the
    // token header, fetch exactly that many rows, and produce the same
    // response a cache-less client would.
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let p0 = manual_prompt("Proceed with the checks now.", "outcome");
    let p1 = manual_prompt("Continue with the checks now.", "outcome");
    let t0 = eng.tokenize_prompt(&p0.full_text());
    let t1 = eng.tokenize_prompt(&p1.full_text());
    let lcp = common_prefix_len(&t0, &t1);
    assert!(lcp > 20, "the manual prompts must share a long prefix ({lcp})");
    assert!(lcp < t1.len());

    let mut solo = EdgeClient::new(Arc::clone(&eng), cfg("solo", None)).unwrap();
    let expected = solo.query(&p1).unwrap();

    let mut a = EdgeClient::new(Arc::clone(&eng), cfg("donor", Some(cb.addr()))).unwrap();
    let ra = a.query(&p0).unwrap();
    assert_eq!(ra.case, HitCase::Miss); // donor upload

    let mut k = cfg("semantic", Some(cb.addr()));
    k.semantic_dist = 24; // headroom over the default for the short target
    let mut b = EdgeClient::new(Arc::clone(&eng), k).unwrap();
    b.sync_catalog_now().unwrap();
    let rb = b.query(&p1).unwrap();

    assert_eq!(b.stats.semantic_probes, 1);
    assert_eq!(b.stats.semantic_hits, 1, "the paraphrase must hit");
    assert_eq!(b.stats.semantic_false_probes, 0);
    assert_eq!(
        rb.matched_tokens, lcp,
        "reuse is exactly the verified token-prefix overlap"
    );
    assert_eq!(b.stats.semantic_tokens_recovered, lcp as u64);
    assert!(rb.downloaded_bytes > 0);
    // bit-exactness, end to end: the semantically-reused rows feed the
    // same decode a cache-less prefill would
    assert_eq!(rb.response_tokens, expected.response_tokens);

    // and the ledger saw the sketch arrive through sync
    let ledgers = b.peer_ledgers();
    assert!(ledgers[0].sketch_entries >= 1, "sketch table must be synced");
    a.shutdown();
    b.shutdown();
    solo.shutdown();
    cb.shutdown();
}

#[test]
fn no_semantic_ablation_is_exact_only_and_interoperates() {
    // `--no-semantic` in a mixed fleet: a sketch-capable box and a
    // semantic uploader around it, yet the ablated client never probes,
    // never registers, and keeps exact behaviour bit-for-bit.
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let p0 = manual_prompt("Proceed with the checks now.", "outcome");
    let p1 = manual_prompt("Continue with the checks now.", "outcome");

    let mut a = EdgeClient::new(Arc::clone(&eng), cfg("donor", Some(cb.addr()))).unwrap();
    let _ = a.query(&p0).unwrap();

    let mut k = cfg("ablated", Some(cb.addr()));
    k.semantic = false;
    let mut b = EdgeClient::new(Arc::clone(&eng), k).unwrap();
    b.sync_catalog_now().unwrap();
    let r1 = b.query(&p1).unwrap();
    assert_eq!(r1.case, HitCase::Miss, "paraphrase stays a miss without the tier");
    assert_eq!(r1.matched_tokens, 0);
    assert_eq!(b.stats.semantic_probes, 0);
    let r0 = b.query(&p0).unwrap();
    assert_eq!(r0.case, HitCase::Full, "exact matching fully intact");
    a.shutdown();
    b.shutdown();
    cb.shutdown();
}

#[test]
fn perturbed_workload_semantic_strictly_improves_reuse() {
    // The acceptance shape of the semantic bench, in miniature: under a
    // seeded paraphrase perturbation, the semantic client recovers
    // strictly more tokens than the ablated one on an identical trace.
    let Some(eng) = engine() else { return };
    let gen = Generator::new(41);
    let base = gen.prompt("marketing", 0, 2);
    // same prompt, perturbed early (instruction vocabulary) — every
    // exact range hash changes
    let mut pert = Perturber::new(7, 1.0);
    pert.reorder = 0.0;
    let para = pert.perturb(&base);
    assert_ne!(base.instruction, para.instruction, "perturbation must land");

    // size the distance knob from the actual perturbation instead of
    // guessing: the test pins the *mechanism* (engage → verify → reuse),
    // the bench measures the default knob's yield
    let ham = edgecache::sketch::hamming(
        sketch_tokens(&eng.tokenize_prompt(&base.full_text())),
        sketch_tokens(&eng.tokenize_prompt(&para.full_text())),
    );

    let run = |semantic: bool| -> (usize, u64) {
        let cb = CacheBox::start_local().unwrap();
        let mut k = cfg(if semantic { "sem" } else { "nosem" }, Some(cb.addr()));
        k.semantic = semantic;
        k.semantic_dist = ham.max(1);
        let mut c = EdgeClient::new(Arc::clone(&eng), k).unwrap();
        let _ = c.query(&base).unwrap();
        let r = c.query(&para).unwrap();
        let out = (r.matched_tokens, c.stats.semantic_tokens_recovered);
        c.shutdown();
        cb.shutdown();
        out
    };
    let (m_on, rec_on) = run(true);
    let (m_off, rec_off) = run(false);
    assert_eq!(m_off, 0, "exact-only cannot see the paraphrase");
    assert_eq!(rec_off, 0);
    assert!(m_on > 0, "semantic recovers verified prefix tokens");
    assert_eq!(rec_on, m_on as u64);
}

#[test]
fn repair_sweep_restores_deleted_replicas() {
    // The proactive sweep: ring placement, replicas=1, two boxes.  Every
    // entry lives on both; wipe box B's state keys, let the sweep walk
    // box A, and the ring owners must be healed without any query
    // touching the lost entries.
    let Some(eng) = engine() else { return };
    let cb1 = CacheBox::start_local().unwrap();
    let cb2 = CacheBox::start_local().unwrap();
    let mut k = cfg("sweeper", Some(cb1.addr()));
    k.peers = vec![PeerConfig::new(cb1.addr()), PeerConfig::new(cb2.addr())];
    k.placement = PlacementKind::RendezvousRing;
    k.replicas = 1;
    let mut c = EdgeClient::new(Arc::clone(&eng), k).unwrap();

    let gen = Generator::new(43);
    let r0 = c.query(&gen.prompt("anatomy", 0, 1)).unwrap();
    assert!(r0.uploaded_bytes > 0);
    // arm the sweep only now, so no earlier sweep step has memoized the
    // (then-intact) owner sets
    c.cfg.repair_sweep = Duration::from_millis(1);

    // wipe B's state keys (replica loss without a death)
    let lost: Vec<Vec<u8>> = cb2
        .handle
        .server
        .store
        .all_keys()
        .into_iter()
        .filter(|kk| kk.starts_with(b"state:"))
        .collect();
    assert!(!lost.is_empty(), "ring+replica must have placed copies on B");
    for kk in &lost {
        assert!(cb2.handle.server.store.del(kk));
    }

    // a later, unrelated query triggers the timer-gated sweep
    std::thread::sleep(Duration::from_millis(5));
    let _ = c.query(&gen.prompt("sociology", 0, 1)).unwrap();

    assert!(c.stats.repair_republishes > 0, "sweep must republish");
    for kk in &lost {
        assert!(
            cb2.handle.server.store.strlen(kk).is_some(),
            "replica not healed: {:?}",
            String::from_utf8_lossy(kk)
        );
    }
    c.shutdown();
    cb1.shutdown();
    cb2.shutdown();
}
