//! Failure injection: the system must degrade to pure local inference, never
//! corrupt an answer (paper §3.3 and §5.3 — "local LLM inference ... remains
//! functional even if the middle node is unavailable").

use std::sync::Arc;

use edgecache::catalog::{ranges_for, state_store_key, ModelMeta};
use edgecache::coordinator::{CacheBox, EdgeClient, EdgeClientConfig, HitCase};
use edgecache::engine::Engine;
use edgecache::kvstore::KvClient;
use edgecache::workload::Generator;

fn engine() -> Option<Arc<Engine>> {
    if !edgecache::artifacts_dir().join("tiny/meta.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return None;
    }
    Some(Arc::new(Engine::load_preset("tiny").unwrap()))
}

fn cfg(name: &str, server: Option<String>) -> EdgeClientConfig {
    EdgeClientConfig {
        name: name.into(),
        max_new_tokens: Some(2),
        sync_interval: None,
        ..EdgeClientConfig::native(server)
    }
}

#[test]
fn server_dies_midway_client_keeps_answering() {
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("survivor", Some(cb.addr()))).unwrap();
    let gen = Generator::new(1);

    let p = gen.prompt("anatomy", 0, 1);
    let r1 = c.query(&p).unwrap();
    assert_eq!(r1.case, HitCase::Miss);

    // kill the cache box; the client's connection is now dead
    cb.shutdown();
    std::thread::sleep(std::time::Duration::from_millis(50));

    // identical prompt: the catalog says "hit", the download fails, and the
    // client must fall back to local prefill with a correct answer
    let r2 = c.query(&p).unwrap();
    assert!(
        r2.false_positive || r2.case == HitCase::Miss,
        "dead server must look like a miss/FP, got {:?}",
        r2.case
    );
    assert_eq!(
        r1.response_tokens, r2.response_tokens,
        "degraded mode must still answer correctly"
    );

    // and fresh prompts keep working too
    let p2 = gen.prompt("virology", 0, 1);
    let r3 = c.query(&p2).unwrap();
    assert!(!r3.response_tokens.is_empty());
    c.shutdown();
}

#[test]
fn corrupt_blob_on_server_is_rejected_and_bypassed() {
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("victim", Some(cb.addr()))).unwrap();
    let gen = Generator::new(2);
    let p = gen.prompt("philosophy", 0, 1);

    let r1 = c.query(&p).unwrap(); // seed

    // corrupt every stored state blob in place
    {
        let server = &cb.handle.server;
        let mut store = server.store.lock().unwrap();
        let keys: Vec<Vec<u8>> = store.keys().cloned().collect();
        for k in keys {
            let mut v = store.get(&k).unwrap().to_vec();
            let mid = v.len() / 2;
            v[mid] ^= 0xFF;
            store.set(&k, v);
        }
    }

    let r2 = c.query(&p).unwrap();
    assert!(r2.false_positive, "corrupt blob must be detected (crc)");
    assert_eq!(
        r1.response_tokens, r2.response_tokens,
        "local fallback reproduces the correct answer"
    );
    c.shutdown();
    cb.shutdown();
}

#[test]
fn truncated_blob_is_rejected() {
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("trunc", Some(cb.addr()))).unwrap();
    let gen = Generator::new(3);
    let p = gen.prompt("prehistory", 0, 1);
    let _ = c.query(&p).unwrap();

    {
        let server = &cb.handle.server;
        let mut store = server.store.lock().unwrap();
        let keys: Vec<Vec<u8>> = store.keys().cloned().collect();
        for k in keys {
            let v = store.get(&k).unwrap().to_vec();
            store.set(&k, v[..v.len() / 3].to_vec());
        }
    }
    let r = c.query(&p).unwrap();
    assert!(r.false_positive);
    assert!(!r.response_tokens.is_empty());
    c.shutdown();
    cb.shutdown();
}

#[test]
fn wrong_model_blob_is_rejected() {
    // another fleet uploads a state under the same *store key* (simulated
    // key collision / tampering): the model-hash check must catch it
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("crossmodel", Some(cb.addr()))).unwrap();
    let gen = Generator::new(4);
    let p = gen.prompt("management", 0, 1);

    // craft: register the catalog ranges AND store a blob from a "different
    // model" under the right store key
    let tokens = eng.tokenize_prompt(&p.full_text());
    let meta = ModelMeta::new(eng.model_hash());
    let ranges = ranges_for(&meta, &tokens, &[tokens.len()]);
    {
        let mut s = eng.fresh_state();
        s.n_tokens = tokens.len().min(4);
        let alien = s.serialize("alien-model-hash", edgecache::model::state::Compression::None);
        let mut kv = KvClient::connect(&cb.addr()).unwrap();
        kv.set(&state_store_key(&ranges[0].key), &alien).unwrap();
        kv.catalog_register(&ranges[0].key).unwrap();
    }
    c.sync_catalog_now().unwrap();
    let r = c.query(&p).unwrap();
    assert!(r.false_positive, "alien-model blob must be rejected");
    assert!(!r.response_tokens.is_empty());
    c.shutdown();
    cb.shutdown();
}

#[test]
fn eviction_between_catalog_and_store_behaves_like_fp() {
    // tiny cache box: uploads succeed, then get evicted; the catalog (which
    // never forgets) reports hits whose GETs come back empty
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start("127.0.0.1:0", 64 * 1024).unwrap(); // 64 KB budget
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("evicted", Some(cb.addr()))).unwrap();
    let gen = Generator::new(5);
    let p = gen.prompt("econometrics", 0, 1);

    let r1 = c.query(&p).unwrap(); // states > 64 KB never even fit
    let r2 = c.query(&p).unwrap();
    assert!(
        r2.false_positive || r2.case == HitCase::Miss,
        "evicted/never-stored state must degrade to a local answer"
    );
    assert_eq!(r1.response_tokens, r2.response_tokens);
    c.shutdown();
    cb.shutdown();
}

#[test]
fn client_construction_fails_fast_when_server_absent() {
    let Some(eng) = engine() else { return };
    let r = EdgeClient::new(eng, cfg("noserver", Some("127.0.0.1:1".into())));
    assert!(r.is_err(), "connecting to a dead cache box must error");
}

#[test]
fn standalone_flag_still_serves_without_any_server() {
    let Some(eng) = engine() else { return };
    let mut c = EdgeClient::new(eng, cfg("island", None)).unwrap();
    let gen = Generator::new(6);
    for i in 0..3 {
        let p = gen.prompt("global_facts", i, 1);
        let r = c.query(&p).unwrap();
        assert_eq!(r.case, HitCase::Miss);
        assert!(!r.response_tokens.is_empty());
        assert_eq!(r.uploaded_bytes, 0);
        assert_eq!(r.downloaded_bytes, 0);
    }
    c.shutdown();
}
