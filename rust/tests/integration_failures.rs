//! Failure injection: the system must degrade to pure local inference, never
//! corrupt an answer (paper §3.3 and §5.3 — "local LLM inference ... remains
//! functional even if the middle node is unavailable").

use std::sync::Arc;

use edgecache::catalog::{ranges_for, state_store_key, ModelMeta};
use edgecache::coordinator::{CacheBox, EdgeClient, EdgeClientConfig, HitCase};
use edgecache::engine::Engine;
use edgecache::kvstore::KvClient;
use edgecache::model::state::{
    read_chunk_index, BlobLayout, Compression, KvState, StateError,
};
use edgecache::util::rng::Rng;
use edgecache::workload::Generator;

fn engine() -> Option<Arc<Engine>> {
    if !edgecache::artifacts_dir().join("tiny/meta.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return None;
    }
    Some(Arc::new(Engine::load_preset("tiny").unwrap()))
}

fn cfg(name: &str, server: Option<String>) -> EdgeClientConfig {
    EdgeClientConfig {
        name: name.into(),
        max_new_tokens: Some(2),
        sync_interval: None,
        ..EdgeClientConfig::native(server)
    }
}

#[test]
fn server_dies_midway_client_keeps_answering() {
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("survivor", Some(cb.addr()))).unwrap();
    let gen = Generator::new(1);

    let p = gen.prompt("anatomy", 0, 1);
    let r1 = c.query(&p).unwrap();
    assert_eq!(r1.case, HitCase::Miss);

    // kill the cache box; the client's connection is now dead
    cb.shutdown();
    std::thread::sleep(std::time::Duration::from_millis(50));

    // identical prompt: the catalog says "hit", the download fails, and the
    // client must fall back to local prefill with a correct answer
    let r2 = c.query(&p).unwrap();
    assert!(
        r2.false_positive || r2.case == HitCase::Miss,
        "dead server must look like a miss/FP, got {:?}",
        r2.case
    );
    assert_eq!(
        r1.response_tokens, r2.response_tokens,
        "degraded mode must still answer correctly"
    );

    // and fresh prompts keep working too
    let p2 = gen.prompt("virology", 0, 1);
    let r3 = c.query(&p2).unwrap();
    assert!(!r3.response_tokens.is_empty());
    c.shutdown();
}

#[test]
fn corrupt_blob_on_server_is_rejected_and_bypassed() {
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("victim", Some(cb.addr()))).unwrap();
    let gen = Generator::new(2);
    let p = gen.prompt("philosophy", 0, 1);

    let r1 = c.query(&p).unwrap(); // seed

    // corrupt every stored state blob in place
    {
        let server = &cb.handle.server;
        let mut store = server.store.lock().unwrap();
        let keys: Vec<Vec<u8>> = store.keys().cloned().collect();
        for k in keys {
            let mut v = store.get(&k).unwrap().to_vec();
            let mid = v.len() / 2;
            v[mid] ^= 0xFF;
            store.set(&k, v);
        }
    }

    let r2 = c.query(&p).unwrap();
    assert!(r2.false_positive, "corrupt blob must be detected (crc)");
    assert_eq!(
        r1.response_tokens, r2.response_tokens,
        "local fallback reproduces the correct answer"
    );
    c.shutdown();
    cb.shutdown();
}

#[test]
fn truncated_blob_is_rejected() {
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("trunc", Some(cb.addr()))).unwrap();
    let gen = Generator::new(3);
    let p = gen.prompt("prehistory", 0, 1);
    let _ = c.query(&p).unwrap();

    {
        let server = &cb.handle.server;
        let mut store = server.store.lock().unwrap();
        let keys: Vec<Vec<u8>> = store.keys().cloned().collect();
        for k in keys {
            let v = store.get(&k).unwrap().to_vec();
            store.set(&k, v[..v.len() / 3].to_vec());
        }
    }
    let r = c.query(&p).unwrap();
    assert!(r.false_positive);
    assert!(!r.response_tokens.is_empty());
    c.shutdown();
    cb.shutdown();
}

#[test]
fn wrong_model_blob_is_rejected() {
    // another fleet uploads a state under the same *store key* (simulated
    // key collision / tampering): the model-hash check must catch it
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("crossmodel", Some(cb.addr()))).unwrap();
    let gen = Generator::new(4);
    let p = gen.prompt("management", 0, 1);

    // craft: register the catalog ranges AND store a blob from a "different
    // model" under the right store key
    let tokens = eng.tokenize_prompt(&p.full_text());
    let meta = ModelMeta::new(eng.model_hash());
    let ranges = ranges_for(&meta, &tokens, &[tokens.len()]);
    {
        let mut s = eng.fresh_state();
        s.n_tokens = tokens.len().min(4);
        let alien = s.serialize("alien-model-hash", edgecache::model::state::Compression::None);
        let mut kv = KvClient::connect(&cb.addr()).unwrap();
        kv.set(&state_store_key(&ranges[0].key), &alien).unwrap();
        kv.catalog_register(&ranges[0].key).unwrap();
    }
    c.sync_catalog_now().unwrap();
    let r = c.query(&p).unwrap();
    assert!(r.false_positive, "alien-model blob must be rejected");
    assert!(!r.response_tokens.is_empty());
    c.shutdown();
    cb.shutdown();
}

#[test]
fn eviction_between_catalog_and_store_behaves_like_fp() {
    // tiny cache box: uploads succeed, then get evicted; the catalog (which
    // never forgets) reports hits whose GETs come back empty
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start("127.0.0.1:0", 64 * 1024).unwrap(); // 64 KB budget
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg("evicted", Some(cb.addr()))).unwrap();
    let gen = Generator::new(5);
    let p = gen.prompt("econometrics", 0, 1);

    let r1 = c.query(&p).unwrap(); // states > 64 KB never even fit
    let r2 = c.query(&p).unwrap();
    assert!(
        r2.false_positive || r2.case == HitCase::Miss,
        "evicted/never-stored state must degrade to a local answer"
    );
    assert_eq!(r1.response_tokens, r2.response_tokens);
    c.shutdown();
    cb.shutdown();
}

fn filled_state(l: usize, s: usize, kh: usize, d: usize, n: usize, seed: u64) -> KvState {
    let mut st = KvState::zeroed(l, s, kh, d);
    st.n_tokens = n;
    let mut rng = Rng::new(seed);
    let row = kh * d;
    let le = s * row;
    for li in 0..l {
        for e in 0..n * row {
            st.k[li * le + e] = rng.f64() as f32;
            st.v[li * le + e] = rng.f64() as f32 - 0.5;
        }
    }
    st
}

#[test]
fn corrupted_chunk_is_rejected_chunk_granularly() {
    // ECS3 failure injection: flipping a byte inside one compressed chunk
    // must fail exactly the ranges that cover that chunk — prefixes that
    // stop short of it keep restoring.
    let st = filled_state(2, 32, 1, 8, 20, 9);
    let ct = 4;
    let blob = st.serialize_prefix_opts(20, "h", Compression::Deflate, ct);
    let lo = BlobLayout::new("h", 2, 1, 8).with_chunk_tokens(ct);
    let (_, entries) = read_chunk_index(&blob).unwrap();
    assert_eq!(entries.len(), 5);

    // flip one byte inside chunk 2's stored bytes (tokens 8..12)
    let mut bad = blob.clone();
    let c2_off = lo.payload_off(20)
        + entries[..2].iter().map(|e| e.len as usize).sum::<usize>();
    bad[c2_off + 1] ^= 0x01;

    // whole-blob restore pins exactly the guilty chunk
    assert_eq!(
        KvState::restore(&bad, "h", (2, 32, 1, 8)).unwrap_err(),
        StateError::ChunkChecksum { chunk: 2 }
    );
    let head = &bad[..lo.payload_off(20)];
    let pay = lo.payload_off(20);
    // every range that covers chunk 2 is rejected, naming chunk 2...
    for m in [9usize, 12, 16, 20] {
        let span: usize = entries[..lo.prefix_chunks(m)]
            .iter()
            .map(|e| e.len as usize)
            .sum();
        assert_eq!(
            KvState::restore_prefix_from_parts(head, &bad[pay..pay + span], m, "h", (2, 32, 1, 8))
                .unwrap_err(),
            StateError::ChunkChecksum { chunk: 2 },
            "m={m}"
        );
    }
    // ...while ranges that stop short of it still restore
    for m in [1usize, 4, 8] {
        let span: usize = entries[..lo.prefix_chunks(m)]
            .iter()
            .map(|e| e.len as usize)
            .sum();
        let part = KvState::restore_prefix_from_parts(
            head,
            &bad[pay..pay + span],
            m,
            "h",
            (2, 32, 1, 8),
        )
        .unwrap();
        assert_eq!(part.n_tokens, m, "clean prefix m={m} must restore");
    }
}

#[test]
fn truncated_final_chunk_detected() {
    for comp in [Compression::None, Compression::Deflate] {
        let st = filled_state(1, 16, 1, 8, 10, 4);
        let blob = st.serialize_prefix_opts(10, "h", comp, 4);
        // whole-blob restores of a cut blob always fail
        for cut in [blob.len() - 1, blob.len() - 3, blob.len() / 2] {
            assert!(
                KvState::restore(&blob[..cut], "h", (1, 16, 1, 8)).is_err(),
                "cut at {cut} ({comp:?}) must fail"
            );
        }
        // a range reply whose final chunk is short is malformed, not a panic
        // and not a partial restore
        let lo = BlobLayout::new("h", 1, 1, 8).with_chunk_tokens(4);
        let (_, entries) = read_chunk_index(&blob).unwrap();
        let span: usize = entries.iter().map(|e| e.len as usize).sum();
        let head = &blob[..lo.payload_off(10)];
        let pay = lo.payload_off(10);
        let err = KvState::restore_prefix_from_parts(
            head,
            &blob[pay..pay + span - 1],
            10,
            "h",
            (1, 16, 1, 8),
        )
        .unwrap_err();
        assert!(
            matches!(err, StateError::Malformed(_)),
            "short final chunk must be Malformed, got {err:?} ({comp:?})"
        );
    }
}

#[test]
fn stale_chunk_geometry_falls_back_to_full_download() {
    // The alias promises chunk size 4 but the entry was re-written with
    // chunk size 8 (as a newer writer might): the range path must refuse to
    // guess and the client must recover the hit via a full-blob download.
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut k = cfg("stale", Some(cb.addr()));
    k.compression = Compression::Deflate;
    k.chunk_tokens = 4;
    let mut c = EdgeClient::new(Arc::clone(&eng), k).unwrap();
    let gen = Generator::new(8);
    let p0 = gen.prompt("astronomy", 0, 2);
    let p1 = gen.prompt("astronomy", 1, 2);

    let r0 = c.query(&p0).unwrap();
    assert_eq!(r0.case, HitCase::Miss);

    // re-encode the big entry with a different chunk size, in place
    {
        let mcfg = &eng.model.config;
        let dims = (mcfg.n_layers, mcfg.max_seq, mcfg.n_kv_heads, mcfg.head_dim);
        let mut store = cb.handle.server.store.lock().unwrap();
        let key: Vec<u8> = store
            .keys()
            .max_by_key(|kk| store.strlen(kk).unwrap_or(0))
            .unwrap()
            .clone();
        let blob = store.get(&key).unwrap().to_vec();
        let st = KvState::restore(&blob, eng.model_hash(), dims).unwrap();
        let re = st.serialize_prefix_opts(
            st.n_tokens,
            eng.model_hash(),
            Compression::Deflate,
            8,
        );
        store.set(&key, re);
    }

    let r1 = c.query(&p1).unwrap();
    assert_eq!(r1.case, HitCase::AllExamples, "fallback must still hit");
    assert!(!r1.false_positive);
    assert!(r1.matched_tokens > 0);
    assert_eq!(c.stats.full_fetch_fallbacks, 1, "range path must have bailed");
    assert_eq!(c.stats.range_fetches, 0);
    c.shutdown();
    cb.shutdown();
}

#[test]
fn corrupt_chunk_on_server_never_restores_and_degrades_to_local() {
    // A corrupted chunk inside the matched prefix: the range path rejects
    // it (chunk crc), the full-blob fallback rejects it too, and the client
    // answers from local prefill — corrupt state is never restored.
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut k = cfg("chunkvictim", Some(cb.addr()));
    k.compression = Compression::Deflate;
    k.chunk_tokens = 4;
    let mut c = EdgeClient::new(Arc::clone(&eng), k).unwrap();
    let gen = Generator::new(12);
    let p0 = gen.prompt("virology", 0, 2);
    let p1 = gen.prompt("virology", 1, 2);

    let r0 = c.query(&p0).unwrap();
    assert_eq!(r0.case, HitCase::Miss);
    let baseline = {
        let mut solo = EdgeClient::new(Arc::clone(&eng), cfg("solo", None)).unwrap();
        let r = solo.query(&p1).unwrap();
        solo.shutdown();
        r.response_tokens
    };

    // flip a byte inside the entry's first body chunk (always matched)
    {
        let mut store = cb.handle.server.store.lock().unwrap();
        let key: Vec<u8> = store
            .keys()
            .max_by_key(|kk| store.strlen(kk).unwrap_or(0))
            .unwrap()
            .clone();
        let mut blob = store.get(&key).unwrap().to_vec();
        let hdr = KvState::peek_header(&blob).unwrap();
        let lo = BlobLayout::new(
            &hdr.model_hash,
            hdr.n_layers,
            hdr.n_kv_heads,
            hdr.head_dim,
        )
        .with_chunk_tokens(hdr.chunk_tokens);
        let off = lo.payload_off(hdr.n_tokens) + 3;
        blob[off] ^= 0xFF;
        store.set(&key, blob);
    }

    let r1 = c.query(&p1).unwrap();
    assert!(r1.false_positive, "corrupt chunk must surface as an FP miss");
    assert_eq!(r1.case, HitCase::Miss);
    assert!(
        c.stats.full_fetch_fallbacks >= 1,
        "the range path must have tried the full-blob fallback first"
    );
    assert_eq!(
        r1.response_tokens, baseline,
        "local fallback reproduces the correct answer"
    );
    c.shutdown();
    cb.shutdown();
}

#[test]
fn client_construction_fails_fast_when_server_absent() {
    let Some(eng) = engine() else { return };
    let r = EdgeClient::new(eng, cfg("noserver", Some("127.0.0.1:1".into())));
    assert!(r.is_err(), "connecting to a dead cache box must error");
}

#[test]
fn standalone_flag_still_serves_without_any_server() {
    let Some(eng) = engine() else { return };
    let mut c = EdgeClient::new(eng, cfg("island", None)).unwrap();
    let gen = Generator::new(6);
    for i in 0..3 {
        let p = gen.prompt("global_facts", i, 1);
        let r = c.query(&p).unwrap();
        assert_eq!(r.case, HitCase::Miss);
        assert!(!r.response_tokens.is_empty());
        assert_eq!(r.uploaded_bytes, 0);
        assert_eq!(r.downloaded_bytes, 0);
    }
    c.shutdown();
}
