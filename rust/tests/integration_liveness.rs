//! Liveness integration: the deadline-budget + membership layer against
//! real sockets, no engine required.
//!
//! The headline guarantee (ISSUE acceptance): a *stalled* peer — one that
//! accepts the TCP connection and then never answers — cannot delay a
//! restore beyond one deadline budget.  Before the budgets existed this
//! was the worst failure mode: a blocking read against an accepted-but-
//! silent socket hangs forever, which no amount of re-planning can see.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use edgecache::coordinator::fabric::{fetch_prefix_multi, Peer, PeerConfig};
use edgecache::coordinator::{
    CacheBox, DeadlineBudget, HealthPolicy, Membership, Outcome, PeerHealth,
    PeerPlanner,
};
use edgecache::kvstore::KvClient;
use edgecache::model::state::{Compression, KvState};
use edgecache::netsim::LinkModel;
use edgecache::util::rng::Rng;

const HASH: &str = "liveness-test";
const DIMS: (usize, usize, usize, usize) = (2, 64, 1, 8);
const CT: usize = 4;

fn filled_state(total_rows: usize, seed: u64) -> KvState {
    let (l, s, kh, d) = DIMS;
    let mut st = KvState::zeroed(l, s, kh, d);
    st.n_tokens = total_rows;
    let mut rng = Rng::new(seed);
    for x in st.k.iter_mut().take(total_rows * 2 * kh * d * l) {
        *x = rng.f64() as f32;
    }
    for x in st.v.iter_mut().take(total_rows * 2 * kh * d * l) {
        *x = rng.f64() as f32 - 0.5;
    }
    st
}

/// An endpoint that accepts connections and then goes silent, holding the
/// accepted sockets open so the client sees a stall, not a reset.
struct SilentPeer {
    addr: String,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl SilentPeer {
    fn start() -> SilentPeer {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = std::thread::spawn(move || {
            let mut held = Vec::new();
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((s, _)) => held.push(s),
                    Err(_) => std::thread::sleep(Duration::from_millis(5)),
                }
            }
        });
        SilentPeer { addr, stop, thread: Some(thread) }
    }
}

impl Drop for SilentPeer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[test]
fn stalled_peer_cannot_delay_restore_beyond_one_budget() {
    let (rows, m) = (16usize, 12usize);
    let st = filled_state(rows, 5);
    let blob = st.serialize_prefix_opts(rows, HASH, Compression::None, CT);
    let truth = KvState::restore(
        &st.serialize_prefix_opts(m, HASH, Compression::None, CT),
        HASH,
        DIMS,
    )
    .unwrap();
    let cb = CacheBox::start_local().unwrap();
    KvClient::connect(&cb.addr())
        .unwrap()
        .set(b"state:x", &blob)
        .unwrap();

    let b = DeadlineBudget::from_millis(200, 300);
    let planner = PeerPlanner::default();
    let membership = Membership::new(2, HealthPolicy::default());
    let silent_ep = SilentPeer::start();
    let mut silent = Peer::connect(
        PeerConfig::new(silent_ep.addr.clone()).with_deadline(b),
        LinkModel::loopback(),
        1,
        1,
    )
    .unwrap();
    silent.set_health(membership.sink(0));
    let mut real = Peer::connect(
        PeerConfig::new(cb.addr()).with_deadline(b),
        LinkModel::loopback(),
        2,
        1,
    )
    .unwrap();
    real.set_health(membership.sink(1));

    // control: the live replica alone
    let control = {
        let t0 = Instant::now();
        let f = {
            let mut cl = vec![(1usize, &mut real)];
            fetch_prefix_multi(
                &mut cl, &planner, b"state:x", rows, false, CT, m, HASH, DIMS, None,
            )
            .expect("control fetch")
        };
        assert_eq!(f.state.k, truth.k);
        t0.elapsed()
    };

    // the silent peer claims the entry and is the preferred head every
    // time; each restore must rotate off it within one op budget (plus
    // one budget of slack for the connect + scheduling noise)
    for i in 0..3 {
        let t0 = Instant::now();
        let f = {
            let mut cl = vec![(0usize, &mut silent), (1usize, &mut real)];
            fetch_prefix_multi(
                &mut cl, &planner, b"state:x", rows, false, CT, m, HASH, DIMS, None,
            )
        }
        .unwrap_or_else(|| panic!("fetch {i} must restore via the live replica"));
        let el = t0.elapsed();
        assert!(
            el < control + 2 * b.op,
            "fetch {i}: {el:?} exceeds control {control:?} + one op budget ({:?}) + slack",
            b.op
        );
        assert_eq!(f.state.k, truth.k, "fetch {i}: corrupt restore");
        assert_eq!(f.state.v, truth.v, "fetch {i}: corrupt restore");
    }

    // the stall is a deadline expiry, counted and classified as Suspect
    // (slow, not gone) — never Dead off a single strike, and never a
    // wedged client
    assert!(silent.ledger.timeouts >= 1, "expiries must land in the ledger");
    assert!(
        matches!(
            membership.state(0),
            PeerHealth::Suspect | PeerHealth::Dead
        ),
        "stalls must demote the silent peer, got {:?}",
        membership.state(0)
    );
    assert_eq!(membership.state(1), PeerHealth::Up);
    assert!(membership.timeouts() >= 1);
    assert_eq!(real.ledger.timeouts, 0);
    cb.shutdown();
}

#[test]
fn suspect_peer_heals_through_io_successes() {
    // IoTimeout demotes to Suspect; subsequent successful ops on the same
    // sink must walk the peer back to Up through the hysteresis — the
    // fabric-level half of the heal loop, no sync thread involved.
    let membership = Membership::new(1, HealthPolicy::default());
    let sink = membership.sink(0);
    sink.report(Outcome::IoTimeout);
    assert_eq!(membership.state(0), PeerHealth::Suspect);
    for _ in 0..HealthPolicy::default().up_after {
        sink.report(Outcome::IoOk);
    }
    assert_eq!(membership.state(0), PeerHealth::Up);
    assert!(membership.suspect_transitions() >= 1);
    // no Dead -> Recovering heal happened: Suspect -> Up is hysteresis,
    // not a reboot rediscovery
    assert_eq!(membership.heals(), 0);
}

#[test]
fn heartbeat_loop_detects_death_and_recovery() {
    // the sync loop *is* the failure detector: killing the box drives
    // Up -> Suspect -> Dead on missed heartbeats, and restarting it on
    // the same address heals Dead -> Recovering -> Up off the backoff
    // probe — no extra connections, no fetch traffic at all.
    let cb = CacheBox::start_local().unwrap();
    let addr = cb.addr();
    let membership = Membership::new(1, HealthPolicy::default());
    let mut peer = Peer::connect(
        PeerConfig::new(addr.clone())
            .with_deadline(DeadlineBudget::from_millis(200, 300)),
        LinkModel::loopback(),
        3,
        1,
    )
    .unwrap();
    peer.set_health(membership.sink(0));
    peer.spawn_sync_with(Duration::from_millis(10), Some(membership.sink(0)))
        .unwrap();

    let wait = |what: &str, cond: &dyn Fn() -> bool| {
        let t0 = Instant::now();
        while !cond() {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "timed out waiting for {what}"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
    };
    wait("first heartbeat", &|| membership.state(0) == PeerHealth::Up
        && membership.peer_counters(0).heartbeats >= 1);

    cb.shutdown();
    wait("death detection", &|| membership.state(0) == PeerHealth::Dead);
    assert!(membership.deaths() >= 1);

    // reboot on the same address; the backoff probe doubles as recovery
    // detection (std listeners set SO_REUSEADDR, so the rebind is safe)
    let t0 = Instant::now();
    let cb = loop {
        match CacheBox::start(&addr, 1 << 24) {
            Ok(cb) => break cb,
            Err(e) => {
                assert!(
                    t0.elapsed() < Duration::from_secs(10),
                    "could not rebind {addr}: {e}"
                );
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    };
    wait("heal", &|| membership.state(0) == PeerHealth::Up);
    assert!(membership.heals() >= 1 || membership.recoveries() >= 1);
    assert!(membership.peer_counters(0).heals >= 1);

    peer.stop_sync();
    cb.shutdown();
}
