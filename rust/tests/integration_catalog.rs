//! Integration: catalog semantics across the wire — multi-client delta
//! sync, convergence, and false-positive behaviour at population scale.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use edgecache::catalog::{range_key, ranges_for, LocalCatalog, Lookup, ModelMeta};
use edgecache::coordinator::{CacheBox, CatalogSync};
use edgecache::kvstore::KvClient;
use edgecache::util::rng::Rng;

#[test]
fn three_clients_converge_through_the_master() {
    let cb = CacheBox::start_local().unwrap();
    let catalogs: Vec<Arc<Mutex<LocalCatalog>>> = (0..3)
        .map(|_| Arc::new(Mutex::new(LocalCatalog::new())))
        .collect();
    let syncs: Vec<CatalogSync> = catalogs
        .iter()
        .map(|c| {
            CatalogSync::spawn(cb.addr(), Arc::clone(c), Duration::from_millis(10)).unwrap()
        })
        .collect();

    // each client registers its own key set on the master
    let meta = ModelMeta::new("m");
    let mut expected = Vec::new();
    for t in 0..3u32 {
        let mut conn = KvClient::connect(&cb.addr()).unwrap();
        for i in 0..20u32 {
            let toks: Vec<u32> = (0..10).map(|x| x + i * 100 + t * 10_000).collect();
            let key = range_key(&meta, &toks);
            conn.catalog_register(&key).unwrap();
            expected.push(key);
        }
    }

    // all three local catalogs converge to contain all 60 keys
    let t0 = std::time::Instant::now();
    'wait: loop {
        assert!(t0.elapsed() < Duration::from_secs(10), "no convergence");
        for c in &catalogs {
            let cat = c.lock().unwrap();
            if cat.synced_version < 60 {
                std::thread::sleep(Duration::from_millis(10));
                continue 'wait;
            }
        }
        break;
    }
    for c in &catalogs {
        let cat = c.lock().unwrap();
        for k in &expected {
            assert!(cat.filter.contains(k));
        }
    }
    drop(syncs);
    cb.shutdown();
}

#[test]
fn delta_paging_handles_large_logs() {
    // CAT.DELTA caps replies at 100k; sync_once loops until caught up.
    let cb = CacheBox::start_local().unwrap();
    let mut reg = KvClient::connect(&cb.addr()).unwrap();
    // register in bulk via pipeline for speed
    let cmds: Vec<Vec<Vec<u8>>> = (0..5000u32)
        .map(|i| vec![b"CAT.REGISTER".to_vec(), format!("key:{i}").into_bytes()])
        .collect();
    for chunk in cmds.chunks(500) {
        reg.pipeline(chunk).unwrap();
    }

    let catalog = Arc::new(Mutex::new(LocalCatalog::new()));
    let mut conn = KvClient::connect(&cb.addr()).unwrap();
    CatalogSync::sync_once(&mut conn, &catalog).unwrap();
    let cat = catalog.lock().unwrap();
    assert_eq!(cat.synced_version, 5000);
    assert!(cat.filter.contains(b"key:0"));
    assert!(cat.filter.contains(b"key:4999"));
    drop(cat);
    cb.shutdown();
}

#[test]
fn population_scale_fp_rate_holds() {
    // register 50k realistic range keys; probe 50k absent ones — the
    // measured FP ratio must stay near the 1% design point (paper §3.3).
    let meta = ModelMeta::new("model-hash-x");
    let mut cat = LocalCatalog::new();
    let mut rng = Rng::new(2026);
    for i in 0..50_000u32 {
        let len = 4 + (rng.below(60)) as usize;
        let toks: Vec<u32> = (0..len).map(|x| (x as u32) ^ (i * 7919)).collect();
        cat.register_key(&range_key(&meta, &toks));
    }
    let mut fp = 0usize;
    let trials = 50_000;
    for i in 0..trials {
        let toks: Vec<u32> = (0..12).map(|x| x as u32 + 1_000_000 + i * 13).collect();
        if cat.filter.contains(&range_key(&meta, &toks)) {
            fp += 1;
        }
    }
    let rate = fp as f64 / trials as f64;
    assert!(rate < 0.005, "at 5% fill of a 1M filter, FP must be tiny: {rate}");
}

#[test]
fn lookup_respects_longest_match_through_sync() {
    // client A registers only the two shorter ranges; client B must get a
    // partial (not full) hit after syncing.
    let cb = CacheBox::start_local().unwrap();
    let meta = ModelMeta::new("m2");
    let toks: Vec<u32> = (0..120).collect();
    let ranges = ranges_for(&meta, &toks, &[30, 60, 120]);

    let mut conn = KvClient::connect(&cb.addr()).unwrap();
    conn.catalog_register(&ranges[0].key).unwrap();
    conn.catalog_register(&ranges[1].key).unwrap();

    let catalog = Arc::new(Mutex::new(LocalCatalog::new()));
    CatalogSync::sync_once(&mut conn, &catalog).unwrap();
    match catalog.lock().unwrap().lookup(&ranges) {
        Lookup::Hit(r) => assert_eq!(r.token_len, 60, "longest synced range"),
        Lookup::Miss => panic!("must hit"),
    }
    cb.shutdown();
}

#[test]
fn model_metadata_partitions_the_keyspace() {
    // identical token streams under different models/quantizations never
    // collide (paper §3.1's integrity requirement)
    let toks: Vec<u32> = (0..64).collect();
    let mut keys = std::collections::HashSet::new();
    for hash in ["modelA", "modelB"] {
        for quant in ["f32", "q8", "q4"] {
            let mut meta = ModelMeta::new(hash);
            meta.quant = quant.into();
            assert!(keys.insert(range_key(&meta, &toks)), "collision for {hash}/{quant}");
        }
    }
    // and format bumps invalidate too
    let mut meta = ModelMeta::new("modelA");
    meta.state_format = 2;
    assert!(keys.insert(range_key(&meta, &toks)));
}
