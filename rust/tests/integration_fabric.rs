//! Peer-fabric integration: multi-source chunk fetches across several
//! cache boxes, peer death mid-trace, survivor re-planning and placement.
//!
//! The first half drives the fabric machinery directly with hand-built
//! states (no engine artifacts needed); the second half runs the full
//! `EdgeClient` flow and skips when `artifacts/tiny` is absent.

use std::sync::Arc;
use std::time::{Duration, Instant};

use edgecache::coordinator::fabric::{fetch_prefix_multi, LocalRecompute, Peer, PeerConfig};
use edgecache::coordinator::{
    CacheBox, DeadlineBudget, EdgeClient, EdgeClientConfig, HitCase, PeerPlanner, PlacementKind,
};
use edgecache::engine::Engine;
use edgecache::model::state::{BlobLayout, Compression, KvState};
use edgecache::netsim::LinkModel;
use edgecache::util::rng::Rng;

const HASH: &str = "fabric-test";
const DIMS: (usize, usize, usize, usize) = (2, 64, 1, 8); // 128 B/token

fn filled_state(n: usize, seed: u64) -> KvState {
    let (l, s, kh, d) = DIMS;
    let mut st = KvState::zeroed(l, s, kh, d);
    st.n_tokens = n;
    let mut rng = Rng::new(seed);
    let row = kh * d;
    let le = s * row;
    for li in 0..l {
        for e in 0..n * row {
            st.k[li * le + e] = rng.f64() as f32;
            st.v[li * le + e] = rng.f64() as f32 - 0.5;
        }
    }
    st
}

fn peer_for(cb: &CacheBox, seed: u64) -> Peer {
    Peer::connect(PeerConfig::new(cb.addr()), LinkModel::loopback(), seed, 1).unwrap()
}

/// The m-row truth a fabric fetch must reproduce bit-for-bit.
fn expected_prefix(st: &KvState, m: usize, ct: usize, comp: Compression) -> KvState {
    let blob = st.serialize_prefix_opts(m, HASH, comp, ct);
    KvState::restore(&blob, HASH, DIMS).unwrap()
}

#[test]
fn multi_source_fetch_matches_single_source() {
    for comp in [Compression::None, Compression::Deflate] {
        let st = filled_state(24, 7);
        let ct = 4;
        let m = 17;
        let blob = st.serialize_prefix_opts(24, HASH, comp, ct);

        let (cb_a, cb_b) = (CacheBox::start_local().unwrap(), CacheBox::start_local().unwrap());
        for cb in [&cb_a, &cb_b] {
            let mut c = edgecache::kvstore::KvClient::connect(&cb.addr()).unwrap();
            c.set(b"state:e", &blob).unwrap();
        }
        let planner = PeerPlanner::default();
        let compressed = comp == Compression::Deflate;

        // single source: the degenerate one-stripe plan
        let mut p0 = peer_for(&cb_a, 1);
        let single = {
            let mut claimers = vec![(0usize, &mut p0)];
            fetch_prefix_multi(
                &mut claimers, &planner, b"state:e", 24, compressed, ct, m, HASH, DIMS,
                None,
            )
            .expect("single-source fetch")
        };
        assert!(!single.multi_source);
        assert_eq!(single.re_plans, 0);

        // dual source: stripes split across both claimers
        let mut pa = peer_for(&cb_a, 2);
        let mut pb = peer_for(&cb_b, 3);
        let dual = {
            let mut claimers = vec![(0usize, &mut pa), (1usize, &mut pb)];
            fetch_prefix_multi(
                &mut claimers, &planner, b"state:e", 24, compressed, ct, m, HASH, DIMS,
                None,
            )
            .expect("dual-source fetch")
        };
        assert!(dual.multi_source, "5 chunks over 2 peers must stripe");
        assert_eq!(dual.re_plans, 0);
        assert_eq!(dual.share_failures, 0);
        // both peers actually served chunk bytes
        assert!(pa.ledger.bytes_down > 0 && pb.ledger.bytes_down > 0);

        let want = expected_prefix(&st, m, ct, comp);
        for got in [&single.state, &dual.state] {
            assert_eq!(got.n_tokens, m);
            assert_eq!(got.k, want.k, "comp={comp:?}");
            assert_eq!(got.v, want.v, "comp={comp:?}");
        }
        assert_eq!(single.wire, dual.wire, "striping moves the same bytes");
        cb_a.shutdown();
        cb_b.shutdown();
    }
}

#[test]
fn dead_share_peer_replans_onto_survivor() {
    // peer B dies after the plan names it: its stripe fails mid-fetch and
    // the orphaned chunks are re-planned onto the survivor — assembly
    // completes with the exact same bytes (StateAssembler invariants hold)
    let st = filled_state(32, 11);
    let ct = 4;
    let m = 26;
    let blob = st.serialize_prefix_opts(32, HASH, Compression::Deflate, ct);

    let cb_a = CacheBox::start_local().unwrap();
    let cb_b = CacheBox::start_local().unwrap();
    for cb in [&cb_a, &cb_b] {
        let mut c = edgecache::kvstore::KvClient::connect(&cb.addr()).unwrap();
        c.set(b"state:e", &blob).unwrap();
    }
    let mut pa = peer_for(&cb_a, 4);
    let mut pb = peer_for(&cb_b, 5);
    cb_b.shutdown(); // B dies between the catalog claim and the fetch

    let planner = PeerPlanner::default();
    let fetch = {
        let mut claimers = vec![(0usize, &mut pa), (1usize, &mut pb)];
        fetch_prefix_multi(
            &mut claimers, &planner, b"state:e", 32, true, ct, m, HASH, DIMS, None,
        )
        .expect("survivor must complete the fetch")
    };
    assert!(fetch.re_plans >= 1, "orphaned chunks must be re-planned");
    assert!(fetch.share_failures >= 1);
    assert!(!pb.is_connected(), "dead peer's connection must be torn down");
    assert!(pb.ledger.share_failures >= 1);

    let want = expected_prefix(&st, m, ct, Compression::Deflate);
    assert_eq!(fetch.state.n_tokens, m);
    assert_eq!(fetch.state.k, want.k, "re-planned restore must be bit-exact");
    assert_eq!(fetch.state.v, want.v);
    cb_a.shutdown();
}

#[test]
fn dead_head_peer_rotates_then_survivor_serves() {
    // the *first* claimer is dead: head acquisition rotates to the
    // survivor, and the dead peer's planned stripe re-plans back too
    let st = filled_state(32, 13);
    let ct = 4;
    let m = 32;
    let blob = st.serialize_prefix_opts(32, HASH, Compression::None, ct);

    let cb_a = CacheBox::start_local().unwrap();
    let cb_b = CacheBox::start_local().unwrap();
    {
        let mut c = edgecache::kvstore::KvClient::connect(&cb_b.addr()).unwrap();
        c.set(b"state:e", &blob).unwrap();
    }
    let mut pa = peer_for(&cb_a, 6);
    let mut pb = peer_for(&cb_b, 7);
    cb_a.shutdown(); // the would-be head peer is gone

    let planner = PeerPlanner::default();
    let fetch = {
        let mut claimers = vec![(0usize, &mut pa), (1usize, &mut pb)];
        fetch_prefix_multi(
            &mut claimers, &planner, b"state:e", 32, false, ct, m, HASH, DIMS, None,
        )
        .expect("head rotation must find the survivor")
    };
    assert_eq!(fetch.head_peer, 1, "survivor serves the head");
    assert!(fetch.share_failures >= 1, "dead head attempt is a failure");
    let want = expected_prefix(&st, m, ct, Compression::None);
    assert_eq!(fetch.state.k, want.k);
    assert_eq!(fetch.state.v, want.v);
    cb_b.shutdown();
}

#[test]
fn no_live_claimer_degrades_to_none_not_corruption() {
    let st = filled_state(16, 17);
    let blob = st.serialize_prefix_opts(16, HASH, Compression::None, 4);
    let cb = CacheBox::start_local().unwrap();
    {
        let mut c = edgecache::kvstore::KvClient::connect(&cb.addr()).unwrap();
        c.set(b"state:e", &blob).unwrap();
    }
    let mut p = peer_for(&cb, 8);
    cb.shutdown();
    let planner = PeerPlanner::default();
    let mut claimers = vec![(0usize, &mut p)];
    let fetch = fetch_prefix_multi(
        &mut claimers, &planner, b"state:e", 16, false, 4, 12, HASH, DIMS, None,
    );
    assert!(fetch.is_none(), "all-dead fabric must fail, never restore junk");
}

// ---------------------------------------------------------------------------
// mixed fetch/recompute plans (the `coordinator::plan` chunk planner)
// ---------------------------------------------------------------------------

/// A hand-built local feeder: serves the true row payloads straight out of
/// `st`, shaped exactly like the client's engine-backed feeder output
/// (stored-rows geometry per the `commit_chunk` contract).
fn truth_feeder<'a>(
    st: &'a KvState,
    ct: usize,
    total: usize,
) -> impl FnMut(&[usize], Option<KvState>) -> Option<Vec<(usize, Vec<u8>)>> + 'a {
    move |chunks: &[usize], _seed: Option<KvState>| {
        Some(
            chunks
                .iter()
                .map(|&c| {
                    let t0 = c * ct;
                    (c, st.chunk_payload(t0, ct.min(total - t0)))
                })
                .collect(),
        )
    }
}

#[test]
fn dead_peer_orphans_rescue_onto_local_recompute() {
    // peer B dies after the plan names it and the planner has *zero*
    // re-plan budget: its orphaned stripe must go to the local feeder —
    // not a survivor — the restore stays bit-exact, and the dead peer
    // costs at most one deadline-budget op of wall time
    let st = filled_state(32, 19);
    let (ct, m) = (4, 32);
    let blob = st.serialize_prefix_opts(32, HASH, Compression::None, ct);
    let b = DeadlineBudget::from_millis(200, 250);

    let cb_a = CacheBox::start_local().unwrap();
    let cb_b = CacheBox::start_local().unwrap();
    for cb in [&cb_a, &cb_b] {
        let mut c = edgecache::kvstore::KvClient::connect(&cb.addr()).unwrap();
        c.set(b"state:e", &blob).unwrap();
    }
    let mut pa =
        Peer::connect(PeerConfig::new(cb_a.addr()).with_deadline(b), LinkModel::loopback(), 21, 1)
            .unwrap();
    let mut pb =
        Peer::connect(PeerConfig::new(cb_b.addr()).with_deadline(b), LinkModel::loopback(), 22, 1)
            .unwrap();
    cb_b.shutdown(); // B dies between the catalog claim and the fetch

    // no survivor retries allowed: the only way out is the feeder
    let planner = PeerPlanner { max_replan_rounds: 0 };
    let mut feed = truth_feeder(&st, ct, 32);
    let t0 = Instant::now();
    let fetch = {
        let mut claimers = vec![(0usize, &mut pa), (1usize, &mut pb)];
        fetch_prefix_multi(
            &mut claimers, &planner, b"state:e", 32, false, ct, m, HASH, DIMS,
            Some(LocalRecompute { feed: &mut feed, prefill_ms_per_tok: 50.0 }),
        )
        .expect("orphaned chunks must be rescued by local recompute")
    };
    let el = t0.elapsed();
    assert!(
        fetch.chunks_recomputed >= 1 && fetch.chunks_fetched >= 1,
        "B's stripe must go local while A's still rides the wire: {} fetched / {} recomputed",
        fetch.chunks_fetched,
        fetch.chunks_recomputed
    );
    assert_eq!(
        fetch.chunks_fetched + fetch.chunks_recomputed,
        8,
        "every chunk has exactly one source"
    );
    assert!(fetch.share_failures >= 1);
    assert!(pb.ledger.share_failures >= 1);
    assert!(
        el < b.connect + 2 * b.op,
        "a dead stripe peer costs at most ~one deadline op, took {el:?}"
    );
    let want = expected_prefix(&st, m, ct, Compression::None);
    assert_eq!(fetch.state.n_tokens, m);
    assert_eq!(fetch.state.k, want.k, "rescued restore must be bit-exact");
    assert_eq!(fetch.state.v, want.v);
    cb_a.shutdown();
}

#[test]
fn corrupt_chunk_degrades_to_recompute_not_fallback() {
    // one stored chunk's bytes are flipped on the box: the share
    // crc-rejects exactly that chunk, prior chunks stay committed, and
    // with a feeder attached the fetch degrades the rejected tail to
    // local recompute instead of abandoning the whole range
    let st = filled_state(32, 23);
    let (ct, m) = (4, 32);
    let mut blob = st.serialize_prefix_opts(32, HASH, Compression::None, ct);
    let lo = BlobLayout::new(HASH, DIMS.0, DIMS.2, DIMS.3).with_chunk_tokens(ct);
    // first byte of chunk 3's stored rows (uncompressed: ct * stride each)
    let bad = lo.payload_off(32) + 3 * ct * lo.token_stride();
    blob[bad] ^= 0x5A;

    let cb = CacheBox::start_local().unwrap();
    {
        let mut c = edgecache::kvstore::KvClient::connect(&cb.addr()).unwrap();
        c.set(b"state:e", &blob).unwrap();
    }
    let mut p = peer_for(&cb, 24);
    let planner = PeerPlanner::default();
    let mut feed = truth_feeder(&st, ct, 32);
    let fetch = {
        let mut claimers = vec![(0usize, &mut p)];
        fetch_prefix_multi(
            &mut claimers, &planner, b"state:e", 32, false, ct, m, HASH, DIMS,
            Some(LocalRecompute { feed: &mut feed, prefill_ms_per_tok: 5.0 }),
        )
        .expect("a corrupt chunk must degrade to recompute, not fail the range")
    };
    assert_eq!(fetch.chunks_fetched, 3, "chunks before the corruption stay fetched");
    assert_eq!(fetch.chunks_recomputed, 5, "the corrupt chunk and its tail go local");
    assert!(fetch.share_failures >= 1, "the crc reject is a share failure");
    // the feeder supplied the true rows for every rejected chunk
    let want = expected_prefix(&st, m, ct, Compression::None);
    assert_eq!(fetch.state.n_tokens, m);
    assert_eq!(fetch.state.k, want.k, "degraded restore must be bit-exact");
    assert_eq!(fetch.state.v, want.v);
    cb.shutdown();
}

#[test]
fn slow_link_fast_device_plans_genuinely_mixed() {
    // the planner's reason to exist: over a slow link with a fast device
    // the cost model must split the range — the cheap prefix is
    // recomputed locally while the tail is fetched, overlapped, and the
    // result is still bit-exact
    let st = filled_state(32, 29);
    let (ct, m) = (4, 32);
    let blob = st.serialize_prefix_opts(32, HASH, Compression::None, ct);
    let cb = CacheBox::start_local().unwrap();
    {
        let mut c = edgecache::kvstore::KvClient::connect(&cb.addr()).unwrap();
        c.set(b"state:e", &blob).unwrap();
    }
    // 512 B chunks over ~100 kB/s + 5 ms RTT vs 4 ms/chunk recompute:
    // neither extreme is optimal (all-fetch ≈ 46 ms, all-recompute 32 ms,
    // the s=5 split ≈ 20 ms)
    let slow = LinkModel {
        name: "test-slow",
        goodput_bps: 100_000.0,
        rtt: Duration::from_millis(5),
        jitter_frac: 0.0,
    };
    let mut p = Peer::connect(PeerConfig::new(cb.addr()), slow, 25, 1).unwrap();
    let planner = PeerPlanner::default();
    let mut feed = truth_feeder(&st, ct, 32);
    let fetch = {
        let mut claimers = vec![(0usize, &mut p)];
        fetch_prefix_multi(
            &mut claimers, &planner, b"state:e", 32, false, ct, m, HASH, DIMS,
            Some(LocalRecompute { feed: &mut feed, prefill_ms_per_tok: 1.0 }),
        )
        .expect("mixed-plan fetch")
    };
    assert!(
        fetch.chunks_fetched >= 1 && fetch.chunks_recomputed >= 1,
        "plan must mix on this cell: {} fetched / {} recomputed",
        fetch.chunks_fetched, fetch.chunks_recomputed
    );
    assert_eq!(fetch.share_failures, 0, "no failures: this split is *planned*");
    assert_eq!(fetch.re_plans, 0);
    let want = expected_prefix(&st, m, ct, Compression::None);
    assert_eq!(fetch.state.n_tokens, m);
    assert_eq!(fetch.state.k, want.k, "mixed restore must be bit-exact");
    assert_eq!(fetch.state.v, want.v);
    cb.shutdown();
}

// ---------------------------------------------------------------------------
// engine-backed end-to-end failover (skips without artifacts/tiny)
// ---------------------------------------------------------------------------

fn engine() -> Option<Arc<Engine>> {
    if !edgecache::artifacts_dir().join("tiny/meta.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return None;
    }
    Some(Arc::new(Engine::load_preset("tiny").unwrap()))
}

fn fabric_cfg(name: &str, boxes: &[&CacheBox]) -> EdgeClientConfig {
    let mut cfg = EdgeClientConfig::native(None);
    cfg.name = name.into();
    cfg.max_new_tokens = Some(2);
    cfg.sync_interval = None;
    cfg.peers = boxes
        .iter()
        .map(|cb| edgecache::coordinator::PeerConfig::new(cb.addr()))
        .collect();
    cfg
}

#[test]
fn replicated_upload_survives_peer_death_mid_trace() {
    // the satellite acceptance: with two peers and replication, killing a
    // peer mid-trace keeps the partial hit alive — the planner re-fetches
    // the orphaned chunks from the survivor, the assembled state is
    // uncorrupted (the response reproduces the solo baseline), and the
    // counters show re-planning instead of full-blob fallbacks
    let Some(eng) = engine() else { return };
    let cb_a = CacheBox::start_local().unwrap();
    let cb_b = CacheBox::start_local().unwrap();
    let mut cfg = fabric_cfg("failover", &[&cb_a, &cb_b]);
    cfg.replicas = 1; // every upload lands on both boxes
    cfg.compression = Compression::Deflate;
    cfg.chunk_tokens = 4;
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg).unwrap();

    let gen = edgecache::workload::Generator::new(31);
    let p0 = gen.prompt("astronomy", 0, 2);
    let p1 = gen.prompt("astronomy", 1, 2); // shares instruction + examples

    let baseline = {
        let mut solo = EdgeClient::new(
            Arc::clone(&eng),
            fabric_cfg("solo", &[]),
        )
        .unwrap();
        let r = solo.query(&p1).unwrap();
        solo.shutdown();
        r.response_tokens
    };

    let r0 = c.query(&p0).unwrap();
    assert_eq!(r0.case, HitCase::Miss);
    assert_eq!(c.stats.replica_uploads, 1, "replication must copy the blob");
    let (keys_a, ..) = cb_a.stats();
    let (keys_b, ..) = cb_b.stats();
    assert!(keys_a > 0 && keys_b > 0, "both boxes hold the entry");

    // kill peer 0 mid-trace; its catalog still claims every range
    cb_a.shutdown();

    let r1 = c.query(&p1).unwrap();
    assert_eq!(
        r1.case,
        HitCase::AllExamples,
        "survivor must keep the partial hit alive"
    );
    assert!(!r1.false_positive);
    assert_eq!(r1.response_tokens, baseline, "no corruption through failover");
    assert_eq!(c.stats.range_fetches, 1, "the fabric range path served the hit");
    assert_eq!(
        c.stats.full_fetch_fallbacks, 0,
        "orphans re-plan to the survivor, not to a full blob"
    );
    assert!(
        c.stats.re_plans >= 1 || c.stats.peer_failures >= 1,
        "the dead peer must show up in the planner counters: {:?}",
        c.stats
    );

    // the trace keeps going: an exact repeat now fully hits via survivor
    let r2 = c.query(&p1).unwrap();
    assert_eq!(r2.case, HitCase::Full);
    assert_eq!(r2.response_tokens, baseline);
    c.shutdown();
    cb_b.shutdown();
}

#[test]
fn two_peer_client_stripes_partial_hits() {
    // multi-source acceptance through the full client: a replicated entry
    // is fetched from both boxes at once and the ledgers show both sides
    let Some(eng) = engine() else { return };
    let cb_a = CacheBox::start_local().unwrap();
    let cb_b = CacheBox::start_local().unwrap();
    let mut cfg = fabric_cfg("stripe", &[&cb_a, &cb_b]);
    cfg.replicas = 1;
    cfg.chunk_tokens = 2; // many chunks: both stripes non-empty
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg).unwrap();

    let gen = edgecache::workload::Generator::new(37);
    let p0 = gen.prompt("virology", 0, 2);
    let p1 = gen.prompt("virology", 1, 2);

    let r0 = c.query(&p0).unwrap();
    assert_eq!(r0.case, HitCase::Miss);
    let r1 = c.query(&p1).unwrap();
    assert_eq!(r1.case, HitCase::AllExamples);
    assert_eq!(c.stats.range_fetches, 1);
    assert_eq!(c.stats.full_fetch_fallbacks, 0);
    assert_eq!(c.stats.multi_source_fetches, 1, "hit must stripe across peers");
    let ledgers = c.peer_ledgers();
    assert!(
        ledgers.iter().all(|l| l.bytes_down > 0),
        "both peers served bytes: {ledgers:?}"
    );
    // correctness through the striped path
    let r2 = c.query(&p1).unwrap();
    assert_eq!(r2.case, HitCase::Full);
    assert_eq!(r1.response_tokens, r2.response_tokens);
    c.shutdown();
    cb_a.shutdown();
    cb_b.shutdown();
}

#[test]
fn one_peer_config_is_the_degenerate_fabric() {
    // no special-case single-box path: a 1-peer fabric behaves exactly
    // like the paper's topology, range path included
    let Some(eng) = engine() else { return };
    let cb = CacheBox::start_local().unwrap();
    let mut cfg = fabric_cfg("degenerate", &[&cb]);
    cfg.compression = Compression::Deflate;
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg).unwrap();
    let gen = edgecache::workload::Generator::new(41);
    let p0 = gen.prompt("anatomy", 0, 2);
    let p1 = gen.prompt("anatomy", 1, 2);

    let r0 = c.query(&p0).unwrap();
    assert_eq!(r0.case, HitCase::Miss);
    let r1 = c.query(&p1).unwrap();
    assert_eq!(r1.case, HitCase::AllExamples);
    assert_eq!(c.stats.range_fetches, 1);
    assert_eq!(c.stats.multi_source_fetches, 0, "one peer cannot stripe");
    assert!(r1.saved_bytes > 0);
    c.shutdown();
    cb.shutdown();
}

#[test]
fn ring_fallback_probing_recovers_after_reboot() {
    // the catalog-less recovery path: client 1 uploads under ring
    // placement; client 2 "reboots" with an empty Bloom catalog and no
    // sync, yet still serves the hit by probing the key's deterministic
    // owners — a Bloom false negative stops being an unrecoverable miss
    let Some(eng) = engine() else { return };
    let boxes: Vec<CacheBox> = (0..3).map(|_| CacheBox::start_local().unwrap()).collect();
    let box_refs: Vec<&CacheBox> = boxes.iter().collect();
    let mut cfg = fabric_cfg("ring-up", &box_refs);
    cfg.placement = PlacementKind::RendezvousRing;
    let mut c1 = EdgeClient::new(Arc::clone(&eng), cfg).unwrap();

    let gen = edgecache::workload::Generator::new(47);
    let p = gen.prompt("astronomy", 0, 1);
    let r0 = c1.query(&p).unwrap();
    assert_eq!(r0.case, HitCase::Miss);
    let baseline = r0.response_tokens.clone();

    // a rebooted client: same fleet, fresh (empty) Bloom filters, never
    // synced — the pure-catalog path would miss forever
    let mut cfg2 = fabric_cfg("ring-reboot", &box_refs);
    cfg2.placement = PlacementKind::RendezvousRing;
    let mut c2 = EdgeClient::new(Arc::clone(&eng), cfg2).unwrap();
    let r1 = c2.query(&p).unwrap();
    assert_eq!(r1.case, HitCase::Full, "owner probing must recover the hit");
    assert_eq!(r1.response_tokens, baseline, "no corruption through the fallback");
    assert!(!r1.false_positive);
    assert!(c2.stats.fallback_probes >= 1, "{:?}", c2.stats);
    assert_eq!(c2.stats.fallback_probe_hits, 1);
    // bounded probing: at most (1 + replicas) owners per candidate range
    let ranges = 5; // 4 prefix ranges + full, the most a prompt registers
    assert!(
        c2.stats.fallback_probes <= ((1 + c2.cfg.replicas) * ranges) as u64,
        "probing must stay bounded to the owner sets: {:?}",
        c2.stats
    );
    // the probe-confirmed hit re-warmed the local catalog: an identical
    // query hits via Bloom without new fallback probes
    let probes = c2.stats.fallback_probes;
    let r2 = c2.query(&p).unwrap();
    assert_eq!(r2.case, HitCase::Full);
    assert_eq!(
        c2.stats.fallback_probes, probes,
        "a warm catalog must skip owner probing"
    );
    c1.shutdown();
    c2.shutdown();
    for cb in boxes {
        cb.shutdown();
    }
}

#[test]
fn ring_fallback_recovers_partial_hits_after_reboot() {
    // the harder half of catalog-less recovery: the shared-prefix ranges
    // exist only as *aliases*.  Under the ring they are also placed at
    // their own store key's owners (alias indirection), so a rebooted
    // client probing a prefix key's owner finds the pointer and follows
    // it to the blob at the target key's owners.
    let Some(eng) = engine() else { return };
    let boxes: Vec<CacheBox> = (0..3).map(|_| CacheBox::start_local().unwrap()).collect();
    let box_refs: Vec<&CacheBox> = boxes.iter().collect();
    let mut cfg = fabric_cfg("ring-partial-up", &box_refs);
    cfg.placement = PlacementKind::RendezvousRing;
    let mut c1 = EdgeClient::new(Arc::clone(&eng), cfg).unwrap();

    let gen = edgecache::workload::Generator::new(59);
    let p0 = gen.prompt("anatomy", 0, 2);
    let p1 = gen.prompt("anatomy", 1, 2); // shares instruction + examples
    assert_eq!(p0.examples, p1.examples);
    let r0 = c1.query(&p0).unwrap();
    assert_eq!(r0.case, HitCase::Miss);

    // what an uncached client answers for p1 — the recovery must match it
    let baseline = {
        let mut solo = EdgeClient::new(Arc::clone(&eng), fabric_cfg("solo", &[])).unwrap();
        let r = solo.query(&p1).unwrap();
        solo.shutdown();
        r.response_tokens
    };

    // rebooted client: empty Bloom filters, no sync — only the ring knows
    // where anything lives
    let mut cfg2 = fabric_cfg("ring-partial-reboot", &box_refs);
    cfg2.placement = PlacementKind::RendezvousRing;
    let mut c2 = EdgeClient::new(Arc::clone(&eng), cfg2).unwrap();
    let r1 = c2.query(&p1).unwrap();
    assert_eq!(
        r1.case,
        HitCase::AllExamples,
        "owner probing must recover the shared-prefix partial hit"
    );
    assert!(r1.matched_tokens > 0 && r1.matched_tokens < r1.prompt_tokens);
    assert!(r1.downloaded_bytes > 0);
    assert_eq!(r1.response_tokens, baseline, "no corruption through recovery");
    assert!(c2.stats.fallback_probe_hits >= 1, "{:?}", c2.stats);
    c1.shutdown();
    c2.shutdown();
    for cb in boxes {
        cb.shutdown();
    }
}

#[test]
fn ring_repair_restores_replication_after_peer_death() {
    // replica bookkeeping derived from the ring: after an owner dies, the
    // next client to *use* the entry re-publishes it to the successor
    // owner, restoring the configured replication factor with no
    // per-entry tracking anywhere
    let Some(eng) = engine() else { return };
    let boxes: Vec<CacheBox> = (0..3).map(|_| CacheBox::start_local().unwrap()).collect();
    let box_refs: Vec<&CacheBox> = boxes.iter().collect();
    let mut cfg = fabric_cfg("ring-repair", &box_refs);
    cfg.placement = PlacementKind::RendezvousRing;
    cfg.replicas = 1; // replication factor 2 of 3 boxes
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg).unwrap();

    let gen = edgecache::workload::Generator::new(53);
    let p = gen.prompt("virology", 0, 1);
    let r0 = c.query(&p).unwrap();
    assert_eq!(r0.case, HitCase::Miss);
    assert_eq!(c.stats.replica_uploads, 1, "ring must ship the replica copy");
    // the blob bundle lives on its two HRW owners — the byte-heavy boxes
    // (other boxes may hold tiny indirection aliases)
    let bytes: Vec<usize> = boxes.iter().map(|cb| cb.stats().1).collect();
    let heavy = (0..3).max_by_key(|&i| bytes[i]).unwrap();

    // kill one bundle owner; catalogs (and the stale ring view) still
    // point at it until the failed fetch flips membership
    let mut boxes: Vec<Option<CacheBox>> = boxes.into_iter().map(Some).collect();
    boxes[heavy].take().unwrap().shutdown();
    let survivors: Vec<usize> = (0..3).filter(|&i| i != heavy).collect();
    let before: Vec<usize> = survivors
        .iter()
        .map(|&i| boxes[i].as_ref().unwrap().stats().1)
        .collect();

    // the next use of the entry fetches from the survivor and, post
    // response, repairs the successor owner back up to 2 live copies
    let r1 = c.query(&p).unwrap();
    assert_eq!(r1.case, HitCase::Full, "survivor keeps the hit alive");
    assert_eq!(r1.response_tokens, r0.response_tokens);
    assert!(
        c.stats.repair_republishes >= 1,
        "repair must re-publish the lost copy: {:?}",
        c.stats
    );
    // with 2 of 3 boxes live the recomputed owner set is exactly the two
    // survivors: one already held the blob, the other must have gained it
    let gained: usize = survivors
        .iter()
        .zip(&before)
        .map(|(&i, &b)| boxes[i].as_ref().unwrap().stats().1.saturating_sub(b))
        .sum();
    assert!(
        gained > 500,
        "a survivor must have received the repaired blob (+{gained} B)"
    );
    // replication factor is back: another use finds every live owner
    // intact and re-publishes nothing new
    let repairs = c.stats.repair_republishes;
    let r2 = c.query(&p).unwrap();
    assert_eq!(r2.case, HitCase::Full);
    assert_eq!(
        c.stats.repair_republishes, repairs,
        "an intact owner set must not be re-repaired"
    );
    c.shutdown();
    for cb in boxes.into_iter().flatten() {
        cb.shutdown();
    }
}

#[test]
fn placement_spreads_fresh_uploads_across_peers() {
    // power-of-two-choices on used_bytes: distinct-domain misses must not
    // all pile onto one box
    let Some(eng) = engine() else { return };
    let cb_a = CacheBox::start_local().unwrap();
    let cb_b = CacheBox::start_local().unwrap();
    let cfg = fabric_cfg("placer", &[&cb_a, &cb_b]);
    let mut c = EdgeClient::new(Arc::clone(&eng), cfg).unwrap();
    let gen = edgecache::workload::Generator::new(43);
    for (i, domain) in ["marketing", "sociology", "nutrition", "prehistory"]
        .iter()
        .enumerate()
    {
        let p = gen.prompt(domain, i as u64, 1);
        let r = c.query(&p).unwrap();
        assert_eq!(r.case, HitCase::Miss);
    }
    let (keys_a, ..) = cb_a.stats();
    let (keys_b, ..) = cb_b.stats();
    assert!(
        keys_a > 0 && keys_b > 0,
        "two-choices placement must use both boxes ({keys_a}/{keys_b})"
    );
    let ledgers = c.peer_ledgers();
    assert_eq!(
        ledgers.iter().map(|l| l.uploads).sum::<u64>(),
        4,
        "{ledgers:?}"
    );
    c.shutdown();
    cb_a.shutdown();
    cb_b.shutdown();
}
