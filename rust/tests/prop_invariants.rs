//! Property tests on system invariants (the proptest-style suite; see
//! `util::prop` for the harness and replay mechanics).

use edgecache::catalog::{range_key, ranges_for, LocalCatalog, Lookup, ModelMeta};
use edgecache::devicemodel::DeviceProfile;
use edgecache::kvstore::resp::{Decoder, Value};
use edgecache::model::state::{read_chunk_index, BlobLayout, Compression, KvState};
use edgecache::netsim::LinkModel;
use edgecache::tokenizer::Tokenizer;
use edgecache::util::prop::{run_prop_n, Gen};
use edgecache::workload::{Generator, DOMAINS};

/// Catalog: registered ranges are always found, and lookup returns the
/// longest registered candidate — never a shorter one, never an unregistered
/// longer one.
#[test]
fn prop_lookup_is_longest_registered_prefix() {
    run_prop_n("lookup-longest-registered", 200, |g: &mut Gen| {
        let meta = ModelMeta::new(g.ascii_string(8));
        let n = g.usize_in(8, 400);
        let toks = g.tokens(n, 4096);
        let lens = [n / 8, n / 4, n / 2, n];
        let ranges = ranges_for(&meta, &toks, &lens);
        // register a random subset
        let mut cat = LocalCatalog::new();
        let mut registered = Vec::new();
        for r in &ranges {
            if g.bool() {
                cat.register(std::slice::from_ref(r));
                registered.push(r.token_len);
            }
        }
        match cat.lookup(&ranges) {
            Lookup::Miss => assert!(
                registered.is_empty(),
                "registered {registered:?} but lookup missed"
            ),
            Lookup::Hit(hit) => {
                let want = registered.iter().max().copied().unwrap_or_else(|| {
                    // a Bloom false positive can surface an unregistered
                    // range; with a ~empty 1M filter this is ~impossible
                    panic!("hit with nothing registered (FP at empty fill?)")
                });
                assert_eq!(hit.token_len, want, "must return the longest");
            }
        }
    });
}

/// Tokenizer: workload prompts tokenize prefix-stably across all four
/// catalog ranges — the property partial matching depends on.
#[test]
fn prop_workload_ranges_are_token_prefixes() {
    let tok = Tokenizer::full();
    run_prop_n("workload-prefix-stability", 60, |g: &mut Gen| {
        let gen = Generator::new(g.rng.next_u64());
        let domain = DOMAINS[g.usize_in(0, DOMAINS.len() - 1)];
        let shots = g.usize_in(0, 5);
        let p = gen.prompt(domain, g.rng.next_u64() % 50, shots);
        let full = tok.encode(&p.full_text());
        for prefix in p.prefix_texts() {
            let pt = tok.encode(&prefix);
            assert!(
                full.starts_with(&pt),
                "range of {} chars is not a token prefix (domain {domain})",
                prefix.len()
            );
        }
    });
}

/// Range keys: equal iff (meta, token prefix) equal.
#[test]
fn prop_range_key_injective_on_observations() {
    run_prop_n("range-key-injective", 120, |g: &mut Gen| {
        let meta_a = ModelMeta::new(g.ascii_string(6));
        let meta_b = ModelMeta::new(g.ascii_string(6));
        let n = g.usize_in(1, 100);
        let ta = g.tokens(n, 512);
        let mut tb = ta.clone();
        if g.bool() && n > 0 {
            let i = g.usize_in(0, n - 1);
            tb[i] = tb[i].wrapping_add(1) % 512;
        }
        let ka = range_key(&meta_a, &ta);
        let kb = range_key(&meta_a, &tb);
        assert_eq!(ta == tb, ka == kb, "token equality must match key equality");
        if meta_a != meta_b {
            assert_ne!(
                range_key(&meta_a, &ta),
                range_key(&meta_b, &ta),
                "distinct metadata must partition the keyspace"
            );
        }
    });
}

/// KV-state blobs: serialize∘restore is the identity on the valid prefix
/// for arbitrary geometry, token counts and compression.
#[test]
fn prop_state_roundtrip_any_geometry() {
    run_prop_n("state-roundtrip-geometry", 80, |g: &mut Gen| {
        let l = g.usize_in(1, 6);
        let s = g.usize_in(2, 64);
        let kh = g.usize_in(1, 4);
        let d = 4 * g.usize_in(1, 8);
        let n = g.usize_in(0, s);
        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = n;
        for i in 0..st.k.len() {
            if g.rng.chance(0.25) {
                st.k[i] = (g.rng.f64() - 0.5) as f32;
                st.v[i] = (g.rng.f64() * 3.0) as f32;
            }
        }
        let comp = if g.bool() { Compression::Deflate } else { Compression::None };
        let ct = g.usize_in(1, s + 1);
        let blob = st.serialize_prefix_opts(n, "h", comp, ct);
        let back = KvState::restore(&blob, "h", (l, s, kh, d)).unwrap();
        // rows beyond n_tokens are not shipped: compare the valid prefix
        let row = kh * d;
        let le = s * row;
        for li in 0..l {
            let a = &st.k[li * le..li * le + n * row];
            let b = &back.k[li * le..li * le + n * row];
            assert_eq!(a, b, "layer {li} K prefix");
        }
        assert_eq!(back.n_tokens, n);
    });
}

/// Range transfer (ECS3): a prefix assembled from whole-chunk `GETRANGE`
/// windows of a long blob restores to exactly the same state as the full
/// blob deserialized and truncated at that prefix — for arbitrary token
/// counts, chunk sizes (including the degenerate per-token `ct = 1` and
/// larger-than-blob sizes), prefix lengths (including exact chunk
/// boundaries) and both compressions.  This is the invariant the
/// alias/partial-download path rides on.
#[test]
fn prop_range_assembly_matches_full_blob_truncation() {
    run_prop_n("range-assembly-prefix", 60, |g: &mut Gen| {
        let l = g.usize_in(1, 4);
        let s = g.usize_in(2, 32);
        let kh = g.usize_in(1, 3);
        let d = 4 * g.usize_in(1, 4);
        let n = g.usize_in(1, s);
        let ct = if g.bool() { 1 } else { g.usize_in(1, n + 2) };
        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = n;
        for i in 0..st.k.len() {
            if g.rng.chance(0.3) {
                st.k[i] = (g.rng.f64() - 0.5) as f32;
                st.v[i] = (g.rng.f64() * 2.0) as f32;
            }
        }
        let hash = "ph";
        let comp = if g.bool() { Compression::Deflate } else { Compression::None };
        let blob = st.serialize_prefix_opts(n, hash, comp, ct);
        let lo = BlobLayout::new(hash, l, kh, d).with_chunk_tokens(ct);
        if comp == Compression::None {
            assert_eq!(blob.len(), lo.blob_len(n), "layout arithmetic matches bytes");
        }
        // prefix length: half the time exactly on a chunk boundary
        let m = if g.bool() && n >= ct {
            (g.usize_in(1, n / ct) * ct).min(n)
        } else {
            g.usize_in(1, n)
        };

        // the byte windows the client would GETRANGE: the whole head
        // (header + chunk index) and the whole chunks covering [0, m)
        let (ct2, entries) = read_chunk_index(&blob).expect("well-formed v3 head");
        assert_eq!(ct2, ct);
        let k = lo.prefix_chunks(m);
        let span: usize = entries.iter().take(k).map(|e| e.len as usize).sum();
        let head = &blob[..lo.payload_off(n)];
        let rows = &blob[lo.payload_off(n)..lo.payload_off(n) + span];

        let assembled =
            KvState::restore_prefix_from_parts(head, rows, m, hash, (l, s, kh, d)).unwrap();
        // the spec: full-blob deserialize, then truncate to m rows
        let full = KvState::restore(&blob, hash, (l, s, kh, d)).unwrap();
        assert_eq!(assembled.n_tokens, m, "l={l} s={s} kh={kh} d={d} n={n} m={m} ct={ct}");
        let row = kh * d;
        let le = s * row;
        for li in 0..l {
            assert_eq!(
                &assembled.k[li * le..li * le + m * row],
                &full.k[li * le..li * le + m * row],
                "layer {li} K prefix (n={n} m={m} ct={ct} comp={comp:?})"
            );
            assert_eq!(
                &assembled.v[li * le..li * le + m * row],
                &full.v[li * le..li * le + m * row],
                "layer {li} V prefix"
            );
            // rows past m stay zero: the over-fetched tail of the last
            // chunk must not leak into the restored state
            for e in m * row..le {
                assert_eq!(assembled.k[li * le + e], 0.0, "layer {li} leaked past m");
            }
        }

        // token-major property (uncompressed bodies are raw rows): the
        // short blob's payload is byte-identical to the long blob's prefix
        if comp == Compression::None {
            let short = st.serialize_prefix_opts(m, hash, Compression::None, ct);
            let stride = lo.token_stride();
            assert_eq!(
                &short[lo.payload_off(m)..],
                &blob[lo.payload_off(n)..lo.payload_off(n) + m * stride]
            );
        }
    });
}

/// State blobs: any single bit flip in the body is detected.
#[test]
fn prop_state_bitflip_detected() {
    run_prop_n("state-bitflip-detected", 60, |g: &mut Gen| {
        let mut st = KvState::zeroed(2, 8, 1, 4);
        st.n_tokens = g.usize_in(1, 8);
        for x in st.k.iter_mut() {
            *x = g.rng.f64() as f32;
        }
        let mut blob = st.serialize("h", Compression::None);
        // v3 fixed-header bound for a 1-byte hash: anything at or past the
        // chunk index must be caught by the index crc, the body length
        // prefix, or a per-chunk crc
        let hdr = 4 + 4 + 1 + 5 * 4 + 1 + 4 + 4;
        if blob.len() <= hdr {
            return;
        }
        let idx = g.usize_in(hdr, blob.len() - 1);
        let bit = 1u8 << g.usize_in(0, 7);
        blob[idx] ^= bit;
        let r = KvState::restore(&blob, "h", (2, 8, 1, 4));
        assert!(r.is_err(), "bit flip at {idx} went undetected");
    });
}

/// RESP: encode∘decode identity for arbitrary nested values, under arbitrary
/// buffer fragmentation.
#[test]
fn prop_resp_roundtrip_fragmented() {
    fn arb_value(g: &mut Gen, depth: usize) -> Value {
        match g.usize_in(0, if depth == 0 { 4 } else { 5 }) {
            0 => {
                let n = g.usize_in(0, 20);
                Value::Simple(g.ascii_string(n))
            }
            1 => Value::Int(g.rng.next_u64() as i64),
            2 => {
                let n = g.usize_in(0, 200);
                Value::bulk(g.bytes(n))
            }
            3 => Value::Nil,
            4 => Value::Error(format!("ERR {}", g.ascii_string(5))),
            _ => {
                let n = g.usize_in(0, 4);
                Value::Array((0..n).map(|_| arb_value(g, depth - 1)).collect())
            }
        }
    }
    run_prop_n("resp-roundtrip-fragmented", 200, |g: &mut Gen| {
        let v = arb_value(g, 2);
        let enc = v.encode();
        let mut dec = Decoder::new();
        let mut pos = 0;
        let mut out = None;
        while pos < enc.len() {
            let step = g.usize_in(1, (enc.len() - pos).min(17));
            dec.feed(&enc[pos..pos + step]);
            pos += step;
            if let Some(got) = dec.next_value().unwrap() {
                out = Some(got);
                assert_eq!(pos, enc.len(), "value complete only at the end");
            }
        }
        assert_eq!(out.expect("decoded"), v);
    });
}

/// Device/link models: time is monotone in work, and the break-even
/// relation is consistent (fetch wins exactly when transfer < prefill).
#[test]
fn prop_models_monotone_and_consistent() {
    run_prop_n("models-monotone", 150, |g: &mut Gen| {
        let dev = if g.bool() { DeviceProfile::pi_zero_2w() } else { DeviceProfile::pi5_4gb() };
        let link = if g.bool() { LinkModel::wifi4_2g4() } else { LinkModel::ethernet_1g() };
        let a = g.usize_in(0, 2000);
        let b = g.usize_in(0, 2000);
        let (lo, hi) = (a.min(b), a.max(b));
        assert!(dev.prefill_time(lo) <= dev.prefill_time(hi));
        assert!(dev.decode_time(lo) <= dev.decode_time(hi));
        assert!(link.delay_for(lo, None) <= link.delay_for(hi, None));

        let bytes = g.usize_in(0, 20_000_000);
        let toks = g.usize_in(1, 2000);
        let fetch_wins = link.delay_for(bytes, None) < dev.prefill_time(toks);
        let policy = edgecache::coordinator::FetchPolicy::BreakEven;
        assert_eq!(policy.should_fetch(&dev, &link, toks, bytes), fetch_wins);
    });
}

/// Bloom under union: merging two filters never loses members.
#[test]
fn prop_bloom_merge_preserves_members() {
    run_prop_n("bloom-merge-members", 60, |g: &mut Gen| {
        let mut a = edgecache::bloom::BloomFilter::new(10_000, 0.01);
        let mut b = edgecache::bloom::BloomFilter::new(10_000, 0.01);
        let na = g.usize_in(0, 200);
        let nb = g.usize_in(0, 200);
        let keys_a: Vec<Vec<u8>> = (0..na)
            .map(|_| {
                let n = g.usize_in(1, 32);
                g.bytes(n)
            })
            .collect();
        let keys_b: Vec<Vec<u8>> = (0..nb)
            .map(|_| {
                let n = g.usize_in(1, 32);
                g.bytes(n)
            })
            .collect();
        for k in &keys_a {
            a.insert(k);
        }
        for k in &keys_b {
            b.insert(k);
        }
        a.merge(&b).unwrap();
        for k in keys_a.iter().chain(&keys_b) {
            assert!(a.contains(k));
        }
    });
}
