//! Integration: the cache-box substrate under realistic multi-client load.

use std::sync::Arc;
use std::thread;

use edgecache::kvstore::{KvClient, KvServer};
use edgecache::util::bytes::SharedBytes;

fn spawn_server(max_bytes: usize) -> edgecache::kvstore::ServerHandle {
    KvServer::new(max_bytes).serve("127.0.0.1:0").unwrap()
}

#[test]
fn alias_chunk_size_keeps_getranges_chunk_aligned() {
    // Regression for the chunk-boundary-aware alias record: the alias
    // carries the target's chunk size, so a reader that only ever saw the
    // alias computes byte windows that land exactly on whole chunks of the
    // deflated entry — never a mid-chunk GETRANGE that per-chunk crcs and
    // deflate streams could not verify or decode.
    use edgecache::model::state::{
        decode_range_alias, encode_range_alias, read_chunk_index, BlobLayout, Compression,
        KvState,
    };
    let h = spawn_server(usize::MAX);
    let mut c = KvClient::connect(&h.addr_string()).unwrap();

    let mut st = KvState::zeroed(2, 32, 1, 8);
    st.n_tokens = 20;
    for (i, x) in st.k.iter_mut().enumerate() {
        *x = (i % 17) as f32;
    }
    let ct = 4;
    let blob = st.serialize_prefix_opts(20, "h", Compression::Deflate, ct);
    c.set(b"state:long", &blob).unwrap();
    let alias = encode_range_alias(b"state:long", 20, true, ct);
    c.set(b"state:short", &alias).unwrap();

    let a = decode_range_alias(&c.get(b"state:short").unwrap().unwrap()).unwrap();
    assert_eq!(a.chunk_tokens, Some(ct), "alias must carry the chunk size");
    assert!(a.compressed);
    let lo = BlobLayout::new("h", 2, 1, 8).with_chunk_tokens(a.chunk_tokens.unwrap());
    let head_len = lo.payload_off(a.total_rows);
    let head = c.getrange(&a.target_key, 0, head_len).unwrap().unwrap();
    let (ct2, entries) = read_chunk_index(&head).unwrap();
    assert_eq!(ct2, ct);

    // a 10-row prefix rounds up to whole chunks (12 rows), never mid-chunk
    let m = 10;
    assert_eq!(lo.prefix_rows(m, a.total_rows), 12);
    assert_eq!(lo.prefix_rows(m, a.total_rows) % ct, 0);
    let span: usize = entries
        .iter()
        .take(lo.prefix_chunks(m))
        .map(|e| e.len as usize)
        .sum();
    let rows = c.getrange(&a.target_key, head_len, span).unwrap().unwrap();
    let part =
        KvState::restore_prefix_from_parts(&head, &rows, m, "h", (2, 32, 1, 8)).unwrap();
    assert_eq!(part.n_tokens, m);
    for i in 0..m * 8 {
        assert_eq!(part.k[i], st.k[i], "restored prefix row bytes");
    }
}

#[test]
fn concurrent_clients_share_one_keyspace() {
    let h = spawn_server(usize::MAX);
    let addr = h.addr_string();
    let n_threads = 8;
    let per_thread = 50;

    let handles: Vec<_> = (0..n_threads)
        .map(|t| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut c = KvClient::connect(&addr).unwrap();
                for i in 0..per_thread {
                    let key = format!("t{t}:k{i}");
                    let val = format!("value-{t}-{i}").repeat(50);
                    c.set(key.as_bytes(), val.as_bytes()).unwrap();
                    let got = c.get(key.as_bytes()).unwrap().unwrap();
                    assert_eq!(got, val.as_bytes());
                }
            })
        })
        .collect();
    for jh in handles {
        jh.join().unwrap();
    }

    let mut c = KvClient::connect(&addr).unwrap();
    assert_eq!(c.dbsize().unwrap(), n_threads * per_thread);
    // cross-thread visibility
    assert!(c.get(b"t0:k0").unwrap().is_some());
    assert!(c.get(b"t7:k49").unwrap().is_some());
    h.shutdown();
}

#[test]
fn pipelined_bulk_uploads_interleaved_with_reads() {
    let h = spawn_server(usize::MAX);
    let mut w = KvClient::connect(&h.addr_string()).unwrap();
    let mut r = KvClient::connect(&h.addr_string()).unwrap();

    let blob = vec![7u8; 300_000];
    let cmds: Vec<Vec<Vec<u8>>> = (0..16)
        .map(|i| vec![b"SET".to_vec(), format!("state:{i}").into_bytes(), blob.clone()])
        .collect();
    let writer = thread::spawn(move || {
        for _ in 0..5 {
            let replies = w.pipeline(&cmds).unwrap();
            assert_eq!(replies.len(), 16);
        }
    });
    // reader polls while the writer hammers
    for _ in 0..50 {
        let _ = r.dbsize().unwrap();
        let _ = r.get(b"state:3").unwrap();
    }
    writer.join().unwrap();
    assert_eq!(r.strlen(b"state:15").unwrap(), 300_000);
    h.shutdown();
}

#[test]
fn eviction_keeps_most_recent_states() {
    // budget for ~4 x 1MB entries; insert 10, touching even keys
    let h = spawn_server(4_200_000);
    let mut c = KvClient::connect(&h.addr_string()).unwrap();
    let blob = vec![1u8; 1_000_000];
    for i in 0..6 {
        c.set(format!("s{i}").as_bytes(), &blob).unwrap();
        // keep s0 hot
        let _ = c.get(b"s0").unwrap();
    }
    assert!(c.exists(b"s0").unwrap(), "hot key must survive eviction");
    let n = c.dbsize().unwrap();
    assert!(n <= 4, "budget enforced, have {n}");
    let info = c.info().unwrap();
    assert!(info.contains("evictions:"), "{info}");
    h.shutdown();
}

#[test]
fn catalog_registration_is_concurrent_safe() {
    let h = spawn_server(usize::MAX);
    let addr = h.addr_string();
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut c = KvClient::connect(&addr).unwrap();
                for i in 0..100 {
                    c.catalog_register(format!("t{t}:{i}").as_bytes()).unwrap();
                }
            })
        })
        .collect();
    for jh in handles {
        jh.join().unwrap();
    }
    let mut c = KvClient::connect(&addr).unwrap();
    assert_eq!(c.catalog_version().unwrap(), 400);
    let (v, keys) = c.catalog_delta(0).unwrap();
    assert_eq!(v, 400);
    assert_eq!(keys.len(), 400);
    // every registered key is present exactly once
    let set: std::collections::HashSet<_> = keys.iter().collect();
    assert_eq!(set.len(), 400);
    h.shutdown();
}

#[test]
fn getrange_windows_reassemble_the_entry() {
    let h = spawn_server(usize::MAX);
    let mut c = KvClient::connect(&h.addr_string()).unwrap();
    let blob: Vec<u8> = (0u32..250_000).map(|i| (i % 241) as u8).collect();
    c.set_shared(b"entry", SharedBytes::new(blob.clone())).unwrap();

    // fetch in uneven windows and reassemble byte-perfectly
    let mut rebuilt = Vec::new();
    let mut at = 0usize;
    for win in [1usize, 17, 4096, 100_000, 400_000] {
        let part = c.getrange(b"entry", at, win).unwrap().unwrap();
        rebuilt.extend_from_slice(&part);
        at += part.len();
        if part.len() < win {
            break; // clamped at the end of the value
        }
    }
    assert_eq!(rebuilt, blob);
    assert_eq!(c.getrange(b"entry", blob.len() + 10, 4).unwrap().unwrap().len(), 0);
    assert_eq!(c.getrange(b"missing", 0, 4).unwrap(), None);
    h.shutdown();
}

#[test]
fn splice_accounting_stays_exact_under_eviction() {
    // delta uploads (SPLICE-assembled entries) must respect the byte budget
    // with exact entry_cost accounting, and evict LRU like any SET
    let server = KvServer::new(10_000);
    let h = server.serve("127.0.0.1:0").unwrap();
    let mut c = KvClient::connect(&h.addr_string()).unwrap();

    let base = vec![0xABu8; 3000];
    c.set_shared(b"base", SharedBytes::new(base)).unwrap();
    // each spliced entry: 100-byte head + 2000 base bytes + 100-byte tail
    for i in 0..5 {
        let n = c
            .splice(
                format!("d{i}").as_bytes(),
                b"base",
                500,
                2500,
                SharedBytes::new(vec![b'h'; 100]),
                SharedBytes::new(vec![b't'; 100]),
            )
            .unwrap();
        assert_eq!(n, 2200);
    }
    // ground truth: used_bytes equals the sum of key + value lengths
    {
        let store = server.store.lock().unwrap();
        let truth: usize = store
            .keys()
            .map(|k| k.len() + store.strlen(k).unwrap())
            .sum();
        assert_eq!(truth, store.used_bytes(), "entry_cost must stay exact");
        assert!(store.used_bytes() <= 10_000, "budget holds after splices");
        assert!(store.evictions > 0, "5 x 2.2KB entries + base exceed 10KB");
    }
    // a splice result is a first-class entry: readable and evictable
    let alive: Vec<String> = (0..5)
        .filter(|i| {
            server
                .store
                .lock()
                .unwrap()
                .contains(format!("d{i}").as_bytes())
        })
        .map(|i| format!("d{i}"))
        .collect();
    assert!(!alive.is_empty());
    let got = c.get(alive[0].as_bytes()).unwrap().unwrap();
    assert_eq!(got.len(), 2200);
    assert_eq!(&got[..100], &[b'h'; 100][..]);
    assert_eq!(&got[100..2100], &vec![0xABu8; 2000][..]);
    assert_eq!(&got[2100..], &[b't'; 100][..]);
    h.shutdown();
}

#[test]
fn concurrent_overlapping_load_no_torn_reads_and_honest_eviction() {
    // N threads hammer SET / GETRANGE / SPLICE on one small shared key set
    // under a tight memory budget.  Every SET stores a *uniform* value (one
    // repeated byte), so any torn range read — a reply mixing bytes of two
    // writes — is immediately visible; SPLICE results land in per-thread
    // keys so the shared keys stay uniform.  The test also pins liveness
    // (it finishes) and honest accounting under eviction pressure.
    // budget holds only ~3 of the 8 shared entries at once: constant churn
    let server = KvServer::new(6_000);
    let h = server.serve("127.0.0.1:0").unwrap();
    let addr = h.addr_string();
    let n_threads = 6usize;
    let ops = 80usize;
    let shared_keys = 8usize;

    let handles: Vec<_> = (0..n_threads)
        .map(|t| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut c = KvClient::connect(&addr).unwrap();
                let mut torn = 0usize;
                for i in 0..ops {
                    let key = format!("s{}", (t * 7 + i) % shared_keys);
                    let fill = ((t * 31 + i * 11) % 251) as u8 + 1;
                    let len = 500 + (i % 7) * 300;
                    c.set(key.as_bytes(), &vec![fill; len]).unwrap();
                    // overlapping range read on a (possibly re-written) key
                    let other = format!("s{}", (t * 7 + i + 3) % shared_keys);
                    if let Some(win) = c.getrange(other.as_bytes(), i % 400, 200).unwrap() {
                        if let Some(&b0) = win.first() {
                            if !win.iter().all(|&b| b == b0) {
                                torn += 1;
                            }
                        }
                    }
                    // suffix-delta shaped traffic: splice a base range into a
                    // per-thread destination (base may be evicted — an error
                    // reply is legal, a hang or a torn value is not)
                    if i % 5 == 0 {
                        let _ = c.splice(
                            format!("d{t}").as_bytes(),
                            other.as_bytes(),
                            100,
                            300,
                            SharedBytes::new(vec![b'h'; 40]),
                            SharedBytes::new(vec![b't'; 40]),
                        );
                    }
                }
                torn
            })
        })
        .collect();
    let torn: usize = handles.into_iter().map(|jh| jh.join().unwrap()).sum();
    assert_eq!(torn, 0, "range reads must never observe mixed writes");

    // honest accounting after the dust settles: byte ledger matches ground
    // truth, the budget held, and evictions were really counted
    {
        let store = server.store.lock().unwrap();
        let truth: usize = store
            .keys()
            .map(|k| k.len() + store.strlen(k).unwrap())
            .sum();
        assert_eq!(truth, store.used_bytes(), "used_bytes must stay exact");
        assert!(store.used_bytes() <= 6_000, "budget must hold");
        assert!(
            store.evictions > 0,
            "this workload oversubscribes the budget; evictions must be counted"
        );
    }
    h.shutdown();
}

#[test]
fn server_shutdown_is_clean_and_reconnect_fails() {
    let h = spawn_server(usize::MAX);
    let addr = h.addr_string();
    let mut c = KvClient::connect(&addr).unwrap();
    c.set(b"x", b"1").unwrap();
    h.shutdown();
    // subsequent connections must fail (no half-dead accept loop)
    std::thread::sleep(std::time::Duration::from_millis(50));
    let r = KvClient::connect_timeout(&addr, std::time::Duration::from_millis(300));
    if let Ok(mut conn) = r {
        // OS may accept briefly; any command must fail
        assert!(conn.ping().is_err() || conn.set(b"y", b"2").is_err());
    }
}

#[test]
fn shared_server_arc_allows_in_process_introspection() {
    let server = KvServer::new(usize::MAX);
    let h = server.serve("127.0.0.1:0").unwrap();
    let mut c = KvClient::connect(&h.addr_string()).unwrap();
    c.set(b"probe", b"data").unwrap();
    // the embedding process can inspect the store without a round trip
    {
        let store = server.store.lock().unwrap();
        assert!(store.contains(b"probe"));
    }
    let arc = Arc::clone(&server);
    assert_eq!(arc.catalog.lock().unwrap().version(), 0);
    h.shutdown();
}
