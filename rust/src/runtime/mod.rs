//! PJRT runtime — loads the AOT artifacts (`make artifacts`) and executes
//! them on the request path.  This is the only module that touches the `xla`
//! crate; everything above it deals in plain `Vec<f32>`/`Vec<i32>`.
//!
//! Wiring (see /opt/xla-example/load_hlo and aot_recipe): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute_b`.  Parameters are uploaded to the
//! device once at load time and stay resident as [`xla::PjRtBuffer`]s; per
//! call we upload only the KV caches, tokens and scalars.  Outputs come back
//! as one tuple literal (the artifacts are lowered with `return_tuple=True`)
//! and are decomposed into (logits, kcache, vcache).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::log_info;
use crate::util::json::{parse_file, Json};

/// Mirror of the Python `ModelConfig` (from meta.json).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub prefill_chunks: Vec<usize>,
}

impl ModelConfig {
    fn from_json(j: &Json) -> Result<Self> {
        let get = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("meta.json config missing {k}"))
        };
        Ok(ModelConfig {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("config missing name"))?
                .to_string(),
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            n_kv_heads: get("n_kv_heads")?,
            head_dim: get("head_dim")?,
            d_ff: get("d_ff")?,
            max_seq: get("max_seq")?,
            prefill_chunks: j
                .get("prefill_chunks")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
        })
    }

    /// K+V f32 bytes one token contributes across all layers.
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.n_kv_heads * self.head_dim * 4
    }

    /// Elements in one KV cache tensor [L, S, Kh, D].
    pub fn kv_cache_elems(&self) -> usize {
        self.n_layers * self.max_seq * self.n_kv_heads * self.head_dim
    }
}

#[derive(Debug, Clone)]
struct ParamSpec {
    name: String,
    shape: Vec<usize>,
    offset_bytes: usize,
    size_bytes: usize,
}

/// One compiled entry point (decode or prefill_<C>).
pub struct Entry {
    pub name: String,
    /// 0 for decode, chunk length for prefill variants.
    pub chunk: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// A fully-loaded model: compiled executables + device-resident parameters.
pub struct LoadedModel {
    pub config: ModelConfig,
    pub model_hash: String,
    pub dir: PathBuf,
    client: xla::PjRtClient,
    params: Vec<xla::PjRtBuffer>,
    entries: HashMap<String, Entry>,
    /// Total parameter bytes resident on device (diagnostics).
    pub param_bytes: usize,
}

/// Execution result of one prefill/decode call.
pub struct StepOutput {
    /// Flat logits: `[vocab]` for decode, `[chunk * vocab]` for prefill.
    pub logits: Vec<f32>,
    pub kcache: Vec<f32>,
    pub vcache: Vec<f32>,
}

impl LoadedModel {
    /// Load `artifacts/<preset>` produced by `python -m compile.aot`.
    pub fn load(dir: &Path) -> Result<Self> {
        let t0 = std::time::Instant::now();
        let meta = parse_file(&dir.join("meta.json"))?;
        if meta.get("format_version").and_then(Json::as_i64) != Some(1) {
            bail!("unsupported artifact format_version in {}", dir.display());
        }
        let config = ModelConfig::from_json(meta.req("config").map_err(|e| anyhow!("{e}"))?)?;
        let model_hash = meta
            .get("model_hash")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("meta.json missing model_hash"))?
            .to_string();

        let client = xla::PjRtClient::cpu()?;

        // -- parameters: read params.bin, upload each tensor once ------------
        let mut specs = Vec::new();
        for p in meta
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("meta.json missing params"))?
        {
            specs.push(ParamSpec {
                name: p.req("name")?.as_str().unwrap_or_default().to_string(),
                shape: p
                    .req("shape")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect(),
                offset_bytes: p.req("offset_bytes")?.as_usize().unwrap_or(0),
                size_bytes: p.req("size_bytes")?.as_usize().unwrap_or(0),
            });
        }
        // manifest order must be sorted-name order (the jax flatten contract)
        for w in specs.windows(2) {
            if w[0].name >= w[1].name {
                bail!("params manifest not in sorted order: {} >= {}", w[0].name, w[1].name);
            }
        }
        let blob = std::fs::read(dir.join("params.bin"))
            .with_context(|| format!("reading {}/params.bin", dir.display()))?;
        let mut params = Vec::with_capacity(specs.len());
        let mut param_bytes = 0usize;
        for s in &specs {
            let end = s.offset_bytes + s.size_bytes;
            if end > blob.len() {
                bail!("params.bin truncated: {} needs {end} bytes, file has {}", s.name, blob.len());
            }
            let data = crate::util::bytes::bytes_to_f32(&blob[s.offset_bytes..end]);
            let expect: usize = s.shape.iter().product::<usize>().max(1);
            if data.len() != expect {
                bail!("param {} shape/size mismatch", s.name);
            }
            let buf = client
                .buffer_from_host_buffer::<f32>(&data, &s.shape, None)
                .map_err(|e| anyhow!("uploading {}: {e:?}", s.name))?;
            params.push(buf);
            param_bytes += s.size_bytes;
        }

        // -- entry points -----------------------------------------------------
        let mut entries = HashMap::new();
        for e in meta
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("meta.json missing entries"))?
        {
            let name = e.req("name")?.as_str().unwrap_or_default().to_string();
            let hlo_file = e.req("hlo")?.as_str().unwrap_or_default().to_string();
            let chunk = e.req("chunk")?.as_usize().unwrap_or(0);
            let path = dir.join(&hlo_file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            entries.insert(name.clone(), Entry { name, chunk, exe });
        }
        if !entries.contains_key("decode") {
            bail!("artifact dir {} lacks a decode entry", dir.display());
        }

        log_info!(
            "runtime",
            "loaded {} ({}): {} entries, {:.1} MB params, {:.2}s",
            config.name,
            model_hash,
            entries.len(),
            param_bytes as f64 / 1e6,
            t0.elapsed().as_secs_f64()
        );
        Ok(LoadedModel {
            config,
            model_hash,
            dir: dir.to_path_buf(),
            client,
            params,
            entries,
            param_bytes,
        })
    }

    /// Load a named preset from the repo artifacts dir.
    pub fn load_preset(preset: &str) -> Result<Self> {
        Self::load(&crate::artifacts_dir().join(preset))
    }

    /// Prefill chunk sizes available, ascending.
    pub fn chunks(&self) -> Vec<usize> {
        let mut c: Vec<usize> = self
            .entries
            .values()
            .filter(|e| e.chunk > 0)
            .map(|e| e.chunk)
            .collect();
        c.sort_unstable();
        c
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(|e| anyhow!("host->device f32: {e:?}"))
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(data, dims, None)
            .map_err(|e| anyhow!("host->device i32: {e:?}"))
    }

    fn run(
        &self,
        entry: &Entry,
        kcache: &[f32],
        vcache: &[f32],
        tail: Vec<xla::PjRtBuffer>,
    ) -> Result<StepOutput> {
        let cfg = &self.config;
        let kv_dims = [cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim];
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.params.len() + 5);
        for p in &self.params {
            args.push(p);
        }
        let kbuf = self.buf_f32(kcache, &kv_dims)?;
        let vbuf = self.buf_f32(vcache, &kv_dims)?;
        args.push(&kbuf);
        args.push(&vbuf);
        for t in &tail {
            args.push(t);
        }
        let outs = entry
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {}: {e:?}", entry.name))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let (logits_l, k_l, v_l) = lit
            .to_tuple3()
            .map_err(|e| anyhow!("untuple result: {e:?}"))?;
        Ok(StepOutput {
            logits: logits_l.to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?,
            kcache: k_l.to_vec::<f32>().map_err(|e| anyhow!("kcache: {e:?}"))?,
            vcache: v_l.to_vec::<f32>().map_err(|e| anyhow!("vcache: {e:?}"))?,
        })
    }

    /// Execute `prefill_<chunk>` — `tokens` must have length == chunk
    /// (pre-padded); `valid_len` marks the real token count.
    pub fn prefill(
        &self,
        chunk: usize,
        kcache: &[f32],
        vcache: &[f32],
        tokens: &[i32],
        pos: i32,
        valid_len: i32,
    ) -> Result<StepOutput> {
        let name = format!("prefill_{chunk}");
        let entry = self
            .entries
            .get(&name)
            .ok_or_else(|| anyhow!("no entry {name}; have {:?}", self.chunks()))?;
        if tokens.len() != chunk {
            bail!("prefill_{chunk} got {} tokens", tokens.len());
        }
        let tail = vec![
            self.buf_i32(tokens, &[chunk])?,
            self.buf_i32(&[pos], &[])?,
            self.buf_i32(&[valid_len], &[])?,
        ];
        self.run(entry, kcache, vcache, tail)
    }

    /// Execute the single-token decode step writing the updated KV caches
    /// directly into `kcache`/`vcache` (no per-step allocations — the decode
    /// loop is the latency-critical path; see EXPERIMENTS.md §Perf).
    pub fn decode_in_place(
        &self,
        kcache: &mut [f32],
        vcache: &mut [f32],
        token: i32,
        pos: i32,
    ) -> Result<Vec<f32>> {
        let cfg = &self.config;
        let kv_dims = [cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim];
        let entry = self.entries.get("decode").expect("checked at load");
        let kbuf = self.buf_f32(kcache, &kv_dims)?;
        let vbuf = self.buf_f32(vcache, &kv_dims)?;
        let tbuf = self.buf_i32(&[token], &[])?;
        let pbuf = self.buf_i32(&[pos], &[])?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.params.len() + 4);
        args.extend(self.params.iter());
        args.push(&kbuf);
        args.push(&vbuf);
        args.push(&tbuf);
        args.push(&pbuf);
        let outs = entry
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute decode: {e:?}"))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let (logits_l, k_l, v_l) = lit
            .to_tuple3()
            .map_err(|e| anyhow!("untuple result: {e:?}"))?;
        k_l.copy_raw_to(kcache).map_err(|e| anyhow!("kcache copy: {e:?}"))?;
        v_l.copy_raw_to(vcache).map_err(|e| anyhow!("vcache copy: {e:?}"))?;
        logits_l
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits: {e:?}"))
    }

    /// Execute the single-token decode step.
    pub fn decode(
        &self,
        kcache: &[f32],
        vcache: &[f32],
        token: i32,
        pos: i32,
    ) -> Result<StepOutput> {
        let entry = self.entries.get("decode").expect("checked at load");
        let tail = vec![self.buf_i32(&[token], &[])?, self.buf_i32(&[pos], &[])?];
        self.run(entry, kcache, vcache, tail)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dir() -> Option<PathBuf> {
        let d = crate::artifacts_dir().join("tiny");
        d.join("meta.json").exists().then_some(d)
    }

    #[test]
    fn load_tiny_and_inspect() {
        let Some(dir) = tiny_dir() else {
            eprintln!("skipping: artifacts/tiny missing (run `make artifacts`)");
            return;
        };
        let m = LoadedModel::load(&dir).unwrap();
        assert_eq!(m.config.name, "tiny");
        assert_eq!(m.config.vocab, 512);
        assert!(!m.chunks().is_empty());
        assert!(m.param_bytes > 0);
        assert_eq!(m.config.kv_bytes_per_token(), 2 * 2 * 2 * 16 * 4);
    }

    #[test]
    fn prefill_and_decode_shapes() {
        let Some(dir) = tiny_dir() else {
            return;
        };
        let m = LoadedModel::load(&dir).unwrap();
        let cfg = m.config.clone();
        let n = cfg.kv_cache_elems();
        let kc = vec![0f32; n];
        let vc = vec![0f32; n];
        let chunk = m.chunks()[0];
        let tokens: Vec<i32> = (0..chunk as i32).map(|i| i + 3).collect();
        let out = m.prefill(chunk, &kc, &vc, &tokens, 0, chunk as i32).unwrap();
        assert_eq!(out.logits.len(), chunk * cfg.vocab);
        assert_eq!(out.kcache.len(), n);
        assert!(out.logits.iter().all(|x| x.is_finite()));

        let out2 = m.decode(&out.kcache, &out.vcache, 7, chunk as i32).unwrap();
        assert_eq!(out2.logits.len(), cfg.vocab);
        assert!(out2.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_deterministic() {
        let Some(dir) = tiny_dir() else {
            return;
        };
        let m = LoadedModel::load(&dir).unwrap();
        let n = m.config.kv_cache_elems();
        let kc = vec![0f32; n];
        let vc = vec![0f32; n];
        let a = m.decode(&kc, &vc, 5, 0).unwrap();
        let b = m.decode(&kc, &vc, 5, 0).unwrap();
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn missing_dir_fails_cleanly() {
        let r = LoadedModel::load(Path::new("/nonexistent/artifact"));
        assert!(r.is_err());
    }
}

impl LoadedModel {
    /// Perf probe: per-component timing of one decode step (buffer upload /
    /// execute / tuple fetch / host conversion), in microseconds.
    pub fn decode_timing_probe(
        &self,
        kcache: &[f32],
        vcache: &[f32],
    ) -> Result<[u128; 4]> {
        use std::time::Instant;
        let cfg = &self.config;
        let kv_dims = [cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim];
        let entry = self.entries.get("decode").unwrap();

        let t0 = Instant::now();
        let kbuf = self.buf_f32(kcache, &kv_dims)?;
        let vbuf = self.buf_f32(vcache, &kv_dims)?;
        let tbuf = self.buf_i32(&[5], &[])?;
        let pbuf = self.buf_i32(&[10], &[])?;
        let t_upload = t0.elapsed().as_micros();

        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&kbuf);
        args.push(&vbuf);
        args.push(&tbuf);
        args.push(&pbuf);
        let t1 = Instant::now();
        let outs = entry.exe.execute_b(&args).map_err(|e| anyhow!("{e:?}"))?;
        let t_exec = t1.elapsed().as_micros();

        let t2 = Instant::now();
        let lit = outs[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
        let t_fetch = t2.elapsed().as_micros();

        let t3 = Instant::now();
        let (l, k, v) = lit.to_tuple3().map_err(|e| anyhow!("{e:?}"))?;
        let _ = std::hint::black_box((
            l.to_vec::<f32>().unwrap(),
            k.to_vec::<f32>().unwrap(),
            v.to_vec::<f32>().unwrap(),
        ));
        let t_conv = t3.elapsed().as_micros();
        Ok([t_upload, t_exec, t_fetch, t_conv])
    }
}
