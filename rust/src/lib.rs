//! # edgecache
//!
//! Distributed prompt caching for local LLMs on resource-constrained edge
//! devices — a full-system reproduction of Matsutani et al. (2026).
//!
//! The crate is the L3 (rust) layer of a three-layer rust + JAX + Pallas
//! stack: Python authors the model (L2) and kernels (L1) and AOT-lowers them
//! to HLO text once (`make artifacts`); this crate loads the artifacts via
//! the PJRT C API and owns everything on the request path:
//!
//! * [`runtime`] / [`model`] / [`engine`] — local LLM inference (prefill,
//!   decode, KV-state snapshot/restore — the `llama_state_get_data()` analog)
//! * [`kvstore`] — the Redis-analog cache box (RESP2 TCP server + client)
//! * [`bloom`] / [`catalog`] — the paper's Bloom-filter *catalog* with
//!   master/local delta synchronization
//! * [`coordinator`] — the paper's contribution: the steps 1–4 client flow,
//!   partial prompt matching, upload/retrieval policy
//! * [`netsim`] / [`devicemodel`] — calibrated Wi-Fi 4 link shaping and
//!   Raspberry-Pi device pacing so the paper's testbed numbers reproduce
//! * [`sketch`] — SimHash similarity sketches: the semantic tier that
//!   turns paraphrase misses into verified partial hits
//! * [`workload`] — MMLU-like multi-domain prompt generator
//! * [`metrics`] / [`report`] — the six-phase latency breakdown and the
//!   paper-table renderers
//!
//! See DESIGN.md for the per-experiment index and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod bloom;
pub mod catalog;
pub mod coordinator;
pub mod devicemodel;
pub mod engine;
pub mod kvstore;
pub mod metrics;
pub mod model;
pub mod netsim;
pub mod report;
pub mod runtime;
pub mod sketch;
pub mod tokenizer;
pub mod util;
pub mod workload;
pub mod xbench;

/// Returns the PJRT platform name — used as a wiring smoke test.
pub fn xla_smoke() -> anyhow::Result<String> {
    let c = xla::PjRtClient::cpu()?;
    Ok(c.platform_name())
}

/// Repo-relative artifacts directory honouring `EDGECACHE_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    match std::env::var("EDGECACHE_ARTIFACTS") {
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    }
}
