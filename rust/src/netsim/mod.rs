//! Network link simulation — the 2.4 GHz Wi-Fi 4 substitute.
//!
//! The paper connects clients and the cache box over Wi-Fi 4; Redis access
//! time is dominated by `state_size / goodput + per-op overhead`.  We run
//! over loopback TCP, which is orders of magnitude faster, so the client
//! wraps every cache-box operation in a [`Shaper`]: it computes the delay the
//! modelled link *would* have imposed for the payload size, subtracts the
//! time the real transfer actually took, and sleeps the remainder.  Total
//! time is therefore `max(real, modelled)` — the simulation can never
//! under-report a slow real link.
//!
//! The `wifi4_2g4` preset is calibrated against paper Table 3: a 2.25 MB
//! state entry transfers in ≈0.86 s and a 9.94 MB entry in ≈2.9 s
//! (`tests::paper_calibration` pins both).
//!
//! **Deterministic fault injection**: a seeded, *op-indexed* [`FaultPlan`]
//! can be attached to any [`Shaper`] ([`Shaper::attach_faults`]) to
//! reproduce link churn byte-for-byte — a stall window, a goodput
//! degradation or a blackhole hits exactly the Nth…Mth shaped operations,
//! never "whatever happened to run at second 3", so churn benches and
//! tests replay identically on any machine.

use std::time::{Duration, Instant};

use crate::util::rng::Rng;

/// Modelled delay a blackholed op is stretched by on a shaper.  A shaper
/// wraps *completed* real transfers, so it cannot actually lose a reply —
/// harnesses that consult a [`FaultPlan`] directly (process-level churn)
/// implement true loss by killing the box; on a shaper a blackhole
/// degrades to this bounded worst-case stall, long past any sane
/// [`crate::coordinator::membership::DeadlineBudget`].
pub const BLACKHOLE_STALL: Duration = Duration::from_secs(5);

/// One fault kind a [`FaultWindow`] injects.
///
/// The first three stretch an op's *modelled time*; the byte-granular
/// trio (`TruncateAt` / `CorruptByteAt` / `ResetAfter`) instead mutates an
/// op's *payload bytes* — injected partial writes that drive the ECS3
/// chunk-crc verification, the `StateAssembler` mid-stream corruption path
/// and the rescue ladder, not just timeouts and deaths.  Byte faults are
/// timing-neutral ([`Fault::stretch`] passes the base delay through) and
/// fire through [`StreamSession::take_byte_fault`] +
/// [`apply_byte_fault`] on streamed chunk paths; ops that never stream
/// payload bytes pass through them unaffected.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// A hung-but-alive peer: every op in the window takes this much
    /// extra modelled time before its reply lands.
    Stall(Duration),
    /// Reply never arrives (see [`BLACKHOLE_STALL`] for the shaper
    /// interpretation; harnesses kill the box instead).
    Blackhole,
    /// Goodput degradation: modelled delays are multiplied by this
    /// factor (values below 1.0 are clamped up — a fault never speeds a
    /// link up).
    Degrade(f64),
    /// Partial write: the op's payload stream is cut at byte `n` — the
    /// reply arrives short, and the chunk crc must reject it.
    TruncateAt(usize),
    /// Bit-rot: the payload byte at stream offset `n` is XOR-flipped —
    /// the reply arrives with the right length and a wrong crc.
    CorruptByteAt(usize),
    /// Partial write then a torn connection: the stream is cut at byte
    /// `n` and the socket reports `ConnectionReset` — the fabric
    /// classifies it `IoDead`, the rescue ladder takes over.
    ResetAfter(usize),
}

impl Fault {
    /// The modelled-delay transform this fault applies to one op.  Byte
    /// faults are timing-neutral: they damage payloads, not clocks, so
    /// every calibration bound holds with a byte schedule attached.
    pub fn stretch(self, base: Duration) -> Duration {
        match self {
            Fault::Stall(d) => base + d,
            Fault::Blackhole => base + BLACKHOLE_STALL,
            Fault::Degrade(x) => base.mul_f64(x.max(1.0)),
            Fault::TruncateAt(_) | Fault::CorruptByteAt(_) | Fault::ResetAfter(_) => base,
        }
    }

    /// The stream offset a byte-granular fault acts at; `None` for the
    /// timing faults.
    pub fn byte_offset(self) -> Option<usize> {
        match self {
            Fault::TruncateAt(n) | Fault::CorruptByteAt(n) | Fault::ResetAfter(n) => {
                Some(n)
            }
            _ => None,
        }
    }

    /// Rebase a byte fault's stream offset (see
    /// [`StreamSession::take_byte_fault`]).
    fn with_byte_offset(self, n: usize) -> Fault {
        match self {
            Fault::TruncateAt(_) => Fault::TruncateAt(n),
            Fault::CorruptByteAt(_) => Fault::CorruptByteAt(n),
            Fault::ResetAfter(_) => Fault::ResetAfter(n),
            other => other,
        }
    }
}

/// Apply a byte-granular fault to one reply's payload buffer, offset
/// already rebased to within the buffer.  Truncation and corruption mutate
/// in place (the ECS3 chunk crc rejects the result downstream — a damaged
/// chunk must *never* commit a row); `ResetAfter` truncates and then
/// reports the torn socket as a `ConnectionReset` io error so the caller's
/// error classification sees exactly what a real mid-write reset produces.
/// Timing faults are a no-op here.
pub fn apply_byte_fault(fault: Fault, bytes: &mut Vec<u8>) -> std::io::Result<()> {
    match fault {
        Fault::TruncateAt(n) => {
            bytes.truncate(n);
            Ok(())
        }
        Fault::CorruptByteAt(n) => {
            if !bytes.is_empty() {
                let i = n.min(bytes.len() - 1);
                bytes[i] ^= 0xA5;
            }
            Ok(())
        }
        Fault::ResetAfter(n) => {
            bytes.truncate(n);
            Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "injected mid-stream reset",
            ))
        }
        _ => Ok(()),
    }
}

/// A half-open op-index window `[from_op, to_op)` during which `fault`
/// applies.
#[derive(Debug, Clone, Copy)]
pub struct FaultWindow {
    pub from_op: u64,
    pub to_op: u64,
    pub fault: Fault,
}

/// A deterministic churn script: which shaped operations are faulted and
/// how.  Indexed by the shaper's own op counter — wall-clock-free, so the
/// same plan against the same workload reproduces the same byte-for-byte
/// behaviour regardless of host speed.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    windows: Vec<FaultWindow>,
    /// Ops drawn so far (advances once per shaped op when attached).
    op: u64,
}

impl FaultPlan {
    pub fn new(mut windows: Vec<FaultWindow>) -> Self {
        windows.sort_by_key(|w| w.from_op);
        FaultPlan { windows, op: 0 }
    }

    /// A seeded flap schedule: `flaps` disjoint fault windows scattered
    /// over the first `ops` operations, one per equal slot so they never
    /// overlap.  Same seed → same schedule, on every machine.
    pub fn flap_schedule(seed: u64, ops: u64, flaps: usize, fault: Fault) -> Self {
        let mut rng = Rng::new(seed);
        let mut windows = Vec::with_capacity(flaps);
        let slot = if flaps == 0 { 0 } else { ops / flaps as u64 };
        if slot >= 2 {
            for i in 0..flaps as u64 {
                let lo = i * slot;
                let start = lo + rng.below(slot - 1);
                let len = 1 + rng.below(slot - (start - lo));
                windows.push(FaultWindow {
                    from_op: start,
                    to_op: start + len,
                    fault,
                });
            }
        }
        Self::new(windows)
    }

    /// A point schedule: each `(op, fault)` pair faults exactly that one
    /// op — the natural shape for byte-fault scripts ("truncate op 3's
    /// stream at byte 100, corrupt op 7's at byte 5").
    pub fn at_ops(points: &[(u64, Fault)]) -> Self {
        Self::new(
            points
                .iter()
                .map(|&(op, fault)| FaultWindow { from_op: op, to_op: op + 1, fault })
                .collect(),
        )
    }

    /// The fault (if any) covering op index `op` — pure lookup, no state.
    pub fn fault_at(&self, op: u64) -> Option<Fault> {
        self.windows
            .iter()
            .find(|w| w.from_op <= op && op < w.to_op)
            .map(|w| w.fault)
    }

    /// Draw the fault for the next shaped op and advance the counter.
    pub fn next_op(&mut self) -> Option<Fault> {
        let f = self.fault_at(self.op);
        self.op += 1;
        f
    }

    /// Ops drawn so far.
    pub fn op_index(&self) -> u64 {
        self.op
    }
}

/// A point-to-point link model: effective goodput + per-operation RTT, with
/// optional jitter.
#[derive(Debug, Clone)]
pub struct LinkModel {
    pub name: &'static str,
    /// Effective application-level goodput, bytes/second (already accounts
    /// for TCP/Wi-Fi framing overhead — it is *goodput*, not PHY rate).
    pub goodput_bps: f64,
    /// Round-trip time added per request/response exchange.
    pub rtt: Duration,
    /// Jitter as a fraction of the computed delay (uniform ±jitter/2).
    pub jitter_frac: f64,
}

impl LinkModel {
    /// 2.4 GHz Wi-Fi 4 between Raspberry Pis (paper testbed).  Calibrated
    /// directly from the paper's two Redis measurements — 2.25 MB in 0.862 s
    /// and 9.94 MB in 2.887 s (Table 3) — which solve to a steady goodput of
    /// 30.4 Mbit/s plus a fixed ~270 ms per-operation overhead (TCP
    /// slow-start + Wi-Fi contention + Redis/llama-state protocol cost).
    /// Both paper points reproduce to <2 %.
    pub fn wifi4_2g4() -> Self {
        LinkModel {
            name: "wifi4-2g4",
            goodput_bps: 30.4e6 / 8.0,
            rtt: Duration::from_millis(270),
            jitter_frac: 0.0,
        }
    }

    /// Same link with mild jitter for robustness experiments.
    pub fn wifi4_2g4_jittery() -> Self {
        LinkModel { jitter_frac: 0.2, ..Self::wifi4_2g4() }
    }

    /// Gigabit Ethernet (ablation: what if the cache box were wired?).
    pub fn ethernet_1g() -> Self {
        LinkModel {
            name: "ethernet-1g",
            goodput_bps: 940.0e6 / 8.0,
            rtt: Duration::from_micros(200),
            jitter_frac: 0.0,
        }
    }

    /// No shaping: report the raw loopback/host performance.
    pub fn loopback() -> Self {
        LinkModel {
            name: "loopback",
            goodput_bps: f64::INFINITY,
            rtt: Duration::ZERO,
            jitter_frac: 0.0,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "wifi4-2g4" | "wifi" => Some(Self::wifi4_2g4()),
            "wifi4-2g4-jitter" => Some(Self::wifi4_2g4_jittery()),
            "ethernet-1g" | "ethernet" => Some(Self::ethernet_1g()),
            "loopback" | "none" => Some(Self::loopback()),
            _ => None,
        }
    }

    /// Modelled one-way duration for moving `bytes` plus one RTT of
    /// request/response overhead.
    pub fn delay_for(&self, bytes: usize, rng: Option<&mut Rng>) -> Duration {
        if self.goodput_bps.is_infinite() && self.rtt.is_zero() {
            return Duration::ZERO;
        }
        let mut secs = self.rtt.as_secs_f64() + bytes as f64 / self.goodput_bps;
        if self.jitter_frac > 0.0 {
            if let Some(r) = rng {
                let j = (r.f64() - 0.5) * self.jitter_frac;
                secs *= 1.0 + j;
            }
        }
        Duration::from_secs_f64(secs.max(0.0))
    }
}

/// Applies a [`LinkModel`] around real transfers: `max(real, modelled)`.
/// Also the system's ledger of link traffic: every payload byte a client
/// moves over the modelled link lands in [`Shaper::moved_bytes`], which is
/// what makes range-aware transfers *measurably* cheaper — the partial
/// matching tests and Table-4 benches read this counter to show the
/// suffix-delta pipeline moving fewer bytes than full-blob transfers.
#[derive(Debug)]
pub struct Shaper {
    pub link: LinkModel,
    rng: Rng,
    /// Total time spent sleeping to honour the model (diagnostic).
    pub injected: Duration,
    /// Total payload bytes accounted against the link (both directions).
    pub moved_bytes: u64,
    /// Logical (uncompressed) state bytes the moved payloads represent —
    /// the second axis that keeps `moved_bytes`/`saved_bytes` honest under
    /// chunk compression: with deflate on, `moved_bytes` shrinks while this
    /// counter still reflects the KV rows actually transferred, so a
    /// "fewer wire bytes" claim can never hide "fewer rows moved".
    pub inflated_bytes: u64,
    /// Latency the streaming assembly path hid by decoding chunk `i` while
    /// chunk `i+1` was still on the modelled wire: store-and-forward time
    /// (wire + all decode, serial) minus the streamed elapsed time, summed
    /// over every [`Shaper::shaped_stream`] session.  Credited only from
    /// work that measurably happened between arrivals, so the ledger cannot
    /// claim overlap a serial pipeline would not actually have paid for.
    pub overlap_saved: Duration,
    /// Optional deterministic fault schedule ([`Shaper::attach_faults`]);
    /// advances one op per shaped call.
    faults: Option<FaultPlan>,
    /// Ops whose modelled delay a [`FaultPlan`] stretched (diagnostic).
    pub faulted_ops: u64,
}

impl Shaper {
    pub fn new(link: LinkModel, seed: u64) -> Self {
        Shaper {
            link,
            rng: Rng::new(seed),
            injected: Duration::ZERO,
            moved_bytes: 0,
            inflated_bytes: 0,
            overlap_saved: Duration::ZERO,
            faults: None,
            faulted_ops: 0,
        }
    }

    /// Attach a deterministic [`FaultPlan`]: from the next shaped op on,
    /// every op draws the plan's fault for its index and stretches its
    /// modelled delay accordingly.  Replaces any previous plan.
    pub fn attach_faults(&mut self, plan: FaultPlan) {
        self.faults = Some(plan);
    }

    /// Draw the next op's fault from the attached plan, if any.
    fn draw_fault(&mut self) -> Option<Fault> {
        let f = self.faults.as_mut().and_then(|p| p.next_op());
        if f.is_some() {
            self.faulted_ops += 1;
        }
        f
    }

    /// Apply `fault` to a modelled delay target.
    fn stretched(target: Duration, fault: Option<Fault>) -> Duration {
        match fault {
            Some(f) => f.stretch(target),
            None => target,
        }
    }

    /// Record the logical payload size behind a (possibly compressed)
    /// transfer already counted in [`Shaper::moved_bytes`].
    pub fn note_inflated(&mut self, bytes: usize) {
        self.inflated_bytes += bytes as u64;
    }

    /// Run `op` (a real network transfer moving `bytes`) and stretch its
    /// duration to at least the modelled link delay.
    pub fn shaped<T>(&mut self, bytes: usize, op: impl FnOnce() -> T) -> T {
        let fault = self.draw_fault();
        let target =
            Self::stretched(self.link.delay_for(bytes, Some(&mut self.rng)), fault);
        self.moved_bytes += bytes as u64;
        let t0 = Instant::now();
        let out = op();
        let real = t0.elapsed();
        if real < target {
            let pad = target - real;
            std::thread::sleep(pad);
            self.injected += pad;
        }
        out
    }

    /// Like [`Shaper::shaped`] for transfers whose size is only known after
    /// the fact (downloads): `op` returns `(value, bytes_moved)` and the
    /// stretch is computed from the actual byte count.
    pub fn shaped_post<T>(&mut self, op: impl FnOnce() -> (T, usize)) -> T {
        let fault = self.draw_fault();
        let t0 = Instant::now();
        let (out, bytes) = op();
        let real = t0.elapsed();
        self.moved_bytes += bytes as u64;
        let target =
            Self::stretched(self.link.delay_for(bytes, Some(&mut self.rng)), fault);
        if real < target {
            let pad = target - real;
            std::thread::sleep(pad);
            self.injected += pad;
        }
        out
    }

    /// Begin a shaped **streaming** download: one pipelined request batch is
    /// already on the wire and its replies arrive back-to-back.  Each
    /// [`StreamSession::arrived`] call models the next reply's payload
    /// landing `rtt + cum_bytes/goodput` after the session started and
    /// blocks only for the remainder, so whatever the caller does between
    /// arrivals (chunk crc + inflate + scatter) runs *during* the modelled
    /// flight time of later bytes.  [`StreamSession::finish`] credits the
    /// resulting overlap into [`Shaper::overlap_saved`].
    ///
    /// Per-session jitter is drawn once so arrival targets stay monotone in
    /// cumulative bytes (per-call jitter could model bytes arriving out of
    /// order, which TCP does not do).
    pub fn shaped_stream(&mut self) -> StreamSession<'_> {
        let fault = self.draw_fault();
        let jitter = if self.link.jitter_frac > 0.0 {
            1.0 + (self.rng.f64() - 0.5) * self.link.jitter_frac
        } else {
            1.0
        };
        let now = Instant::now();
        StreamSession {
            shaper: self,
            t0: now,
            last_return: now,
            jitter,
            fault,
            cum_bytes: 0,
            first: true,
            saved: Duration::ZERO,
        }
    }
}

/// One shaped streaming transfer — see [`Shaper::shaped_stream`].
#[derive(Debug)]
pub struct StreamSession<'a> {
    shaper: &'a mut Shaper,
    /// Session start (the pipelined request batch hitting the wire).
    t0: Instant,
    /// When the previous `arrived` returned control to the caller; the gap
    /// until the next call is caller CPU work (decode) that a
    /// store-and-forward pipeline would have paid *after* the last byte.
    last_return: Instant,
    jitter: f64,
    /// One fault per session (a pipelined batch is one op): every arrival
    /// target is stretched through it, so a stall delays the whole stream
    /// head-of-line and a degradation slows every chunk.
    fault: Option<Fault>,
    cum_bytes: usize,
    /// The work before the first arrival is request building + the raw
    /// socket read, not decode — it earns no overlap credit.
    first: bool,
    saved: Duration,
}

impl StreamSession<'_> {
    /// Modelled arrival time of the cumulative byte count, relative to `t0`:
    /// one RTT for the batch plus the serialization delay of every byte so
    /// far.
    fn target_for(&self, cum: usize) -> Duration {
        let l = &self.shaper.link;
        if l.goodput_bps.is_infinite() && l.rtt.is_zero() && self.fault.is_none() {
            return Duration::ZERO;
        }
        let secs = (l.rtt.as_secs_f64() + cum as f64 / l.goodput_bps) * self.jitter;
        let base = Duration::from_secs_f64(secs.max(0.0).min(1e6));
        Shaper::stretched(base, self.fault)
    }

    /// Payload bytes accounted so far in this session.
    pub fn bytes(&self) -> usize {
        self.cum_bytes
    }

    /// If this op carries a byte-granular fault that the next `len`-byte
    /// reply reaches, consume it and return it rebased to an offset within
    /// that reply (ready for [`apply_byte_fault`]).  One-shot per session:
    /// a byte fault damages exactly one reply of the faulted op.  Call
    /// *before* [`StreamSession::arrived`] for the same reply — arrival
    /// accounting advances the cumulative stream offset.
    pub fn take_byte_fault(&mut self, len: usize) -> Option<Fault> {
        let f = self.fault?;
        let off = f.byte_offset()?;
        if len == 0 || off >= self.cum_bytes + len {
            // the fault sits past this reply: leave it armed
            return None;
        }
        self.fault = None;
        Some(f.with_byte_offset(off.saturating_sub(self.cum_bytes).min(len - 1)))
    }

    /// The next `bytes` wire bytes have really been read; block until their
    /// modelled arrival time.
    ///
    /// Overlap is credited incrementally from modelled targets, not from
    /// total elapsed time: decode work the caller did in
    /// `[last_return, min(now, target)]` ran while this reply's bytes were
    /// still in modelled flight — exactly the latency a store-and-forward
    /// pipeline would have added after its last byte.  (Computing the credit
    /// per-interval keeps it immune to `thread::sleep` overshoot, which
    /// inflates elapsed time but not the modelled targets.)
    pub fn arrived(&mut self, bytes: usize) {
        let work_start = self.last_return.duration_since(self.t0);
        let now = self.t0.elapsed();
        self.cum_bytes += bytes;
        self.shaper.moved_bytes += bytes as u64;
        let target = self.target_for(self.cum_bytes);
        if !self.first {
            let hidden_until = now.min(target);
            if hidden_until > work_start {
                self.saved += hidden_until - work_start;
            }
        }
        self.first = false;
        if now < target {
            let pad = target - now;
            std::thread::sleep(pad);
            self.shaper.injected += pad;
        }
        self.last_return = Instant::now();
    }

    /// End the session and bank the credit into
    /// [`Shaper::overlap_saved`].  Work after the final arrival (the last
    /// chunk's decode) earns nothing — the wire is already idle.
    pub fn finish(self) -> Duration {
        let saved = self.saved;
        self.shaper.overlap_saved += saved;
        saved
    }
}

/// A byte-level TCP chaos proxy for the *real-socket* paths the modelled
/// [`Shaper`] cannot reach: `CatalogSync` heartbeats and gossip dial real
/// TCP, so simulating an **asymmetric partition** (one client ↔ one box
/// edge dark, every other path up) needs an actual wire to cut.  The proxy
/// listens on its own ephemeral port and pumps bytes to `upstream`; while
/// [partitioned](ChaosProxy::set_partitioned), established connections are
/// severed and new ones are accepted-then-dropped — the partitioned client
/// sees resets and refused syncs against this one box, while clients
/// dialing the box directly stay healthy.  That is exactly the scenario
/// incarnation refutation + indirect probes must survive with zero false
/// `Dead` verdicts.
pub struct ChaosProxy {
    addr: String,
    partitioned: std::sync::Arc<std::sync::atomic::AtomicBool>,
    stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ChaosProxy {
    /// Bind an ephemeral local port and start forwarding to `upstream`.
    pub fn start(upstream: &str) -> std::io::Result<ChaosProxy> {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?.to_string();
        let upstream = upstream.to_string();
        let partitioned = Arc::new(AtomicBool::new(false));
        let stop = Arc::new(AtomicBool::new(false));
        let (p, s) = (Arc::clone(&partitioned), Arc::clone(&stop));
        let handle = std::thread::spawn(move || {
            while !s.load(Ordering::Acquire) {
                match listener.accept() {
                    Ok((conn, _)) => {
                        if p.load(Ordering::Acquire) {
                            // refuse: accept-then-drop reads as a reset
                            drop(conn);
                            continue;
                        }
                        let Ok(up) = std::net::TcpStream::connect(&upstream) else {
                            drop(conn);
                            continue;
                        };
                        Self::pump_pair(conn, up, Arc::clone(&p), Arc::clone(&s));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(ChaosProxy { addr, partitioned, stop, handle: Some(handle) })
    }

    /// Spawn one relay thread per direction; each exits (dropping its
    /// sockets, which severs the connection) as soon as the partition flag
    /// rises or either side closes.
    fn pump_pair(
        client: std::net::TcpStream,
        upstream: std::net::TcpStream,
        partitioned: std::sync::Arc<std::sync::atomic::AtomicBool>,
        stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    ) {
        let (Ok(c2), Ok(u2)) = (client.try_clone(), upstream.try_clone()) else {
            return;
        };
        for (rd, wr) in [(client, u2), (upstream, c2)] {
            let (p, s) = (
                std::sync::Arc::clone(&partitioned),
                std::sync::Arc::clone(&stop),
            );
            std::thread::spawn(move || Self::pump(rd, wr, p, s));
        }
    }

    fn pump(
        mut rd: std::net::TcpStream,
        mut wr: std::net::TcpStream,
        partitioned: std::sync::Arc<std::sync::atomic::AtomicBool>,
        stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    ) {
        use std::io::{Read, Write};
        use std::sync::atomic::Ordering;
        let _ = rd.set_read_timeout(Some(Duration::from_millis(25)));
        let mut buf = [0u8; 16 * 1024];
        loop {
            if partitioned.load(Ordering::Acquire) || stop.load(Ordering::Acquire) {
                break;
            }
            match rd.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => {
                    if wr.write_all(&buf[..n]).is_err() {
                        break;
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue;
                }
                Err(_) => break,
            }
        }
        let _ = rd.shutdown(std::net::Shutdown::Both);
        let _ = wr.shutdown(std::net::Shutdown::Both);
    }

    /// The proxy's own dialable address — what the partitioned client's
    /// peer table points at instead of the box.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Raise or clear the partition.  Raising severs established proxied
    /// connections within one pump poll (≤ ~25 ms) and refuses new ones.
    pub fn set_partitioned(&self, on: bool) {
        self.partitioned.store(on, std::sync::atomic::Ordering::Release);
    }

    pub fn is_partitioned(&self) -> bool {
        self.partitioned.load(std::sync::atomic::Ordering::Acquire)
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_calibration() {
        // Table 3: 2.25 MB in ~0.86 s (low-end), 9.94 MB in ~2.89 s (high-end)
        let l = LinkModel::wifi4_2g4();
        let d270 = l.delay_for(2_250_000, None).as_secs_f64();
        let d1b = l.delay_for(9_940_000, None).as_secs_f64();
        assert!((0.78..0.95).contains(&d270), "2.25MB -> {d270:.3}s, want ~0.86");
        assert!((2.6..3.2).contains(&d1b), "9.94MB -> {d1b:.3}s, want ~2.89");
    }

    #[test]
    fn loopback_is_free() {
        let l = LinkModel::loopback();
        assert_eq!(l.delay_for(100 << 20, None), Duration::ZERO);
    }

    #[test]
    fn ethernet_much_faster_than_wifi() {
        let w = LinkModel::wifi4_2g4().delay_for(1 << 20, None);
        let e = LinkModel::ethernet_1g().delay_for(1 << 20, None);
        assert!(e < w / 10);
    }

    #[test]
    fn delay_monotone_in_bytes() {
        let l = LinkModel::wifi4_2g4();
        let mut prev = Duration::ZERO;
        for b in [0usize, 1000, 100_000, 1_000_000, 10_000_000] {
            let d = l.delay_for(b, None);
            assert!(d >= prev);
            prev = d;
        }
    }

    #[test]
    fn jitter_bounded_and_deterministic() {
        let l = LinkModel::wifi4_2g4_jittery();
        let base = LinkModel::wifi4_2g4().delay_for(1_000_000, None).as_secs_f64();
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        for _ in 0..100 {
            let d1 = l.delay_for(1_000_000, Some(&mut r1)).as_secs_f64();
            let d2 = l.delay_for(1_000_000, Some(&mut r2)).as_secs_f64();
            assert_eq!(d1, d2, "same seed same jitter");
            assert!((base * 0.89..base * 1.11).contains(&d1));
        }
    }

    #[test]
    fn shaper_enforces_minimum_duration() {
        let mut s = Shaper::new(
            LinkModel {
                name: "test",
                goodput_bps: 1e6, // 1 MB/s
                rtt: Duration::from_millis(10),
                jitter_frac: 0.0,
            },
            1,
        );
        let t0 = Instant::now();
        s.shaped(50_000, || ()); // model: 10ms + 50ms
        let el = t0.elapsed();
        assert!(el >= Duration::from_millis(55), "{el:?}");
        assert!(s.injected > Duration::ZERO);
        assert_eq!(s.moved_bytes, 50_000);
    }

    #[test]
    fn shaper_accounts_moved_bytes_both_ways() {
        let mut s = Shaper::new(LinkModel::loopback(), 1);
        s.shaped(1000, || ());
        s.shaped_post(|| ((), 234));
        assert_eq!(s.moved_bytes, 1234);
    }

    #[test]
    fn shaper_tracks_inflated_separately_from_wire() {
        let mut s = Shaper::new(LinkModel::loopback(), 1);
        // a compressed transfer: 300 wire bytes standing for 1000 logical
        s.shaped(300, || ());
        s.note_inflated(1000);
        s.note_inflated(24);
        assert_eq!(s.moved_bytes, 300);
        assert_eq!(s.inflated_bytes, 1024);
    }

    #[test]
    fn shaper_never_slows_already_slow_ops() {
        let mut s = Shaper::new(LinkModel::loopback(), 1);
        let t0 = Instant::now();
        s.shaped(1 << 20, || std::thread::sleep(Duration::from_millis(5)));
        assert!(t0.elapsed() < Duration::from_millis(50));
        assert_eq!(s.injected, Duration::ZERO);
    }

    fn test_link() -> LinkModel {
        LinkModel {
            name: "test",
            goodput_bps: 1e6, // 1 MB/s
            rtt: Duration::from_millis(10),
            jitter_frac: 0.0,
        }
    }

    #[test]
    fn stream_session_enforces_cumulative_arrival_times() {
        let mut s = Shaper::new(test_link(), 1);
        let t0 = Instant::now();
        let mut sess = s.shaped_stream();
        sess.arrived(50_000); // model: 10ms rtt + 50ms
        let mid = t0.elapsed();
        assert!(mid >= Duration::from_millis(55), "{mid:?}");
        sess.arrived(50_000); // cumulative 100KB -> 10ms + 100ms
        let done = t0.elapsed();
        assert!(done >= Duration::from_millis(105), "{done:?}");
        // no decode work between arrivals: nothing to credit
        let saved = sess.finish();
        assert!(saved < Duration::from_millis(5), "{saved:?}");
        assert_eq!(s.moved_bytes, 100_000);
    }

    #[test]
    fn stream_session_credits_overlapped_decode() {
        let mut s = Shaper::new(test_link(), 1);
        let t0 = Instant::now();
        let mut sess = s.shaped_stream();
        sess.arrived(50_000); // arrives at ~60ms
        // 20ms of "decode" fits inside the next chunk's 50ms flight time
        std::thread::sleep(Duration::from_millis(20));
        sess.arrived(50_000); // arrives at ~110ms regardless
        let elapsed = t0.elapsed();
        assert!(elapsed >= Duration::from_millis(105), "{elapsed:?}");
        assert!(elapsed < Duration::from_millis(160), "decode must be hidden");
        let saved = sess.finish();
        // serial = 110ms wire + 20ms decode; streamed ~110ms -> ~20ms saved
        assert!(saved >= Duration::from_millis(12), "{saved:?}");
        assert!(saved <= Duration::from_millis(30), "{saved:?}");
        assert_eq!(s.overlap_saved, saved);
    }

    #[test]
    fn stream_session_never_credits_when_decode_dominates() {
        let mut s = Shaper::new(test_link(), 1);
        let mut sess = s.shaped_stream();
        sess.arrived(1_000); // ~11ms
        std::thread::sleep(Duration::from_millis(40)); // decode >> wire
        sess.arrived(1_000); // target ~12ms already passed: no sleep
        let saved = sess.finish();
        // serial = 12ms + 40ms; elapsed ~51ms -> credit stays ~0, never the
        // full decode time
        assert!(saved < Duration::from_millis(15), "{saved:?}");
    }

    #[test]
    fn stream_session_on_loopback_is_free_and_creditless() {
        let mut s = Shaper::new(LinkModel::loopback(), 1);
        let t0 = Instant::now();
        let mut sess = s.shaped_stream();
        for _ in 0..10 {
            sess.arrived(1 << 20);
        }
        let saved = sess.finish();
        assert!(t0.elapsed() < Duration::from_millis(50));
        assert_eq!(saved, Duration::ZERO);
        assert_eq!(s.moved_bytes, 10 << 20);
        // an empty session credits nothing either
        let saved = s.shaped_stream().finish();
        assert_eq!(saved, Duration::ZERO);
        assert_eq!(s.overlap_saved, Duration::ZERO);
    }

    #[test]
    fn preset_lookup() {
        assert!(LinkModel::by_name("wifi").is_some());
        assert!(LinkModel::by_name("ethernet-1g").is_some());
        assert!(LinkModel::by_name("loopback").is_some());
        assert!(LinkModel::by_name("carrier-pigeon").is_none());
    }

    #[test]
    fn fault_plan_is_seed_deterministic() {
        for seed in [1u64, 7, 42, 1234] {
            let a = FaultPlan::flap_schedule(seed, 400, 5, Fault::Blackhole);
            let b = FaultPlan::flap_schedule(seed, 400, 5, Fault::Blackhole);
            for op in 0..400 {
                assert_eq!(a.fault_at(op), b.fault_at(op), "seed {seed} op {op}");
            }
        }
        // different seeds disagree somewhere (overwhelmingly likely)
        let a = FaultPlan::flap_schedule(1, 400, 5, Fault::Blackhole);
        let b = FaultPlan::flap_schedule(2, 400, 5, Fault::Blackhole);
        assert!((0..400).any(|op| a.fault_at(op) != b.fault_at(op)));
    }

    #[test]
    fn flap_schedule_windows_are_disjoint_and_bounded() {
        let plan = FaultPlan::flap_schedule(9, 100, 4, Fault::Stall(Duration::ZERO));
        let faulted: Vec<u64> = (0..200).filter(|&op| plan.fault_at(op).is_some()).collect();
        assert!(!faulted.is_empty(), "4 flaps over 100 ops must fault something");
        assert!(faulted.iter().all(|&op| op < 100), "windows stay inside [0, ops)");
        // one flap per 25-op slot: no slot holds two windows, so runs of
        // faulted ops never span a slot boundary's worth of ops
        for w in 0..4u64 {
            let in_slot = faulted.iter().filter(|&&op| op / 25 == w).count();
            assert!(in_slot <= 25);
        }
        // degenerate inputs produce an empty (never-faulting) plan
        assert!(FaultPlan::flap_schedule(9, 0, 4, Fault::Blackhole).fault_at(0).is_none());
        assert!(FaultPlan::flap_schedule(9, 100, 0, Fault::Blackhole).fault_at(0).is_none());
    }

    #[test]
    fn fault_stretch_transforms() {
        let base = Duration::from_millis(100);
        assert_eq!(
            Fault::Stall(Duration::from_millis(40)).stretch(base),
            Duration::from_millis(140)
        );
        assert_eq!(Fault::Degrade(3.0).stretch(base), Duration::from_millis(300));
        // a fault never speeds a link up
        assert_eq!(Fault::Degrade(0.1).stretch(base), base);
        assert_eq!(Fault::Blackhole.stretch(base), base + BLACKHOLE_STALL);
    }

    #[test]
    fn attached_stall_hits_exactly_its_window() {
        // window [1,2): op 0 and op 2 ride the plain link, op 1 stalls
        let mut s = Shaper::new(LinkModel::loopback(), 1);
        s.attach_faults(FaultPlan::new(vec![FaultWindow {
            from_op: 1,
            to_op: 2,
            fault: Fault::Stall(Duration::from_millis(40)),
        }]));
        let t0 = Instant::now();
        s.shaped(1000, || ());
        assert!(t0.elapsed() < Duration::from_millis(20), "op 0 unfaulted");
        let t1 = Instant::now();
        s.shaped(1000, || ());
        assert!(t1.elapsed() >= Duration::from_millis(40), "op 1 stalled");
        let t2 = Instant::now();
        s.shaped(1000, || ());
        assert!(t2.elapsed() < Duration::from_millis(20), "op 2 unfaulted");
        assert_eq!(s.faulted_ops, 1);
    }

    #[test]
    fn degraded_stream_slows_every_arrival() {
        // Degrade(4): the 1 MB/s test link serves 10 KB in ~10ms rtt +
        // 10ms wire; degraded that becomes ~80ms total
        let mut s = Shaper::new(test_link(), 1);
        s.attach_faults(FaultPlan::new(vec![FaultWindow {
            from_op: 0,
            to_op: u64::MAX,
            fault: Fault::Degrade(4.0),
        }]));
        let t0 = Instant::now();
        let mut sess = s.shaped_stream();
        sess.arrived(10_000);
        sess.finish();
        let el = t0.elapsed();
        assert!(el >= Duration::from_millis(75), "degraded arrival: {el:?}");
        assert_eq!(s.faulted_ops, 1, "one stream session is one op");
    }

    #[test]
    fn faultless_shaper_behaviour_is_unchanged() {
        // calibration safety: attaching no plan leaves delays identical
        let mut a = Shaper::new(test_link(), 3);
        let mut b = Shaper::new(test_link(), 3);
        b.attach_faults(FaultPlan::new(Vec::new()));
        let ta = Instant::now();
        a.shaped(20_000, || ());
        let da = ta.elapsed();
        let tb = Instant::now();
        b.shaped(20_000, || ());
        let db = tb.elapsed();
        let diff = if da > db { da - db } else { db - da };
        assert!(diff < Duration::from_millis(15), "{da:?} vs {db:?}");
        assert_eq!(b.faulted_ops, 0);
    }

    #[test]
    fn byte_faults_are_timing_neutral() {
        // stretch() passes the base delay through: a byte schedule can
        // never break a calibration bound
        let base = Duration::from_millis(123);
        assert_eq!(Fault::TruncateAt(10).stretch(base), base);
        assert_eq!(Fault::CorruptByteAt(0).stretch(base), base);
        assert_eq!(Fault::ResetAfter(99).stretch(base), base);
        assert_eq!(Fault::Stall(base).stretch(base), base + base);
    }

    #[test]
    fn apply_byte_fault_damages_exactly_as_scripted() {
        let mut b = vec![1u8, 2, 3, 4, 5];
        apply_byte_fault(Fault::TruncateAt(2), &mut b).unwrap();
        assert_eq!(b, vec![1, 2]);

        let mut b = vec![1u8, 2, 3, 4, 5];
        apply_byte_fault(Fault::CorruptByteAt(3), &mut b).unwrap();
        assert_eq!(b, vec![1, 2, 3, 4 ^ 0xA5, 5], "one byte flipped, length kept");

        let mut b = vec![1u8, 2, 3, 4, 5];
        let err = apply_byte_fault(Fault::ResetAfter(1), &mut b).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::ConnectionReset);
        assert_eq!(b, vec![1], "reset still delivers the bytes before the tear");

        // timing faults are a payload no-op
        let mut b = vec![9u8; 4];
        apply_byte_fault(Fault::Blackhole, &mut b).unwrap();
        assert_eq!(b, vec![9u8; 4]);
    }

    #[test]
    fn stream_session_fires_byte_fault_on_the_covering_reply() {
        let mut s = Shaper::new(LinkModel::loopback(), 1);
        s.attach_faults(FaultPlan::at_ops(&[(0, Fault::CorruptByteAt(150))]));
        let mut sess = s.shaped_stream();
        // reply 0 covers [0, 100): fault at 150 stays armed
        assert_eq!(sess.take_byte_fault(100), None);
        sess.arrived(100);
        // reply 1 covers [100, 200): fires, rebased to offset 50
        assert_eq!(sess.take_byte_fault(100), Some(Fault::CorruptByteAt(50)));
        sess.arrived(100);
        // one-shot: later replies are clean
        assert_eq!(sess.take_byte_fault(100), None);
        sess.finish();

        // an unfaulted op draws nothing
        let mut sess = s.shaped_stream();
        assert_eq!(sess.take_byte_fault(100), None);
        sess.finish();
    }

    #[test]
    fn chaos_proxy_partitions_one_edge() {
        use std::io::{Read, Write};
        // a tiny echo upstream
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let upstream = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            for conn in listener.incoming().flatten() {
                std::thread::spawn(move || {
                    let mut conn = conn;
                    let mut buf = [0u8; 256];
                    while let Ok(n) = conn.read(&mut buf) {
                        if n == 0 || conn.write_all(&buf[..n]).is_err() {
                            break;
                        }
                    }
                });
            }
        });

        let proxy = ChaosProxy::start(&upstream).unwrap();
        let mut c = std::net::TcpStream::connect(proxy.addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
        c.write_all(b"ping").unwrap();
        let mut buf = [0u8; 4];
        c.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"ping", "healthy proxy forwards both ways");

        // raise the partition: the established connection is severed...
        proxy.set_partitioned(true);
        std::thread::sleep(Duration::from_millis(80));
        let dead = match c.write_all(b"x") {
            Err(_) => true,
            Ok(()) => c.read_exact(&mut buf).is_err(),
        };
        assert!(dead, "partition must sever the established connection");
        // ...and new dials through the proxy fail fast (accept-then-drop)
        let mut c2 = std::net::TcpStream::connect(proxy.addr()).unwrap();
        c2.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
        let gone = match c2.write_all(b"ping") {
            Err(_) => true,
            Ok(()) => c2.read_exact(&mut buf).is_err(),
        };
        assert!(gone, "partitioned proxy must not carry new connections");
        // the upstream itself is still reachable directly (asymmetric!)
        let mut d = std::net::TcpStream::connect(&upstream).unwrap();
        d.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
        d.write_all(b"pong").unwrap();
        d.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"pong");

        // clearing the partition restores service for fresh dials
        proxy.set_partitioned(false);
        let mut c3 = std::net::TcpStream::connect(proxy.addr()).unwrap();
        c3.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
        c3.write_all(b"back").unwrap();
        c3.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"back");
    }
}
