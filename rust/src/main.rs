//! edgecache CLI — launcher for the cache box, edge clients, workload
//! inspection and paper-table regeneration.
//!
//! ```text
//! edgecache server    --addr 0.0.0.0:7600 --max-mb 14336
//! edgecache client    --server HOST:PORT --preset edge-270m --device low-end \
//!                     --link wifi --domains 8 --per-domain 4 --shots 1
//! edgecache client    --server H1:P1 --peer H2:P2 --peer H3:P3 --replicas 1 \
//!                     --placement ring
//! edgecache run       --preset tiny --clients 2 --peers 2 --domains 6 --per-domain 3
//! edgecache tables    --prompts 6434        # analytic Table 2/3/4 + figures
//! edgecache workload  --domain astronomy --shots 5 --index 0
//! edgecache info      --preset edge-270m
//! ```

use std::sync::Arc;

use anyhow::{anyhow, Result};

use edgecache::coordinator::{
    CacheBox, DeadlineBudget, EdgeClient, EdgeClientConfig, FetchPolicy, PeerConfig,
    PlacementKind, PlanMode,
};
use edgecache::devicemodel::DeviceProfile;
use edgecache::engine::Engine;
use edgecache::kvstore::ServeMode;
use edgecache::metrics::CaseAggregate;
use edgecache::model::state::Compression;
use edgecache::netsim::LinkModel;
use edgecache::report::experiments as exp;
use edgecache::util::cli::Command;
use edgecache::workload::{Generator, Trace, DOMAINS};
use edgecache::{log_info, report};

fn main() {
    edgecache::util::logger::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    let result = match sub {
        "server" => cmd_server(rest),
        "client" => cmd_client(rest),
        "run" => cmd_run(rest),
        "tables" => cmd_tables(rest),
        "workload" => cmd_workload(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand {other:?}\n")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        print_help();
        std::process::exit(1);
    }
}

fn print_help() {
    eprintln!(
        "edgecache — distributed prompt caching for local LLMs on edge devices\n\n\
         subcommands:\n\
         \x20 server     run a cache box (kvstore + master catalog)\n\
         \x20 client     run an edge client over a generated MMLU-like trace\n\
         \x20 run        in-process cluster: cache box + N clients + trace\n\
         \x20 tables     regenerate the paper's tables/figures (analytic track)\n\
         \x20 workload   print a generated prompt\n\
         \x20 info       show artifact/preset information\n\n\
         use `edgecache <subcommand> --help` for options"
    );
}

fn parse_or_help(c: Command, argv: &[String]) -> Result<edgecache::util::cli::Matches> {
    c.parse(argv).map_err(|msg| {
        eprintln!("{msg}");
        std::process::exit(2);
    })
}

fn cmd_server(argv: &[String]) -> Result<()> {
    let m = parse_or_help(
        Command::new("server", "run the cache box (Figure 1, middle node)")
            .opt("addr", "127.0.0.1:7600", "listen address")
            .opt("max-mb", "14336", "prompt-cache memory budget in MB")
            .choice(
                "serve",
                &["threads", "poll"],
                "threads",
                "serving core: per-connection threads, or the non-blocking poll loop",
            )
            .opt("shards", "1", "independent store shards under one global byte budget")
            .opt(
                "max-pending",
                "0",
                "admission gate: pending ops before shedding with BUSY (0 = unbounded)",
            ),
        argv,
    )?;
    let addr = m.str("addr");
    let max_mb: usize = m.usize("max-mb").map_err(|e| anyhow!(e))?;
    let mode = ServeMode::by_name(&m.str("serve"))
        .ok_or_else(|| anyhow!("unknown --serve (threads|poll)"))?;
    let shards: usize = m.usize("shards").map_err(|e| anyhow!(e))?;
    let max_pending: usize = m.usize("max-pending").map_err(|e| anyhow!(e))?;
    let cb = CacheBox::start_tuned(&addr, max_mb << 20, shards, max_pending, mode)?;
    log_info!(
        "cli",
        "cache box on {} ({} MB budget, {} core, {} shards, {} pending cap); Ctrl-C to stop",
        cb.addr(),
        max_mb,
        mode.name(),
        shards.max(1),
        max_pending
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn client_config(m: &edgecache::util::cli::Matches, server: Option<String>) -> Result<EdgeClientConfig> {
    let device = DeviceProfile::by_name(&m.str("device"))
        .ok_or_else(|| anyhow!("unknown --device (pi-zero-2w|pi5-4gb|host)"))?;
    let link = LinkModel::by_name(&m.str("link"))
        .ok_or_else(|| anyhow!("unknown --link (wifi|ethernet|loopback)"))?;
    // the peer fabric: --server (if any) is peer 0, every repeated --peer
    // adds another cache box sharing the prompt-cache load
    let peers: Vec<PeerConfig> = server
        .into_iter()
        .chain(m.all("peer"))
        .map(PeerConfig::new)
        .collect();
    Ok(EdgeClientConfig {
        name: "cli".into(),
        peers,
        replicas: m.usize("replicas").map_err(|e| anyhow!(e))?,
        // the parser already validated the value against the choice list
        placement: PlacementKind::by_name(&m.str("placement"))
            .ok_or_else(|| anyhow!("unknown --placement (p2c|ring)"))?,
        link,
        device,
        max_new_tokens: m.get("max-new").and_then(|v| v.parse().ok()),
        compression: if m.flag("compress") { Compression::Deflate } else { Compression::None },
        chunk_tokens: edgecache::model::state::DEFAULT_CHUNK_TOKENS,
        adaptive_chunk: m.flag("adaptive-chunk"),
        partial_matching: !m.flag("no-partial"),
        use_catalog: !m.flag("no-catalog"),
        fetch_policy: if m.flag("break-even") { FetchPolicy::BreakEven } else { FetchPolicy::Always },
        // the parser already validated the value against the choice list
        plan: PlanMode::by_name(&m.str("plan"))
            .ok_or_else(|| anyhow!("unknown --plan (chunk|range)"))?,
        probe_negative_ttl: std::time::Duration::from_millis(
            m.u64("negcache-ms").map_err(|e| anyhow!(e))?,
        ),
        min_hit_tokens: 1,
        sync_interval: Some(std::time::Duration::from_millis(200)),
        // liveness is on by default for the real tool: a stalled box
        // costs one op budget, never a wedged client (--deadline-ms 0
        // restores fully blocking sockets)
        deadline: match m.u64("deadline-ms").map_err(|e| anyhow!(e))? {
            0 => None,
            op_ms => Some(DeadlineBudget::from_millis(
                m.u64("connect-ms").map_err(|e| anyhow!(e))?.max(1),
                op_ms,
            )),
        },
        // fleet-health knobs: gossip rides the sync wire unless ablated,
        // indirect probes gate circumstantial death verdicts, and k > 0
        // scales per-op deadlines to each link's expected transfer time
        gossip: !m.flag("no-gossip"),
        indirect_probes: m.usize("indirect-probes").map_err(|e| anyhow!(e))?,
        adaptive_deadline_k: m
            .str("deadline-k")
            .parse::<f64>()
            .map_err(|e| anyhow!("bad --deadline-k: {e}"))?,
        // the semantic tier: sketch registration + nearest-sketch search on
        // total exact misses, every candidate verified by its real token
        // prefix before any state is reused
        semantic: !m.flag("no-semantic"),
        semantic_dist: m.usize("semantic-dist").map_err(|e| anyhow!(e))? as u32,
        semantic_k: m.usize("semantic-k").map_err(|e| anyhow!(e))?,
        repair_sweep: std::time::Duration::from_millis(
            m.u64("repair-sweep-ms").map_err(|e| anyhow!(e))?,
        ),
        seed: m.u64("seed").map_err(|e| anyhow!(e))?,
    })
}

fn client_cmd_spec(name: &'static str, about: &'static str) -> Command {
    Command::new(name, about)
        .opt("preset", "edge-270m", "artifact preset (tiny|edge-270m|edge-1b)")
        .opt("device", "host", "device pacing profile (pi-zero-2w|pi5-4gb|host)")
        .opt("link", "loopback", "link model (wifi|ethernet|loopback)")
        .multi("peer", "additional cache-box peer address (repeatable)")
        .opt("replicas", "0", "extra peers each upload is replicated to")
        .choice(
            "placement",
            &["p2c", "ring"],
            "p2c",
            "upload placement policy: p2c probes loads (power-of-two-choices), \
             ring places deterministically (rendezvous hash; enables \
             catalog-less fallback probing and replica repair)",
        )
        .opt("domains", "6", "number of MMLU-like domains")
        .opt("per-domain", "3", "questions per domain")
        .opt("shots", "1", "few-shot examples per prompt")
        .opt("max-new", "8", "response token budget")
        .opt("seed", "42", "workload seed")
        .opt(
            "deadline-ms",
            "2000",
            "per-op deadline budget on pooled peer connections; a stall \
             marks the peer Suspect and re-plans (0 = blocking sockets)",
        )
        .opt("connect-ms", "500", "connect timeout for peer dials")
        .choice(
            "plan",
            &["chunk", "range"],
            "chunk",
            "fetch planning granularity: chunk prices each ECS3 chunk \
             (fetch vs local recompute, mixed plans), range keeps the \
             all-or-nothing break-even decision (PR 3 ablation)",
        )
        .opt(
            "negcache-ms",
            "1500",
            "fallback-probe negative-cache TTL; a missed probe is not \
             retried for this long (0 = probe every time)",
        )
        .opt(
            "indirect-probes",
            "1",
            "relays asked to PING a Suspect before a circumstantial death \
             verdict commits (0 = trust first-hand evidence only)",
        )
        .opt(
            "deadline-k",
            "0",
            "adaptive deadline multiplier: arm each op's timeout at k x the \
             link's expected transfer time, floored by --deadline-ms and \
             widened x2 under Suspect (0 = static budget)",
        )
        .opt(
            "semantic-dist",
            "16",
            "max Hamming distance (of 64 sketch bits) a semantic donor \
             candidate may sit from the query sketch",
        )
        .opt(
            "semantic-k",
            "3",
            "max semantic donor candidates verified (token-header probes) \
             per total exact miss",
        )
        .opt(
            "repair-sweep-ms",
            "0",
            "proactive repair sweep period: SCAN a slice of one box's key \
             space and re-publish entries whose ring owners lost their \
             copy (0 = off; deterministic placement only)",
        )
        .flag(
            "no-semantic",
            "disable the semantic similarity tier (exact-match-only \
             ablation: no sketch registration, sync or probes)",
        )
        .flag(
            "no-gossip",
            "disable SWIM gossip digests on the sync wire (per-client \
             heartbeat ablation)",
        )
        .flag("no-partial", "disable partial matching (full-prompt keys only)")
        .flag("no-catalog", "disable the local Bloom catalog (probe server)")
        .flag("break-even", "fetch only when the transfer beats local prefill")
        .flag("compress", "deflate state blobs before upload")
        .flag("adaptive-chunk", "pick ECS3 chunk size from the link break-even")
}

fn run_trace(
    engine: Arc<Engine>,
    clients: &mut [EdgeClient],
    trace: &Trace,
    gen: &Generator,
) -> Result<()> {
    let _ = engine;
    let mut agg_by_case: std::collections::BTreeMap<usize, CaseAggregate> = Default::default();
    let t0 = std::time::Instant::now();
    for (i, q) in trace.queries.iter().enumerate() {
        let c = &mut clients[q.client % clients.len()];
        let prompt = gen.prompt(&q.domain, q.question_index, q.n_shots);
        let r = c.query(&prompt)?;
        agg_by_case.entry(r.case.number()).or_default().push(&r.breakdown);
        log_info!(
            "cli",
            "[{}/{}] client{} {} case{} ttft={:.3}s ttlt={:.3}s",
            i + 1,
            trace.queries.len(),
            q.client,
            q.domain,
            r.case.number(),
            r.breakdown.ttft().as_secs_f64(),
            r.breakdown.ttlt().as_secs_f64()
        );
    }
    println!("\ntrace finished in {:.1}s", t0.elapsed().as_secs_f64());
    let rows: Vec<Vec<String>> = agg_by_case
        .iter()
        .map(|(case, a)| {
            vec![
                format!("Case {case}"),
                a.n.to_string(),
                format!("{:.3}", a.ttft.mean()),
                format!("{:.3}", a.ttlt.mean()),
                format!("{:.1}", a.mean_prompt_tokens()),
            ]
        })
        .collect();
    println!(
        "{}",
        report::ascii_table(&["Case", "n", "TTFT [s]", "TTLT [s]", "# tokens"], &rows)
    );
    for c in clients.iter_mut() {
        c.refresh_stats();
        println!(
            "client {} [{}]: {} queries, hits by case {:?}, FPs {}, down {} KB, up {} KB, \
             chunks {} fetched / {} recomputed ({} mixed plans), \
             fallback probes {} ({} hits, {} suppressed), repairs {}, \
             timeouts {}, suspects {}, heals {}, \
             gossip {} adopted / {} refuted, probes {} indirect ({} saves), \
             busy rejections {} ({} free replans), \
             semantic {} probes / {} hits / {} false ({} tokens recovered)",
            c.cfg.name,
            c.placement_name(),
            c.stats.queries,
            c.stats.hits_by_case,
            c.stats.false_positives,
            c.stats.bytes_down / 1024,
            c.stats.bytes_up / 1024,
            c.stats.chunks_fetched,
            c.stats.chunks_recomputed,
            c.stats.plan_mixed,
            c.stats.fallback_probes,
            c.stats.fallback_probe_hits,
            c.stats.probes_suppressed,
            c.stats.repair_republishes,
            c.stats.timeouts,
            c.stats.suspect_transitions,
            c.stats.heals,
            c.stats.gossip_adoptions,
            c.stats.gossip_refutations,
            c.stats.indirect_probes,
            c.stats.probe_saves,
            c.stats.busy_rejections,
            c.stats.replans_on_busy,
            c.stats.semantic_probes,
            c.stats.semantic_hits,
            c.stats.semantic_false_probes,
            c.stats.semantic_tokens_recovered
        );
        for l in c.peer_ledgers() {
            println!(
                "  peer {}: down {} KB, up {} KB, shares {} ({} failed, {} chunks), \
                 uploads {} (+{} replicas), \
                 placed {}, probes {}, repairs {}, {} sync rounds, \
                 {} heartbeats, {} heals, {} timeouts, \
                 {} sheds, peak pending {}, \
                 {} sketch entries ({} sections synced)",
                l.addr,
                l.bytes_down / 1024,
                l.bytes_up / 1024,
                l.fetch_shares,
                l.share_failures,
                l.chunks_served,
                l.uploads,
                l.replica_uploads,
                l.placed_entries,
                l.fallback_probes,
                l.repair_republishes,
                l.sync_rounds,
                l.heartbeats,
                l.heals,
                l.timeouts,
                l.sheds,
                l.peak_pending,
                l.sketch_entries,
                l.sketch_sections
            );
        }
    }
    Ok(())
}

fn cmd_client(argv: &[String]) -> Result<()> {
    let m = parse_or_help(
        client_cmd_spec("client", "run one edge client against a cache box")
            .req("server", "cache box address (host:port)"),
        argv,
    )?;
    let engine = Arc::new(Engine::load_preset(&m.str("preset"))?);
    let cfg = client_config(&m, Some(m.str("server")))?;
    let mut clients = vec![EdgeClient::new(Arc::clone(&engine), cfg)?];
    let gen = Generator::new(m.u64("seed").map_err(|e| anyhow!(e))?);
    let trace = Trace::generate(
        gen.seed,
        1,
        m.usize("domains").map_err(|e| anyhow!(e))?.min(DOMAINS.len()),
        m.usize("per-domain").map_err(|e| anyhow!(e))?,
        m.usize("shots").map_err(|e| anyhow!(e))?,
    );
    run_trace(engine, &mut clients, &trace, &gen)
}

fn cmd_run(argv: &[String]) -> Result<()> {
    let m = parse_or_help(
        client_cmd_spec("run", "in-process cluster: N cache boxes + N clients")
            .opt("clients", "2", "number of edge clients")
            .opt("peers", "1", "number of in-process cache boxes (peer fabric)"),
        argv,
    )?;
    let engine = Arc::new(Engine::load_preset(&m.str("preset"))?);
    let n_boxes = m.usize("peers").map_err(|e| anyhow!(e))?.max(1);
    let boxes: Vec<CacheBox> = (0..n_boxes)
        .map(|_| CacheBox::start_local())
        .collect::<Result<_>>()?;
    let n_clients = m.usize("clients").map_err(|e| anyhow!(e))?.max(1);
    let mut clients = Vec::new();
    for i in 0..n_clients {
        let mut cfg = client_config(&m, Some(boxes[0].addr()))?;
        // every client talks to the whole fabric (plus any --peer extras)
        cfg.peers
            .extend(boxes[1..].iter().map(|b| PeerConfig::new(b.addr())));
        cfg.name = format!("c{i}");
        cfg.seed ^= i as u64;
        clients.push(EdgeClient::new(Arc::clone(&engine), cfg)?);
    }
    let gen = Generator::new(m.u64("seed").map_err(|e| anyhow!(e))?);
    let trace = Trace::generate(
        gen.seed,
        n_clients,
        m.usize("domains").map_err(|e| anyhow!(e))?.min(DOMAINS.len()),
        m.usize("per-domain").map_err(|e| anyhow!(e))?,
        m.usize("shots").map_err(|e| anyhow!(e))?,
    );
    run_trace(engine, &mut clients, &trace, &gen)?;
    for (i, cb) in boxes.iter().enumerate() {
        let (keys, bytes, evictions) = cb.stats();
        println!(
            "cache box {i} ({}): {keys} keys, {:.1} MB, {evictions} evictions",
            cb.addr(),
            bytes as f64 / 1e6
        );
    }
    for cb in boxes {
        cb.shutdown();
    }
    Ok(())
}

fn cmd_tables(argv: &[String]) -> Result<()> {
    let m = parse_or_help(
        Command::new("tables", "regenerate paper tables (analytic track)")
            .opt("prompts", "6434", "population size (paper: 6434)")
            .opt("seed", "42", "workload seed"),
        argv,
    )?;
    let n = m.usize("prompts").map_err(|e| anyhow!(e))?;
    let seed = m.u64("seed").map_err(|e| anyhow!(e))?;

    println!("== Table 2 / Figure 4: TTFT & TTLT, Case 1 vs Case 5 ==\n");
    for s in [exp::Setting::low_end_paper(), exp::Setting::high_end_paper()] {
        let (miss, hit) = exp::analytic_table23(&s, seed, n);
        let (t2, means) = exp::render_table2(s.name, &miss, &hit);
        println!("{t2}");
        println!(
            "{}",
            report::ascii_bars(
                &format!("Figure 4 ({}): TTFT / TTLT [s]", s.name),
                &[
                    ("TTFT case1".into(), means[0]),
                    ("TTFT case5".into(), means[1]),
                    ("TTLT case1".into(), means[2]),
                    ("TTLT case5".into(), means[3]),
                ],
                "s",
            )
        );
        println!("== Table 3 ({}) ==\n{}", s.name, exp::render_table3(&[
            (&format!("{} (Case 1)", s.name), &miss, s.n_shots, s.max_new),
            (&format!("{} (Case 5)", s.name), &hit, s.n_shots, s.max_new),
        ]));
    }

    println!("== Table 4 / Figure 5: partial matching (astronomy, N=5) ==\n");
    for s in [exp::Setting::low_end_paper(), exp::Setting::high_end_paper()] {
        let rows = exp::analytic_table4(&s, seed);
        let body: Vec<Vec<String>> = rows
            .iter()
            .map(|(c, m_, pct, td, _)| {
                vec![
                    format!("{} (Case {c})", s.name),
                    m_.to_string(),
                    format!("{pct:.2}"),
                    format!("{:.2}", td * 1e3),
                ]
            })
            .collect();
        println!(
            "{}",
            report::ascii_table(&["Setting", "# matched", "% matched", "T-decode [ms]"], &body)
        );
        if s.name == "Low-end" {
            let bars: Vec<(String, f64, f64)> = rows
                .iter()
                .map(|(c, _, _, td, redis)| (format!("Case {c}"), *td, *redis))
                .collect();
            println!(
                "{}",
                report::ascii_stacked_bars(
                    "Figure 5 (Low-end): total decoding time + Redis overhead [s]",
                    &bars,
                    "T-decode",
                    "Redis",
                    "s"
                )
            );
        }
    }
    Ok(())
}

fn cmd_workload(argv: &[String]) -> Result<()> {
    let m = parse_or_help(
        Command::new("workload", "print a generated MMLU-like prompt")
            .opt("domain", "astronomy", "one of the 57 MMLU domains")
            .opt("shots", "5", "few-shot examples")
            .opt("index", "0", "question index")
            .opt("seed", "42", "generator seed"),
        argv,
    )?;
    let g = Generator::new(m.u64("seed").map_err(|e| anyhow!(e))?);
    let p = g.prompt(
        &m.str("domain"),
        m.u64("index").map_err(|e| anyhow!(e))?,
        m.usize("shots").map_err(|e| anyhow!(e))?,
    );
    println!("{}", p.full_text());
    eprintln!(
        "\n--- {} words; ranges at {:?} chars; answer {}",
        p.word_count(),
        p.prefix_texts().iter().map(|t| t.len()).collect::<Vec<_>>(),
        p.answer
    );
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let m = parse_or_help(
        Command::new("info", "artifact/preset information")
            .opt("preset", "tiny", "artifact preset"),
        argv,
    )?;
    let engine = Engine::load_preset(&m.str("preset"))?;
    let c = &engine.model.config;
    println!("preset        : {}", c.name);
    println!("model hash    : {}", engine.model_hash());
    println!("vocab         : {}", c.vocab);
    println!("d_model       : {}", c.d_model);
    println!("layers        : {}", c.n_layers);
    println!("heads (kv)    : {} ({})", c.n_heads, c.n_kv_heads);
    println!("head_dim      : {}", c.head_dim);
    println!("d_ff          : {}", c.d_ff);
    println!("max_seq       : {}", c.max_seq);
    println!("prefill chunks: {:?}", engine.model.chunks());
    println!("param bytes   : {:.1} MB", engine.model.param_bytes as f64 / 1e6);
    println!("KV bytes/tok  : {}", c.kv_bytes_per_token());
    println!(
        "state @65 tok : {:.2} MB (paper 270M: 2.25 MB)",
        (65 * c.kv_bytes_per_token()) as f64 / 1e6
    );
    Ok(())
}
