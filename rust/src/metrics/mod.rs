//! Latency instrumentation: the paper's six-phase breakdown plus summary
//! statistics over prompt populations.
//!
//! Table 3 decomposes every query into **Token** (tokenize), **Bloom** (local
//! catalog lookup), **P-decode** (prompt prefill), **Redis** (cache-box
//! down/upload), **R-decode** (response decoding) and **Sample** (token
//! sampling).  [`PhaseBreakdown`] carries exactly those six accumulators;
//! TTFT/TTLT derive from them the same way the paper composes Table 2 from
//! Table 3.

use std::fmt;
use std::time::{Duration, Instant};

/// The six latency components of Table 3, in paper order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Tokenizing the input prompt.
    Token,
    /// Querying the local Bloom-filter catalog.
    Bloom,
    /// Decoding (prefilling) the prompt locally.
    PDecode,
    /// Downloading/uploading prompt-cache entries from/to the server.
    Redis,
    /// Decoding response tokens.
    RDecode,
    /// Sampling response tokens.
    Sample,
}

pub const PHASES: [Phase; 6] = [
    Phase::Token,
    Phase::Bloom,
    Phase::PDecode,
    Phase::Redis,
    Phase::RDecode,
    Phase::Sample,
];

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Token => "Token",
            Phase::Bloom => "Bloom",
            Phase::PDecode => "P-decode",
            Phase::Redis => "Redis",
            Phase::RDecode => "R-decode",
            Phase::Sample => "Sample",
        }
    }

    fn index(self) -> usize {
        match self {
            Phase::Token => 0,
            Phase::Bloom => 1,
            Phase::PDecode => 2,
            Phase::Redis => 3,
            Phase::RDecode => 4,
            Phase::Sample => 5,
        }
    }
}

/// Per-query phase accumulator.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PhaseBreakdown {
    durs: [Duration; 6],
    /// Number of prompt tokens (paper Table 3 "# tokens").
    pub prompt_tokens: usize,
    /// Number of generated response tokens.
    pub response_tokens: usize,
    /// Bytes moved over the cache-box link (paper "State size").
    pub state_bytes: usize,
    /// Bytes the range-aware transfer path avoided moving (vs the
    /// full-blob-per-range model; see `coordinator::client`).
    pub saved_bytes: usize,
    /// Total wire bytes moved over the link this query, both directions
    /// summed (unlike `state_bytes`, which keeps the paper's per-direction
    /// "State size" semantics).
    pub wire_bytes: usize,
    /// Logical (uncompressed) KV bytes the moved payloads represent — with
    /// chunk compression `wire_bytes` shrinks while this one doesn't, so
    /// per-query compression ratios stay computable and honest.
    pub inflated_bytes: usize,
    /// Tokens whose prefill was skipped thanks to a cache hit.
    pub reused_tokens: usize,
    /// Latency the streaming download path hid by decoding chunks while
    /// later chunks were still on the modelled wire (store-and-forward
    /// serial time minus the streamed elapsed time; see
    /// `netsim::Shaper::shaped_stream`).  Already reflected in the Redis
    /// phase — this is the *credit* ledger, not an extra cost.
    pub overlap_saved: Duration,
}

impl PhaseBreakdown {
    pub fn add(&mut self, p: Phase, d: Duration) {
        self.durs[p.index()] += d;
    }

    pub fn get(&self, p: Phase) -> Duration {
        self.durs[p.index()]
    }

    /// Time a closure into a phase.
    pub fn time<T>(&mut self, p: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let r = f();
        self.add(p, t0.elapsed());
        r
    }

    /// Time to First Token = everything before response decoding starts
    /// (paper: Token + Bloom + P-decode [+ Redis on hits]).
    pub fn ttft(&self) -> Duration {
        self.get(Phase::Token) + self.get(Phase::Bloom) + self.get(Phase::PDecode)
            + self.get(Phase::Redis)
    }

    /// Time to Last Token = TTFT + R-decode + Sample.
    pub fn ttlt(&self) -> Duration {
        self.ttft() + self.get(Phase::RDecode) + self.get(Phase::Sample)
    }

    /// Total decoding time (paper Table 4 "T-decode" = P-decode + R-decode).
    pub fn t_decode(&self) -> Duration {
        self.get(Phase::PDecode) + self.get(Phase::RDecode)
    }

    pub fn merge(&mut self, other: &PhaseBreakdown) {
        for (a, b) in self.durs.iter_mut().zip(&other.durs) {
            *a += *b;
        }
        self.prompt_tokens += other.prompt_tokens;
        self.response_tokens += other.response_tokens;
        self.state_bytes += other.state_bytes;
        self.saved_bytes += other.saved_bytes;
        self.wire_bytes += other.wire_bytes;
        self.inflated_bytes += other.inflated_bytes;
        self.reused_tokens += other.reused_tokens;
        self.overlap_saved += other.overlap_saved;
    }
}

impl fmt::Display for PhaseBreakdown {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for p in PHASES {
            write!(f, "{}={:.2}ms ", p.name(), self.get(p).as_secs_f64() * 1e3)?;
        }
        write!(
            f,
            "ttft={:.2}ms ttlt={:.2}ms",
            self.ttft().as_secs_f64() * 1e3,
            self.ttlt().as_secs_f64() * 1e3
        )
    }
}

/// Per-peer ledger of the peer fabric: one per cache box a client talks
/// to, so "how much did each box contribute / cost" stays answerable when
/// transfers fan out across N peers.  Byte counters are payload bytes over
/// that peer's modelled link; `breakdown` accumulates wall time per phase
/// attributed to this peer (its fetch shares and uploads land in
/// [`Phase::Redis`]).
#[derive(Debug, Clone, Default)]
pub struct PeerLedger {
    /// The peer's cache-box address.
    pub addr: String,
    /// Payload bytes downloaded from this peer.
    pub bytes_down: u64,
    /// Payload bytes uploaded to this peer.
    pub bytes_up: u64,
    /// Multi-source fetch shares this peer served to completion.
    pub fetch_shares: u64,
    /// Fetch shares this peer failed mid-stream (dead conn, short or
    /// corrupt reply) — the planner re-plans these onto survivors.
    pub share_failures: u64,
    /// Individual ECS3 chunks this peer delivered to completion across all
    /// its fetch shares — the per-peer denominator of the chunk-level fetch
    /// plan (`coordinator::plan`): together with a client's
    /// `chunks_recomputed` it answers "who actually produced each chunk".
    pub chunks_served: u64,
    /// Uploads this peer received as placement primary.
    pub uploads: u64,
    /// Uploads this peer received as a replica copy.
    pub replica_uploads: u64,
    /// Entries this peer stored because a placement decision (policy
    /// choice, splice pin, salvage or repair) designated it — the
    /// per-peer view of where the placement policy is sending data.
    pub placed_entries: u64,
    /// Catalog-less EXISTS probes sent to this peer: ring-designated
    /// owner probes on a catalog miss (`Placement::owners`) plus repair
    /// sweeps (`fabric::repair_entry`).
    pub fallback_probes: u64,
    /// Entries re-published to this peer by ring-driven replica repair.
    pub repair_republishes: u64,
    /// Completed catalog-sync rounds against this peer.
    pub sync_rounds: u64,
    /// Sketch records currently held in this peer's synced sketch table
    /// (the semantic tier's per-box search space; 0 against a legacy box).
    pub sketch_entries: u64,
    /// Sketch sections this peer's sync loop has merged over its lifetime.
    pub sketch_sections: u64,
    /// Liveness heartbeats acknowledged by this peer (one per completed
    /// sync round and per manual sync; see `coordinator::membership`).
    pub heartbeats: u64,
    /// Times this peer healed — came back from Dead after its heartbeat
    /// returned (Dead → Recovering transitions).
    pub heals: u64,
    /// Deadline-budget expiries on this peer's pooled connection
    /// (`WouldBlock`/`TimedOut`): the peer stalled but was not declared
    /// dead for it.
    pub timeouts: u64,
    /// Operations this peer shed with a `BUSY` reply (admission control) —
    /// replanned for free, never a health strike.
    pub sheds: u64,
    /// High-water mark of the peer's pending-op queue, as last advertised
    /// by its `INFO pending_peak:` line (0 until a probe has seen one).
    pub peak_pending: u64,
    /// Smoothed observed per-share service time (EWMA, milliseconds) —
    /// wall time from request to last byte of completed fetch shares.
    pub srv_observed_ms: f64,
    /// Smoothed *expected* per-share service time under the link model
    /// alone (EWMA, ms).  The ratio observed/expected isolates peer-side
    /// queueing from link cost, and derates this peer's planner share
    /// (`plan::LinkCost::derated`) before it stalls.
    pub srv_expected_ms: f64,
    /// Per-peer phase time (Redis = this peer's transfers).
    pub breakdown: PhaseBreakdown,
}

impl PeerLedger {
    /// Fold one completed fetch share's service time into the EWMAs
    /// (`observed_ms` wall clock vs `expected_ms` from the link model).
    /// First sample initialises both; later samples smooth with α = 0.2 so
    /// a transient hiccup cannot swing the planner share by itself.
    pub fn note_service_time(&mut self, observed_ms: f64, expected_ms: f64) {
        const ALPHA: f64 = 0.2;
        if !(observed_ms.is_finite() && expected_ms.is_finite()) {
            return;
        }
        if self.srv_observed_ms <= 0.0 {
            self.srv_observed_ms = observed_ms;
            self.srv_expected_ms = expected_ms;
        } else {
            self.srv_observed_ms = (1.0 - ALPHA) * self.srv_observed_ms + ALPHA * observed_ms;
            self.srv_expected_ms = (1.0 - ALPHA) * self.srv_expected_ms + ALPHA * expected_ms;
        }
    }

    /// Observed/expected service-time ratio: `1.0` = the link model alone
    /// explains this peer's latency; `> 1` = peer-side queueing.  `1.0`
    /// until enough samples exist.
    pub fn service_slowdown(&self) -> f64 {
        if self.srv_observed_ms <= 0.0 || self.srv_expected_ms <= 0.0 {
            return 1.0;
        }
        (self.srv_observed_ms / self.srv_expected_ms).max(0.0)
    }
}

/// Running summary over a population of scalar samples (seconds).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn push_dur(&mut self, d: Duration) {
        self.push(d.as_secs_f64());
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() as f64) * p) as usize;
        s[idx.min(s.len() - 1)]
    }

    /// Relative change vs a baseline mean, in percent (negative = reduction).
    /// The paper's headline "−93.12 % TTFT" is this quantity.
    pub fn reduction_pct(&self, baseline: &Summary) -> f64 {
        let b = baseline.mean();
        if b == 0.0 {
            return 0.0;
        }
        (self.mean() - b) / b * 100.0
    }
}

/// Aggregates phase breakdowns per experimental case (e.g. Case 1 vs Case 5).
#[derive(Debug, Default)]
pub struct CaseAggregate {
    pub n: usize,
    pub phase_sums: [f64; 6],
    pub ttft: Summary,
    pub ttlt: Summary,
    pub t_decode: Summary,
    pub prompt_tokens: f64,
    pub state_bytes: f64,
    pub saved_bytes: f64,
    pub wire_bytes: f64,
    pub inflated_bytes: f64,
    /// Seconds of decode latency hidden inside wire time by the streaming
    /// assembly path, summed over queries.
    pub overlap_saved: f64,
}

impl CaseAggregate {
    pub fn push(&mut self, b: &PhaseBreakdown) {
        self.n += 1;
        for p in PHASES {
            self.phase_sums[p.index()] += b.get(p).as_secs_f64();
        }
        self.ttft.push_dur(b.ttft());
        self.ttlt.push_dur(b.ttlt());
        self.t_decode.push_dur(b.t_decode());
        self.prompt_tokens += b.prompt_tokens as f64;
        self.state_bytes += b.state_bytes as f64;
        self.saved_bytes += b.saved_bytes as f64;
        self.wire_bytes += b.wire_bytes as f64;
        self.inflated_bytes += b.inflated_bytes as f64;
        self.overlap_saved += b.overlap_saved.as_secs_f64();
    }

    /// Mean time in a phase, milliseconds (Table 3 cell).
    pub fn phase_mean_ms(&self, p: Phase) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.phase_sums[p.index()] / self.n as f64 * 1e3
    }

    pub fn mean_prompt_tokens(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.prompt_tokens / self.n as f64
    }

    pub fn mean_state_mb(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.state_bytes / self.n as f64 / 1e6
    }

    /// Mean wire bytes the range-aware transfer path saved per query, MB.
    pub fn mean_saved_mb(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.saved_bytes / self.n as f64 / 1e6
    }

    /// Mean decode latency hidden inside wire time per query, milliseconds.
    pub fn mean_overlap_saved_ms(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.overlap_saved / self.n as f64 * 1e3
    }

    /// Achieved wire compression ratio: logical KV bytes represented per
    /// wire byte moved, both directions (≈1.0 when uncompressed — wire adds
    /// only header/index/alias overhead — > 1.0 when deflate pays).
    pub fn compression_ratio(&self) -> f64 {
        if self.wire_bytes == 0.0 {
            return 1.0;
        }
        self.inflated_bytes / self.wire_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_ttlt_composition() {
        let mut b = PhaseBreakdown::default();
        b.add(Phase::Token, Duration::from_millis(3));
        b.add(Phase::Bloom, Duration::from_millis(1));
        b.add(Phase::PDecode, Duration::from_millis(100));
        b.add(Phase::Redis, Duration::from_millis(50));
        b.add(Phase::RDecode, Duration::from_millis(200));
        b.add(Phase::Sample, Duration::from_millis(2));
        assert_eq!(b.ttft(), Duration::from_millis(154));
        assert_eq!(b.ttlt(), Duration::from_millis(356));
        assert_eq!(b.t_decode(), Duration::from_millis(300));
    }

    #[test]
    fn time_accumulates() {
        let mut b = PhaseBreakdown::default();
        let r = b.time(Phase::Token, || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(r, 42);
        assert!(b.get(Phase::Token) >= Duration::from_millis(4));
        b.time(Phase::Token, || std::thread::sleep(Duration::from_millis(5)));
        assert!(b.get(Phase::Token) >= Duration::from_millis(9), "accumulate");
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseBreakdown::default();
        a.add(Phase::Redis, Duration::from_millis(10));
        a.prompt_tokens = 5;
        a.saved_bytes = 100;
        let mut b = PhaseBreakdown::default();
        b.add(Phase::Redis, Duration::from_millis(20));
        b.prompt_tokens = 7;
        b.saved_bytes = 23;
        b.inflated_bytes = 400;
        b.overlap_saved = Duration::from_millis(4);
        a.merge(&b);
        assert_eq!(a.get(Phase::Redis), Duration::from_millis(30));
        assert_eq!(a.prompt_tokens, 12);
        assert_eq!(a.saved_bytes, 123);
        assert_eq!(a.inflated_bytes, 400);
        assert_eq!(a.overlap_saved, Duration::from_millis(4));
    }

    #[test]
    fn overlap_saved_aggregates_to_mean_ms() {
        let mut agg = CaseAggregate::default();
        for ms in [10u64, 30] {
            let mut b = PhaseBreakdown::default();
            b.overlap_saved = Duration::from_millis(ms);
            agg.push(&b);
        }
        assert!((agg.mean_overlap_saved_ms() - 20.0).abs() < 1e-9);
        assert_eq!(CaseAggregate::default().mean_overlap_saved_ms(), 0.0);
    }

    #[test]
    fn compression_ratio_from_wire_and_inflated() {
        let mut agg = CaseAggregate::default();
        let mut b = PhaseBreakdown::default();
        b.wire_bytes = 250_000;
        b.inflated_bytes = 1_000_000;
        agg.push(&b);
        assert!((agg.compression_ratio() - 4.0).abs() < 1e-9);
        assert_eq!(CaseAggregate::default().compression_ratio(), 1.0);
    }

    #[test]
    fn summary_stats() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.n(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.std() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.percentile(0.5), 3.0);
    }

    #[test]
    fn reduction_pct_headline() {
        // paper: TTFT 12.59 s -> 0.87 s is a 93.1 % reduction
        let mut base = Summary::new();
        base.push(12.59);
        let mut hit = Summary::new();
        hit.push(0.87);
        let red = hit.reduction_pct(&base);
        assert!((-93.5..=-92.5).contains(&red), "{red}");
    }

    #[test]
    fn case_aggregate_means() {
        let mut agg = CaseAggregate::default();
        for i in 1..=4u64 {
            let mut b = PhaseBreakdown::default();
            b.add(Phase::PDecode, Duration::from_millis(100 * i));
            b.prompt_tokens = 10 * i as usize;
            b.state_bytes = 1_000_000;
            agg.push(&b);
        }
        assert_eq!(agg.n, 4);
        assert!((agg.phase_mean_ms(Phase::PDecode) - 250.0).abs() < 1e-9);
        assert!((agg.mean_prompt_tokens() - 25.0).abs() < 1e-9);
        assert!((agg.mean_state_mb() - 1.0).abs() < 1e-9);
    }
}
