//! Bloom filter — the data structure behind the paper's *catalog* (libbloom
//! 2.0 analog, DESIGN.md §Substitutions).
//!
//! Sizing follows the standard closed forms: for target capacity `n` and
//! false-positive ratio `p`,
//!
//! ```text
//!   m = ceil(-n ln p / (ln 2)^2)      bits
//!   k = round(m/n ln 2)               hash functions
//! ```
//!
//! The paper's configuration — 1 M entries at 1 % — yields a 1.20 MB bitmap
//! with k = 7, which [`BloomFilter::paper_default`] reproduces exactly and
//! `tests::paper_sizing` pins.
//!
//! Hashing uses the Kirsch–Mitzenmacher double-hashing scheme over the two
//! 64-bit halves of a SHA-256 digest: index_i = h1 + i*h2 (mod m).  The
//! filter serializes to a versioned byte blob for master→local catalog
//! synchronization, and supports `merge` (bitwise OR) for delta application.

use sha2::{Digest, Sha256};
use thiserror::Error;

use crate::util::bytes::{Reader, Writer};

#[derive(Debug, Error)]
pub enum BloomError {
    #[error("bad bloom blob: {0}")]
    BadBlob(String),
    #[error("incompatible filters: {0}")]
    Incompatible(String),
    #[error(transparent)]
    Bytes(#[from] crate::util::bytes::ByteError),
}

const MAGIC: u32 = 0x424C4D31; // "BLM1"

#[derive(Debug, Clone, PartialEq)]
pub struct BloomFilter {
    /// number of bits (m)
    m_bits: u64,
    /// number of hash functions (k)
    k: u32,
    /// design capacity (n) — informational
    capacity: u64,
    /// design false-positive ratio — informational
    fp_rate: f64,
    /// inserted-element counter (approximate under merge)
    count: u64,
    bits: Vec<u64>,
}

impl BloomFilter {
    /// Dimension a filter for `capacity` elements at `fp_rate` false positives.
    pub fn new(capacity: u64, fp_rate: f64) -> Self {
        assert!(capacity > 0, "capacity must be positive");
        assert!((0.0..1.0).contains(&fp_rate) && fp_rate > 0.0, "fp_rate in (0,1)");
        let ln2 = std::f64::consts::LN_2;
        let m = (-(capacity as f64) * fp_rate.ln() / (ln2 * ln2)).ceil() as u64;
        let m = m.max(64);
        let k = ((m as f64 / capacity as f64) * ln2).round().max(1.0) as u32;
        BloomFilter {
            m_bits: m,
            k,
            capacity,
            fp_rate,
            count: 0,
            bits: vec![0u64; m.div_ceil(64) as usize],
        }
    }

    /// The paper's configuration: 1 M entries, 1 % target FP ratio (≈1.20 MB).
    pub fn paper_default() -> Self {
        BloomFilter::new(1_000_000, 0.01)
    }

    pub fn m_bits(&self) -> u64 {
        self.m_bits
    }

    pub fn k(&self) -> u32 {
        self.k
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Bitmap size in bytes (the paper quotes 1.20 MB for the default).
    pub fn size_bytes(&self) -> usize {
        self.bits.len() * 8
    }

    fn hash_pair(key: &[u8]) -> (u64, u64) {
        let digest = Sha256::digest(key);
        let h1 = u64::from_le_bytes(digest[0..8].try_into().unwrap());
        let h2 = u64::from_le_bytes(digest[8..16].try_into().unwrap());
        // force h2 odd so the probe sequence cycles through distinct slots
        (h1, h2 | 1)
    }

    #[inline]
    fn set_bit(&mut self, idx: u64) {
        self.bits[(idx / 64) as usize] |= 1u64 << (idx % 64);
    }

    #[inline]
    fn get_bit(&self, idx: u64) -> bool {
        self.bits[(idx / 64) as usize] & (1u64 << (idx % 64)) != 0
    }

    /// Insert a key.  Returns true if the key was (probably) new — i.e. at
    /// least one bit flipped.
    pub fn insert(&mut self, key: &[u8]) -> bool {
        let (h1, h2) = Self::hash_pair(key);
        let mut novel = false;
        for i in 0..self.k as u64 {
            let idx = h1.wrapping_add(i.wrapping_mul(h2)) % self.m_bits;
            if !self.get_bit(idx) {
                novel = true;
                self.set_bit(idx);
            }
        }
        if novel {
            self.count += 1;
        }
        novel
    }

    /// Membership query; false positives possible at ~the design rate,
    /// false negatives never.
    pub fn contains(&self, key: &[u8]) -> bool {
        let (h1, h2) = Self::hash_pair(key);
        (0..self.k as u64).all(|i| {
            let idx = h1.wrapping_add(i.wrapping_mul(h2)) % self.m_bits;
            self.get_bit(idx)
        })
    }

    /// Expected false-positive ratio at the current fill level:
    /// `(1 - e^{-kn/m})^k`.
    pub fn expected_fp_rate(&self) -> f64 {
        let k = self.k as f64;
        let n = self.count as f64;
        let m = self.m_bits as f64;
        (1.0 - (-k * n / m).exp()).powf(k)
    }

    /// Fraction of bits set (diagnostic; ~0.5 at design capacity).
    pub fn fill_ratio(&self) -> f64 {
        let ones: u64 = self.bits.iter().map(|w| w.count_ones() as u64).sum();
        ones as f64 / self.m_bits as f64
    }

    /// Bitwise-OR another filter into this one (used to apply catalog deltas).
    pub fn merge(&mut self, other: &BloomFilter) -> Result<(), BloomError> {
        if self.m_bits != other.m_bits || self.k != other.k {
            return Err(BloomError::Incompatible(format!(
                "m/k mismatch: ({}, {}) vs ({}, {})",
                self.m_bits, self.k, other.m_bits, other.k
            )));
        }
        for (a, b) in self.bits.iter_mut().zip(&other.bits) {
            *a |= b;
        }
        self.count = self.count.max(other.count); // lower bound, approximate
        Ok(())
    }

    pub fn clear(&mut self) {
        self.bits.iter_mut().for_each(|w| *w = 0);
        self.count = 0;
    }

    // -- serialization (catalog sync wire format) ---------------------------

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(self.size_bytes() + 64);
        w.u32(MAGIC);
        w.u64(self.m_bits);
        w.u32(self.k);
        w.u64(self.capacity);
        w.u64(self.fp_rate.to_bits());
        w.u64(self.count);
        w.u32(self.bits.len() as u32);
        for word in &self.bits {
            w.u64(*word);
        }
        w.into_vec()
    }

    pub fn from_bytes(data: &[u8]) -> Result<Self, BloomError> {
        let mut r = Reader::new(data);
        let magic = r.u32()?;
        if magic != MAGIC {
            return Err(BloomError::BadBlob(format!("bad magic {magic:#x}")));
        }
        let m_bits = r.u64()?;
        let k = r.u32()?;
        let capacity = r.u64()?;
        let fp_rate = f64::from_bits(r.u64()?);
        let count = r.u64()?;
        let n_words = r.u32()? as usize;
        if n_words != m_bits.div_ceil(64) as usize {
            return Err(BloomError::BadBlob(format!(
                "word count {n_words} inconsistent with m={m_bits}"
            )));
        }
        let mut bits = Vec::with_capacity(n_words);
        for _ in 0..n_words {
            bits.push(r.u64()?);
        }
        if r.remaining() != 0 {
            return Err(BloomError::BadBlob("trailing bytes".into()));
        }
        Ok(BloomFilter { m_bits, k, capacity, fp_rate, count, bits })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop_n;
    use crate::util::rng::Rng;

    #[test]
    fn paper_sizing() {
        // 1M entries @ 1% — the paper reports "only 1.20MB", k=7 from theory
        let b = BloomFilter::paper_default();
        let mb = b.size_bytes() as f64 / 1e6;
        assert!(
            (1.19..1.21).contains(&mb),
            "paper says 1.20 MB, got {mb:.3} MB"
        );
        assert_eq!(b.k(), 7);
    }

    #[test]
    fn no_false_negatives() {
        run_prop_n("bloom-no-false-negatives", 32, |g| {
            let n = g.size(500);
            let mut b = BloomFilter::new(1000, 0.01);
            let keys: Vec<Vec<u8>> = (0..n)
                .map(|_| {
                    let len = g.usize_in(1, 64);
                    g.bytes(len)
                })
                .collect();
            for k in &keys {
                b.insert(k);
            }
            for k in &keys {
                assert!(b.contains(k), "inserted key reported absent");
            }
        });
    }

    #[test]
    fn fp_rate_near_design_point() {
        // fill to design capacity, then measure FP ratio on fresh keys
        let cap = 20_000u64;
        let mut b = BloomFilter::new(cap, 0.01);
        let mut rng = Rng::new(99);
        for i in 0..cap {
            b.insert(format!("member-{i}-{}", rng.next_u64()).as_bytes());
        }
        let trials = 50_000;
        let mut fp = 0;
        for i in 0..trials {
            if b.contains(format!("nonmember-{i}").as_bytes()) {
                fp += 1;
            }
        }
        let rate = fp as f64 / trials as f64;
        assert!(rate < 0.02, "measured FP rate {rate:.4} >> design 0.01");
        assert!(rate > 0.001, "measured FP rate {rate:.4} implausibly low");
        // analytic estimate agrees with measurement within 2x
        let est = b.expected_fp_rate();
        assert!(rate < est * 2.0 + 0.005 && est < 0.02, "est {est:.4} vs {rate:.4}");
    }

    #[test]
    fn insert_novelty_flag() {
        let mut b = BloomFilter::new(100, 0.01);
        assert!(b.insert(b"alpha"));
        assert!(!b.insert(b"alpha"), "second insert must report non-novel");
        assert_eq!(b.count(), 1);
    }

    #[test]
    fn serialization_roundtrip() {
        let mut b = BloomFilter::new(5000, 0.02);
        for i in 0..1000 {
            b.insert(format!("k{i}").as_bytes());
        }
        let blob = b.to_bytes();
        let c = BloomFilter::from_bytes(&blob).unwrap();
        assert_eq!(b, c);
        for i in 0..1000 {
            assert!(c.contains(format!("k{i}").as_bytes()));
        }
    }

    #[test]
    fn corrupt_blob_rejected() {
        let mut b = BloomFilter::new(100, 0.01).to_bytes();
        b[0] ^= 0xff; // magic
        assert!(BloomFilter::from_bytes(&b).is_err());
        let b2 = BloomFilter::new(100, 0.01).to_bytes();
        assert!(BloomFilter::from_bytes(&b2[..b2.len() - 3]).is_err());
        assert!(BloomFilter::from_bytes(&[]).is_err());
    }

    #[test]
    fn merge_is_union() {
        let mut a = BloomFilter::new(1000, 0.01);
        let mut b = BloomFilter::new(1000, 0.01);
        a.insert(b"only-a");
        b.insert(b"only-b");
        a.merge(&b).unwrap();
        assert!(a.contains(b"only-a"));
        assert!(a.contains(b"only-b"));
    }

    #[test]
    fn merge_incompatible_rejected() {
        let mut a = BloomFilter::new(1000, 0.01);
        let b = BloomFilter::new(2000, 0.01);
        assert!(a.merge(&b).is_err());
    }

    #[test]
    fn clear_resets() {
        let mut a = BloomFilter::new(1000, 0.01);
        a.insert(b"x");
        a.clear();
        assert!(!a.contains(b"x"));
        assert_eq!(a.count(), 0);
        assert_eq!(a.fill_ratio(), 0.0);
    }
}
