//! The *catalog* — the paper's §3.1/§3.2 contribution.
//!
//! A local Bloom filter on every client summarises which prompt-cache
//! entries exist on the cache box, so a Redis round-trip happens only when a
//! hit is probable.  Keys bind the cached state to everything that must
//! match for it to be reusable (Figure 3, top): the **model metadata**
//! (architecture hash, quantization) and the exact **token-id sequence** of
//! a prompt range.
//!
//! Partial matching (§3.2) registers up to four nested prefix ranges per
//! prompt — instruction / +first example / +all examples / full prompt — and
//! lookup returns the *longest* probable match, since longer reused prefixes
//! save more prefill time.
//!
//! [`LocalCatalog`] additionally tracks the master-catalog version it last
//! synchronized to; the async sync loop lives in `coordinator` and applies
//! [`LocalCatalog::apply_delta`].
//!
//! The catalog suppresses wasted probes but a Bloom false *negative*
//! (fresh filter after a reboot, lagging sync) is an unrecoverable miss on
//! its own — the client layers deterministic rendezvous placement on top
//! (`coordinator::placement`), so a catalog miss can still fall back to
//! probing the ring-designated owners, and a probe-confirmed hit is
//! registered back here ([`LocalCatalog::register_key`]) to re-warm the
//! filter.

use sha2::{Digest, Sha256};

use crate::bloom::BloomFilter;

/// Length of a catalog key in bytes (truncated SHA-256; collision probability
/// is negligible against the Bloom filter's own 1 % FP rate).
pub const KEY_LEN: usize = 16;

/// Everything that must be identical for a cached state to be restorable
/// (paper: "model name and its configuration parameters ... distinguishes
/// cached states from those generated under different model architectures or
/// quantization settings").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelMeta {
    /// `ModelConfig::model_hash()` from the artifact's meta.json.
    pub model_hash: String,
    /// Quantization / dtype tag (always "f32" for our artifacts).
    pub quant: String,
    /// State-blob format version (bumps invalidate all cached states).
    pub state_format: u32,
}

impl ModelMeta {
    pub fn new(model_hash: impl Into<String>) -> Self {
        ModelMeta { model_hash: model_hash.into(), quant: "f32".into(), state_format: 1 }
    }

    fn digest_seed(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(64);
        v.extend_from_slice(self.model_hash.as_bytes());
        v.push(0);
        v.extend_from_slice(self.quant.as_bytes());
        v.push(0);
        v.extend_from_slice(&self.state_format.to_le_bytes());
        v
    }
}

/// Catalog key for (model meta, token-id range).  Also used verbatim as the
/// cache box key for the state blob (prefixed "state:").
pub fn range_key(meta: &ModelMeta, tokens: &[u32]) -> [u8; KEY_LEN] {
    let mut h = Sha256::new();
    h.update(meta.digest_seed());
    h.update((tokens.len() as u64).to_le_bytes());
    for t in tokens {
        h.update(t.to_le_bytes());
    }
    let d = h.finalize();
    let mut out = [0u8; KEY_LEN];
    out.copy_from_slice(&d[..KEY_LEN]);
    out
}

/// The kvstore key under which the state blob for `key` is stored.
pub fn state_store_key(key: &[u8; KEY_LEN]) -> Vec<u8> {
    let mut v = Vec::with_capacity(6 + KEY_LEN * 2);
    v.extend_from_slice(b"state:");
    v.extend_from_slice(crate::util::hex::encode(key).as_bytes());
    v
}

/// The kvstore key under which the cheap token-id header for `key` is stored
/// (the semantic tier's verification source — see `crate::sketch`).
pub fn token_store_key(key: &[u8; KEY_LEN]) -> Vec<u8> {
    let mut v = Vec::with_capacity(4 + KEY_LEN * 2);
    v.extend_from_slice(b"tok:");
    v.extend_from_slice(crate::util::hex::encode(key).as_bytes());
    v
}

/// A candidate prefix range of a tokenized prompt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PromptRange {
    /// Number of prompt tokens this range covers (a strict prefix length).
    pub token_len: usize,
    pub key: [u8; KEY_LEN],
}

/// Compute catalog keys for a set of nested prefix lengths of `tokens`.
/// Lengths are deduplicated, clamped to the prompt length and sorted
/// ascending; zero-length ranges are dropped.
pub fn ranges_for(meta: &ModelMeta, tokens: &[u32], prefix_lens: &[usize]) -> Vec<PromptRange> {
    let mut lens: Vec<usize> = prefix_lens
        .iter()
        .map(|&l| l.min(tokens.len()))
        .filter(|&l| l > 0)
        .collect();
    lens.sort_unstable();
    lens.dedup();
    lens.into_iter()
        .map(|l| PromptRange { token_len: l, key: range_key(meta, &tokens[..l]) })
        .collect()
}

/// Result of a local-catalog lookup.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Lookup {
    /// No range is (probably) cached.
    Miss,
    /// The longest probable hit.
    Hit(PromptRange),
}

/// Peer-tagged lookup across one [`LocalCatalog`] per cache-box peer: the
/// longest range of sufficient length that *some* peer (probably) holds,
/// together with the index of every claiming peer — the fan-out set the
/// peer planner splits a multi-source chunk fetch across.  `catalogs[i]`
/// is peer `i`'s filter, merged by that peer's own `CatalogSync` loop;
/// each filter honours its own `min_hit_tokens`.  Returns `None` when no
/// peer claims any range.
pub fn lookup_tagged(
    catalogs: &[&LocalCatalog],
    ranges: &[PromptRange],
) -> Option<(PromptRange, Vec<usize>)> {
    // ranges_for yields ascending lengths; longest hit wins, like
    // LocalCatalog::lookup
    for r in ranges.iter().rev() {
        let claimers: Vec<usize> = catalogs
            .iter()
            .enumerate()
            .filter(|(_, c)| r.token_len >= c.min_hit_tokens && c.filter.contains(&r.key))
            .map(|(i, _)| i)
            .collect();
        if !claimers.is_empty() {
            return Some((r.clone(), claimers));
        }
    }
    None
}

/// Client-side catalog state: Bloom filter + sync cursor.
#[derive(Debug)]
pub struct LocalCatalog {
    pub filter: BloomFilter,
    /// Master-catalog version this filter has incorporated.
    pub synced_version: u64,
    /// Minimum range length worth fetching (paper §3.2: "a match of
    /// sufficient length"); ranges shorter than this are ignored at lookup.
    pub min_hit_tokens: usize,
}

impl LocalCatalog {
    pub fn new() -> Self {
        LocalCatalog {
            filter: BloomFilter::paper_default(),
            synced_version: 0,
            min_hit_tokens: 1,
        }
    }

    pub fn with_filter(filter: BloomFilter) -> Self {
        LocalCatalog { filter, synced_version: 0, min_hit_tokens: 1 }
    }

    /// Step 2 of the client flow: probe all candidate ranges, return the
    /// longest probable hit of sufficient length.
    pub fn lookup(&self, ranges: &[PromptRange]) -> Lookup {
        let mut best: Option<&PromptRange> = None;
        for r in ranges {
            if r.token_len >= self.min_hit_tokens && self.filter.contains(&r.key) {
                match best {
                    Some(b) if b.token_len >= r.token_len => {}
                    _ => best = Some(r),
                }
            }
        }
        match best {
            Some(r) => Lookup::Hit(r.clone()),
            None => Lookup::Miss,
        }
    }

    /// Step 3 (miss path): after uploading new states, reflect them locally
    /// so this client does not re-upload or re-miss its own entries.
    pub fn register(&mut self, ranges: &[PromptRange]) {
        for r in ranges {
            self.filter.insert(&r.key);
        }
    }

    pub fn register_key(&mut self, key: &[u8]) {
        self.filter.insert(key);
    }

    /// Probe the filter for a single key (upload dedup and fallback-probe
    /// warm-up checks; no `min_hit_tokens` filtering — that is a lookup
    /// concern, not a membership one).
    pub fn contains_key(&self, key: &[u8]) -> bool {
        self.filter.contains(key)
    }

    /// Apply a master-catalog delta (async sync, Figure 2 green arrow).
    pub fn apply_delta(&mut self, new_version: u64, keys: &[Vec<u8>]) {
        for k in keys {
            self.filter.insert(k);
        }
        self.synced_version = self.synced_version.max(new_version);
    }
}

impl Default for LocalCatalog {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop_n;

    fn meta() -> ModelMeta {
        ModelMeta::new("abcd1234")
    }

    #[test]
    fn key_depends_on_tokens_and_meta() {
        let m = meta();
        let k1 = range_key(&m, &[1, 2, 3]);
        let k2 = range_key(&m, &[1, 2, 4]);
        let k3 = range_key(&m, &[1, 2]);
        assert_ne!(k1, k2);
        assert_ne!(k1, k3);
        // different model hash → different key space
        let m2 = ModelMeta::new("ffff0000");
        assert_ne!(range_key(&m, &[1, 2, 3]), range_key(&m2, &[1, 2, 3]));
        // different quantization → different key (paper §3.1)
        let mut m3 = meta();
        m3.quant = "q4".into();
        assert_ne!(range_key(&m, &[1, 2, 3]), range_key(&m3, &[1, 2, 3]));
        // stable across calls
        assert_eq!(k1, range_key(&meta(), &[1, 2, 3]));
    }

    #[test]
    fn key_not_confusable_across_lengths() {
        // ensure the length prefix prevents [1,2]+[3] v [1]+[2,3] style issues
        let m = meta();
        let a = range_key(&m, &[0x00010002]);
        let b = range_key(&m, &[0x0001, 0x0002]);
        assert_ne!(a, b);
    }

    #[test]
    fn ranges_sorted_deduped_clamped() {
        let m = meta();
        let toks: Vec<u32> = (0..50).collect();
        let rs = ranges_for(&m, &toks, &[10, 25, 50, 120, 25, 0]);
        let lens: Vec<usize> = rs.iter().map(|r| r.token_len).collect();
        assert_eq!(lens, vec![10, 25, 50]);
        for r in &rs {
            assert_eq!(r.key, range_key(&m, &toks[..r.token_len]));
        }
    }

    #[test]
    fn lookup_returns_longest_hit() {
        let m = meta();
        let toks: Vec<u32> = (0..100).collect();
        let rs = ranges_for(&m, &toks, &[10, 40, 70, 100]);
        let mut cat = LocalCatalog::new();
        // register only the 10 and 70 ranges
        cat.register(&[rs[0].clone(), rs[2].clone()]);
        match cat.lookup(&rs) {
            Lookup::Hit(r) => assert_eq!(r.token_len, 70),
            other => panic!("expected hit, got {other:?}"),
        }
    }

    #[test]
    fn lookup_miss_when_nothing_registered() {
        let m = meta();
        let toks: Vec<u32> = (0..30).collect();
        let rs = ranges_for(&m, &toks, &[10, 20, 30]);
        let cat = LocalCatalog::new();
        assert_eq!(cat.lookup(&rs), Lookup::Miss);
    }

    #[test]
    fn min_hit_tokens_filters_short_ranges() {
        let m = meta();
        let toks: Vec<u32> = (0..100).collect();
        let rs = ranges_for(&m, &toks, &[5, 80]);
        let mut cat = LocalCatalog::new();
        cat.register(&rs);
        cat.min_hit_tokens = 10;
        // only the 80-range qualifies
        match cat.lookup(&rs[..1]) {
            Lookup::Miss => {}
            other => panic!("5-token range should be ignored, got {other:?}"),
        }
        match cat.lookup(&rs) {
            Lookup::Hit(r) => assert_eq!(r.token_len, 80),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn delta_sync_propagates_remote_entries() {
        let m = meta();
        let toks: Vec<u32> = (0..60).collect();
        let rs = ranges_for(&m, &toks, &[20, 40, 60]);

        // client A registers; its keys travel via the master log to client B
        let mut a = LocalCatalog::new();
        a.register(&rs);
        let log: Vec<Vec<u8>> = rs.iter().map(|r| r.key.to_vec()).collect();

        let mut b = LocalCatalog::new();
        assert_eq!(b.lookup(&rs), Lookup::Miss);
        b.apply_delta(3, &log);
        assert_eq!(b.synced_version, 3);
        match b.lookup(&rs) {
            Lookup::Hit(r) => assert_eq!(r.token_len, 60),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn tagged_lookup_names_every_claiming_peer() {
        let m = meta();
        let toks: Vec<u32> = (0..100).collect();
        let rs = ranges_for(&m, &toks, &[10, 40, 70, 100]);
        let mut a = LocalCatalog::new(); // peer 0: 10 and 70
        a.register(&[rs[0].clone(), rs[2].clone()]);
        let mut b = LocalCatalog::new(); // peer 1: 70 only
        b.register(&[rs[2].clone()]);
        let c = LocalCatalog::new(); // peer 2: nothing

        let (hit, peers) = lookup_tagged(&[&a, &b, &c], &rs).unwrap();
        assert_eq!(hit.token_len, 70, "longest claimed range wins");
        assert_eq!(peers, vec![0, 1], "both claimers named, empty peer not");

        // a range only one peer claims tags exactly that peer
        let short = &rs[..1];
        let (hit, peers) = lookup_tagged(&[&a, &b, &c], short).unwrap();
        assert_eq!(hit.token_len, 10);
        assert_eq!(peers, vec![0]);

        // nothing claimed anywhere -> None; empty peer set -> None
        assert!(lookup_tagged(&[&c], &rs).is_none());
        assert!(lookup_tagged(&[], &rs).is_none());

        // per-peer min_hit_tokens filters that peer's claims only
        let mut strict = LocalCatalog::new();
        strict.register(&rs);
        strict.min_hit_tokens = 1000;
        let (hit, peers) = lookup_tagged(&[&strict, &a], &rs).unwrap();
        assert_eq!((hit.token_len, peers), (70, vec![1]));
    }

    #[test]
    fn apply_delta_version_monotone() {
        let mut c = LocalCatalog::new();
        c.apply_delta(5, &[]);
        c.apply_delta(3, &[]); // stale delta must not regress the cursor
        assert_eq!(c.synced_version, 5);
    }

    #[test]
    fn no_false_negatives_property() {
        run_prop_n("catalog-no-false-negatives", 64, |g| {
            let m = ModelMeta::new(g.ascii_string(8));
            let n = g.usize_in(4, 200);
            let toks = g.tokens(n, 4096);
            let lens = [n / 4, n / 2, n];
            let rs = ranges_for(&m, &toks, &lens);
            let mut cat = LocalCatalog::new();
            cat.register(&rs);
            match cat.lookup(&rs) {
                Lookup::Hit(r) => assert_eq!(r.token_len, n, "longest wins"),
                Lookup::Miss => panic!("registered ranges must hit"),
            }
        });
    }

    #[test]
    fn state_store_key_format() {
        let k = range_key(&meta(), &[1, 2, 3]);
        let sk = state_store_key(&k);
        assert!(sk.starts_with(b"state:"));
        assert_eq!(sk.len(), 6 + 32);
    }

    #[test]
    fn token_store_key_format() {
        let k = range_key(&meta(), &[1, 2, 3]);
        let tk = token_store_key(&k);
        assert!(tk.starts_with(b"tok:"));
        assert_eq!(tk.len(), 4 + 32);
        assert_ne!(tk, state_store_key(&k));
    }
}
