//! RESP2 protocol codec (REdis Serialization Protocol).
//!
//! Exactly the framing real Redis speaks: simple strings `+OK\r\n`, errors
//! `-ERR ...\r\n`, integers `:42\r\n`, bulk strings `$5\r\nhello\r\n` (with
//! `$-1\r\n` as nil) and arrays `*N\r\n...`.  Requests are arrays of bulk
//! strings.  The codec is incremental: [`Decoder`] buffers partial frames
//! across reads, which the server relies on for pipelining.
//!
//! Bulk payloads are [`SharedBytes`]: the decoder's read buffer is a shared
//! allocation and every decoded `Bulk` is an O(1) *slice* of it, so a
//! multi-megabyte state blob travels socket → decoder → [`Value`] → store
//! without being copied.  The buffer is re-homed lazily (on the next `feed`)
//! once decoded values still reference it.

use std::borrow::Cow;
use std::io::{self, Read, Write};
use std::sync::Arc;

use crate::util::bytes::{copymeter, SharedBytes};

/// Maximum accepted bulk-string / array size (64 MB guards against
/// malformed length prefixes taking the server down).
pub const MAX_BULK: usize = 64 << 20;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Simple(String),
    Error(String),
    Int(i64),
    Bulk(SharedBytes),
    Nil,
    Array(Vec<Value>),
}

impl Value {
    pub fn ok() -> Value {
        Value::Simple("OK".into())
    }

    pub fn bulk_str(s: &str) -> Value {
        Value::Bulk(SharedBytes::copy_from(s.as_bytes()))
    }

    /// Wrap anything byte-like as a bulk string.
    pub fn bulk(b: impl Into<SharedBytes>) -> Value {
        Value::Bulk(b.into())
    }

    /// Interpret as UTF-8 text where possible (diagnostics).  Borrows the
    /// payload for the Simple/Error/Bulk cases; only `Int` allocates.
    pub fn as_text(&self) -> Option<Cow<'_, str>> {
        match self {
            Value::Simple(s) | Value::Error(s) => Some(Cow::Borrowed(s.as_str())),
            Value::Bulk(b) => std::str::from_utf8(b).ok().map(Cow::Borrowed),
            Value::Int(i) => Some(Cow::Owned(i.to_string())),
            _ => None,
        }
    }

    pub fn as_bulk(&self) -> Option<&[u8]> {
        match self {
            Value::Bulk(b) => Some(b.as_slice()),
            _ => None,
        }
    }

    /// Take the bulk payload out without copying.
    pub fn into_bulk(self) -> Option<SharedBytes> {
        match self {
            Value::Bulk(b) => Some(b),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn is_nil(&self) -> bool {
        matches!(self, Value::Nil)
    }

    /// Serialize into `out`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            Value::Simple(s) => {
                out.push(b'+');
                out.extend_from_slice(s.as_bytes());
                out.extend_from_slice(b"\r\n");
            }
            Value::Error(s) => {
                out.push(b'-');
                out.extend_from_slice(s.as_bytes());
                out.extend_from_slice(b"\r\n");
            }
            Value::Int(i) => {
                out.push(b':');
                out.extend_from_slice(i.to_string().as_bytes());
                out.extend_from_slice(b"\r\n");
            }
            Value::Bulk(b) => {
                out.push(b'$');
                out.extend_from_slice(b.len().to_string().as_bytes());
                out.extend_from_slice(b"\r\n");
                out.extend_from_slice(b);
                copymeter::add(b.len()); // the one unavoidable wire copy
                out.extend_from_slice(b"\r\n");
            }
            Value::Nil => out.extend_from_slice(b"$-1\r\n"),
            Value::Array(items) => {
                out.push(b'*');
                out.extend_from_slice(items.len().to_string().as_bytes());
                out.extend_from_slice(b"\r\n");
                for it in items {
                    it.encode_into(out);
                }
            }
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut v = Vec::new();
        self.encode_into(&mut v);
        v
    }
}

/// Build a RESP request (array of bulk strings) from command parts.
pub fn request(parts: &[&[u8]]) -> Value {
    Value::Array(parts.iter().map(|p| Value::bulk(*p)).collect())
}

/// Build a RESP request from already-shared parts (no payload copies).
pub fn request_shared(parts: Vec<SharedBytes>) -> Value {
    Value::Array(parts.into_iter().map(Value::Bulk).collect())
}

#[derive(Debug, thiserror::Error)]
pub enum RespError {
    #[error("protocol error: {0}")]
    Protocol(String),
    #[error(transparent)]
    Io(#[from] io::Error),
}

/// Incremental RESP decoder with a shared internal buffer.  Complete bulk
/// payloads are sliced out of the buffer without copying; the buffer is
/// abandoned to its outstanding slices and restarted when the next `feed`
/// arrives while values still hold references.
#[derive(Default)]
pub struct Decoder {
    buf: Arc<Vec<u8>>,
    pos: usize,
}

impl Decoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append raw bytes received from the socket.
    pub fn feed(&mut self, data: &[u8]) {
        match Arc::get_mut(&mut self.buf) {
            Some(buf) => {
                // sole owner: append in place, compacting the consumed
                // prefix occasionally to bound memory
                if self.pos > 0 && self.pos * 2 > buf.len() {
                    buf.drain(..self.pos);
                    self.pos = 0;
                }
                buf.extend_from_slice(data);
            }
            None => {
                // decoded values still reference the old buffer: re-home the
                // unconsumed tail (usually empty) into a fresh allocation
                let tail = &self.buf[self.pos..];
                let mut nb = Vec::with_capacity(tail.len() + data.len());
                nb.extend_from_slice(tail);
                copymeter::add(tail.len());
                nb.extend_from_slice(data);
                self.buf = Arc::new(nb);
                self.pos = 0;
            }
        }
    }

    /// Try to decode one complete value; `Ok(None)` means "need more bytes".
    pub fn next_value(&mut self) -> Result<Option<Value>, RespError> {
        let start = self.pos;
        match self.parse_at(start) {
            Ok(Some((v, consumed))) => {
                self.pos = consumed;
                Ok(Some(v))
            }
            Ok(None) => Ok(None),
            Err(e) => Err(e),
        }
    }

    fn find_crlf(&self, from: usize) -> Option<usize> {
        let b = &self.buf[from..];
        b.windows(2).position(|w| w == b"\r\n").map(|i| from + i)
    }

    fn parse_at(&self, at: usize) -> Result<Option<(Value, usize)>, RespError> {
        if at >= self.buf.len() {
            return Ok(None);
        }
        let t = self.buf[at];
        let Some(line_end) = self.find_crlf(at + 1) else {
            return Ok(None);
        };
        let line = std::str::from_utf8(&self.buf[at + 1..line_end])
            .map_err(|_| RespError::Protocol("non-utf8 header line".into()))?;
        let after = line_end + 2;
        match t {
            b'+' => Ok(Some((Value::Simple(line.to_string()), after))),
            b'-' => Ok(Some((Value::Error(line.to_string()), after))),
            b':' => {
                let i = line
                    .parse::<i64>()
                    .map_err(|_| RespError::Protocol(format!("bad integer {line:?}")))?;
                Ok(Some((Value::Int(i), after)))
            }
            b'$' => {
                let n = line
                    .parse::<i64>()
                    .map_err(|_| RespError::Protocol(format!("bad bulk len {line:?}")))?;
                if n < 0 {
                    return Ok(Some((Value::Nil, after)));
                }
                let n = n as usize;
                if n > MAX_BULK {
                    return Err(RespError::Protocol(format!("bulk too large: {n}")));
                }
                if self.buf.len() < after + n + 2 {
                    return Ok(None);
                }
                if &self.buf[after + n..after + n + 2] != b"\r\n" {
                    return Err(RespError::Protocol("bulk missing trailing CRLF".into()));
                }
                // zero-copy: the value is a slice of the read buffer
                let data = SharedBytes::from_arc_slice(Arc::clone(&self.buf), after, n);
                Ok(Some((Value::Bulk(data), after + n + 2)))
            }
            b'*' => {
                let n = line
                    .parse::<i64>()
                    .map_err(|_| RespError::Protocol(format!("bad array len {line:?}")))?;
                if n < 0 {
                    return Ok(Some((Value::Nil, after)));
                }
                let n = n as usize;
                if n > MAX_BULK / 16 {
                    return Err(RespError::Protocol(format!("array too large: {n}")));
                }
                let mut items = Vec::with_capacity(n);
                let mut cur = after;
                for _ in 0..n {
                    match self.parse_at(cur)? {
                        Some((v, next)) => {
                            items.push(v);
                            cur = next;
                        }
                        None => return Ok(None),
                    }
                }
                Ok(Some((Value::Array(items), cur)))
            }
            other => Err(RespError::Protocol(format!(
                "unexpected type byte {:?}",
                other as char
            ))),
        }
    }
}

/// One step of element-streamed decoding ([`Decoder::next_frame`]): either
/// a complete non-array value, or a consumed top-level array *header* whose
/// `n` elements will follow as standalone frames.
#[derive(Debug, PartialEq)]
pub enum Frame {
    /// An array header `*n\r\n` was consumed alone; the `n` elements are
    /// still in the stream, each decodable as its own value.
    Array(usize),
    /// A complete non-array value.
    Value(Value),
}

impl Decoder {
    /// Like [`Decoder::next_value`], but when the next frame is an array,
    /// consume only its *header* and hand the element count back — the
    /// elements stay in the stream for the caller to pull one at a time
    /// (each is a self-delimiting RESP value).  This is what lets a client
    /// stream a multi-bulk reply (`GETCHUNKS`) element-by-element instead
    /// of buffering the whole array: element `i` decodes the moment its
    /// bytes land, while elements `i+1..` are still in flight.  Non-array
    /// frames (and nil arrays) come back whole as [`Frame::Value`].
    pub fn next_frame(&mut self) -> Result<Option<Frame>, RespError> {
        let at = self.pos;
        if at >= self.buf.len() {
            return Ok(None);
        }
        if self.buf[at] != b'*' {
            return Ok(self.next_value()?.map(Frame::Value));
        }
        let Some(line_end) = self.find_crlf(at + 1) else {
            return Ok(None); // header line incomplete: need more bytes
        };
        let line = std::str::from_utf8(&self.buf[at + 1..line_end])
            .map_err(|_| RespError::Protocol("non-utf8 header line".into()))?;
        let n = line
            .parse::<i64>()
            .map_err(|_| RespError::Protocol(format!("bad array len {line:?}")))?;
        let after = line_end + 2;
        if n < 0 {
            self.pos = after;
            return Ok(Some(Frame::Value(Value::Nil)));
        }
        let n = n as usize;
        if n > MAX_BULK / 16 {
            return Err(RespError::Protocol(format!("array too large: {n}")));
        }
        self.pos = after;
        Ok(Some(Frame::Array(n)))
    }
}

/// Read values from a stream until one complete value is available.
pub fn read_value(stream: &mut impl Read, dec: &mut Decoder) -> Result<Value, RespError> {
    loop {
        if let Some(v) = dec.next_value()? {
            return Ok(v);
        }
        let mut chunk = [0u8; 16 * 1024];
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(RespError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "connection closed mid-frame",
            )));
        }
        dec.feed(&chunk[..n]);
    }
}

pub fn write_value(stream: &mut impl Write, v: &Value) -> Result<(), RespError> {
    let bytes = v.encode();
    stream.write_all(&bytes)?;
    Ok(())
}

/// Buffered reply writer for nonblocking sockets — the write-side twin of
/// [`Decoder`].  Encoded frames accumulate in one buffer; [`WriteBuf::flush_into`]
/// writes as much as the sink accepts and resumes mid-frame on the next
/// call, so a streamed `GETCHUNKS` reply to a slow reader never blocks the
/// serving loop and never tears a frame.
#[derive(Debug, Default)]
pub struct WriteBuf {
    buf: Vec<u8>,
}

impl Default for WriteBuf {
    fn default() -> Self {
        Self::new()
    }
}

impl WriteBuf {
    pub fn new() -> Self {
        WriteBuf { buf: Vec::new() }
    }

    /// Queue one encoded frame behind whatever is still unflushed.
    pub fn push(&mut self, v: &Value) {
        v.encode_into(&mut self.buf);
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Unflushed bytes queued (the read side gates on this high-water mark).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// Write as much as `w` accepts right now; returns the bytes written by
    /// this call.  `WouldBlock` is not an error — the remaining bytes stay
    /// queued and the next call resumes exactly where this one stopped.
    /// `Interrupted` retries; a sink that accepts zero bytes is reported as
    /// `WriteZero` so callers drop the connection instead of spinning.
    pub fn flush_into(&mut self, w: &mut impl Write) -> io::Result<usize> {
        let mut written = 0usize;
        while !self.buf.is_empty() {
            match w.write(&self.buf) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "sink accepted zero bytes",
                    ))
                }
                Ok(n) => {
                    self.buf.drain(..n);
                    written += n;
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e),
            }
        }
        Ok(written)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop_n;

    fn roundtrip(v: &Value) {
        let enc = v.encode();
        let mut d = Decoder::new();
        d.feed(&enc);
        let got = d.next_value().unwrap().unwrap();
        assert_eq!(&got, v);
        assert!(d.next_value().unwrap().is_none(), "no trailing value");
    }

    #[test]
    fn encode_known_frames() {
        assert_eq!(Value::ok().encode(), b"+OK\r\n");
        assert_eq!(Value::Int(42).encode(), b":42\r\n");
        assert_eq!(Value::bulk_str("hello").encode(), b"$5\r\nhello\r\n");
        assert_eq!(Value::Nil.encode(), b"$-1\r\n");
        assert_eq!(
            request(&[b"GET", b"key1"]).encode(),
            b"*2\r\n$3\r\nGET\r\n$4\r\nkey1\r\n"
        );
    }

    #[test]
    fn roundtrip_all_variants() {
        roundtrip(&Value::Simple("PONG".into()));
        roundtrip(&Value::Error("ERR boom".into()));
        roundtrip(&Value::Int(-7));
        roundtrip(&Value::bulk(vec![0u8, 1, 2, 255, 13, 10]));
        roundtrip(&Value::Nil);
        roundtrip(&Value::Array(vec![
            Value::Int(1),
            Value::bulk(&b"x"[..]),
            Value::Array(vec![Value::Nil]),
        ]));
    }

    #[test]
    fn decoded_bulk_shares_read_buffer() {
        let payload = vec![0xA5u8; 4096];
        let enc = Value::bulk(payload.clone()).encode();
        let mut d = Decoder::new();
        d.feed(&enc);
        let got = d.next_value().unwrap().unwrap();
        let Value::Bulk(b) = got else { panic!("expected bulk") };
        assert_eq!(b, payload);
        // the payload is a slice of the decoder's buffer, not a copy
        assert_eq!(b.backing_len(), enc.len());
        // the decoder survives the outstanding reference: the next feed
        // re-homes its buffer and keeps decoding correctly
        let enc2 = Value::Int(9).encode();
        d.feed(&enc2);
        assert_eq!(d.next_value().unwrap().unwrap(), Value::Int(9));
        assert_eq!(b, payload, "old slice still valid after re-home");
    }

    #[test]
    fn as_text_borrows_payloads() {
        assert_eq!(Value::Simple("PONG".into()).as_text().as_deref(), Some("PONG"));
        assert_eq!(Value::bulk_str("hi").as_text().as_deref(), Some("hi"));
        assert_eq!(Value::Int(-3).as_text().as_deref(), Some("-3"));
        assert_eq!(Value::Nil.as_text(), None);
        assert!(matches!(
            Value::bulk_str("hi").as_text(),
            Some(Cow::Borrowed(_))
        ));
        assert!(Value::bulk(vec![0xFFu8, 0xFE]).as_text().is_none());
    }

    #[test]
    fn incremental_feed_byte_at_a_time() {
        let v = request(&[b"SET", b"k", b"binary\r\nvalue\x00\xff"]);
        let enc = v.encode();
        let mut d = Decoder::new();
        for (i, b) in enc.iter().enumerate() {
            d.feed(std::slice::from_ref(b));
            let r = d.next_value().unwrap();
            if i + 1 < enc.len() {
                assert!(r.is_none(), "premature value at byte {i}");
            } else {
                assert_eq!(r.unwrap(), v);
            }
        }
    }

    #[test]
    fn pipelined_frames_decode_in_order() {
        let mut bytes = Vec::new();
        let vs = [Value::Int(1), Value::ok(), Value::bulk_str("x")];
        for v in &vs {
            v.encode_into(&mut bytes);
        }
        let mut d = Decoder::new();
        d.feed(&bytes);
        for v in &vs {
            assert_eq!(&d.next_value().unwrap().unwrap(), v);
        }
        assert!(d.next_value().unwrap().is_none());
    }

    #[test]
    fn next_frame_streams_array_elements() {
        // a 3-element array decodes as header + three standalone values
        let arr = Value::Array(vec![
            Value::bulk_str("head"),
            Value::bulk_str("c0"),
            Value::bulk_str("c1"),
        ]);
        let enc = arr.encode();
        let mut d = Decoder::new();
        // feed byte-at-a-time: the header frame appears as soon as its CRLF
        // lands, before any element bytes exist
        let mut fed = 0;
        let header_at = loop {
            d.feed(std::slice::from_ref(&enc[fed]));
            fed += 1;
            match d.next_frame().unwrap() {
                Some(Frame::Array(3)) => break fed,
                Some(other) => panic!("unexpected frame {other:?}"),
                None => {}
            }
        };
        assert_eq!(header_at, 4, "header is *3\\r\\n");
        d.feed(&enc[fed..]);
        for want in ["head", "c0", "c1"] {
            assert_eq!(d.next_value().unwrap().unwrap(), Value::bulk_str(want));
        }
        assert!(d.next_value().unwrap().is_none());
        // non-array frames pass through whole; nil arrays collapse to Nil
        let mut d = Decoder::new();
        d.feed(b"+OK\r\n*-1\r\n");
        assert_eq!(
            d.next_frame().unwrap().unwrap(),
            Frame::Value(Value::Simple("OK".into()))
        );
        assert_eq!(d.next_frame().unwrap().unwrap(), Frame::Value(Value::Nil));
    }

    #[test]
    fn oversized_bulk_rejected() {
        let mut d = Decoder::new();
        d.feed(format!("${}\r\n", MAX_BULK + 1).as_bytes());
        assert!(d.next_value().is_err());
    }

    #[test]
    fn garbage_type_byte_rejected() {
        let mut d = Decoder::new();
        d.feed(b"!weird\r\n");
        assert!(d.next_value().is_err());
    }

    #[test]
    fn roundtrip_property_random_payloads() {
        run_prop_n("resp-roundtrip", 128, |g| {
            let len = g.size(2000);
            let payload = g.bytes(len);
            let v = Value::Array(vec![
                Value::bulk(payload.clone()),
                Value::Int(g.rng.next_u64() as i64),
                Value::Nil,
            ]);
            let enc = v.encode();
            // split the encoding at a random point to exercise buffering
            let cut = g.usize_in(0, enc.len());
            let mut d = Decoder::new();
            d.feed(&enc[..cut]);
            let first = d.next_value().unwrap();
            if let Some(got) = first {
                assert_eq!(got, v);
            } else {
                d.feed(&enc[cut..]);
                assert_eq!(d.next_value().unwrap().unwrap(), v);
            }
        });
    }

    /// Decode `enc` fed as two fragments split at `cut` and assert the
    /// result is identical to the whole-buffer decode (`want`).
    fn decode_split(enc: &[u8], cut: usize, want: &[Value]) {
        let mut d = Decoder::new();
        let mut got = Vec::new();
        d.feed(&enc[..cut]);
        while let Some(v) = d.next_value().unwrap() {
            got.push(v);
        }
        d.feed(&enc[cut..]);
        while let Some(v) = d.next_value().unwrap() {
            got.push(v);
        }
        assert_eq!(got, want, "split at byte {cut} of {}", enc.len());
    }

    #[test]
    fn every_split_point_decodes_identically() {
        // frames chosen so cuts land inside bulk length headers, multi-bulk
        // headers, CRLF terminators, negative integers and binary payloads
        // that themselves contain CRLF
        let vs = vec![
            request(&[b"SET", b"key\r\nwith\r\ncrlf", b"\x00\xff\x0d\x0a"]),
            Value::Nil,
            Value::Int(-1234567890),
            Value::Error("BUSY server queue full".into()),
            Value::Array(vec![
                Value::bulk(vec![13u8; 37]),
                Value::Nil,
                Value::Simple("OK".into()),
            ]),
        ];
        let mut enc = Vec::new();
        for v in &vs {
            v.encode_into(&mut enc);
        }
        for cut in 0..=enc.len() {
            decode_split(&enc, cut, &vs);
        }
    }

    #[test]
    fn random_frame_sequences_survive_every_split() {
        run_prop_n("resp-every-split", 24, |g| {
            let n = 1 + g.size(3);
            let mut vs = Vec::new();
            for _ in 0..n {
                let kind = g.usize_in(0, 5);
                let v = match kind {
                    0 => Value::Simple("PONG".into()),
                    1 => Value::Error("ERR boom".into()),
                    2 => Value::Int(g.rng.next_u64() as i64),
                    3 => Value::Nil,
                    4 => {
                        let len = g.size(200);
                        Value::bulk(g.bytes(len))
                    }
                    _ => {
                        let len = g.size(64);
                        Value::Array(vec![Value::bulk(g.bytes(len)), Value::Int(7)])
                    }
                };
                vs.push(v);
            }
            let mut enc = Vec::new();
            for v in &vs {
                v.encode_into(&mut enc);
            }
            // identity holds for a cut at every byte boundary...
            for cut in 0..=enc.len() {
                decode_split(&enc, cut, &vs);
            }
            // ...and for the degenerate one-byte-per-feed dribble
            let mut d = Decoder::new();
            let mut got = Vec::new();
            for b in &enc {
                d.feed(std::slice::from_ref(b));
                while let Some(v) = d.next_value().unwrap() {
                    got.push(v);
                }
            }
            assert_eq!(got, vs);
        });
    }

    /// A sink modelling a non-blocking socket with a tiny send buffer: it
    /// accepts at most `cap` bytes per `write` call and at most `accept`
    /// bytes in total before reporting `WouldBlock`.
    struct CappedWriter {
        data: Vec<u8>,
        cap: usize,
        accept: usize,
    }

    impl io::Write for CappedWriter {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.accept == 0 {
                return Err(io::Error::from(io::ErrorKind::WouldBlock));
            }
            let n = buf.len().min(self.cap).min(self.accept);
            self.data.extend_from_slice(&buf[..n]);
            self.accept -= n;
            Ok(n)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn write_buf_resumes_partial_writes() {
        let vs = [
            Value::ok(),
            Value::bulk(vec![7u8; 300]),
            Value::Int(-5),
            Value::Error("BUSY server queue full".into()),
        ];
        let mut expect = Vec::new();
        for v in &vs {
            v.encode_into(&mut expect);
        }
        for cap in [1usize, 3, 7, 64, 1 << 20] {
            let mut wb = WriteBuf::new();
            for v in &vs {
                wb.push(v);
            }
            assert_eq!(wb.len(), expect.len());
            let mut sink = CappedWriter { data: Vec::new(), cap, accept: 0 };
            let mut rounds = 0usize;
            while !wb.is_empty() {
                // the "kernel" frees a dribble of send-buffer space, then
                // the next flush resumes exactly where the last stopped
                sink.accept += cap.min(11);
                let n = wb.flush_into(&mut sink).unwrap();
                assert!(n <= cap.min(11) + cap, "flushed more than the sink took");
                rounds += 1;
                assert!(rounds < 100_000, "flush wedged at cap {cap}");
            }
            assert_eq!(sink.data, expect, "cap {cap}");
            // an empty buffer flush is a no-op, not an error
            assert_eq!(wb.flush_into(&mut sink).unwrap(), 0);
        }
    }

    #[test]
    fn write_buf_partial_write_random_schedule() {
        run_prop_n("writebuf-resume", 64, |g| {
            let n = 1 + g.size(6);
            let mut wb = WriteBuf::new();
            let mut expect = Vec::new();
            for _ in 0..n {
                let len = g.size(400);
                let v = if g.bool() {
                    Value::bulk(g.bytes(len))
                } else {
                    Value::Int(g.rng.next_u64() as i64)
                };
                v.encode_into(&mut expect);
                wb.push(&v);
            }
            let mut sink = CappedWriter { data: Vec::new(), cap: usize::MAX, accept: 0 };
            while !wb.is_empty() {
                // random per-round send-buffer grants, including 0 (a flush
                // against a full buffer must WouldBlock-break, not error)
                sink.accept = g.size(97) - 1;
                sink.cap = 1 + g.size(31);
                let _ = wb.flush_into(&mut sink).unwrap();
            }
            assert_eq!(sink.data, expect);
        });
    }

    #[test]
    fn write_buf_reports_write_zero() {
        struct ZeroSink;
        impl io::Write for ZeroSink {
            fn write(&mut self, _: &[u8]) -> io::Result<usize> {
                Ok(0)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let mut wb = WriteBuf::new();
        wb.push(&Value::ok());
        let err = wb.flush_into(&mut ZeroSink).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::WriteZero);
    }
}
