//! Blocking, pipelined RESP client — the Hiredis analog the edge clients
//! link against.
//!
//! All cache-box operations the coordinator performs go through here:
//! state download (`GET`/`GETRANGE`), state upload (`SET`/`SPLICE`),
//! existence probes and the catalog-sync calls.  `pipeline`/`pipeline_req`
//! issue several commands in one write and read the replies back in order
//! (used by the upload path, which publishes a prompt's ranges in one round
//! trip).
//!
//! The **streaming** variant, [`KvClient::send_reqs`], writes the same
//! pipelined batch but hands back a [`StreamingReplies`] that yields each
//! reply as it is decoded off the socket instead of buffering the whole
//! batch.  This is what the range-download path rides: it issues one
//! `GETRANGE` per matched ECS3 chunk and verifies + inflates each chunk the
//! moment its reply lands, overlapping decode with the wire time of the
//! chunks still in flight.  An aborted consume must call
//! [`StreamingReplies::drain`] so the connection stays frame-synced for
//! whatever command follows (e.g. the full-blob fallback).
//!
//! Payload-carrying calls speak [`SharedBytes`] end to end: `get` returns a
//! slice of the receive buffer and `set_shared`/`splice` queue views of the
//! caller's blob, so no payload byte is copied between the serialized state
//! and the socket write.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::resp::{read_value, request, request_shared, Decoder, Frame, Value};
use crate::util::bytes::SharedBytes;

pub struct KvClient {
    stream: TcpStream,
    dec: Decoder,
    pub addr: String,
}

/// Build a `GETRANGE` request for a `len > 0` byte window at `start`.  The
/// server speaks Redis's inclusive-end encoding; this is the one place the
/// start/len → start/end conversion lives (used both by
/// [`KvClient::getrange`] and by pipelined range fetches).  Callers fetching
/// ECS3 state blobs must pass whole-chunk windows (`BlobLayout::prefix_rows`
/// / the chunk index) — per-chunk crcs and deflate streams only verify and
/// decode at chunk granularity.
pub fn getrange_req(key: &[u8], start: usize, len: usize) -> Value {
    assert!(len > 0, "GETRANGE request needs a non-empty window");
    request_shared(vec![
        SharedBytes::copy_from(b"GETRANGE"),
        key.into(),
        start.to_string().into_bytes().into(),
        (start + len - 1).to_string().into_bytes().into(),
    ])
}

impl KvClient {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(KvClient { stream, dec: Decoder::new(), addr: addr.to_string() })
    }

    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<Self> {
        let sock_addr: std::net::SocketAddr =
            addr.parse().with_context(|| format!("parse addr {addr}"))?;
        let stream = TcpStream::connect_timeout(&sock_addr, timeout)
            .with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(KvClient { stream, dec: Decoder::new(), addr: addr.to_string() })
    }

    pub fn set_io_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(timeout)?;
        self.stream.set_write_timeout(timeout)?;
        Ok(())
    }

    /// Issue one pre-built request and read its reply.
    fn exec_req(&mut self, req: &Value) -> Result<Value> {
        let mut buf = Vec::with_capacity(64);
        req.encode_into(&mut buf);
        self.stream.write_all(&buf)?;
        let v = read_value(&mut self.stream, &mut self.dec)?;
        if let Value::Error(e) = &v {
            bail!("server error: {e}");
        }
        Ok(v)
    }

    /// Issue one command and read its reply.
    pub fn command(&mut self, parts: &[&[u8]]) -> Result<Value> {
        self.exec_req(&request(parts))
    }

    /// Write a pipelined batch in one go and stream the replies back: the
    /// returned handle decodes each reply off the socket on demand, so the
    /// caller can process reply `i` while replies `i+1..` are still in
    /// flight.  Server-side errors come back in-place as [`Value::Error`].
    pub fn send_reqs(&mut self, reqs: &[Value]) -> Result<StreamingReplies<'_>> {
        let mut buf = Vec::new();
        for r in reqs {
            r.encode_into(&mut buf);
        }
        self.stream.write_all(&buf)?;
        Ok(StreamingReplies { remaining: reqs.len(), client: self })
    }

    /// Issue several pre-built requests in one write; replies come back in
    /// order.  Server-side errors are returned in-place (not turned into
    /// Err) so a batch with one failure still yields the other replies.
    /// Buffer-everything wrapper over [`KvClient::send_reqs`].
    pub fn pipeline_req(&mut self, reqs: &[Value]) -> Result<Vec<Value>> {
        let mut replies = self.send_reqs(reqs)?;
        let mut out = Vec::with_capacity(reqs.len());
        while let Some(v) = replies.next_reply()? {
            out.push(v);
        }
        Ok(out)
    }

    /// Issue several commands in one write; replies come back in order.
    pub fn pipeline(&mut self, cmds: &[Vec<Vec<u8>>]) -> Result<Vec<Value>> {
        let reqs: Vec<Value> = cmds
            .iter()
            .map(|c| {
                let parts: Vec<&[u8]> = c.iter().map(|p| p.as_slice()).collect();
                request(&parts)
            })
            .collect();
        self.pipeline_req(&reqs)
    }

    // -- typed helpers -------------------------------------------------------

    pub fn ping(&mut self) -> Result<()> {
        match self.command(&[b"PING"])? {
            Value::Simple(s) if s == "PONG" => Ok(()),
            other => Err(anyhow!("unexpected PING reply {other:?}")),
        }
    }

    pub fn set(&mut self, key: &[u8], value: &[u8]) -> Result<()> {
        match self.command(&[b"SET", key, value])? {
            Value::Simple(s) if s == "OK" => Ok(()),
            other => Err(anyhow!("unexpected SET reply {other:?}")),
        }
    }

    /// `SET` without copying the payload into the request: the wire write
    /// streams straight out of the shared blob.
    pub fn set_shared(&mut self, key: &[u8], value: SharedBytes) -> Result<()> {
        let req = request_shared(vec![SharedBytes::copy_from(b"SET"), key.into(), value]);
        match self.exec_req(&req)? {
            Value::Simple(s) if s == "OK" => Ok(()),
            other => Err(anyhow!("unexpected SET reply {other:?}")),
        }
    }

    pub fn get(&mut self, key: &[u8]) -> Result<Option<SharedBytes>> {
        match self.command(&[b"GET", key])? {
            Value::Bulk(b) => Ok(Some(b)),
            Value::Nil => Ok(None),
            other => Err(anyhow!("unexpected GET reply {other:?}")),
        }
    }

    /// Fetch `len` bytes of a value starting at byte `start` (token-row
    /// ranges of state blobs, but the server is layout-agnostic).  `None`
    /// when the key is absent; a short/empty result means the entry is
    /// smaller than the requested window.
    pub fn getrange(&mut self, key: &[u8], start: usize, len: usize) -> Result<Option<SharedBytes>> {
        if len == 0 {
            return Ok(Some(SharedBytes::empty()));
        }
        match self.exec_req(&getrange_req(key, start, len))? {
            Value::Bulk(b) => Ok(Some(b)),
            Value::Nil => Ok(None),
            other => Err(anyhow!("unexpected GETRANGE reply {other:?}")),
        }
    }

    /// Store `head ++ basekey[start, end) ++ tail` under `newkey`
    /// (end-exclusive) — the suffix-delta upload primitive.  Returns the
    /// assembled entry's length.
    pub fn splice(
        &mut self,
        newkey: &[u8],
        basekey: &[u8],
        start: usize,
        end: usize,
        head: SharedBytes,
        tail: SharedBytes,
    ) -> Result<usize> {
        let req = request_shared(vec![
            SharedBytes::copy_from(b"SPLICE"),
            newkey.into(),
            basekey.into(),
            start.to_string().into_bytes().into(),
            end.to_string().into_bytes().into(),
            head,
            tail,
        ]);
        match self.exec_req(&req)? {
            Value::Int(n) => Ok(n as usize),
            other => Err(anyhow!("unexpected SPLICE reply {other:?}")),
        }
    }

    /// `GETCHUNKS key m` — the server-push range fetch: the box parses its
    /// own copy of the entry and replies with a multi-bulk of `1 + k`
    /// elements (the ECS3 head, then each whole chunk covering an `m`-row
    /// prefix; `m = 0` asks for the head alone).  The reply comes back as a
    /// [`StreamingReplies`]-style handle over the array *elements*, so the
    /// caller decodes chunk `i` while chunk `i+1` is still on the wire —
    /// one round trip, no client-side offset math.  Terminal replies
    /// (`Nil` = key absent, `Error` = not a chunked entry / old server)
    /// are handed back whole for the caller to dispatch on.
    pub fn getchunks_stream(&mut self, key: &[u8], m: usize) -> Result<ChunksReply<'_>> {
        let m_s = m.to_string();
        let req = request(&[b"GETCHUNKS", key, m_s.as_bytes()]);
        let mut buf = Vec::with_capacity(64);
        req.encode_into(&mut buf);
        self.stream.write_all(&buf)?;
        loop {
            match self.dec.next_frame()? {
                Some(Frame::Array(n)) => {
                    return Ok(ChunksReply::Stream(StreamingReplies {
                        remaining: n,
                        client: self,
                    }));
                }
                Some(Frame::Value(v)) => return Ok(ChunksReply::Terminal(v)),
                None => {
                    let mut chunk = [0u8; 16 * 1024];
                    let n = self.stream.read(&mut chunk)?;
                    if n == 0 {
                        bail!("connection closed mid-frame");
                    }
                    self.dec.feed(&chunk[..n]);
                }
            }
        }
    }

    /// Keyspace bytes the box currently holds (`INFO` `used_bytes:` field) —
    /// the load signal the upload placement policy balances on.
    pub fn used_bytes(&mut self) -> Result<usize> {
        let info = self.info()?;
        parse_info_used_bytes(&info)
            .ok_or_else(|| anyhow!("INFO reply lacks a parseable used_bytes"))
    }

    pub fn del(&mut self, key: &[u8]) -> Result<bool> {
        Ok(self.command(&[b"DEL", key])?.as_int() == Some(1))
    }

    pub fn exists(&mut self, key: &[u8]) -> Result<bool> {
        Ok(self.command(&[b"EXISTS", key])?.as_int() == Some(1))
    }

    pub fn strlen(&mut self, key: &[u8]) -> Result<usize> {
        Ok(self.command(&[b"STRLEN", key])?.as_int().unwrap_or(0) as usize)
    }

    pub fn dbsize(&mut self) -> Result<usize> {
        Ok(self.command(&[b"DBSIZE"])?.as_int().unwrap_or(0) as usize)
    }

    pub fn flushall(&mut self) -> Result<()> {
        self.command(&[b"FLUSHALL"])?;
        Ok(())
    }

    pub fn info(&mut self) -> Result<String> {
        Ok(self
            .command(&[b"INFO"])?
            .as_text()
            .map(|c| c.into_owned())
            .unwrap_or_default())
    }

    pub fn shutdown_server(&mut self) -> Result<()> {
        let _ = self.command(&[b"SHUTDOWN"]);
        Ok(())
    }

    // -- catalog sync --------------------------------------------------------

    pub fn catalog_version(&mut self) -> Result<u64> {
        Ok(self.command(&[b"CAT.VERSION"])?.as_int().unwrap_or(0) as u64)
    }

    pub fn catalog_register(&mut self, key: &[u8]) -> Result<u64> {
        Ok(self.command(&[b"CAT.REGISTER", key])?.as_int().unwrap_or(0) as u64)
    }

    /// Pull catalog entries appended after `since`; returns (new_version, keys).
    pub fn catalog_delta(&mut self, since: u64) -> Result<(u64, Vec<Vec<u8>>)> {
        let since_s = since.to_string();
        match self.command(&[b"CAT.DELTA", since_s.as_bytes()])? {
            Value::Array(items) => {
                let mut it = items.into_iter();
                let ver = it
                    .next()
                    .and_then(|v| v.as_int())
                    .ok_or_else(|| anyhow!("CAT.DELTA missing version"))? as u64;
                let mut keys = Vec::new();
                for v in it {
                    match v {
                        Value::Bulk(b) => keys.push(b.to_vec()),
                        other => bail!("CAT.DELTA non-bulk entry {other:?}"),
                    }
                }
                Ok((ver, keys))
            }
            other => Err(anyhow!("unexpected CAT.DELTA reply {other:?}")),
        }
    }

    // -- sketch sync (the semantic tier's versioned sections) ----------------

    /// Append one encoded sketch section to the box's master sketch log.
    /// Legacy boxes answer `ERR unknown command`, surfaced as `Err` — the
    /// upload pipeline and sync loops treat that as "tier unavailable
    /// there", never as a failed upload.
    pub fn sketch_register(&mut self, section: &[u8]) -> Result<u64> {
        Ok(self
            .command(&[b"CAT.SREGISTER", section])?
            .as_int()
            .unwrap_or(0) as u64)
    }

    /// Pull sketch sections appended after `since`; returns
    /// (new_version, sections).  Sections are opaque bytes here — the
    /// `sketch` module's versioned decoder decides what is usable.
    pub fn sketch_delta(&mut self, since: u64) -> Result<(u64, Vec<SharedBytes>)> {
        let since_s = since.to_string();
        match self.command(&[b"CAT.SDELTA", since_s.as_bytes()])? {
            Value::Array(items) => {
                let mut it = items.into_iter();
                let ver = it
                    .next()
                    .and_then(|v| v.as_int())
                    .ok_or_else(|| anyhow!("CAT.SDELTA missing version"))?
                    as u64;
                let mut sections = Vec::new();
                for v in it {
                    match v {
                        Value::Bulk(b) => sections.push(b),
                        other => bail!("CAT.SDELTA non-bulk entry {other:?}"),
                    }
                }
                Ok((ver, sections))
            }
            other => Err(anyhow!("unexpected CAT.SDELTA reply {other:?}")),
        }
    }

    /// One page of the box's sorted key space: keys `[cursor, cursor+count)`
    /// plus the next cursor (`0` when the walk wrapped) — the repair
    /// sweep's window into what a box actually holds.
    pub fn scan_keys(&mut self, cursor: usize, count: usize) -> Result<(usize, Vec<Vec<u8>>)> {
        let c = cursor.to_string();
        let n = count.to_string();
        match self.command(&[b"SCAN", c.as_bytes(), n.as_bytes()])? {
            Value::Array(items) => {
                let mut it = items.into_iter();
                let next = it
                    .next()
                    .and_then(|v| v.as_int())
                    .ok_or_else(|| anyhow!("SCAN missing cursor"))? as usize;
                let mut keys = Vec::new();
                for v in it {
                    match v {
                        Value::Bulk(b) => keys.push(b.to_vec()),
                        other => bail!("SCAN non-bulk entry {other:?}"),
                    }
                }
                Ok((next, keys))
            }
            other => Err(anyhow!("unexpected SCAN reply {other:?}")),
        }
    }

    // -- gossip (SWIM fleet health over the sync wire) -----------------------

    /// One gossip exchange: push the local membership digest, receive the
    /// box's merged board (encoded `MembershipDigest` bytes).  Errors on
    /// boxes that predate `GOSSIP` surface as `Err` (the typed `ERR unknown
    /// command` reply) — sync loops swallow them, so gossip degrades to
    /// plain heartbeats against an old fleet.
    pub fn gossip_exchange(&mut self, digest: &[u8]) -> Result<SharedBytes> {
        match self.command(&[b"GOSSIP", digest])? {
            Value::Bulk(b) => Ok(b),
            other => Err(anyhow!("unexpected GOSSIP reply {other:?}")),
        }
    }

    /// Ask this box to probe `target` on our behalf (the indirect-probe
    /// relay): `true` iff the relay reached it within its budget.
    pub fn probe_relay(&mut self, target: &str) -> Result<bool> {
        Ok(self
            .command(&[b"PROBE.RELAY", target.as_bytes()])?
            .as_int()
            == Some(1))
    }
}

/// Extract the `used_bytes:` field from an `INFO` reply — the one place
/// the field name/format is interpreted, shared by [`KvClient::used_bytes`]
/// and callers that shape the `INFO` exchange themselves (the upload
/// placement probe).
pub fn parse_info_used_bytes(info: &str) -> Option<usize> {
    parse_info_field(info, "used_bytes")
}

/// Extract any numeric `name:value` field from an `INFO` reply (the format
/// is append-only `name:value\r\n` lines, so parsing by prefix stays
/// compatible across server generations that add fields).
pub fn parse_info_field(info: &str, name: &str) -> Option<usize> {
    info.lines()
        .find_map(|l| l.strip_prefix(name).and_then(|r| r.strip_prefix(':')))
        .and_then(|v| v.trim().parse::<usize>().ok())
}

/// Reply of a [`KvClient::getchunks_stream`] call.
pub enum ChunksReply<'a> {
    /// The multi-bulk head+chunks stream (`remaining() == 1 + k` elements).
    /// Consume every element or [`StreamingReplies::drain`] before issuing
    /// another command.
    Stream(StreamingReplies<'a>),
    /// A terminal single-value reply: `Nil` (key absent) or `Error` (entry
    /// is not a chunked state blob / server predates `GETCHUNKS`).
    Terminal(Value),
}

/// In-flight replies of one pipelined batch ([`KvClient::send_reqs`]).
/// Yields replies in request order, decoding each from the socket only when
/// asked — the batch is never buffered wholesale.  Also serves as the
/// element stream of one `GETCHUNKS` multi-bulk reply, where each "reply"
/// is the next array element.
pub struct StreamingReplies<'a> {
    remaining: usize,
    client: &'a mut KvClient,
}

impl StreamingReplies<'_> {
    /// Replies not yet read.
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    /// Block for the next reply; `Ok(None)` once the batch is exhausted.
    /// Server-side errors are returned in-place as [`Value::Error`].
    pub fn next_reply(&mut self) -> Result<Option<Value>> {
        if self.remaining == 0 {
            return Ok(None);
        }
        let v = read_value(&mut self.client.stream, &mut self.client.dec)?;
        self.remaining -= 1;
        Ok(Some(v))
    }

    /// Read and discard every outstanding reply, re-syncing the connection
    /// after an aborted streaming consume.  Must be called before issuing
    /// any further command on the client when a consume stops early;
    /// otherwise stale replies would be mis-attributed to later requests.
    pub fn drain(mut self) -> Result<()> {
        while self.next_reply()?.is_some() {}
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::server::KvServer;
    use super::*;

    fn spawn() -> (super::super::server::ServerHandle, KvClient) {
        let srv = KvServer::new(usize::MAX);
        let handle = srv.serve("127.0.0.1:0").unwrap();
        let client = KvClient::connect(&handle.addr_string()).unwrap();
        (handle, client)
    }

    #[test]
    fn ping_set_get_roundtrip() {
        let (_h, mut c) = spawn();
        c.ping().unwrap();
        c.set(b"key1", b"hello").unwrap();
        assert_eq!(c.get(b"key1").unwrap().as_deref(), Some(&b"hello"[..]));
        assert_eq!(c.get(b"missing").unwrap(), None);
        assert!(c.exists(b"key1").unwrap());
        assert_eq!(c.strlen(b"key1").unwrap(), 5);
        assert_eq!(c.dbsize().unwrap(), 1);
        assert!(c.del(b"key1").unwrap());
        assert_eq!(c.dbsize().unwrap(), 0);
    }

    #[test]
    fn large_binary_values() {
        let (_h, mut c) = spawn();
        // a realistic prompt-cache entry: a few MB of binary state
        let blob: Vec<u8> = (0..2_250_000u32)
            .map(|i| i.wrapping_mul(2654435761) as u8)
            .collect();
        c.set(b"state:abc", &blob).unwrap();
        let got = c.get(b"state:abc").unwrap().unwrap();
        assert_eq!(got.len(), blob.len());
        assert_eq!(got, blob);
    }

    #[test]
    fn shared_set_and_ranged_get() {
        let (_h, mut c) = spawn();
        let blob: Vec<u8> = (0u32..100_000).map(|i| (i % 251) as u8).collect();
        c.set_shared(b"blob", SharedBytes::new(blob.clone())).unwrap();
        assert_eq!(c.strlen(b"blob").unwrap(), blob.len());
        // windows come back exactly
        let win = c.getrange(b"blob", 1000, 500).unwrap().unwrap();
        assert_eq!(win, blob[1000..1500].to_vec());
        // zero-length request short-circuits client-side
        assert_eq!(c.getrange(b"blob", 0, 0).unwrap().unwrap().len(), 0);
        // windows past the end clamp; missing keys are None
        let tail = c.getrange(b"blob", blob.len() - 10, 100).unwrap().unwrap();
        assert_eq!(tail, blob[blob.len() - 10..].to_vec());
        assert_eq!(c.getrange(b"absent", 0, 10).unwrap(), None);
    }

    #[test]
    fn splice_over_network() {
        let (_h, mut c) = spawn();
        c.set(b"base", b"0123456789").unwrap();
        let n = c
            .splice(
                b"new",
                b"base",
                2,
                6,
                SharedBytes::copy_from(b"<<"),
                SharedBytes::copy_from(b">>"),
            )
            .unwrap();
        assert_eq!(n, 8);
        assert_eq!(c.get(b"new").unwrap().unwrap(), b"<<2345>>");
        // missing base is a typed error
        assert!(c
            .splice(b"x", b"gone", 0, 0, SharedBytes::empty(), SharedBytes::empty())
            .is_err());
        // connection still usable afterwards
        c.ping().unwrap();
    }

    #[test]
    fn pipeline_preserves_order() {
        let (_h, mut c) = spawn();
        let cmds: Vec<Vec<Vec<u8>>> = (0..20)
            .map(|i| {
                vec![
                    b"SET".to_vec(),
                    format!("k{i}").into_bytes(),
                    format!("v{i}").into_bytes(),
                ]
            })
            .collect();
        let replies = c.pipeline(&cmds).unwrap();
        assert_eq!(replies.len(), 20);
        assert!(replies.iter().all(|r| matches!(r, Value::Simple(s) if s == "OK")));
        for i in 0..20 {
            assert_eq!(
                c.get(format!("k{i}").as_bytes()).unwrap().unwrap(),
                format!("v{i}").into_bytes()
            );
        }
    }

    #[test]
    fn streaming_replies_yield_in_order_and_drain_resyncs() {
        let (_h, mut c) = spawn();
        c.set(b"k", b"0123456789").unwrap();
        let reqs = vec![
            getrange_req(b"k", 0, 3),
            getrange_req(b"k", 3, 3),
            getrange_req(b"k", 6, 4),
        ];
        let mut s = c.send_reqs(&reqs).unwrap();
        assert_eq!(s.remaining(), 3);
        assert_eq!(s.next_reply().unwrap().unwrap(), Value::bulk(&b"012"[..]));
        assert_eq!(s.remaining(), 2);
        // abort mid-batch: drain re-syncs the connection for later commands
        s.drain().unwrap();
        c.ping().unwrap();
        assert_eq!(c.get(b"k").unwrap().unwrap(), b"0123456789");
        // a full consume yields every reply in request order, then None
        let mut s = c.send_reqs(&reqs).unwrap();
        let mut got = Vec::new();
        while let Some(v) = s.next_reply().unwrap() {
            got.push(v);
        }
        assert_eq!(
            got,
            vec![
                Value::bulk(&b"012"[..]),
                Value::bulk(&b"345"[..]),
                Value::bulk(&b"6789"[..]),
            ]
        );
    }

    #[test]
    fn getchunks_streams_head_and_chunks_in_one_round_trip() {
        use crate::model::state::{BlobLayout, Compression, KvState};
        let (_h, mut c) = spawn();
        let (l, s, kh, d) = (2usize, 16usize, 1usize, 8usize);
        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = 10;
        for (i, x) in st.v.iter_mut().enumerate() {
            *x = (i % 97) as f32;
        }
        let ct = 4;
        let blob = st.serialize_prefix_opts(10, "h", Compression::None, ct);
        let lo = BlobLayout::new("h", l, kh, d).with_chunk_tokens(ct);
        c.set(b"state:x", &blob).unwrap();

        let mut stream = match c.getchunks_stream(b"state:x", 6).unwrap() {
            ChunksReply::Stream(s) => s,
            ChunksReply::Terminal(v) => panic!("expected stream, got {v:?}"),
        };
        assert_eq!(stream.remaining(), 1 + 2, "head + 2 whole chunks for 6 rows");
        let head = stream.next_reply().unwrap().unwrap();
        assert_eq!(head.as_bulk().unwrap(), &blob[..lo.payload_off(10)]);
        // abort mid-stream: drain re-syncs the connection
        stream.drain().unwrap();
        c.ping().unwrap();

        // full consume restores the exact prefix bytes
        let mut stream = match c.getchunks_stream(b"state:x", 10).unwrap() {
            ChunksReply::Stream(s) => s,
            ChunksReply::Terminal(v) => panic!("{v:?}"),
        };
        let mut got = Vec::new();
        while let Some(v) = stream.next_reply().unwrap() {
            got.extend_from_slice(v.as_bulk().unwrap());
        }
        assert_eq!(got, blob, "head ++ all chunks == the stored entry");

        // terminal replies: missing key is Nil, non-state entry is an error
        c.set(b"plain", b"hello").unwrap();
        assert!(matches!(
            c.getchunks_stream(b"absent", 4).unwrap(),
            ChunksReply::Terminal(Value::Nil)
        ));
        assert!(matches!(
            c.getchunks_stream(b"plain", 4).unwrap(),
            ChunksReply::Terminal(Value::Error(_))
        ));
        c.ping().unwrap();
    }

    #[test]
    fn used_bytes_parses_info() {
        let (_h, mut c) = spawn();
        let before = c.used_bytes().unwrap();
        let payload = [7u8; 10_000];
        c.set(b"k", &payload).unwrap();
        let after = c.used_bytes().unwrap();
        assert!(after >= before + 10_000, "{before} -> {after}");
    }

    #[test]
    fn catalog_sync_over_network() {
        let (_h, mut c) = spawn();
        assert_eq!(c.catalog_version().unwrap(), 0);
        c.catalog_register(b"hash-a").unwrap();
        c.catalog_register(b"hash-b").unwrap();
        let (v, keys) = c.catalog_delta(0).unwrap();
        assert_eq!(v, 2);
        assert_eq!(keys, vec![b"hash-a".to_vec(), b"hash-b".to_vec()]);
        let (v2, keys2) = c.catalog_delta(v).unwrap();
        assert_eq!(v2, 2);
        assert!(keys2.is_empty());
    }

    #[test]
    fn two_clients_share_state() {
        let (h, mut c1) = spawn();
        let mut c2 = KvClient::connect(&h.addr_string()).unwrap();
        c1.set(b"shared", b"from-c1").unwrap();
        assert_eq!(c2.get(b"shared").unwrap().unwrap(), b"from-c1");
    }

    #[test]
    fn unknown_command_is_error() {
        let (_h, mut c) = spawn();
        assert!(c.command(&[b"BOGUS"]).is_err());
        // connection still usable afterwards
        c.ping().unwrap();
    }

    #[test]
    fn eviction_under_memory_cap() {
        let srv = KvServer::new(3000);
        let h = srv.serve("127.0.0.1:0").unwrap();
        let mut c = KvClient::connect(&h.addr_string()).unwrap();
        for i in 0..10 {
            c.set(format!("k{i}").as_bytes(), &vec![0u8; 500]).unwrap();
        }
        let n = c.dbsize().unwrap();
        assert!(n < 10, "eviction must have occurred, have {n}");
        let info = c.info().unwrap();
        assert!(info.contains("evictions:"), "{info}");
    }

    #[test]
    fn info_fields_present() {
        let (_h, mut c) = spawn();
        c.set(b"a", b"x").unwrap();
        let info = c.info().unwrap();
        for field in ["keys:", "used_bytes:", "hits:", "misses:", "catalog_version:"] {
            assert!(info.contains(field), "missing {field} in {info}");
        }
    }

    #[test]
    fn connect_timeout_to_dead_port_fails_fast() {
        let t0 = std::time::Instant::now();
        let r = KvClient::connect_timeout("127.0.0.1:1", Duration::from_millis(300));
        assert!(r.is_err());
        assert!(t0.elapsed() < Duration::from_secs(5));
    }
}
