//! In-memory keyspace with LRU eviction under a byte budget — Redis
//! `maxmemory` + `allkeys-lru` semantics, the configuration that matters for
//! a cache box whose entries are multi-megabyte KV states on a 16 GB Pi.
//!
//! LRU is exact (not Redis's sampled approximation): a monotonic clock
//! stamps every access and eviction removes the stalest entries until the
//! budget holds.  Exactness makes the eviction integration tests
//! deterministic; the asymptotic behaviour under cache pressure is the same.
//!
//! Entries are [`SharedBytes`], so inserting a value decoded off the wire
//! and serving it back out of `GET`/`GETRANGE` are refcount operations, not
//! copies.  Loose views (a small slice pinning a much larger read buffer)
//! are compacted on insert so `entry_cost` — and therefore eviction — keeps
//! tracking real memory.

use std::collections::HashMap;

use crate::util::bytes::SharedBytes;

#[derive(Debug)]
struct Entry {
    data: SharedBytes,
    last_used: u64,
}

/// Byte-budgeted LRU keyspace.
#[derive(Debug)]
pub struct Store {
    map: HashMap<Vec<u8>, Entry>,
    clock: u64,
    used_bytes: usize,
    /// Maximum payload bytes held (keys counted too); `usize::MAX` = unbounded.
    pub max_bytes: usize,
    /// Cumulative eviction counter (INFO / diagnostics).
    pub evictions: u64,
    /// Hit/miss counters (INFO).
    pub hits: u64,
    pub misses: u64,
}

impl Default for Store {
    fn default() -> Self {
        Self::new(usize::MAX)
    }
}

impl Store {
    pub fn new(max_bytes: usize) -> Self {
        Store {
            map: HashMap::new(),
            clock: 0,
            used_bytes: 0,
            max_bytes,
            evictions: 0,
            hits: 0,
            misses: 0,
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn entry_cost(key: &[u8], data: &[u8]) -> usize {
        key.len() + data.len()
    }

    /// Insert/overwrite; evicts LRU entries if the budget would overflow.
    /// Returns false (and stores nothing) if the value alone exceeds the
    /// budget.  Accepts anything convertible into [`SharedBytes`]; the view
    /// is compacted if it pins a disproportionately large backing buffer.
    pub fn set(&mut self, key: &[u8], data: impl Into<SharedBytes>) -> bool {
        let data = data.into().detach_loose();
        let cost = Self::entry_cost(key, &data);
        if cost > self.max_bytes {
            return false;
        }
        let t = self.tick();
        if let Some(old) = self.map.remove(key) {
            self.used_bytes -= Self::entry_cost(key, &old.data);
        }
        self.used_bytes += cost;
        self.map.insert(key.to_vec(), Entry { data, last_used: t });
        self.evict_to_budget();
        true
    }

    fn evict_to_budget(&mut self) {
        while self.used_bytes > self.max_bytes {
            // exact LRU: find the stalest key
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    if let Some(e) = self.map.remove(&k) {
                        self.used_bytes -= Self::entry_cost(&k, &e.data);
                        self.evictions += 1;
                    }
                }
                None => break,
            }
        }
    }

    /// Fetch an entry as a shared view — an O(1) refcount bump, no payload
    /// copy.  Refreshes LRU and the hit/miss counters.
    pub fn get(&mut self, key: &[u8]) -> Option<SharedBytes> {
        let t = self.tick();
        match self.map.get_mut(key) {
            Some(e) => {
                e.last_used = t;
                self.hits += 1;
                Some(e.data.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// `GETRANGE`-shaped fetch: an O(1) shared subview of an entry, Redis
    /// semantics (inclusive `end`, clamped to the value; an empty or
    /// inverted range yields an empty view).  `None` when the key is
    /// absent.  Refreshes LRU and the hit/miss counters like
    /// [`Store::get`] — serving chunk ranges of a state blob must keep the
    /// blob warm, or partial matching would evict exactly the entries it
    /// reuses most.
    pub fn get_range(&mut self, key: &[u8], start: usize, end: usize) -> Option<SharedBytes> {
        let v = self.get(key)?;
        if start >= v.len() || end < start {
            return Some(SharedBytes::empty());
        }
        let end = end.min(v.len() - 1);
        Some(v.slice(start..end + 1))
    }

    /// Non-mutating existence check (does not refresh LRU or counters).
    pub fn contains(&self, key: &[u8]) -> bool {
        self.map.contains_key(key)
    }

    pub fn strlen(&self, key: &[u8]) -> Option<usize> {
        self.map.get(key).map(|e| e.data.len())
    }

    pub fn del(&mut self, key: &[u8]) -> bool {
        if let Some(e) = self.map.remove(key) {
            self.used_bytes -= Self::entry_cost(key, &e.data);
            true
        } else {
            false
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.used_bytes = 0;
    }

    pub fn keys(&self) -> impl Iterator<Item = &Vec<u8>> {
        self.map.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop_n;

    #[test]
    fn set_get_del() {
        let mut s = Store::default();
        assert!(s.set(b"a", vec![1, 2, 3]));
        assert_eq!(s.get(b"a").as_deref(), Some(&[1u8, 2, 3][..]));
        assert_eq!(s.strlen(b"a"), Some(3));
        assert!(s.contains(b"a"));
        assert!(s.del(b"a"));
        assert!(!s.del(b"a"));
        assert_eq!(s.get(b"a"), None);
        assert_eq!(s.len(), 0);
        assert_eq!(s.used_bytes(), 0);
    }

    #[test]
    fn get_returns_shared_view_not_copy() {
        let mut s = Store::default();
        let payload = vec![9u8; 256 * 1024];
        s.set(b"big", SharedBytes::new(payload.clone()));
        let a = s.get(b"big").unwrap();
        let b = s.get(b"big").unwrap();
        assert_eq!(a, payload);
        // both views are the same backing allocation as the stored entry
        assert_eq!(a.backing_len(), payload.len());
        assert_eq!(b.backing_len(), payload.len());
    }

    #[test]
    fn loose_views_are_compacted_on_insert() {
        let mut s = Store::default();
        let big = SharedBytes::new(vec![3u8; 1 << 20]);
        s.set(b"slice", big.slice(0..100));
        // entry_cost must reflect the 100 bytes, and the entry must not pin
        // the megabyte backing buffer
        assert_eq!(s.used_bytes(), 5 + 100);
        assert_eq!(s.get(b"slice").unwrap().backing_len(), 100);
    }

    #[test]
    fn get_range_semantics_and_lru_refresh() {
        let mut s = Store::default();
        s.set(b"k", b"hello world".to_vec());
        assert_eq!(s.get_range(b"k", 0, 4).unwrap(), b"hello");
        // inclusive end, clamped past the value length
        assert_eq!(s.get_range(b"k", 6, 999).unwrap(), b"world");
        // start beyond the value / inverted range → empty view, not None
        assert_eq!(s.get_range(b"k", 99, 100).unwrap().len(), 0);
        assert_eq!(s.get_range(b"k", 4, 2).unwrap().len(), 0);
        assert_eq!(s.get_range(b"gone", 0, 1), None);
        // the subview shares the stored backing allocation (zero-copy)
        assert_eq!(s.get_range(b"k", 0, 4).unwrap().backing_len(), 11);
        // and counts as an access: range-served entries stay warm
        let hits_before = s.hits;
        s.get_range(b"k", 0, 0);
        assert_eq!(s.hits, hits_before + 1);
    }

    #[test]
    fn overwrite_accounts_bytes() {
        let mut s = Store::default();
        s.set(b"k", vec![0; 100]);
        s.set(b"k", vec![0; 10]);
        assert_eq!(s.used_bytes(), 1 + 10);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut s = Store::new(3 * 11); // three 10-byte values with 1-byte keys
        s.set(b"a", vec![0; 10]);
        s.set(b"b", vec![0; 10]);
        s.set(b"c", vec![0; 10]);
        // touch "a" so "b" becomes LRU
        s.get(b"a");
        s.set(b"d", vec![0; 10]);
        assert!(s.contains(b"a"), "recently used survives");
        assert!(!s.contains(b"b"), "LRU evicted");
        assert!(s.contains(b"c") && s.contains(b"d"));
        assert_eq!(s.evictions, 1);
    }

    #[test]
    fn oversized_value_rejected() {
        let mut s = Store::new(100);
        assert!(!s.set(b"big", vec![0; 200]));
        assert_eq!(s.len(), 0);
        // and does not evict existing entries trying
        s.set(b"ok", vec![0; 50]);
        assert!(!s.set(b"big", vec![0; 200]));
        assert!(s.contains(b"ok"));
    }

    #[test]
    fn hit_miss_counters() {
        let mut s = Store::default();
        s.set(b"x", vec![1]);
        s.get(b"x");
        s.get(b"y");
        s.get(b"x");
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn budget_invariant_property() {
        run_prop_n("store-budget-invariant", 64, |g| {
            let budget = g.usize_in(64, 4096);
            let mut s = Store::new(budget);
            for _ in 0..g.size(200) {
                let klen = g.usize_in(1, 16);
                let key = g.bytes(klen);
                let vlen = g.usize_in(0, 512);
                s.set(&key, g.bytes(vlen));
                assert!(
                    s.used_bytes() <= budget,
                    "used {} > budget {budget}",
                    s.used_bytes()
                );
                // bookkeeping agrees with ground truth
                let truth: usize = s
                    .map
                    .iter()
                    .map(|(k, e)| k.len() + e.data.len())
                    .sum();
                assert_eq!(truth, s.used_bytes());
            }
        });
    }
}
