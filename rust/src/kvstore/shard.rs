//! Sharded store lock — N independent `Mutex<Store>` shards keyed by a
//! stable hash of the store key, so concurrent `GETRANGE`/`SET`/`SPLICE`
//! from many connections stop serializing on one box-wide mutex.
//!
//! Budget discipline: the global byte budget is partitioned *exactly*
//! across shards (shard `i` gets `max/n` plus one of the `max % n`
//! remainder bytes), so the fleet-consistent invariant
//! `Σ shard.used_bytes ≤ global max_bytes` holds by construction and each
//! shard keeps its own exact-LRU accounting.  Eviction is therefore
//! per-shard LRU rather than globally exact LRU — the same approximation
//! Redis Cluster and every sharded cache makes; with keys hashed uniformly
//! the per-shard working sets track the global one.
//!
//! The single-shard configuration is bit-for-bit the old behaviour
//! (`KvServer::new` defaults to it), and [`ShardedStore::lock`] keeps the
//! historical `server.store.lock().unwrap()` call sites compiling against
//! it; that shim panics on a multi-shard store rather than silently
//! returning a partial view.

use std::sync::{LockResult, Mutex, MutexGuard};

use super::store::Store;
use crate::util::bytes::SharedBytes;

/// Stable FNV-1a over the store key: cheap, dependency-free, and fixed
/// across runs so tests can place keys deterministically.
fn fnv1a(key: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in key {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// N independent byte-budgeted LRU shards behind one facade.
#[derive(Debug)]
pub struct ShardedStore {
    shards: Vec<Mutex<Store>>,
}

impl ShardedStore {
    /// Partition `max_bytes` exactly across `n_shards` stores.
    /// `usize::MAX` means unbounded — every shard stays unbounded too.
    pub fn new(max_bytes: usize, n_shards: usize) -> Self {
        let n = n_shards.max(1);
        let shards = (0..n)
            .map(|i| {
                let budget = if max_bytes == usize::MAX {
                    usize::MAX
                } else {
                    max_bytes / n + usize::from(i < max_bytes % n)
                };
                Mutex::new(Store::new(budget))
            })
            .collect();
        ShardedStore { shards }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard owns `key` (exposed so tests can colocate keys).
    pub fn shard_index(&self, key: &[u8]) -> usize {
        (fnv1a(key) % self.shards.len() as u64) as usize
    }

    /// The shard that owns `key`.
    pub fn shard(&self, key: &[u8]) -> &Mutex<Store> {
        &self.shards[self.shard_index(key)]
    }

    /// Shard by index (aggregation / diagnostics).
    pub fn shard_at(&self, i: usize) -> &Mutex<Store> {
        &self.shards[i]
    }

    /// Compatibility shim for the historical single-`Mutex<Store>` call
    /// sites (`server.store.lock().unwrap()`).  Only meaningful when the
    /// store has exactly one shard; a multi-shard store panics here — a
    /// partial view silently standing in for the whole keyspace is the
    /// kind of bug this type exists to prevent.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, Store>> {
        assert_eq!(
            self.shards.len(),
            1,
            "ShardedStore::lock() is the single-shard compatibility shim; \
             use shard()/shard_at() on a {}-shard store",
            self.shards.len()
        );
        self.shards[0].lock()
    }

    // -- keyed operations: lock only the owning shard --

    pub fn set(&self, key: &[u8], data: impl Into<SharedBytes>) -> bool {
        self.shard(key).lock().unwrap().set(key, data)
    }

    pub fn get(&self, key: &[u8]) -> Option<SharedBytes> {
        self.shard(key).lock().unwrap().get(key)
    }

    pub fn get_range(&self, key: &[u8], start: usize, end: usize) -> Option<SharedBytes> {
        self.shard(key).lock().unwrap().get_range(key, start, end)
    }

    pub fn contains(&self, key: &[u8]) -> bool {
        self.shard(key).lock().unwrap().contains(key)
    }

    pub fn strlen(&self, key: &[u8]) -> Option<usize> {
        self.shard(key).lock().unwrap().strlen(key)
    }

    pub fn del(&self, key: &[u8]) -> bool {
        self.shard(key).lock().unwrap().del(key)
    }

    // -- aggregates: fold over shards, locking one at a time --

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.lock().unwrap().is_empty())
    }

    pub fn used_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().used_bytes())
            .sum()
    }

    /// Global budget — the exact sum of the per-shard budgets
    /// (`usize::MAX` if unbounded).
    pub fn max_bytes(&self) -> usize {
        let mut total = 0usize;
        for s in &self.shards {
            let b = s.lock().unwrap().max_bytes;
            if b == usize::MAX {
                return usize::MAX;
            }
            total += b;
        }
        total
    }

    pub fn evictions(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().evictions)
            .sum()
    }

    pub fn hits(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().hits).sum()
    }

    pub fn misses(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().misses).sum()
    }

    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }

    /// All keys, collected across shards (diagnostics / repair sweeps).
    pub fn all_keys(&self) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(s.lock().unwrap().keys().cloned());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop_n;

    #[test]
    fn budget_partitions_exactly() {
        for max in [0usize, 1, 63, 64, 65, 1000, 1 << 20] {
            for n in [1usize, 2, 3, 7, 8, 16] {
                let s = ShardedStore::new(max, n);
                assert_eq!(s.n_shards(), n);
                assert_eq!(s.max_bytes(), max, "max={max} n={n}");
                // no shard deviates from the mean by more than a byte
                let budgets: Vec<usize> = (0..n)
                    .map(|i| s.shard_at(i).lock().unwrap().max_bytes)
                    .collect();
                let (lo, hi) = (budgets.iter().min().unwrap(), budgets.iter().max().unwrap());
                assert!(hi - lo <= 1, "uneven partition {budgets:?}");
            }
        }
    }

    #[test]
    fn unbounded_budget_stays_unbounded_per_shard() {
        let s = ShardedStore::new(usize::MAX, 8);
        assert_eq!(s.max_bytes(), usize::MAX);
        for i in 0..8 {
            assert_eq!(s.shard_at(i).lock().unwrap().max_bytes, usize::MAX);
        }
        assert!(s.set(b"k", vec![0u8; 1 << 20]));
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let s = ShardedStore::new(100, 0);
        assert_eq!(s.n_shards(), 1);
        assert_eq!(s.max_bytes(), 100);
    }

    #[test]
    fn keyed_ops_route_to_a_stable_shard() {
        let s = ShardedStore::new(usize::MAX, 8);
        for i in 0..64u32 {
            let key = format!("key-{i}").into_bytes();
            assert!(s.set(&key, key.clone()));
            assert_eq!(s.shard_index(&key), s.shard_index(&key), "stable");
            // the entry lives exactly in its owning shard
            let own = s.shard_index(&key);
            assert!(s.shard_at(own).lock().unwrap().contains(&key));
            for other in (0..8).filter(|o| *o != own) {
                assert!(!s.shard_at(other).lock().unwrap().contains(&key));
            }
        }
        assert_eq!(s.len(), 64);
    }

    #[test]
    fn facade_mirrors_store_semantics() {
        let s = ShardedStore::new(usize::MAX, 4);
        assert!(s.set(b"k", b"hello world".to_vec()));
        assert_eq!(s.get(b"k").as_deref(), Some(&b"hello world"[..]));
        assert_eq!(s.get_range(b"k", 0, 4).unwrap(), b"hello");
        assert_eq!(s.get_range(b"gone", 0, 4), None);
        assert_eq!(s.strlen(b"k"), Some(11));
        assert!(s.contains(b"k"));
        assert_eq!(s.hits(), 2);
        assert_eq!(s.misses(), 1);
        assert!(s.del(b"k"));
        assert!(!s.del(b"k"));
        assert!(s.is_empty());
        s.set(b"a", vec![1]);
        s.clear();
        assert_eq!(s.used_bytes(), 0);
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn single_shard_lock_shim_works() {
        let s = ShardedStore::new(usize::MAX, 1);
        s.lock().unwrap().set(b"a", vec![1, 2, 3]);
        assert_eq!(s.lock().unwrap().get(b"a").as_deref(), Some(&[1u8, 2, 3][..]));
    }

    #[test]
    #[should_panic(expected = "single-shard compatibility shim")]
    fn multi_shard_lock_shim_panics() {
        let s = ShardedStore::new(usize::MAX, 2);
        let _ = s.lock();
    }

    #[test]
    fn global_budget_invariant_across_shards() {
        run_prop_n("shard-global-budget", 64, |g| {
            let n = g.usize_in(1, 8);
            let budget = g.usize_in(128, 4096);
            let s = ShardedStore::new(budget, n);
            for _ in 0..g.size(200) {
                let key = g.bytes(g.usize_in(1, 12));
                let val = g.bytes(g.usize_in(0, 300));
                s.set(&key, val);
                assert!(
                    s.used_bytes() <= budget,
                    "used {} > global budget {budget} (n={n})",
                    s.used_bytes()
                );
                // each shard honours its own slice of the budget
                for i in 0..n {
                    let sh = s.shard_at(i).lock().unwrap();
                    assert!(sh.used_bytes() <= sh.max_bytes);
                }
            }
        });
    }

    #[test]
    fn eviction_is_shard_local() {
        // hammering one shard's keyspace must never evict another shard's
        // entries — per-shard LRU is independent by construction
        let s = ShardedStore::new(4096, 4);
        let cold_key = b"cold".to_vec();
        let cold_shard = s.shard_index(&cold_key);
        assert!(s.set(&cold_key, vec![0u8; 64]));
        let mut hot = 0u32;
        let mut i = 0u32;
        while hot < 200 {
            let key = format!("hot-{i}").into_bytes();
            i += 1;
            if s.shard_index(&key) == cold_shard {
                continue; // only pressure the *other* shards
            }
            s.set(&key, vec![1u8; 200]);
            hot += 1;
        }
        assert!(s.evictions() > 0, "pressure must actually evict");
        assert!(s.contains(&cold_key), "cold shard untouched by hot shards");
    }
}
