//! kvstore — the cache box (Redis 8 + Hiredis analog, DESIGN.md
//! §Substitutions).
//!
//! The paper stores prompt-cache entries in an off-the-shelf Redis on a
//! Raspberry Pi 5 with snapshotting disabled (pure in-memory), accessed from
//! C++ clients via Hiredis.  This module rebuilds that substrate:
//!
//! * [`resp`] — RESP2 wire protocol (the actual Redis framing), with
//!   zero-copy bulk payloads (`SharedBytes` slices of the read buffer);
//! * [`store`] — in-memory keyspace with LRU eviction under a memory cap
//!   (Redis `maxmemory` + `allkeys-lru`), holding shared views;
//! * [`server`] — threaded TCP server speaking RESP2: `GET SET DEL EXISTS
//!   STRLEN DBSIZE INFO FLUSHALL PING`, the byte-range pair
//!   `GETRANGE`/`SPLICE` powering range-aware state transfer, plus three
//!   catalog-sync commands (`CAT.VERSION`, `CAT.DELTA`, `CAT.REGISTER` —
//!   the master-catalog side of the paper's Figure 2);
//! * [`client`] — blocking pipelined client (Hiredis analog).

pub mod client;
pub mod resp;
pub mod server;
pub mod shard;
pub mod store;

pub use client::KvClient;
pub use resp::Value;
pub use server::{KvServer, ServeMode, ServerHandle};
pub use shard::ShardedStore;
pub use store::Store;
