//! RESP2 TCP server — the *cache box* process (Figure 1, middle).
//!
//! Two serving cores share one dispatcher ([`ServeMode`], `--serve`):
//!
//! * [`ServeMode::Threads`] — the historical one-OS-thread-per-connection
//!   loop (the paper has a handful of edge clients; Redis itself is
//!   single-threaded, so a thread-per-conn loop is a faithful stand-in at
//!   that scale).  Kept as the ablation baseline for `benches/fleet.rs`.
//! * [`ServeMode::Poll`] — the fleet-scale core: a single non-blocking
//!   readiness loop (`TcpStream::set_nonblocking` + polling, no runtime
//!   deps) owns every socket, the resumable [`Decoder`] tolerates frames
//!   split at any byte (`WouldBlock` mid-frame resumes where it left off),
//!   and replies accumulate in a per-connection [`WriteBuf`] so a streamed
//!   `GETCHUNKS` reply to a slow reader never blocks the loop and never
//!   tears a frame.  Decoded requests are executed by a small worker pool;
//!   one connection's requests stay strictly ordered (pipelining keeps its
//!   reply order) while different connections run concurrently against the
//!   sharded store.
//!
//! The keyspace behind both cores is a [`ShardedStore`] — N independent
//! `Mutex<Store>` shards keyed by store-key hash, each with its own exact
//! LRU under an exact partition of the global byte budget — so concurrent
//! `GETRANGE`/`SET`/`SPLICE` from many clients stop serializing on one
//! box-wide lock.
//!
//! [`Admission`] puts a bound on the pending-op queue: past `max_pending`
//! in-flight ops the box *sheds* with a `BUSY` error instead of queueing
//! without bound.  The client fabric treats `BUSY` as a one-free-replan
//! signal (like an absent-claimer Nil share), never a peer-health strike —
//! an overloaded box is alive, and striking it would amplify overload into
//! false churn.  `INFO` exports `sheds:` and `pending_peak:` so ledgers can
//! surface backpressure.
//!
//! Besides the classic string commands the box hosts the **master
//! catalog**: an append-only log of registered catalog keys that clients
//! pull incrementally (`CAT.DELTA`) to synchronize their local Bloom
//! filters (Figure 2, green arrow).
//!
//! Three commands power the zero-copy/suffix-delta transfer path.  Two are
//! byte-oriented (the server never interprets blob layouts — clients compute
//! all offsets from `model::state::BlobLayout`):
//!
//! * `GETRANGE key start end` — Redis-style inclusive byte range of a
//!   value, served as an O(1) slice of the stored entry (`Nil` when the key
//!   is absent, empty bulk when the range is).  ECS3 clients use it to pull
//!   a blob's head (header + chunk index) and then whole compressed chunks;
//!   the chunk-boundary arithmetic stays entirely client-side;
//! * `SPLICE newkey basekey start end head tail` — store
//!   `head ++ basekey[start, end) ++ tail` under `newkey` (end-exclusive).
//!   This is the delta-upload primitive: a client extending a cached prefix
//!   ships only its new suffix chunks, and the server splices them onto the
//!   prefix chunk bytes it already holds — compressed or not, since ECS3
//!   chunks are independent deflate streams.  Under sharding the base view
//!   is taken from the base key's shard and the new entry lands on its own
//!   shard; the two locks are never held together.
//!
//! The third is the one deliberate exception to layout-agnosticism
//! (ROADMAP "server-push streaming"):
//!
//! * `GETCHUNKS key m` — parse the stored entry's own ECS3 header + chunk
//!   index and reply with a multi-bulk of `1 + k` O(1) slices: the head,
//!   then each whole chunk covering an `m`-row prefix (`m` clamped to the
//!   entry; `m = 0` returns the head alone).  One request replaces the
//!   head round trip *plus* the per-chunk offset math on the client — and
//!   because the reply is a RESP array whose elements are self-delimiting,
//!   a streaming client still decodes chunk `i` while chunk `i+1` is on
//!   the wire.  Non-ECS3 entries (legacy v2 blobs, aliases, garbage) get a
//!   typed error so clients fall back to the GETRANGE compatibility path.
//!
//! Two commands make each cache box a **gossip blackboard** for the
//! SWIM-style fleet-health layer (`coordinator::membership`) — clients
//! never talk to each other directly, so the boxes they all sync with are
//! the natural merge points:
//!
//! * `GOSSIP digest` — merge a client's membership digest into the box's
//!   board (the pure [`PeerView::merge`] law per address) and reply with
//!   the merged board.  One client's verdict reaches every other client
//!   within one sync period.  The box **self-refutes**: a claim that this
//!   box is Suspect/Dead at incarnation `i ≥` its own bumps its own
//!   incarnation to `i + 1` and re-advertises `Up`, which out-competes the
//!   stale claim on every board it reaches — and because the bump is
//!   relative to the *claimed* incarnation, refutation survives a box
//!   restart that reset its counter to zero;
//! * `PROBE.RELAY addr` — dial `addr` with a short bounded budget and
//!   `PING` it, replying `1`/`0` — the third-party reachability check an
//!   indirect probe routes through before a circumstantial `Suspect →
//!   Dead` verdict commits.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::resp::{read_value, request, Decoder, RespError, Value, WriteBuf};
use super::shard::ShardedStore;
use crate::coordinator::membership::{MembershipDigest, PeerHealth, PeerView};
use crate::log_debug;
use crate::log_info;
use crate::util::bytes::SharedBytes;

/// Which serving core accepts connections (`--serve threads|poll`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeMode {
    /// One OS thread per connection over blocking sockets (ablation
    /// baseline; the PR 1–8 behaviour).
    Threads,
    /// Non-blocking readiness loop + worker pool (the fleet-scale core).
    Poll,
}

impl ServeMode {
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "threads" | "thread" => Some(ServeMode::Threads),
            "poll" | "nonblocking" => Some(ServeMode::Poll),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ServeMode::Threads => "threads",
            ServeMode::Poll => "poll",
        }
    }
}

/// The `BUSY` shed reply's error text.  The fabric keys on the `BUSY`
/// prefix to classify shed load as `Outcome::Overloaded` — alive but
/// saturated — rather than a health strike.
pub const BUSY_REPLY: &str = "BUSY server queue full";

fn busy_value() -> Value {
    Value::Error(BUSY_REPLY.into())
}

/// Bounded pending-op admission: past `max_pending` in-flight operations
/// the box sheds with [`BUSY_REPLY`] instead of queueing without bound.
/// `max_pending = 0` disables the bound (the historical behaviour).
#[derive(Debug)]
pub struct Admission {
    max_pending: usize,
    pending: AtomicUsize,
    peak: AtomicUsize,
    sheds: AtomicU64,
}

impl Admission {
    fn new(max_pending: usize) -> Self {
        Admission {
            max_pending,
            pending: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            sheds: AtomicU64::new(0),
        }
    }

    /// Claim one pending slot; `false` means the op was shed (and counted).
    pub fn try_enter(&self) -> bool {
        let prev = self.pending.fetch_add(1, Ordering::SeqCst);
        if self.max_pending != 0 && prev >= self.max_pending {
            self.pending.fetch_sub(1, Ordering::SeqCst);
            self.sheds.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        self.peak.fetch_max(prev + 1, Ordering::Relaxed);
        true
    }

    /// Release a slot claimed by a successful [`Admission::try_enter`].
    pub fn exit(&self) {
        self.pending.fetch_sub(1, Ordering::SeqCst);
    }

    pub fn max_pending(&self) -> usize {
        self.max_pending
    }

    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::SeqCst)
    }

    /// Operations shed with `BUSY` since start.
    pub fn sheds(&self) -> u64 {
        self.sheds.load(Ordering::Relaxed)
    }

    /// High-water mark of concurrently pending operations.
    pub fn peak_pending(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }
}

/// Master-catalog state: an append-only key log; version = entries appended.
///
/// Keys are stored as [`SharedBytes`] so a `CAT.DELTA` reply is built from
/// O(1) views of the log entries — no per-key payload copy per syncing
/// client.  Keys arriving off the wire (slices of a connection read buffer)
/// are compacted on insert so the log never pins whole read buffers.
#[derive(Debug, Default)]
pub struct MasterCatalog {
    log: Vec<SharedBytes>,
    /// Parallel append-only log of *sketch sections* (`sketch` module wire
    /// format) powering the semantic tier.  Kept separate from the key log
    /// so legacy clients that only pull `CAT.DELTA` never see sketch bytes
    /// — the tiers version independently.
    sketch_log: Vec<SharedBytes>,
}

impl MasterCatalog {
    pub fn version(&self) -> u64 {
        self.log.len() as u64
    }

    pub fn register(&mut self, key: impl Into<SharedBytes>) -> u64 {
        self.log.push(key.into().detach_loose());
        self.version()
    }

    /// Entries appended after `since` (capped to keep replies bounded).
    pub fn delta(&self, since: u64, cap: usize) -> (u64, &[SharedBytes]) {
        let from = (since as usize).min(self.log.len());
        let to = (from + cap).min(self.log.len());
        (to as u64, &self.log[from..to])
    }

    pub fn sketch_version(&self) -> u64 {
        self.sketch_log.len() as u64
    }

    /// Append one opaque sketch section.  The box never decodes it — the
    /// section's magic/version is a client-side contract, so a box can
    /// relay formats newer than itself.
    pub fn sketch_register(&mut self, section: impl Into<SharedBytes>) -> u64 {
        self.sketch_log.push(section.into().detach_loose());
        self.sketch_version()
    }

    /// Sketch sections appended after `since` (capped like [`Self::delta`]).
    pub fn sketch_delta(&self, since: u64, cap: usize) -> (u64, &[SharedBytes]) {
        let from = (since as usize).min(self.sketch_log.len());
        let to = (from + cap).min(self.sketch_log.len());
        (to as u64, &self.sketch_log[from..to])
    }
}

/// Shared server state.
pub struct KvServer {
    pub store: ShardedStore,
    pub catalog: Mutex<MasterCatalog>,
    /// Admission control shared by both serving cores.
    pub admission: Admission,
    stop: AtomicBool,
    /// Live connection handles, force-closed on shutdown (real Redis's
    /// SHUTDOWN drops client connections too).  Keyed by a per-connection
    /// id so a connection prunes its own handle on exit — a long-lived
    /// server must not retain one dead `TcpStream` per connection ever
    /// accepted.
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_conn_id: AtomicU64,
    /// Simulated per-command processing delay (cache-box CPU time); zero by
    /// default — the link shaping lives client-side in `netsim`.
    pub op_delay: std::time::Duration,
    /// The gossip blackboard: every `GOSSIP` exchange merges the caller's
    /// digest in and replies with the merged view.
    gossip: Mutex<MembershipDigest>,
    /// This box's canonical gossip identity (the bound address, set by
    /// `serve`); `None` until serving, which disables self-refutation.
    self_addr: Mutex<Option<String>>,
    /// This box's own incarnation — bumped past any gossiped claim of its
    /// own suspicion/death (the SWIM subject-refutes rule).
    own_inc: AtomicU64,
    /// Self-refutations issued (stale claims of this box's death heard and
    /// out-advertised).
    refuted: AtomicU64,
}

fn parse_index(arg: &[u8]) -> Option<usize> {
    std::str::from_utf8(arg).ok()?.parse::<usize>().ok()
}

/// Build the `GETCHUNKS` reply for a stored ECS3 entry: the head (header +
/// chunk index) followed by each whole chunk covering an `m`-row prefix,
/// every element an O(1) shared slice of the stored bytes.  `None` when the
/// entry is not a well-formed chunked state blob (v2, alias, truncated,
/// index crc mismatch) — the dispatcher turns that into a typed error and
/// the client falls back to the byte-oriented GETRANGE path.
fn getchunks_reply(blob: &SharedBytes, m: usize) -> Option<Value> {
    use crate::model::state::{read_chunk_index, BlobLayout, KvState};
    let hdr = KvState::peek_header(blob).ok()?;
    let (ct, entries) = read_chunk_index(blob)?;
    let lo = BlobLayout::new(&hdr.model_hash, hdr.n_layers, hdr.n_kv_heads, hdr.head_dim)
        .with_chunk_tokens(ct);
    let head_len = lo.payload_off(hdr.n_tokens);
    if blob.len() < head_len {
        return None;
    }
    let k = lo.prefix_chunks(m.min(hdr.n_tokens));
    let mut items = Vec::with_capacity(k + 1);
    items.push(Value::Bulk(blob.slice(0..head_len)));
    let mut off = head_len;
    for e in entries.iter().take(k) {
        let len = e.len as usize;
        if off + len > blob.len() {
            return None; // index promises more bytes than the entry holds
        }
        items.push(Value::Bulk(blob.slice(off..off + len)));
        off += len;
    }
    Some(Value::Array(items))
}

impl KvServer {
    /// Single-shard, unbounded-admission server — bit-for-bit the
    /// historical behaviour (and what `store.lock()` call sites expect).
    pub fn new(max_bytes: usize) -> Arc<Self> {
        Self::configure(max_bytes, 1, 0)
    }

    /// Full configuration: `shards` independent store locks partitioning
    /// `max_bytes` exactly, and a `max_pending` admission bound
    /// (`0` = unbounded).
    pub fn configure(max_bytes: usize, shards: usize, max_pending: usize) -> Arc<Self> {
        Arc::new(KvServer {
            store: ShardedStore::new(max_bytes, shards),
            catalog: Mutex::new(MasterCatalog::default()),
            admission: Admission::new(max_pending),
            stop: AtomicBool::new(false),
            conns: Mutex::new(HashMap::new()),
            next_conn_id: AtomicU64::new(0),
            op_delay: std::time::Duration::ZERO,
            gossip: Mutex::new(MembershipDigest::default()),
            self_addr: Mutex::new(None),
            own_inc: AtomicU64::new(0),
            refuted: AtomicU64::new(0),
        })
    }

    /// Self-refutations this box has issued against gossiped claims of its
    /// own suspicion/death.
    pub fn gossip_refutations(&self) -> u64 {
        self.refuted.load(Ordering::Relaxed)
    }

    /// A snapshot of the box's merged gossip board (tests/benches).
    pub fn gossip_board(&self) -> MembershipDigest {
        self.gossip.lock().unwrap().clone()
    }

    /// Bind and serve on `addr` with the thread-per-connection core (the
    /// historical entry point; see [`KvServer::serve_with`]).
    pub fn serve(self: &Arc<Self>, addr: &str) -> Result<ServerHandle> {
        self.serve_with(addr, ServeMode::Threads)
    }

    /// Bind and serve on `addr` (use port 0 for an ephemeral port) with the
    /// chosen serving core.  Returns a handle carrying the bound address
    /// and the serving thread.
    pub fn serve_with(self: &Arc<Self>, addr: &str, mode: ServeMode) -> Result<ServerHandle> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        // the bound address is this box's gossip identity — what clients'
        // digests key its health under, and what self-refutation watches for
        *self.self_addr.lock().unwrap() = Some(local.to_string());
        let srv = Arc::clone(self);
        let accept_thread = match mode {
            ServeMode::Threads => std::thread::Builder::new()
                .name("kv-accept".into())
                .spawn(move || srv.accept_loop_threads(listener, local))?,
            ServeMode::Poll => {
                listener.set_nonblocking(true)?;
                std::thread::Builder::new()
                    .name("kv-poll".into())
                    .spawn(move || srv.poll_loop(listener, local))?
            }
        };
        Ok(ServerHandle { server: Arc::clone(self), addr: local, accept_thread: Some(accept_thread) })
    }

    fn accept_loop_threads(self: Arc<Self>, listener: TcpListener, local: std::net::SocketAddr) {
        log_info!("kvstore", "cache box listening on {local} (threads)");
        for conn in listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let srv2 = Arc::clone(&self);
                    let _ = std::thread::Builder::new()
                        .name("kv-conn".into())
                        .spawn(move || srv2.handle_conn(stream));
                }
                Err(e) => {
                    log_debug!("kvstore", "accept error: {e}");
                }
            }
        }
    }

    fn handle_conn(&self, mut stream: TcpStream) {
        let _ = stream.set_nodelay(true);
        let conn_id = self.next_conn_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            self.conns.lock().unwrap().insert(conn_id, clone);
        }
        self.serve_conn(&mut stream);
        // prune on every exit path: `conns` tracks live connections only
        self.conns.lock().unwrap().remove(&conn_id);
    }

    fn serve_conn(&self, stream: &mut TcpStream) {
        let mut dec = Decoder::new();
        let mut out = Vec::with_capacity(64 * 1024);
        loop {
            if self.stop.load(Ordering::SeqCst) {
                return;
            }
            let req = match read_value(stream, &mut dec) {
                Ok(v) => v,
                Err(RespError::Io(_)) => return, // client hung up
                Err(RespError::Protocol(msg)) => {
                    let _ = stream.write_all(&Value::Error(format!("ERR {msg}")).encode());
                    return;
                }
            };
            let reply = self.admit_dispatch(req);
            let shutdown = matches!(&reply, Value::Simple(s) if s == "SHUTTING DOWN");
            out.clear();
            reply.encode_into(&mut out);
            // Drain any further pipelined requests already buffered before
            // flushing, so pipelined batches get answered in one write.  A
            // protocol error mid-batch is surfaced as an error reply and the
            // connection is closed, exactly like the top-of-loop path —
            // swallowing it would leave the stream desynced, with the peer
            // waiting on replies that can never be framed correctly again.
            loop {
                match dec.next_value() {
                    Ok(Some(req)) => {
                        let r = self.admit_dispatch(req);
                        r.encode_into(&mut out);
                    }
                    Ok(None) => break,
                    Err(RespError::Protocol(msg)) => {
                        Value::Error(format!("ERR {msg}")).encode_into(&mut out);
                        let _ = stream.write_all(&out);
                        return;
                    }
                    Err(RespError::Io(_)) => return, // unreachable for a decoder
                }
            }
            if stream.write_all(&out).is_err() {
                return;
            }
            if shutdown {
                return;
            }
        }
    }

    /// Admission-gated dispatch: every serving path routes through this so
    /// a saturated box sheds with `BUSY` instead of queueing without bound.
    pub fn admit_dispatch(&self, req: Value) -> Value {
        if !self.admission.try_enter() {
            return busy_value();
        }
        let r = self.dispatch(req);
        self.admission.exit();
        r
    }

    pub fn dispatch(&self, req: Value) -> Value {
        if !self.op_delay.is_zero() {
            std::thread::sleep(self.op_delay);
        }
        let Value::Array(parts) = req else {
            return Value::Error("ERR expected array request".into());
        };
        let mut args: Vec<SharedBytes> = Vec::with_capacity(parts.len());
        for p in parts {
            match p {
                Value::Bulk(b) => args.push(b),
                Value::Simple(s) => args.push(s.into_bytes().into()),
                _ => return Value::Error("ERR request items must be bulk strings".into()),
            }
        }
        let Some(cmd) = args.first() else {
            return Value::Error("ERR empty request".into());
        };
        let cmd = String::from_utf8_lossy(cmd).to_ascii_uppercase();
        match (cmd.as_str(), args.len()) {
            ("PING", 1) => Value::Simple("PONG".into()),
            ("SET", 3) => {
                // the stored entry shares the wire buffer's allocation
                let ok = self.store.set(&args[1], args[2].clone());
                if ok {
                    Value::ok()
                } else {
                    Value::Error("OOM value exceeds maxmemory".into())
                }
            }
            ("GET", 2) => match self.store.get(&args[1]) {
                Some(v) => Value::Bulk(v),
                None => Value::Nil,
            },
            ("GETRANGE", 4) => {
                let (Some(start), Some(end)) =
                    (parse_index(&args[2]), parse_index(&args[3]))
                else {
                    return Value::Error("ERR bad range".into());
                };
                // Redis semantics (inclusive end, clamped, empty bulk for an
                // empty range) live in Store::get_range; the server stays a
                // dispatcher.  Chunk alignment is a *client* concern — the
                // box never interprets blob layouts.
                match self.store.get_range(&args[1], start, end) {
                    None => Value::Nil,
                    Some(v) => Value::Bulk(v),
                }
            }
            ("GETCHUNKS", 3) => {
                let Some(m) = parse_index(&args[2]) else {
                    return Value::Error("ERR bad row count".into());
                };
                // the shard lock is held only for the O(1) entry lookup;
                // slicing the reply works on the shared view outside it
                let blob = self.store.get(&args[1]);
                match blob {
                    None => Value::Nil,
                    Some(blob) => match getchunks_reply(&blob, m) {
                        Some(v) => v,
                        None => Value::Error("ERR not a chunked state entry".into()),
                    },
                }
            }
            ("SPLICE", 7) => {
                let (Some(start), Some(end)) =
                    (parse_index(&args[3]), parse_index(&args[4]))
                else {
                    return Value::Error("ERR bad splice range".into());
                };
                // the base view escapes its shard's lock as an O(1) shared
                // clone; the new entry may hash to a *different* shard, so
                // the set below takes its own lock — never two at once
                let Some(base) = self.store.get(&args[2]) else {
                    return Value::Error("ERR splice base missing".into());
                };
                if start > end || end > base.len() {
                    return Value::Error(format!(
                        "ERR splice range [{start}, {end}) out of bounds (base {} bytes)",
                        base.len()
                    ));
                }
                let head = &args[5];
                let tail = &args[6];
                let mut v = Vec::with_capacity(head.len() + (end - start) + tail.len());
                v.extend_from_slice(head);
                v.extend_from_slice(&base[start..end]);
                v.extend_from_slice(tail);
                let n = v.len();
                if self.store.set(&args[1], v) {
                    Value::Int(n as i64)
                } else {
                    Value::Error("OOM value exceeds maxmemory".into())
                }
            }
            ("DEL", 2) => Value::Int(self.store.del(&args[1]) as i64),
            ("EXISTS", 2) => Value::Int(self.store.contains(&args[1]) as i64),
            ("STRLEN", 2) => match self.store.strlen(&args[1]) {
                Some(n) => Value::Int(n as i64),
                None => Value::Int(0),
            },
            ("DBSIZE", 1) => Value::Int(self.store.len() as i64),
            ("FLUSHALL", 1) => {
                self.store.clear();
                Value::ok()
            }
            ("INFO", 1) => {
                let c = self.catalog.lock().unwrap();
                Value::bulk(
                    format!(
                        "# edgecache cache box\r\nkeys:{}\r\nused_bytes:{}\r\nevictions:{}\r\nhits:{}\r\nmisses:{}\r\ncatalog_version:{}\r\nshards:{}\r\nsheds:{}\r\npending_peak:{}\r\n",
                        self.store.len(),
                        self.store.used_bytes(),
                        self.store.evictions(),
                        self.store.hits(),
                        self.store.misses(),
                        c.version(),
                        self.store.n_shards(),
                        self.admission.sheds(),
                        self.admission.peak_pending(),
                    )
                    .into_bytes(),
                )
            }
            ("CAT.VERSION", 1) => Value::Int(self.catalog.lock().unwrap().version() as i64),
            ("CAT.REGISTER", 2) => {
                // O(1) view of the wire buffer; register compacts loose ones
                let v = self.catalog.lock().unwrap().register(args[1].clone());
                Value::Int(v as i64)
            }
            ("CAT.DELTA", 2) => {
                let since = match std::str::from_utf8(&args[1])
                    .ok()
                    .and_then(|s| s.parse::<u64>().ok())
                {
                    Some(v) => v,
                    None => return Value::Error("ERR bad since".into()),
                };
                let cat = self.catalog.lock().unwrap();
                let (ver, keys) = cat.delta(since, 100_000);
                let mut items = Vec::with_capacity(keys.len() + 1);
                items.push(Value::Int(ver as i64));
                items.extend(keys.iter().map(|k| Value::bulk(k.clone())));
                Value::Array(items)
            }
            ("CAT.SREGISTER", 2) => {
                let v = self.catalog.lock().unwrap().sketch_register(args[1].clone());
                Value::Int(v as i64)
            }
            ("CAT.SDELTA", 2) => {
                let since = match std::str::from_utf8(&args[1])
                    .ok()
                    .and_then(|s| s.parse::<u64>().ok())
                {
                    Some(v) => v,
                    None => return Value::Error("ERR bad since".into()),
                };
                let cat = self.catalog.lock().unwrap();
                let (ver, sections) = cat.sketch_delta(since, 100_000);
                let mut items = Vec::with_capacity(sections.len() + 1);
                items.push(Value::Int(ver as i64));
                items.extend(sections.iter().map(|s| Value::bulk(s.clone())));
                Value::Array(items)
            }
            ("SCAN", 3) => {
                let (Some(cursor), Some(count)) =
                    (parse_index(&args[1]), parse_index(&args[2]))
                else {
                    return Value::Error("ERR bad cursor".into());
                };
                // sorted snapshot so a cursor walk is stable across calls
                // modulo concurrent inserts/evictions — good enough for the
                // repair sweep, which re-verifies everything it touches
                let mut keys = self.store.all_keys();
                keys.sort_unstable();
                let from = cursor.min(keys.len());
                let to = (from + count.max(1)).min(keys.len());
                let next = if to >= keys.len() { 0 } else { to };
                let mut items = Vec::with_capacity(to - from + 1);
                items.push(Value::Int(next as i64));
                items.extend(
                    keys[from..to].iter().map(|k| Value::bulk(k.clone())),
                );
                Value::Array(items)
            }
            ("GOSSIP", 2) => {
                let Some(incoming) = MembershipDigest::decode(&args[1]) else {
                    return Value::Error("ERR bad gossip digest".into());
                };
                let mut board = self.gossip.lock().unwrap();
                board.merge_from(&incoming);
                if let Some(me) = self.self_addr.lock().unwrap().as_deref() {
                    // subject-refutes: any claim that *this* box is not Up
                    // at an incarnation ≥ ours bumps ours past it — relative
                    // to the claim, so it survives a restart that zeroed the
                    // counter
                    if let Some(claim) = board.get(me) {
                        let own = self.own_inc.load(Ordering::Relaxed);
                        if claim.state != PeerHealth::Up && claim.incarnation >= own {
                            self.own_inc.store(claim.incarnation + 1, Ordering::Relaxed);
                            self.refuted.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    let own = self.own_inc.load(Ordering::Relaxed);
                    board.merge_entry(me, PeerView::new(own, PeerHealth::Up));
                }
                Value::bulk(board.encode())
            }
            ("PROBE.RELAY", 2) => {
                let Ok(target) = std::str::from_utf8(&args[1]) else {
                    return Value::Error("ERR bad probe address".into());
                };
                Value::Int(relay_probe(target) as i64)
            }
            ("SHUTDOWN", 1) => {
                self.stop.store(true, Ordering::SeqCst);
                Value::Simple("SHUTTING DOWN".into())
            }
            _ => Value::Error(format!("ERR unknown command '{cmd}' / arity {}", args.len())),
        }
    }
}

// ---------------------------------------------------------------------------
// The non-blocking poll core (`ServeMode::Poll`)
// ---------------------------------------------------------------------------

/// Stop reading from a connection whose reply backlog exceeds this —
/// natural read-side backpressure against a slow reader streaming a large
/// `GETCHUNKS` reply (the bytes stay queued in its [`WriteBuf`]).
const OUT_HIGH_WATER: usize = 4 << 20;

/// Poll-loop idle sleep when no socket made progress (stdlib-only polling;
/// short enough that added latency stays well under a link RTT).
const IDLE_SLEEP: Duration = Duration::from_micros(200);

/// How long shutdown keeps flushing queued replies before closing sockets.
const FLUSH_GRACE: Duration = Duration::from_millis(500);

/// One queued unit of work for a poll-mode connection.  Shed and
/// protocol-error markers ride the same queue as real requests so replies
/// keep pipeline order — a directly-encoded `BUSY` could otherwise
/// overtake the reply of an earlier admitted request.
enum ConnJob {
    /// An admitted request (holds its admission slot until dispatched).
    Req(Value),
    /// A shed request: reply `BUSY` in order, no dispatch.
    Shed,
    /// A protocol error: reply `-ERR` in order, then close after flush.
    Protocol(String),
}

/// The connection state shared between the poll loop (producer: decoded
/// jobs in, flushes out) and the worker pool (consumer: dispatch, encode).
struct ConnShared {
    /// Decoded jobs awaiting dispatch, strictly FIFO per connection.
    queue: Mutex<VecDeque<ConnJob>>,
    /// Encoded replies awaiting flush (partial writes resume here).
    out: Mutex<WriteBuf>,
    /// Whether a worker currently owns this connection's queue.  Ownership
    /// is acquired by a `false → true` swap — the loop enqueues the
    /// connection on the run queue only when it wins that swap, so a
    /// connection is never drained by two workers at once.
    running: AtomicBool,
    /// Set on SHUTDOWN / protocol error: close once `out` drains.
    close_after_flush: AtomicBool,
}

impl ConnShared {
    fn new() -> Arc<Self> {
        Arc::new(ConnShared {
            queue: Mutex::new(VecDeque::new()),
            out: Mutex::new(WriteBuf::new()),
            running: AtomicBool::new(false),
            close_after_flush: AtomicBool::new(false),
        })
    }
}

/// Run queue of connections with pending jobs, drained by the worker pool.
#[derive(Default)]
struct RunQueue {
    q: Mutex<VecDeque<Arc<ConnShared>>>,
    cv: Condvar,
}

impl RunQueue {
    fn push(&self, c: Arc<ConnShared>) {
        self.q.lock().unwrap().push_back(c);
        self.cv.notify_one();
    }

    /// Pop the next runnable connection; `None` once `stop` is set and the
    /// queue is drained.  The wait is timed so a missed notify can only
    /// delay shutdown, never wedge it.
    fn pop(&self, stop: &AtomicBool) -> Option<Arc<ConnShared>> {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(c) = q.pop_front() {
                return Some(c);
            }
            if stop.load(Ordering::SeqCst) {
                return None;
            }
            let (guard, _) = self
                .cv
                .wait_timeout(q, Duration::from_millis(50))
                .unwrap();
            q = guard;
        }
    }
}

/// Loop-thread-owned per-connection state.
struct PollConn {
    id: u64,
    stream: TcpStream,
    dec: Decoder,
    shared: Arc<ConnShared>,
    /// Peer closed its write side (or errored mid-frame): stop reading,
    /// keep flushing what's owed.
    read_closed: bool,
}

impl KvServer {
    fn poll_loop(self: Arc<Self>, listener: TcpListener, local: std::net::SocketAddr) {
        let n_workers = self
            .store
            .n_shards()
            .min(
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(4),
            )
            .max(1);
        log_info!(
            "kvstore",
            "cache box polling on {local} ({} shards, {n_workers} workers)",
            self.store.n_shards()
        );
        let runq = Arc::new(RunQueue::default());
        let workers: Vec<JoinHandle<()>> = (0..n_workers)
            .map(|i| {
                let srv = Arc::clone(&self);
                let rq = Arc::clone(&runq);
                std::thread::Builder::new()
                    .name(format!("kv-worker-{i}"))
                    .spawn(move || srv.poll_worker(&rq))
                    .expect("spawn poll worker")
            })
            .collect();

        let mut conns: Vec<PollConn> = Vec::new();
        let mut buf = vec![0u8; 64 * 1024];
        while !self.stop.load(Ordering::SeqCst) {
            let mut progress = false;
            // accept everything that's ready, then get back to serving
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        progress = true;
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let _ = stream.set_nodelay(true);
                        let id = self.next_conn_id.fetch_add(1, Ordering::Relaxed);
                        if let Ok(clone) = stream.try_clone() {
                            self.conns.lock().unwrap().insert(id, clone);
                        }
                        conns.push(PollConn {
                            id,
                            stream,
                            dec: Decoder::new(),
                            shared: ConnShared::new(),
                            read_closed: false,
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) => {
                        log_debug!("kvstore", "accept error: {e}");
                        break;
                    }
                }
            }
            let mut i = 0;
            while i < conns.len() {
                if self.poll_conn_step(&mut conns[i], &runq, &mut buf, &mut progress) {
                    i += 1;
                } else {
                    let dead = conns.swap_remove(i);
                    let _ = dead.stream.shutdown(std::net::Shutdown::Both);
                    self.conns.lock().unwrap().remove(&dead.id);
                }
            }
            if !progress {
                std::thread::sleep(IDLE_SLEEP);
            }
        }

        // shutdown: let workers finish the connections they own, then give
        // queued replies (e.g. the SHUTDOWN acknowledgement) a bounded
        // chance to reach their clients before the sockets close
        runq.cv.notify_all();
        for w in workers {
            let _ = w.join();
        }
        let deadline = Instant::now() + FLUSH_GRACE;
        loop {
            let mut all_empty = true;
            for c in &mut conns {
                let mut out = c.shared.out.lock().unwrap();
                if !out.is_empty() && out.flush_into(&mut c.stream).is_err() {
                    out.clear();
                }
                all_empty &= out.is_empty();
            }
            if all_empty || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(IDLE_SLEEP);
        }
        for c in conns {
            let _ = c.stream.shutdown(std::net::Shutdown::Both);
            self.conns.lock().unwrap().remove(&c.id);
        }
    }

    /// One readiness pass over a connection: drain readable bytes into the
    /// decoder, enqueue complete requests (or shed them), and flush as much
    /// of the reply backlog as the socket accepts.  Returns `false` when
    /// the connection should be dropped.
    fn poll_conn_step(
        &self,
        c: &mut PollConn,
        runq: &RunQueue,
        buf: &mut [u8],
        progress: &mut bool,
    ) -> bool {
        // read side, gated on the reply backlog (read-side backpressure)
        if !c.read_closed && c.shared.out.lock().unwrap().len() < OUT_HIGH_WATER {
            loop {
                match c.stream.read(buf) {
                    Ok(0) => {
                        c.read_closed = true;
                        break;
                    }
                    Ok(n) => {
                        *progress = true;
                        c.dec.feed(&buf[..n]);
                        let mut enqueued = false;
                        loop {
                            match c.dec.next_value() {
                                Ok(Some(req)) => {
                                    let job = if self.admission.try_enter() {
                                        ConnJob::Req(req)
                                    } else {
                                        ConnJob::Shed
                                    };
                                    c.shared.queue.lock().unwrap().push_back(job);
                                    enqueued = true;
                                }
                                Ok(None) => break,
                                Err(RespError::Protocol(msg)) => {
                                    c.shared
                                        .queue
                                        .lock()
                                        .unwrap()
                                        .push_back(ConnJob::Protocol(msg));
                                    enqueued = true;
                                    c.read_closed = true;
                                    break;
                                }
                                Err(RespError::Io(_)) => break, // unreachable for a decoder
                            }
                        }
                        if enqueued && !c.shared.running.swap(true, Ordering::SeqCst) {
                            runq.push(Arc::clone(&c.shared));
                        }
                        if c.read_closed {
                            break;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => return false, // reset/fatal: drop the connection
                }
            }
        }
        // write side: flush what the socket accepts, resume next pass
        let mut out = c.shared.out.lock().unwrap();
        if !out.is_empty() {
            match out.flush_into(&mut c.stream) {
                Ok(n) => *progress |= n > 0,
                Err(_) => return false,
            }
        }
        if out.is_empty() {
            if c.shared.close_after_flush.load(Ordering::SeqCst) {
                return false;
            }
            // peer hung up and nothing is owed or in flight: drop.  The
            // running/queue checks are conservative — a racing worker only
            // delays the drop to a later pass, never loses a reply.
            if c.read_closed
                && !c.shared.running.load(Ordering::SeqCst)
                && c.shared.queue.lock().unwrap().is_empty()
            {
                return false;
            }
        }
        true
    }

    /// Worker-pool loop: claim a connection, drain its job queue in FIFO
    /// order (preserving pipelined reply order), encode replies into its
    /// write buffer, and release ownership with a lost-wakeup re-check.
    fn poll_worker(self: Arc<Self>, runq: &RunQueue) {
        while let Some(conn) = runq.pop(&self.stop) {
            loop {
                let job = conn.queue.lock().unwrap().pop_front();
                let Some(job) = job else {
                    conn.running.store(false, Ordering::SeqCst);
                    // a job may have landed between the empty pop and the
                    // store above; re-claim it or it would sit unserved
                    // until the next request arrives
                    if !conn.queue.lock().unwrap().is_empty()
                        && !conn.running.swap(true, Ordering::SeqCst)
                    {
                        continue;
                    }
                    break;
                };
                let reply = match job {
                    ConnJob::Req(req) => {
                        let r = self.dispatch(req);
                        self.admission.exit();
                        r
                    }
                    ConnJob::Shed => busy_value(),
                    ConnJob::Protocol(msg) => {
                        conn.close_after_flush.store(true, Ordering::SeqCst);
                        Value::Error(format!("ERR {msg}"))
                    }
                };
                if matches!(&reply, Value::Simple(s) if s == "SHUTTING DOWN") {
                    conn.close_after_flush.store(true, Ordering::SeqCst);
                }
                conn.out.lock().unwrap().push(&reply);
            }
        }
    }
}

/// The third-party reachability check behind `PROBE.RELAY`: dial `target`
/// under a short fixed budget and `PING` it.  The budget is deliberately a
/// relay-local constant — a probe exists to settle a verdict quickly, and
/// a wedged relay op must never outlive the prober's own patience.
fn relay_probe(target: &str) -> bool {
    const BUDGET: std::time::Duration = std::time::Duration::from_millis(250);
    let Ok(sa) = target.parse::<std::net::SocketAddr>() else {
        return false;
    };
    let Ok(mut conn) = TcpStream::connect_timeout(&sa, BUDGET) else {
        return false;
    };
    let _ = conn.set_read_timeout(Some(BUDGET));
    let _ = conn.set_write_timeout(Some(BUDGET));
    if conn.write_all(&request(&[b"PING"]).encode()).is_err() {
        return false;
    }
    let mut buf = [0u8; 16];
    match conn.read(&mut buf) {
        Ok(n) if n > 0 => buf.starts_with(b"+PONG"),
        _ => false,
    }
}

/// RAII handle to a running server; shutting down unblocks the accept loop.
pub struct ServerHandle {
    pub server: Arc<KvServer>,
    pub addr: std::net::SocketAddr,
    accept_thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr_string(&self) -> String {
        self.addr.to_string()
    }

    pub fn shutdown(mut self) {
        self.do_shutdown();
    }

    fn do_shutdown(&mut self) {
        self.server.stop.store(true, Ordering::SeqCst);
        // poke the accept loop so it observes the stop flag (a no-op for
        // the poll core, which re-checks the flag every pass)
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // force-close live connections so blocked reads return immediately
        for (_, c) in self.server.conns.lock().unwrap().drain() {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.do_shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::super::resp::request;
    use super::*;

    #[test]
    fn master_catalog_versioning() {
        let mut c = MasterCatalog::default();
        assert_eq!(c.version(), 0);
        assert_eq!(c.register(b"k1".to_vec()), 1);
        assert_eq!(c.register(b"k2".to_vec()), 2);
        let (v, keys) = c.delta(0, 100);
        assert_eq!(v, 2);
        assert_eq!(keys.len(), 2);
        let (v, keys) = c.delta(1, 100);
        assert_eq!(v, 2);
        assert_eq!(keys.len(), 1);
        assert_eq!(keys[0], b"k2".to_vec());
        let (v, keys) = c.delta(2, 100);
        assert_eq!(v, 2);
        assert!(keys.is_empty());
        // out-of-range since is clamped, not a panic
        let (v, keys) = c.delta(99, 100);
        assert_eq!(v, 2);
        assert!(keys.is_empty());
    }

    #[test]
    fn delta_cap_respected() {
        let mut c = MasterCatalog::default();
        for i in 0..50 {
            c.register(format!("k{i}").into_bytes());
        }
        let (v, keys) = c.delta(0, 10);
        assert_eq!(v, 10);
        assert_eq!(keys.len(), 10);
        let (v2, keys2) = c.delta(v, 10);
        assert_eq!(v2, 20);
        assert_eq!(keys2[0], b"k10".to_vec());
    }

    #[test]
    fn catalog_log_keys_are_compact_shared_views() {
        let mut c = MasterCatalog::default();
        // a key that arrives as a loose slice of a big read buffer must be
        // compacted, not pin the buffer
        let buf = SharedBytes::new(vec![b'x'; 1 << 20]);
        c.register(buf.slice(0..16));
        let (_, keys) = c.delta(0, 10);
        assert_eq!(keys[0].len(), 16);
        assert!(keys[0].backing_len() <= 4096, "loose key must be re-homed");
        // delta replies are views, not copies: a clone (what CAT.DELTA puts
        // in the reply) points at the very same backing bytes
        let k0 = keys[0].clone();
        assert_eq!(k0.as_slice().as_ptr(), keys[0].as_slice().as_ptr());
    }

    #[test]
    fn serve_mode_names_roundtrip() {
        for m in [ServeMode::Threads, ServeMode::Poll] {
            assert_eq!(ServeMode::by_name(m.name()), Some(m));
        }
        assert_eq!(ServeMode::by_name("nonblocking"), Some(ServeMode::Poll));
        assert!(ServeMode::by_name("epoll").is_none());
    }

    #[test]
    fn admission_bounds_pending_and_counts_sheds() {
        let a = Admission::new(2);
        assert!(a.try_enter());
        assert!(a.try_enter());
        assert!(!a.try_enter(), "third concurrent op must shed");
        assert_eq!(a.sheds(), 1);
        assert_eq!(a.peak_pending(), 2);
        a.exit();
        assert!(a.try_enter(), "a freed slot re-admits");
        a.exit();
        a.exit();
        assert_eq!(a.pending(), 0);
        // unbounded admission never sheds
        let u = Admission::new(0);
        for _ in 0..100 {
            assert!(u.try_enter());
        }
        assert_eq!(u.sheds(), 0);
        assert_eq!(u.peak_pending(), 100);
    }

    #[test]
    fn admit_dispatch_sheds_busy_at_capacity() {
        let srv = KvServer::configure(usize::MAX, 1, 1);
        // saturate the single slot from outside, as a queued op would
        assert!(srv.admission.try_enter());
        let r = srv.admit_dispatch(request(&[b"PING"]));
        let Value::Error(e) = r else { panic!("expected BUSY, got {r:?}") };
        assert!(e.starts_with("BUSY"), "{e:?}");
        srv.admission.exit();
        // with the slot free the same request succeeds
        assert_eq!(srv.admit_dispatch(request(&[b"PING"])), Value::Simple("PONG".into()));
        assert_eq!(srv.admission.sheds(), 1);
    }

    #[test]
    fn pipelined_protocol_error_is_surfaced_and_closes_conn() {
        let srv = KvServer::new(usize::MAX);
        let h = srv.serve("127.0.0.1:0").unwrap();
        let mut raw = std::net::TcpStream::connect(h.addr).unwrap();
        // a valid PING followed, in the same write, by a garbage frame: the
        // drain loop must answer the PING *and* surface the error instead of
        // silently leaving the connection desynced
        raw.write_all(b"*1\r\n$4\r\nPING\r\n!bogus\r\n").unwrap();
        let mut buf = Vec::new();
        raw.read_to_end(&mut buf).unwrap(); // server closes after the error
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("+PONG\r\n"), "{text:?}");
        assert!(text.contains("-ERR"), "protocol error must be surfaced: {text:?}");
        h.shutdown();
    }

    #[test]
    fn poll_core_pipelined_protocol_error_behaves_like_threads() {
        let srv = KvServer::configure(usize::MAX, 4, 0);
        let h = srv.serve_with("127.0.0.1:0", ServeMode::Poll).unwrap();
        let mut raw = std::net::TcpStream::connect(h.addr).unwrap();
        raw.write_all(b"*1\r\n$4\r\nPING\r\n!bogus\r\n").unwrap();
        let mut buf = Vec::new();
        raw.read_to_end(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("+PONG\r\n"), "{text:?}");
        assert!(text.contains("-ERR"), "{text:?}");
        h.shutdown();
    }

    #[test]
    fn poll_core_serves_the_client_protocol() {
        let srv = KvServer::configure(usize::MAX, 4, 0);
        let h = srv.serve_with("127.0.0.1:0", ServeMode::Poll).unwrap();
        let mut c = super::super::client::KvClient::connect(&h.addr_string()).unwrap();
        c.ping().unwrap();
        c.set(b"k", b"hello world").unwrap();
        assert_eq!(c.get(b"k").unwrap().as_deref(), Some(&b"hello world"[..]));
        // pipelined batch keeps reply order
        let reqs: Vec<Value> = (0..16)
            .map(|i| request(&[b"SET", format!("k{i}").as_bytes(), format!("v{i}").as_bytes()]))
            .collect();
        let replies = c.pipeline_req(&reqs).unwrap();
        assert_eq!(replies.len(), 16);
        assert!(replies.iter().all(|r| *r == Value::ok()));
        for i in 0..16 {
            assert_eq!(
                c.get(format!("k{i}").as_bytes()).unwrap().as_deref(),
                Some(format!("v{i}").as_bytes())
            );
        }
        let info = c.info().unwrap();
        assert!(info.contains("shards:4"), "{info}");
        assert!(info.contains("sheds:0"), "{info}");
        h.shutdown();
    }

    #[test]
    fn poll_core_resumes_byte_dribbled_frames() {
        // a request delivered one byte at a time must decode identically —
        // the resumable decoder picks up mid-frame across WouldBlock reads
        let srv = KvServer::configure(usize::MAX, 2, 0);
        let h = srv.serve_with("127.0.0.1:0", ServeMode::Poll).unwrap();
        let mut raw = std::net::TcpStream::connect(h.addr).unwrap();
        raw.set_nodelay(true).unwrap();
        let frame = request(&[b"SET", b"slow", b"value"]).encode();
        for b in &frame {
            raw.write_all(std::slice::from_ref(b)).unwrap();
            std::thread::sleep(Duration::from_micros(300));
        }
        raw.write_all(&request(&[b"GET", b"slow"]).encode()).unwrap();
        let mut dec = Decoder::new();
        let set_reply = read_value(&mut raw, &mut dec).unwrap();
        assert_eq!(set_reply, Value::ok());
        let get_reply = read_value(&mut raw, &mut dec).unwrap();
        assert_eq!(get_reply.as_bulk(), Some(&b"value"[..]));
        h.shutdown();
    }

    #[test]
    fn poll_core_sheds_busy_in_pipeline_order() {
        // one admission slot + a per-op delay: a deep pipelined burst must
        // get some BUSY replies, every reply in order, and the connection
        // stays usable afterwards
        let mut srv = KvServer::configure(usize::MAX, 1, 1);
        Arc::get_mut(&mut srv).unwrap().op_delay = Duration::from_millis(2);
        let h = srv.serve_with("127.0.0.1:0", ServeMode::Poll).unwrap();
        let mut c = super::super::client::KvClient::connect(&h.addr_string()).unwrap();
        let reqs: Vec<Value> = (0..32).map(|_| request(&[b"PING"])).collect();
        let replies = c.pipeline_req(&reqs).unwrap();
        assert_eq!(replies.len(), 32, "every request gets exactly one reply");
        let busy = replies
            .iter()
            .filter(|r| matches!(r, Value::Error(e) if e.starts_with("BUSY")))
            .count();
        let pong = replies
            .iter()
            .filter(|r| **r == Value::Simple("PONG".into()))
            .count();
        assert_eq!(busy + pong, 32, "only PONG or BUSY: {replies:?}");
        assert!(busy >= 1, "a 32-deep burst into one slot must shed");
        assert_eq!(srv.admission.sheds(), busy as u64);
        // the connection survives shedding: a lone request succeeds
        c.ping().expect("conn must stay usable after BUSY");
        h.shutdown();
    }

    #[test]
    fn dead_connections_are_pruned_from_the_handle_list() {
        let srv = KvServer::new(usize::MAX);
        let h = srv.serve("127.0.0.1:0").unwrap();
        for _ in 0..8 {
            let mut c = super::super::client::KvClient::connect(&h.addr_string()).unwrap();
            c.ping().unwrap();
            drop(c);
        }
        // connection threads notice the hangup and prune their handles
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let live = srv.conns.lock().unwrap().len();
            if live == 0 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "{live} dead connection handles still retained"
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        h.shutdown();
    }

    #[test]
    fn poll_core_prunes_dead_connections_too() {
        let srv = KvServer::configure(usize::MAX, 2, 0);
        let h = srv.serve_with("127.0.0.1:0", ServeMode::Poll).unwrap();
        for _ in 0..8 {
            let mut c = super::super::client::KvClient::connect(&h.addr_string()).unwrap();
            c.ping().unwrap();
            drop(c);
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let live = srv.conns.lock().unwrap().len();
            if live == 0 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "{live} dead connection handles still retained"
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        h.shutdown();
    }

    #[test]
    fn dispatch_without_network() {
        let srv = KvServer::new(usize::MAX);
        let set = request(&[b"SET", b"a", b"1"]);
        assert_eq!(srv.dispatch(set), Value::ok());
        let get = request(&[b"GET", b"a"]);
        assert_eq!(srv.dispatch(get), Value::bulk(&b"1"[..]));
        let bad = request(&[b"NOPE"]);
        assert!(matches!(srv.dispatch(bad), Value::Error(_)));
        let wrong_arity = request(&[b"GET"]);
        assert!(matches!(srv.dispatch(wrong_arity), Value::Error(_)));
    }

    #[test]
    fn sharded_dispatch_spreads_keys_and_aggregates_info() {
        let srv = KvServer::configure(usize::MAX, 8, 0);
        for i in 0..64 {
            let k = format!("key-{i}");
            assert_eq!(
                srv.dispatch(request(&[b"SET", k.as_bytes(), k.as_bytes()])),
                Value::ok()
            );
        }
        assert_eq!(srv.dispatch(request(&[b"DBSIZE"])), Value::Int(64));
        // more than one shard actually holds entries
        let populated = (0..8)
            .filter(|i| srv.store.shard_at(*i).lock().unwrap().len() > 0)
            .count();
        assert!(populated > 1, "64 keys all hashed to one of 8 shards?");
        let info = srv.dispatch(request(&[b"INFO"]));
        let text = String::from_utf8(info.as_bulk().unwrap().to_vec()).unwrap();
        assert!(text.contains("keys:64"), "{text}");
        assert!(text.contains("shards:8"), "{text}");
        assert!(text.contains("pending_peak:"), "{text}");
        srv.dispatch(request(&[b"FLUSHALL"]));
        assert_eq!(srv.dispatch(request(&[b"DBSIZE"])), Value::Int(0));
    }

    #[test]
    fn splice_crosses_shards() {
        // base and target keys land wherever the hash sends them; the
        // cross-shard view/set discipline must still splice correctly
        let srv = KvServer::configure(usize::MAX, 8, 0);
        srv.dispatch(request(&[b"SET", b"base", b"hello world"]));
        for i in 0..32 {
            let nk = format!("n{i}");
            let r = srv.dispatch(request(&[b"SPLICE", nk.as_bytes(), b"base", b"3", b"7", b"he", b"!!"]));
            assert_eq!(r, Value::Int(8), "{nk}");
            assert_eq!(
                srv.dispatch(request(&[b"GET", nk.as_bytes()])),
                Value::bulk(&b"helo w!!"[..])
            );
        }
    }

    #[test]
    fn getrange_dispatch_semantics() {
        let srv = KvServer::new(usize::MAX);
        srv.dispatch(request(&[b"SET", b"k", b"hello world"]));
        assert_eq!(
            srv.dispatch(request(&[b"GETRANGE", b"k", b"0", b"4"])),
            Value::bulk(&b"hello"[..])
        );
        // inclusive end, clamped past the value length
        assert_eq!(
            srv.dispatch(request(&[b"GETRANGE", b"k", b"6", b"999"])),
            Value::bulk(&b"world"[..])
        );
        // start beyond the value → empty bulk, missing key → nil
        assert_eq!(
            srv.dispatch(request(&[b"GETRANGE", b"k", b"99", b"100"])),
            Value::Bulk(SharedBytes::empty())
        );
        assert_eq!(
            srv.dispatch(request(&[b"GETRANGE", b"nope", b"0", b"1"])),
            Value::Nil
        );
        assert!(matches!(
            srv.dispatch(request(&[b"GETRANGE", b"k", b"x", b"1"])),
            Value::Error(_)
        ));
    }

    #[test]
    fn getchunks_dispatch_serves_head_and_whole_chunks() {
        use crate::model::state::{BlobLayout, Compression, KvState};
        let srv = KvServer::new(usize::MAX);
        let (l, s, kh, d) = (2usize, 16usize, 1usize, 8usize);
        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = 10;
        for (i, x) in st.k.iter_mut().enumerate() {
            *x = i as f32;
        }
        let ct = 4;
        let blob = st.serialize_prefix_opts(10, "h", Compression::Deflate, ct);
        let lo = BlobLayout::new("h", l, kh, d).with_chunk_tokens(ct);
        srv.dispatch(Value::Array(vec![
            Value::bulk(&b"SET"[..]),
            Value::bulk(&b"k"[..]),
            Value::bulk(blob.clone()),
        ]));

        // m = 6 rows with ct = 4 covers exactly 2 whole chunks
        let r = srv.dispatch(request(&[b"GETCHUNKS", b"k", b"6"]));
        let Value::Array(items) = r else { panic!("expected array, got {r:?}") };
        assert_eq!(items.len(), 1 + 2);
        let head_len = lo.payload_off(10);
        assert_eq!(items[0].as_bulk().unwrap(), &blob[..head_len]);
        let (_, entries) = crate::model::state::read_chunk_index(&blob).unwrap();
        let c0 = entries[0].len as usize;
        let c1 = entries[1].len as usize;
        assert_eq!(items[1].as_bulk().unwrap(), &blob[head_len..head_len + c0]);
        assert_eq!(
            items[2].as_bulk().unwrap(),
            &blob[head_len + c0..head_len + c0 + c1]
        );

        // m = 0 returns the head alone; m past the entry clamps to all chunks
        let r = srv.dispatch(request(&[b"GETCHUNKS", b"k", b"0"]));
        let Value::Array(items) = r else { panic!("{r:?}") };
        assert_eq!(items.len(), 1);
        let r = srv.dispatch(request(&[b"GETCHUNKS", b"k", b"999"]));
        let Value::Array(items) = r else { panic!("{r:?}") };
        assert_eq!(items.len(), 1 + lo.n_chunks(10));

        // missing key is nil; a non-ECS3 entry is a typed error
        assert_eq!(srv.dispatch(request(&[b"GETCHUNKS", b"nope", b"4"])), Value::Nil);
        srv.dispatch(request(&[b"SET", b"plain", b"not a state blob"]));
        assert!(matches!(
            srv.dispatch(request(&[b"GETCHUNKS", b"plain", b"4"])),
            Value::Error(_)
        ));
        assert!(matches!(
            srv.dispatch(request(&[b"GETCHUNKS", b"k", b"x"])),
            Value::Error(_)
        ));
    }

    #[test]
    fn splice_dispatch_assembles_value() {
        let srv = KvServer::new(usize::MAX);
        srv.dispatch(request(&[b"SET", b"base", b"hello world"]));
        // "he" ++ base[3,7) ++ "!!" = "he" + "lo w" + "!!"
        let r = srv.dispatch(request(&[b"SPLICE", b"n", b"base", b"3", b"7", b"he", b"!!"]));
        assert_eq!(r, Value::Int(8));
        assert_eq!(
            srv.dispatch(request(&[b"GET", b"n"])),
            Value::bulk(&b"helo w!!"[..])
        );
        // empty splice range is legal (pure head ++ tail concat)
        let r = srv.dispatch(request(&[b"SPLICE", b"m", b"base", b"0", b"0", b"a", b"b"]));
        assert_eq!(r, Value::Int(2));
        // missing base and out-of-bounds ranges are errors
        assert!(matches!(
            srv.dispatch(request(&[b"SPLICE", b"x", b"nope", b"0", b"0", b"", b""])),
            Value::Error(_)
        ));
        assert!(matches!(
            srv.dispatch(request(&[b"SPLICE", b"x", b"base", b"5", b"99", b"", b""])),
            Value::Error(_)
        ));
        assert!(matches!(
            srv.dispatch(request(&[b"SPLICE", b"x", b"base", b"7", b"3", b"", b""])),
            Value::Error(_)
        ));
    }

    #[test]
    fn gossip_board_merges_and_self_refutes() {
        let srv = KvServer::new(usize::MAX);
        let h = srv.serve("127.0.0.1:0").unwrap();
        let me = h.addr_string();

        // a client digest claiming some third box dead + this box suspect
        let mut d = MembershipDigest::new(4);
        d.merge_entry("10.0.0.9:7000", PeerView::new(0, PeerHealth::Dead));
        d.merge_entry(&me, PeerView::new(3, PeerHealth::Suspect));
        let r = srv.dispatch(request(&[b"GOSSIP", &d.encode()]));
        let Value::Bulk(b) = r else { panic!("expected bulk, got {r:?}") };
        let merged = MembershipDigest::decode(&b).unwrap();

        // the third-box verdict is on the board for other clients to adopt
        assert_eq!(
            merged.get("10.0.0.9:7000"),
            Some(PeerView::new(0, PeerHealth::Dead))
        );
        // and the box refuted its own suspicion: Up at a bumped incarnation
        let self_view = merged.get(&me).unwrap();
        assert_eq!(self_view.state, PeerHealth::Up);
        assert_eq!(self_view.incarnation, 4, "bumped past the claimed incarnation");
        assert_eq!(srv.gossip_refutations(), 1);
        // the refutation wins the merge against the stale claim
        assert_eq!(
            PeerView::merge(self_view, PeerView::new(3, PeerHealth::Suspect)),
            self_view
        );

        // an empty digest still harvests the board (pull-only exchange)
        let empty = MembershipDigest::new(0);
        let r = srv.dispatch(request(&[b"GOSSIP", &empty.encode()]));
        let Value::Bulk(b) = r else { panic!("{r:?}") };
        let board = MembershipDigest::decode(&b).unwrap();
        assert!(board.get("10.0.0.9:7000").is_some(), "board is sticky");

        // garbage digests are a typed error, not a poisoned board
        assert!(matches!(
            srv.dispatch(request(&[b"GOSSIP", b"\xff\xfe"])),
            Value::Error(_)
        ));
        h.shutdown();
    }

    #[test]
    fn probe_relay_reports_reachability() {
        let a = KvServer::new(usize::MAX);
        let ha = a.serve("127.0.0.1:0").unwrap();
        let b = KvServer::new(usize::MAX);
        let hb = b.serve("127.0.0.1:0").unwrap();

        // box A relays a probe to live box B: reachable
        let r = a.dispatch(request(&[b"PROBE.RELAY", hb.addr_string().as_bytes()]));
        assert_eq!(r, Value::Int(1));

        // a dead target address: unreachable (bounded, no wedge)
        let dead = hb.addr_string();
        hb.shutdown();
        let t0 = std::time::Instant::now();
        let r = a.dispatch(request(&[b"PROBE.RELAY", dead.as_bytes()]));
        assert_eq!(r, Value::Int(0));
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(2),
            "relay budget must bound the probe"
        );

        // unparsable addresses are a clean 0, not an error loop
        let r = a.dispatch(request(&[b"PROBE.RELAY", b"not an address"]));
        assert_eq!(r, Value::Int(0));
        ha.shutdown();
    }

    #[test]
    fn splice_respects_memory_budget() {
        let srv = KvServer::new(64);
        srv.dispatch(request(&[b"SET", b"base", b"0123456789"]));
        let big_head = vec![b'x'; 200];
        let r = srv.dispatch(Value::Array(vec![
            Value::bulk(&b"SPLICE"[..]),
            Value::bulk(&b"big"[..]),
            Value::bulk(&b"base"[..]),
            Value::bulk(&b"0"[..]),
            Value::bulk(&b"10"[..]),
            Value::bulk(big_head),
            Value::bulk(&b""[..]),
        ]));
        assert!(matches!(r, Value::Error(_)), "oversized splice must OOM");
        assert_eq!(
            srv.dispatch(request(&[b"EXISTS", b"big"])),
            Value::Int(0),
            "rejected splice must store nothing"
        );
    }
}
