//! Threaded RESP2 TCP server — the *cache box* process (Figure 1, middle).
//!
//! One OS thread per connection (the paper has a handful of edge clients;
//! Redis itself is single-threaded, so a thread-per-conn loop over a shared
//! mutexed [`Store`] is a faithful stand-in at this scale).  Besides the
//! classic string commands it hosts the **master catalog**: an append-only
//! log of registered catalog keys that clients pull incrementally
//! (`CAT.DELTA`) to synchronize their local Bloom filters (Figure 2, green
//! arrow).
//!
//! Three commands power the zero-copy/suffix-delta transfer path.  Two are
//! byte-oriented (the server never interprets blob layouts — clients compute
//! all offsets from `model::state::BlobLayout`):
//!
//! * `GETRANGE key start end` — Redis-style inclusive byte range of a
//!   value, served as an O(1) slice of the stored entry (`Nil` when the key
//!   is absent, empty bulk when the range is).  ECS3 clients use it to pull
//!   a blob's head (header + chunk index) and then whole compressed chunks;
//!   the chunk-boundary arithmetic stays entirely client-side;
//! * `SPLICE newkey basekey start end head tail` — store
//!   `head ++ basekey[start, end) ++ tail` under `newkey` (end-exclusive).
//!   This is the delta-upload primitive: a client extending a cached prefix
//!   ships only its new suffix chunks, and the server splices them onto the
//!   prefix chunk bytes it already holds — compressed or not, since ECS3
//!   chunks are independent deflate streams.
//!
//! The third is the one deliberate exception to layout-agnosticism
//! (ROADMAP "server-push streaming"):
//!
//! * `GETCHUNKS key m` — parse the stored entry's own ECS3 header + chunk
//!   index and reply with a multi-bulk of `1 + k` O(1) slices: the head,
//!   then each whole chunk covering an `m`-row prefix (`m` clamped to the
//!   entry; `m = 0` returns the head alone).  One request replaces the
//!   head round trip *plus* the per-chunk offset math on the client — and
//!   because the reply is a RESP array whose elements are self-delimiting,
//!   a streaming client still decodes chunk `i` while chunk `i+1` is on
//!   the wire.  Non-ECS3 entries (legacy v2 blobs, aliases, garbage) get a
//!   typed error so clients fall back to the GETRANGE compatibility path.
//!
//! Two commands make each cache box a **gossip blackboard** for the
//! SWIM-style fleet-health layer (`coordinator::membership`) — clients
//! never talk to each other directly, so the boxes they all sync with are
//! the natural merge points:
//!
//! * `GOSSIP digest` — merge a client's membership digest into the box's
//!   board (the pure [`PeerView::merge`] law per address) and reply with
//!   the merged board.  One client's verdict reaches every other client
//!   within one sync period.  The box **self-refutes**: a claim that this
//!   box is Suspect/Dead at incarnation `i ≥` its own bumps its own
//!   incarnation to `i + 1` and re-advertises `Up`, which out-competes the
//!   stale claim on every board it reaches — and because the bump is
//!   relative to the *claimed* incarnation, refutation survives a box
//!   restart that reset its counter to zero;
//! * `PROBE.RELAY addr` — dial `addr` with a short bounded budget and
//!   `PING` it, replying `1`/`0` — the third-party reachability check an
//!   indirect probe routes through before a circumstantial `Suspect →
//!   Dead` verdict commits.

use std::collections::HashMap;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::resp::{read_value, request, Decoder, RespError, Value};
use super::store::Store;
use crate::coordinator::membership::{MembershipDigest, PeerHealth, PeerView};
use crate::log_debug;
use crate::log_info;
use crate::util::bytes::SharedBytes;

/// Master-catalog state: an append-only key log; version = entries appended.
///
/// Keys are stored as [`SharedBytes`] so a `CAT.DELTA` reply is built from
/// O(1) views of the log entries — no per-key payload copy per syncing
/// client.  Keys arriving off the wire (slices of a connection read buffer)
/// are compacted on insert so the log never pins whole read buffers.
#[derive(Debug, Default)]
pub struct MasterCatalog {
    log: Vec<SharedBytes>,
}

impl MasterCatalog {
    pub fn version(&self) -> u64 {
        self.log.len() as u64
    }

    pub fn register(&mut self, key: impl Into<SharedBytes>) -> u64 {
        self.log.push(key.into().detach_loose());
        self.version()
    }

    /// Entries appended after `since` (capped to keep replies bounded).
    pub fn delta(&self, since: u64, cap: usize) -> (u64, &[SharedBytes]) {
        let from = (since as usize).min(self.log.len());
        let to = (from + cap).min(self.log.len());
        (to as u64, &self.log[from..to])
    }
}

/// Shared server state.
pub struct KvServer {
    pub store: Mutex<Store>,
    pub catalog: Mutex<MasterCatalog>,
    stop: AtomicBool,
    /// Live connection handles, force-closed on shutdown (real Redis's
    /// SHUTDOWN drops client connections too).  Keyed by a per-connection
    /// id so a connection prunes its own handle on exit — a long-lived
    /// server must not retain one dead `TcpStream` per connection ever
    /// accepted.
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_conn_id: AtomicU64,
    /// Simulated per-command processing delay (cache-box CPU time); zero by
    /// default — the link shaping lives client-side in `netsim`.
    pub op_delay: std::time::Duration,
    /// The gossip blackboard: every `GOSSIP` exchange merges the caller's
    /// digest in and replies with the merged view.
    gossip: Mutex<MembershipDigest>,
    /// This box's canonical gossip identity (the bound address, set by
    /// `serve`); `None` until serving, which disables self-refutation.
    self_addr: Mutex<Option<String>>,
    /// This box's own incarnation — bumped past any gossiped claim of its
    /// own suspicion/death (the SWIM subject-refutes rule).
    own_inc: AtomicU64,
    /// Self-refutations issued (stale claims of this box's death heard and
    /// out-advertised).
    refuted: AtomicU64,
}

fn parse_index(arg: &[u8]) -> Option<usize> {
    std::str::from_utf8(arg).ok()?.parse::<usize>().ok()
}

/// Build the `GETCHUNKS` reply for a stored ECS3 entry: the head (header +
/// chunk index) followed by each whole chunk covering an `m`-row prefix,
/// every element an O(1) shared slice of the stored bytes.  `None` when the
/// entry is not a well-formed chunked state blob (v2, alias, truncated,
/// index crc mismatch) — the dispatcher turns that into a typed error and
/// the client falls back to the byte-oriented GETRANGE path.
fn getchunks_reply(blob: &SharedBytes, m: usize) -> Option<Value> {
    use crate::model::state::{read_chunk_index, BlobLayout, KvState};
    let hdr = KvState::peek_header(blob).ok()?;
    let (ct, entries) = read_chunk_index(blob)?;
    let lo = BlobLayout::new(&hdr.model_hash, hdr.n_layers, hdr.n_kv_heads, hdr.head_dim)
        .with_chunk_tokens(ct);
    let head_len = lo.payload_off(hdr.n_tokens);
    if blob.len() < head_len {
        return None;
    }
    let k = lo.prefix_chunks(m.min(hdr.n_tokens));
    let mut items = Vec::with_capacity(k + 1);
    items.push(Value::Bulk(blob.slice(0..head_len)));
    let mut off = head_len;
    for e in entries.iter().take(k) {
        let len = e.len as usize;
        if off + len > blob.len() {
            return None; // index promises more bytes than the entry holds
        }
        items.push(Value::Bulk(blob.slice(off..off + len)));
        off += len;
    }
    Some(Value::Array(items))
}

impl KvServer {
    pub fn new(max_bytes: usize) -> Arc<Self> {
        Arc::new(KvServer {
            store: Mutex::new(Store::new(max_bytes)),
            catalog: Mutex::new(MasterCatalog::default()),
            stop: AtomicBool::new(false),
            conns: Mutex::new(HashMap::new()),
            next_conn_id: AtomicU64::new(0),
            op_delay: std::time::Duration::ZERO,
            gossip: Mutex::new(MembershipDigest::default()),
            self_addr: Mutex::new(None),
            own_inc: AtomicU64::new(0),
            refuted: AtomicU64::new(0),
        })
    }

    /// Self-refutations this box has issued against gossiped claims of its
    /// own suspicion/death.
    pub fn gossip_refutations(&self) -> u64 {
        self.refuted.load(Ordering::Relaxed)
    }

    /// A snapshot of the box's merged gossip board (tests/benches).
    pub fn gossip_board(&self) -> MembershipDigest {
        self.gossip.lock().unwrap().clone()
    }

    /// Bind and serve on `addr` (use port 0 for an ephemeral port).  Returns
    /// a handle carrying the bound address and the accept-loop thread.
    pub fn serve(self: &Arc<Self>, addr: &str) -> Result<ServerHandle> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        // the bound address is this box's gossip identity — what clients'
        // digests key its health under, and what self-refutation watches for
        *self.self_addr.lock().unwrap() = Some(local.to_string());
        let srv = Arc::clone(self);
        let accept_thread = std::thread::Builder::new()
            .name("kv-accept".into())
            .spawn(move || {
                log_info!("kvstore", "cache box listening on {local}");
                for conn in listener.incoming() {
                    if srv.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let srv2 = Arc::clone(&srv);
                            let _ = std::thread::Builder::new()
                                .name("kv-conn".into())
                                .spawn(move || srv2.handle_conn(stream));
                        }
                        Err(e) => {
                            log_debug!("kvstore", "accept error: {e}");
                        }
                    }
                }
            })?;
        Ok(ServerHandle { server: Arc::clone(self), addr: local, accept_thread: Some(accept_thread) })
    }

    fn handle_conn(&self, mut stream: TcpStream) {
        let _ = stream.set_nodelay(true);
        let conn_id = self.next_conn_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            self.conns.lock().unwrap().insert(conn_id, clone);
        }
        self.serve_conn(&mut stream);
        // prune on every exit path: `conns` tracks live connections only
        self.conns.lock().unwrap().remove(&conn_id);
    }

    fn serve_conn(&self, stream: &mut TcpStream) {
        let mut dec = Decoder::new();
        let mut out = Vec::with_capacity(64 * 1024);
        loop {
            if self.stop.load(Ordering::SeqCst) {
                return;
            }
            let req = match read_value(stream, &mut dec) {
                Ok(v) => v,
                Err(RespError::Io(_)) => return, // client hung up
                Err(RespError::Protocol(msg)) => {
                    let _ = stream.write_all(&Value::Error(format!("ERR {msg}")).encode());
                    return;
                }
            };
            let reply = self.dispatch(req);
            let shutdown = matches!(&reply, Value::Simple(s) if s == "SHUTTING DOWN");
            out.clear();
            reply.encode_into(&mut out);
            // Drain any further pipelined requests already buffered before
            // flushing, so pipelined batches get answered in one write.  A
            // protocol error mid-batch is surfaced as an error reply and the
            // connection is closed, exactly like the top-of-loop path —
            // swallowing it would leave the stream desynced, with the peer
            // waiting on replies that can never be framed correctly again.
            loop {
                match dec.next_value() {
                    Ok(Some(req)) => {
                        let r = self.dispatch(req);
                        r.encode_into(&mut out);
                    }
                    Ok(None) => break,
                    Err(RespError::Protocol(msg)) => {
                        Value::Error(format!("ERR {msg}")).encode_into(&mut out);
                        let _ = stream.write_all(&out);
                        return;
                    }
                    Err(RespError::Io(_)) => return, // unreachable for a decoder
                }
            }
            if stream.write_all(&out).is_err() {
                return;
            }
            if shutdown {
                return;
            }
        }
    }

    fn dispatch(&self, req: Value) -> Value {
        if !self.op_delay.is_zero() {
            std::thread::sleep(self.op_delay);
        }
        let Value::Array(parts) = req else {
            return Value::Error("ERR expected array request".into());
        };
        let mut args: Vec<SharedBytes> = Vec::with_capacity(parts.len());
        for p in parts {
            match p {
                Value::Bulk(b) => args.push(b),
                Value::Simple(s) => args.push(s.into_bytes().into()),
                _ => return Value::Error("ERR request items must be bulk strings".into()),
            }
        }
        let Some(cmd) = args.first() else {
            return Value::Error("ERR empty request".into());
        };
        let cmd = String::from_utf8_lossy(cmd).to_ascii_uppercase();
        match (cmd.as_str(), args.len()) {
            ("PING", 1) => Value::Simple("PONG".into()),
            ("SET", 3) => {
                // the stored entry shares the wire buffer's allocation
                let ok = self.store.lock().unwrap().set(&args[1], args[2].clone());
                if ok {
                    Value::ok()
                } else {
                    Value::Error("OOM value exceeds maxmemory".into())
                }
            }
            ("GET", 2) => match self.store.lock().unwrap().get(&args[1]) {
                Some(v) => Value::Bulk(v),
                None => Value::Nil,
            },
            ("GETRANGE", 4) => {
                let (Some(start), Some(end)) =
                    (parse_index(&args[2]), parse_index(&args[3]))
                else {
                    return Value::Error("ERR bad range".into());
                };
                // Redis semantics (inclusive end, clamped, empty bulk for an
                // empty range) live in Store::get_range; the server stays a
                // dispatcher.  Chunk alignment is a *client* concern — the
                // box never interprets blob layouts.
                match self.store.lock().unwrap().get_range(&args[1], start, end) {
                    None => Value::Nil,
                    Some(v) => Value::Bulk(v),
                }
            }
            ("GETCHUNKS", 3) => {
                let Some(m) = parse_index(&args[2]) else {
                    return Value::Error("ERR bad row count".into());
                };
                // hold the lock only for the O(1) entry lookup; slicing the
                // reply works on the shared view outside it
                let blob = self.store.lock().unwrap().get(&args[1]);
                match blob {
                    None => Value::Nil,
                    Some(blob) => match getchunks_reply(&blob, m) {
                        Some(v) => v,
                        None => Value::Error("ERR not a chunked state entry".into()),
                    },
                }
            }
            ("SPLICE", 7) => {
                let (Some(start), Some(end)) =
                    (parse_index(&args[3]), parse_index(&args[4]))
                else {
                    return Value::Error("ERR bad splice range".into());
                };
                let mut store = self.store.lock().unwrap();
                let Some(base) = store.get(&args[2]) else {
                    return Value::Error("ERR splice base missing".into());
                };
                if start > end || end > base.len() {
                    return Value::Error(format!(
                        "ERR splice range [{start}, {end}) out of bounds (base {} bytes)",
                        base.len()
                    ));
                }
                let head = &args[5];
                let tail = &args[6];
                let mut v = Vec::with_capacity(head.len() + (end - start) + tail.len());
                v.extend_from_slice(head);
                v.extend_from_slice(&base[start..end]);
                v.extend_from_slice(tail);
                let n = v.len();
                if store.set(&args[1], v) {
                    Value::Int(n as i64)
                } else {
                    Value::Error("OOM value exceeds maxmemory".into())
                }
            }
            ("DEL", 2) => Value::Int(self.store.lock().unwrap().del(&args[1]) as i64),
            ("EXISTS", 2) => Value::Int(self.store.lock().unwrap().contains(&args[1]) as i64),
            ("STRLEN", 2) => match self.store.lock().unwrap().strlen(&args[1]) {
                Some(n) => Value::Int(n as i64),
                None => Value::Int(0),
            },
            ("DBSIZE", 1) => Value::Int(self.store.lock().unwrap().len() as i64),
            ("FLUSHALL", 1) => {
                self.store.lock().unwrap().clear();
                Value::ok()
            }
            ("INFO", 1) => {
                let s = self.store.lock().unwrap();
                let c = self.catalog.lock().unwrap();
                Value::bulk(
                    format!(
                        "# edgecache cache box\r\nkeys:{}\r\nused_bytes:{}\r\nevictions:{}\r\nhits:{}\r\nmisses:{}\r\ncatalog_version:{}\r\n",
                        s.len(),
                        s.used_bytes(),
                        s.evictions,
                        s.hits,
                        s.misses,
                        c.version()
                    )
                    .into_bytes(),
                )
            }
            ("CAT.VERSION", 1) => Value::Int(self.catalog.lock().unwrap().version() as i64),
            ("CAT.REGISTER", 2) => {
                // O(1) view of the wire buffer; register compacts loose ones
                let v = self.catalog.lock().unwrap().register(args[1].clone());
                Value::Int(v as i64)
            }
            ("CAT.DELTA", 2) => {
                let since = match std::str::from_utf8(&args[1])
                    .ok()
                    .and_then(|s| s.parse::<u64>().ok())
                {
                    Some(v) => v,
                    None => return Value::Error("ERR bad since".into()),
                };
                let cat = self.catalog.lock().unwrap();
                let (ver, keys) = cat.delta(since, 100_000);
                let mut items = Vec::with_capacity(keys.len() + 1);
                items.push(Value::Int(ver as i64));
                items.extend(keys.iter().map(|k| Value::bulk(k.clone())));
                Value::Array(items)
            }
            ("GOSSIP", 2) => {
                let Some(incoming) = MembershipDigest::decode(&args[1]) else {
                    return Value::Error("ERR bad gossip digest".into());
                };
                let mut board = self.gossip.lock().unwrap();
                board.merge_from(&incoming);
                if let Some(me) = self.self_addr.lock().unwrap().as_deref() {
                    // subject-refutes: any claim that *this* box is not Up
                    // at an incarnation ≥ ours bumps ours past it — relative
                    // to the claim, so it survives a restart that zeroed the
                    // counter
                    if let Some(claim) = board.get(me) {
                        let own = self.own_inc.load(Ordering::Relaxed);
                        if claim.state != PeerHealth::Up && claim.incarnation >= own {
                            self.own_inc.store(claim.incarnation + 1, Ordering::Relaxed);
                            self.refuted.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    let own = self.own_inc.load(Ordering::Relaxed);
                    board.merge_entry(me, PeerView::new(own, PeerHealth::Up));
                }
                Value::bulk(board.encode())
            }
            ("PROBE.RELAY", 2) => {
                let Ok(target) = std::str::from_utf8(&args[1]) else {
                    return Value::Error("ERR bad probe address".into());
                };
                Value::Int(relay_probe(target) as i64)
            }
            ("SHUTDOWN", 1) => {
                self.stop.store(true, Ordering::SeqCst);
                Value::Simple("SHUTTING DOWN".into())
            }
            _ => Value::Error(format!("ERR unknown command '{cmd}' / arity {}", args.len())),
        }
    }
}

/// The third-party reachability check behind `PROBE.RELAY`: dial `target`
/// under a short fixed budget and `PING` it.  The budget is deliberately a
/// relay-local constant — a probe exists to settle a verdict quickly, and
/// a wedged relay op must never outlive the prober's own patience.
fn relay_probe(target: &str) -> bool {
    use std::io::Read;
    const BUDGET: std::time::Duration = std::time::Duration::from_millis(250);
    let Ok(sa) = target.parse::<std::net::SocketAddr>() else {
        return false;
    };
    let Ok(mut conn) = TcpStream::connect_timeout(&sa, BUDGET) else {
        return false;
    };
    let _ = conn.set_read_timeout(Some(BUDGET));
    let _ = conn.set_write_timeout(Some(BUDGET));
    if conn.write_all(&request(&[b"PING"]).encode()).is_err() {
        return false;
    }
    let mut buf = [0u8; 16];
    match conn.read(&mut buf) {
        Ok(n) if n > 0 => buf.starts_with(b"+PONG"),
        _ => false,
    }
}

/// RAII handle to a running server; shutting down unblocks the accept loop.
pub struct ServerHandle {
    pub server: Arc<KvServer>,
    pub addr: std::net::SocketAddr,
    accept_thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr_string(&self) -> String {
        self.addr.to_string()
    }

    pub fn shutdown(mut self) {
        self.do_shutdown();
    }

    fn do_shutdown(&mut self) {
        self.server.stop.store(true, Ordering::SeqCst);
        // poke the accept loop so it observes the stop flag
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // force-close live connections so blocked reads return immediately
        for (_, c) in self.server.conns.lock().unwrap().drain() {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.do_shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::super::resp::request;
    use super::*;

    #[test]
    fn master_catalog_versioning() {
        let mut c = MasterCatalog::default();
        assert_eq!(c.version(), 0);
        assert_eq!(c.register(b"k1".to_vec()), 1);
        assert_eq!(c.register(b"k2".to_vec()), 2);
        let (v, keys) = c.delta(0, 100);
        assert_eq!(v, 2);
        assert_eq!(keys.len(), 2);
        let (v, keys) = c.delta(1, 100);
        assert_eq!(v, 2);
        assert_eq!(keys.len(), 1);
        assert_eq!(keys[0], b"k2".to_vec());
        let (v, keys) = c.delta(2, 100);
        assert_eq!(v, 2);
        assert!(keys.is_empty());
        // out-of-range since is clamped, not a panic
        let (v, keys) = c.delta(99, 100);
        assert_eq!(v, 2);
        assert!(keys.is_empty());
    }

    #[test]
    fn delta_cap_respected() {
        let mut c = MasterCatalog::default();
        for i in 0..50 {
            c.register(format!("k{i}").into_bytes());
        }
        let (v, keys) = c.delta(0, 10);
        assert_eq!(v, 10);
        assert_eq!(keys.len(), 10);
        let (v2, keys2) = c.delta(v, 10);
        assert_eq!(v2, 20);
        assert_eq!(keys2[0], b"k10".to_vec());
    }

    #[test]
    fn catalog_log_keys_are_compact_shared_views() {
        let mut c = MasterCatalog::default();
        // a key that arrives as a loose slice of a big read buffer must be
        // compacted, not pin the buffer
        let buf = SharedBytes::new(vec![b'x'; 1 << 20]);
        c.register(buf.slice(0..16));
        let (_, keys) = c.delta(0, 10);
        assert_eq!(keys[0].len(), 16);
        assert!(keys[0].backing_len() <= 4096, "loose key must be re-homed");
        // delta replies are views, not copies: a clone (what CAT.DELTA puts
        // in the reply) points at the very same backing bytes
        let k0 = keys[0].clone();
        assert_eq!(k0.as_slice().as_ptr(), keys[0].as_slice().as_ptr());
    }

    #[test]
    fn pipelined_protocol_error_is_surfaced_and_closes_conn() {
        use std::io::{Read, Write};
        let srv = KvServer::new(usize::MAX);
        let h = srv.serve("127.0.0.1:0").unwrap();
        let mut raw = std::net::TcpStream::connect(h.addr).unwrap();
        // a valid PING followed, in the same write, by a garbage frame: the
        // drain loop must answer the PING *and* surface the error instead of
        // silently leaving the connection desynced
        raw.write_all(b"*1\r\n$4\r\nPING\r\n!bogus\r\n").unwrap();
        let mut buf = Vec::new();
        raw.read_to_end(&mut buf).unwrap(); // server closes after the error
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("+PONG\r\n"), "{text:?}");
        assert!(text.contains("-ERR"), "protocol error must be surfaced: {text:?}");
        h.shutdown();
    }

    #[test]
    fn dead_connections_are_pruned_from_the_handle_list() {
        let srv = KvServer::new(usize::MAX);
        let h = srv.serve("127.0.0.1:0").unwrap();
        for _ in 0..8 {
            let mut c = super::super::client::KvClient::connect(&h.addr_string()).unwrap();
            c.ping().unwrap();
            drop(c);
        }
        // connection threads notice the hangup and prune their handles
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let live = srv.conns.lock().unwrap().len();
            if live == 0 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "{live} dead connection handles still retained"
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        h.shutdown();
    }

    #[test]
    fn dispatch_without_network() {
        let srv = KvServer::new(usize::MAX);
        let set = request(&[b"SET", b"a", b"1"]);
        assert_eq!(srv.dispatch(set), Value::ok());
        let get = request(&[b"GET", b"a"]);
        assert_eq!(srv.dispatch(get), Value::bulk(&b"1"[..]));
        let bad = request(&[b"NOPE"]);
        assert!(matches!(srv.dispatch(bad), Value::Error(_)));
        let wrong_arity = request(&[b"GET"]);
        assert!(matches!(srv.dispatch(wrong_arity), Value::Error(_)));
    }

    #[test]
    fn getrange_dispatch_semantics() {
        let srv = KvServer::new(usize::MAX);
        srv.dispatch(request(&[b"SET", b"k", b"hello world"]));
        assert_eq!(
            srv.dispatch(request(&[b"GETRANGE", b"k", b"0", b"4"])),
            Value::bulk(&b"hello"[..])
        );
        // inclusive end, clamped past the value length
        assert_eq!(
            srv.dispatch(request(&[b"GETRANGE", b"k", b"6", b"999"])),
            Value::bulk(&b"world"[..])
        );
        // start beyond the value → empty bulk, missing key → nil
        assert_eq!(
            srv.dispatch(request(&[b"GETRANGE", b"k", b"99", b"100"])),
            Value::Bulk(SharedBytes::empty())
        );
        assert_eq!(
            srv.dispatch(request(&[b"GETRANGE", b"nope", b"0", b"1"])),
            Value::Nil
        );
        assert!(matches!(
            srv.dispatch(request(&[b"GETRANGE", b"k", b"x", b"1"])),
            Value::Error(_)
        ));
    }

    #[test]
    fn getchunks_dispatch_serves_head_and_whole_chunks() {
        use crate::model::state::{BlobLayout, Compression, KvState};
        let srv = KvServer::new(usize::MAX);
        let (l, s, kh, d) = (2usize, 16usize, 1usize, 8usize);
        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = 10;
        for (i, x) in st.k.iter_mut().enumerate() {
            *x = i as f32;
        }
        let ct = 4;
        let blob = st.serialize_prefix_opts(10, "h", Compression::Deflate, ct);
        let lo = BlobLayout::new("h", l, kh, d).with_chunk_tokens(ct);
        srv.dispatch(Value::Array(vec![
            Value::bulk(&b"SET"[..]),
            Value::bulk(&b"k"[..]),
            Value::bulk(blob.clone()),
        ]));

        // m = 6 rows with ct = 4 covers exactly 2 whole chunks
        let r = srv.dispatch(request(&[b"GETCHUNKS", b"k", b"6"]));
        let Value::Array(items) = r else { panic!("expected array, got {r:?}") };
        assert_eq!(items.len(), 1 + 2);
        let head_len = lo.payload_off(10);
        assert_eq!(items[0].as_bulk().unwrap(), &blob[..head_len]);
        let (_, entries) = crate::model::state::read_chunk_index(&blob).unwrap();
        let c0 = entries[0].len as usize;
        let c1 = entries[1].len as usize;
        assert_eq!(items[1].as_bulk().unwrap(), &blob[head_len..head_len + c0]);
        assert_eq!(
            items[2].as_bulk().unwrap(),
            &blob[head_len + c0..head_len + c0 + c1]
        );

        // m = 0 returns the head alone; m past the entry clamps to all chunks
        let r = srv.dispatch(request(&[b"GETCHUNKS", b"k", b"0"]));
        let Value::Array(items) = r else { panic!("{r:?}") };
        assert_eq!(items.len(), 1);
        let r = srv.dispatch(request(&[b"GETCHUNKS", b"k", b"999"]));
        let Value::Array(items) = r else { panic!("{r:?}") };
        assert_eq!(items.len(), 1 + lo.n_chunks(10));

        // missing key is nil; a non-ECS3 entry is a typed error
        assert_eq!(srv.dispatch(request(&[b"GETCHUNKS", b"nope", b"4"])), Value::Nil);
        srv.dispatch(request(&[b"SET", b"plain", b"not a state blob"]));
        assert!(matches!(
            srv.dispatch(request(&[b"GETCHUNKS", b"plain", b"4"])),
            Value::Error(_)
        ));
        assert!(matches!(
            srv.dispatch(request(&[b"GETCHUNKS", b"k", b"x"])),
            Value::Error(_)
        ));
    }

    #[test]
    fn splice_dispatch_assembles_value() {
        let srv = KvServer::new(usize::MAX);
        srv.dispatch(request(&[b"SET", b"base", b"hello world"]));
        // "he" ++ base[3,7) ++ "!!" = "he" + "lo w" + "!!"
        let r = srv.dispatch(request(&[b"SPLICE", b"n", b"base", b"3", b"7", b"he", b"!!"]));
        assert_eq!(r, Value::Int(8));
        assert_eq!(
            srv.dispatch(request(&[b"GET", b"n"])),
            Value::bulk(&b"helo w!!"[..])
        );
        // empty splice range is legal (pure head ++ tail concat)
        let r = srv.dispatch(request(&[b"SPLICE", b"m", b"base", b"0", b"0", b"a", b"b"]));
        assert_eq!(r, Value::Int(2));
        // missing base and out-of-bounds ranges are errors
        assert!(matches!(
            srv.dispatch(request(&[b"SPLICE", b"x", b"nope", b"0", b"0", b"", b""])),
            Value::Error(_)
        ));
        assert!(matches!(
            srv.dispatch(request(&[b"SPLICE", b"x", b"base", b"5", b"99", b"", b""])),
            Value::Error(_)
        ));
        assert!(matches!(
            srv.dispatch(request(&[b"SPLICE", b"x", b"base", b"7", b"3", b"", b""])),
            Value::Error(_)
        ));
    }

    #[test]
    fn gossip_board_merges_and_self_refutes() {
        let srv = KvServer::new(usize::MAX);
        let h = srv.serve("127.0.0.1:0").unwrap();
        let me = h.addr_string();

        // a client digest claiming some third box dead + this box suspect
        let mut d = MembershipDigest::new(4);
        d.merge_entry("10.0.0.9:7000", PeerView::new(0, PeerHealth::Dead));
        d.merge_entry(&me, PeerView::new(3, PeerHealth::Suspect));
        let r = srv.dispatch(request(&[b"GOSSIP", &d.encode()]));
        let Value::Bulk(b) = r else { panic!("expected bulk, got {r:?}") };
        let merged = MembershipDigest::decode(&b).unwrap();

        // the third-box verdict is on the board for other clients to adopt
        assert_eq!(
            merged.get("10.0.0.9:7000"),
            Some(PeerView::new(0, PeerHealth::Dead))
        );
        // and the box refuted its own suspicion: Up at a bumped incarnation
        let self_view = merged.get(&me).unwrap();
        assert_eq!(self_view.state, PeerHealth::Up);
        assert_eq!(self_view.incarnation, 4, "bumped past the claimed incarnation");
        assert_eq!(srv.gossip_refutations(), 1);
        // the refutation wins the merge against the stale claim
        assert_eq!(
            PeerView::merge(self_view, PeerView::new(3, PeerHealth::Suspect)),
            self_view
        );

        // an empty digest still harvests the board (pull-only exchange)
        let empty = MembershipDigest::new(0);
        let r = srv.dispatch(request(&[b"GOSSIP", &empty.encode()]));
        let Value::Bulk(b) = r else { panic!("{r:?}") };
        let board = MembershipDigest::decode(&b).unwrap();
        assert!(board.get("10.0.0.9:7000").is_some(), "board is sticky");

        // garbage digests are a typed error, not a poisoned board
        assert!(matches!(
            srv.dispatch(request(&[b"GOSSIP", b"\xff\xfe"])),
            Value::Error(_)
        ));
        h.shutdown();
    }

    #[test]
    fn probe_relay_reports_reachability() {
        let a = KvServer::new(usize::MAX);
        let ha = a.serve("127.0.0.1:0").unwrap();
        let b = KvServer::new(usize::MAX);
        let hb = b.serve("127.0.0.1:0").unwrap();

        // box A relays a probe to live box B: reachable
        let r = a.dispatch(request(&[b"PROBE.RELAY", hb.addr_string().as_bytes()]));
        assert_eq!(r, Value::Int(1));

        // a dead target address: unreachable (bounded, no wedge)
        let dead = hb.addr_string();
        hb.shutdown();
        let t0 = std::time::Instant::now();
        let r = a.dispatch(request(&[b"PROBE.RELAY", dead.as_bytes()]));
        assert_eq!(r, Value::Int(0));
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(2),
            "relay budget must bound the probe"
        );

        // unparsable addresses are a clean 0, not an error loop
        let r = a.dispatch(request(&[b"PROBE.RELAY", b"not an address"]));
        assert_eq!(r, Value::Int(0));
        ha.shutdown();
    }

    #[test]
    fn splice_respects_memory_budget() {
        let srv = KvServer::new(64);
        srv.dispatch(request(&[b"SET", b"base", b"0123456789"]));
        let big_head = vec![b'x'; 200];
        let r = srv.dispatch(Value::Array(vec![
            Value::bulk(&b"SPLICE"[..]),
            Value::bulk(&b"big"[..]),
            Value::bulk(&b"base"[..]),
            Value::bulk(&b"0"[..]),
            Value::bulk(&b"10"[..]),
            Value::bulk(big_head),
            Value::bulk(&b""[..]),
        ]));
        assert!(matches!(r, Value::Error(_)), "oversized splice must OOM");
        assert_eq!(
            srv.dispatch(request(&[b"EXISTS", b"big"])),
            Value::Int(0),
            "rejected splice must store nothing"
        );
    }
}
