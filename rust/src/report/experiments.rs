//! Experiment drivers behind every paper table/figure (DESIGN.md §6).
//!
//! Two tracks, both exercised by the benches:
//!
//! * **real** — the full stack end to end: PJRT model, real state bytes over
//!   real sockets, device pacing + link shaping.  Absolute numbers land on
//!   the paper's scale but each low-end Case-1 query costs ~24 paced
//!   seconds, so the real track runs a handful of prompts.
//! * **analytic** — the calibrated device/link models evaluated over the
//!   full 6434-prompt population (token counts from the real tokenizer and
//!   workload; no model execution).  This is how the population-mean tables
//!   are regenerated at paper scale.
//!
//! The paper's state sizes (34.5 KB/token for 270M, 29.8 KB/token for 1B —
//! Table 3's 2.25 MB / 9.94 MB entries) parameterize the analytic track so
//! transfer times match the testbed; the real track uses the sim-model's
//! actual state bytes.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{CacheBox, EdgeClient, EdgeClientConfig, HitCase};
use crate::devicemodel::DeviceProfile;
use crate::engine::Engine;
use crate::metrics::{CaseAggregate, Phase, PhaseBreakdown, PHASES};
use crate::model::state::Compression;
use crate::netsim::LinkModel;
use crate::tokenizer::Tokenizer;
use crate::workload::{Generator, Prompt, DOMAINS};

/// One experimental setting (a row pair of Table 2).
#[derive(Debug, Clone)]
pub struct Setting {
    pub name: &'static str,
    pub device: DeviceProfile,
    pub link: LinkModel,
    /// Few-shot examples per prompt (paper: N=1 low-end, N=5 high-end).
    pub n_shots: usize,
    /// Response-token budget (paper-implied: 64 low-end, 1 high-end).
    pub max_new: usize,
    /// State bytes per cached token for the analytic track.
    pub bytes_per_token: usize,
    /// Catalog Bloom false-positive design rate (for expected-cost terms).
    pub fp_rate: f64,
}

impl Setting {
    /// Low-end: Pi Zero 2W + Gemma-3-270M-class, Wi-Fi 4 (paper defaults).
    pub fn low_end_paper() -> Self {
        Setting {
            name: "Low-end",
            device: DeviceProfile::pi_zero_2w(),
            link: LinkModel::wifi4_2g4(),
            n_shots: 1,
            max_new: 64,
            bytes_per_token: 34_474, // 2.25 MB / 65.27 tokens
            fp_rate: 0.01,
        }
    }

    /// High-end: Pi 5 + Gemma-3-1B-class.
    pub fn high_end_paper() -> Self {
        Setting {
            name: "High-end",
            device: DeviceProfile::pi5_4gb(),
            link: LinkModel::wifi4_2g4(),
            n_shots: 5,
            max_new: 1,
            bytes_per_token: 29_751, // 9.94 MB / 334.11 tokens
            fp_rate: 0.01,
        }
    }
}

/// Analytic phase model for one (prompt, case) — the closed-form twin of the
/// EdgeClient flow, matching Table 3's composition rules.
pub fn analytic_breakdown(
    s: &Setting,
    prompt_tokens: usize,
    matched_tokens: usize,
    include_expected_fp_cost: bool,
) -> PhaseBreakdown {
    let mut bd = PhaseBreakdown::default();
    bd.prompt_tokens = prompt_tokens;
    bd.reused_tokens = matched_tokens;
    bd.response_tokens = s.max_new;
    bd.add(Phase::Token, s.device.tokenize_time(prompt_tokens));
    bd.add(Phase::Bloom, s.device.bloom_time(1));
    if matched_tokens > 0 {
        let bytes = matched_tokens * s.bytes_per_token;
        bd.state_bytes = bytes;
        bd.add(Phase::Redis, s.link.delay_for(bytes, None));
    } else if include_expected_fp_cost {
        // §5.2.4: a Case-1 query pays the download with probability fp_rate
        let bytes = prompt_tokens * s.bytes_per_token;
        let d = s.link.delay_for(bytes, None).mul_f64(s.fp_rate);
        bd.add(Phase::Redis, d);
    }
    if matched_tokens < prompt_tokens {
        bd.add(
            Phase::PDecode,
            s.device.prefill_time(prompt_tokens - matched_tokens),
        );
    }
    bd.add(Phase::RDecode, s.device.decode_time(s.max_new));
    bd.add(Phase::Sample, s.device.sample_time(s.max_new));
    bd
}

/// The population of prompts a setting is evaluated on.
pub fn population(seed: u64, n_shots: usize, n_prompts: usize) -> Vec<Prompt> {
    let g = Generator::new(seed);
    let per_domain = n_prompts.div_ceil(DOMAINS.len());
    let mut prompts = Vec::with_capacity(n_prompts);
    'outer: for q in 0..per_domain {
        for &d in DOMAINS.iter() {
            prompts.push(g.prompt(d, q as u64, n_shots));
            if prompts.len() >= n_prompts {
                break 'outer;
            }
        }
    }
    prompts
}

/// Analytic Table 2 + Table 3 over `n_prompts` (paper: 6434): returns
/// (case1, case5) aggregates.
pub fn analytic_table23(
    s: &Setting,
    seed: u64,
    n_prompts: usize,
) -> (CaseAggregate, CaseAggregate) {
    let tok = Tokenizer::full();
    let mut miss = CaseAggregate::default();
    let mut hit = CaseAggregate::default();
    for p in population(seed, s.n_shots, n_prompts) {
        let n = tok.encode(&p.full_text()).len() + 1; // +BOS
        miss.push(&analytic_breakdown(s, n, 0, true));
        hit.push(&analytic_breakdown(s, n, n, false));
    }
    (miss, hit)
}

/// Analytic Table 4: total decoding time per partial-matching case for one
/// astronomy N=5 prompt.  Returns rows (case_no, matched, pct, t_decode_s,
/// redis_s).
pub fn analytic_table4(s: &Setting, seed: u64) -> Vec<(usize, usize, f64, f64, f64)> {
    let tok = Tokenizer::full();
    let g = Generator::new(seed);
    let p = g.prompt("astronomy", 0, 5);
    let full: usize = tok.encode(&p.full_text()).len() + 1;
    let mut matched: Vec<usize> = vec![0];
    for ptext in p.prefix_texts() {
        matched.push((tok.encode(&ptext).len() + 1).min(full));
    }
    // prefix_texts ends with the full prompt; dedup artifacts
    matched.dedup();
    let mut out = Vec::new();
    for (i, &m) in matched.iter().enumerate() {
        let bd = analytic_breakdown(s, full, m, false);
        out.push((
            i + 1,
            m,
            m as f64 / full as f64 * 100.0,
            bd.t_decode().as_secs_f64(),
            bd.get(Phase::Redis).as_secs_f64(),
        ));
    }
    out
}

/// Configuration for the real-track run.
#[derive(Debug, Clone)]
pub struct RealRunCfg {
    pub preset: &'static str,
    pub n_prompts: usize,
    pub paced: bool,
    pub setting: Setting,
    pub seed: u64,
}

impl RealRunCfg {
    pub fn native_tiny(n_prompts: usize) -> Self {
        RealRunCfg {
            preset: "tiny",
            n_prompts,
            paced: false,
            setting: Setting {
                // native: no pacing/shaping, real bytes
                device: DeviceProfile::host(),
                link: LinkModel::loopback(),
                ..Setting::low_end_paper()
            },
            seed: 42,
        }
    }
}

/// Real-track Case-1/Case-5 measurement: each prompt queried twice through
/// an in-process cache box (first = miss + upload, second = full hit).
/// Returns (case1, case5) aggregates plus the client stats.
pub fn real_table23(
    engine: Arc<Engine>,
    cfg: &RealRunCfg,
) -> Result<(CaseAggregate, CaseAggregate)> {
    let cb = CacheBox::start_local()?;
    let ecfg = EdgeClientConfig {
        name: cfg.setting.name.into(),
        peers: vec![crate::coordinator::PeerConfig::new(cb.addr())],
        replicas: 0,
        placement: crate::coordinator::PlacementKind::PowerOfTwoChoices,
        link: cfg.setting.link.clone(),
        device: if cfg.paced {
            cfg.setting.device.clone()
        } else {
            DeviceProfile::host()
        },
        max_new_tokens: Some(cfg.setting.max_new.min(8)),
        compression: Compression::None,
        chunk_tokens: crate::model::state::DEFAULT_CHUNK_TOKENS,
        adaptive_chunk: false,
        partial_matching: true,
        use_catalog: true,
        fetch_policy: crate::coordinator::FetchPolicy::Always,
        // the paper's Case-5 rows measure the pure fetch path, so the
        // chunk planner is ablated here even under device pacing
        plan: crate::coordinator::PlanMode::Range,
        probe_negative_ttl: std::time::Duration::from_millis(1500),
        min_hit_tokens: 1,
        sync_interval: None,
        deadline: None,
        gossip: true,
        indirect_probes: 1,
        adaptive_deadline_k: 0.0,
        // the paper's tables measure the exact tier; the semantic tier is
        // ablated so its sketch/token-header uploads don't skew the wire
        // columns (the repeat workload would never probe anyway)
        semantic: false,
        semantic_dist: 16,
        semantic_k: 3,
        repair_sweep: std::time::Duration::ZERO,
        seed: cfg.seed,
    };
    let mut client = EdgeClient::new(engine, ecfg)?;
    let mut miss = CaseAggregate::default();
    let mut hit = CaseAggregate::default();
    for p in population(cfg.seed, cfg.setting.n_shots, cfg.n_prompts) {
        let r1 = client.query(&p)?;
        anyhow::ensure!(
            r1.case == HitCase::Miss || r1.false_positive,
            "first query should miss, got {:?}",
            r1.case
        );
        miss.push(&r1.breakdown);
        let r2 = client.query(&p)?;
        anyhow::ensure!(r2.case == HitCase::Full, "second query should fully hit");
        hit.push(&r2.breakdown);
    }
    client.shutdown();
    cb.shutdown();
    Ok((miss, hit))
}

/// Render a Table-3-style breakdown block.
pub fn render_table3(rows: &[(&str, &CaseAggregate, usize, usize)]) -> String {
    let headers = [
        "Setting (case)", "Token", "Bloom", "P-decode", "Redis", "R-decode",
        "Sample", "N", "# tokens", "State [MB]",
    ];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|(name, agg, n_shots, _max_new)| {
            let mut r = vec![name.to_string()];
            for p in PHASES {
                r.push(format!("{:.2}", agg.phase_mean_ms(p)));
            }
            r.push(n_shots.to_string());
            r.push(format!("{:.2}", agg.mean_prompt_tokens()));
            r.push(format!("{:.2}", agg.mean_state_mb()));
            r
        })
        .collect();
    super::ascii_table(&headers, &body)
}

/// Render a Table-2-style TTFT/TTLT block; returns the text and the four
/// means (ttft_miss, ttft_hit, ttlt_miss, ttlt_hit) in seconds.
pub fn render_table2(
    name: &str,
    miss: &CaseAggregate,
    hit: &CaseAggregate,
) -> (String, [f64; 4]) {
    let tm = miss.ttft.mean();
    let th = hit.ttft.mean();
    let lm = miss.ttlt.mean();
    let lh = hit.ttlt.mean();
    let rows = vec![vec![
        name.to_string(),
        format!("{tm:.2}"),
        format!("{th:.2}"),
        format!("{:.2}", th / tm.max(1e-12) * 100.0),
        format!("{lm:.2}"),
        format!("{lh:.2}"),
        format!("{:.2}", lh / lm.max(1e-12) * 100.0),
    ]];
    (
        super::ascii_table(
            &["Setting", "TTFT c1 [s]", "TTFT c5 [s]", "[%]", "TTLT c1 [s]", "TTLT c5 [s]", "[%]"],
            &rows,
        ),
        [tm, th, lm, lh],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_low_end_matches_paper_shape() {
        // Use the paper's own mean token counts to pin the absolute numbers:
        // 65.27-token prompts, 64-token responses.
        let s = Setting::low_end_paper();
        let c1 = analytic_breakdown(&s, 65, 0, true);
        let c5 = analytic_breakdown(&s, 65, 65, false);
        let ttft1 = c1.ttft().as_secs_f64();
        let ttft5 = c5.ttft().as_secs_f64();
        // paper: 12.59 -> 0.87 (93.12 % reduction)
        assert!((11.5..13.5).contains(&ttft1), "{ttft1}");
        assert!((0.7..1.1).contains(&ttft5), "{ttft5}");
        let red = (ttft5 - ttft1) / ttft1 * 100.0;
        assert!((-95.0..-90.0).contains(&red), "TTFT reduction {red:.2}%");
        // TTLT: 23.74 -> 11.86 (~50 %)
        let r2 = (c5.ttlt().as_secs_f64() - c1.ttlt().as_secs_f64())
            / c1.ttlt().as_secs_f64()
            * 100.0;
        assert!((-56.0..-44.0).contains(&r2), "TTLT reduction {r2:.2}%");
    }

    #[test]
    fn analytic_high_end_regresses_like_paper() {
        let s = Setting::high_end_paper();
        let c1 = analytic_breakdown(&s, 334, 0, true);
        let c5 = analytic_breakdown(&s, 334, 334, false);
        let ttft1 = c1.ttft().as_secs_f64();
        let ttft5 = c5.ttft().as_secs_f64();
        // paper: 2.70 -> 2.89 (+7 %): hit must be SLOWER on the high-end
        assert!(ttft5 > ttft1, "hit {ttft5} must exceed miss {ttft1}");
        let ratio = ttft5 / ttft1 * 100.0;
        assert!((101.0..115.0).contains(&ratio), "ratio {ratio:.1}%");
    }

    #[test]
    fn population_spans_domains() {
        let p = population(1, 1, 100);
        assert_eq!(p.len(), 100);
        let domains: std::collections::HashSet<_> =
            p.iter().map(|x| x.domain.clone()).collect();
        assert!(domains.len() >= 57.min(100));
    }

    #[test]
    fn analytic_table4_monotone() {
        let s = Setting::low_end_paper();
        let rows = analytic_table4(&s, 7);
        assert!(rows.len() >= 4, "cases 1..5 (deduped)");
        assert_eq!(rows[0].1, 0);
        for w in rows.windows(2) {
            assert!(w[1].1 > w[0].1, "matched tokens increase");
            assert!(w[1].3 < w[0].3, "T-decode decreases with matching");
        }
        let last = rows.last().unwrap();
        assert!((last.2 - 100.0).abs() < 1e-9, "last case is the full prompt");
        assert!(last.3 < rows[0].3 * 0.6, "full hit saves most decode time");
    }

    #[test]
    fn table_renderers_smoke() {
        let s = Setting::low_end_paper();
        let (miss, hit) = analytic_table23(&s, 1, 20);
        let (t2, means) = render_table2("Low-end", &miss, &hit);
        assert!(t2.contains("TTFT"));
        assert!(means[0] > means[1], "miss TTFT > hit TTFT on low-end");
        let t3 = render_table3(&[
            ("Low-end (Case 1)", &miss, 1, 64),
            ("Low-end (Case 5)", &hit, 1, 64),
        ]);
        assert!(t3.contains("P-decode"));
    }
}
