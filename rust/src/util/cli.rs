//! Declarative command-line argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, repeatable options
//! (`--peer a --peer b`, read back via [`Matches::all`]), enumerated
//! options with parse-time validation ([`Command::choice`], e.g.
//! `--placement ring|p2c`), positional arguments, subcommands, defaults,
//! and auto-generated `--help`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
    pub required: bool,
    /// When set, provided values must be one of these (enumerated option).
    pub choices: Option<&'static [&'static str]>,
}

#[derive(Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    args: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, args: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: true,
            required: false,
            choices: None,
        });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
            required: false,
            choices: None,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
            required: true,
            choices: None,
        });
        self
    }

    /// A repeatable value option (`--name a --name b`); every occurrence is
    /// collected and read back with [`Matches::all`].  Declared like a
    /// defaultless optional value — zero occurrences is fine.
    pub fn multi(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
            required: false,
            choices: None,
        });
        self
    }

    /// An enumerated value option: anything outside `choices` is rejected
    /// at parse time with a message naming the legal values.  `default`
    /// must be one of the choices.
    pub fn choice(
        mut self,
        name: &'static str,
        choices: &'static [&'static str],
        default: &'static str,
        help: &'static str,
    ) -> Self {
        debug_assert!(choices.contains(&default), "--{name} default not a choice");
        self.args.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
            required: false,
            choices: Some(choices),
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = writeln!(s, "\noptions:");
        for a in &self.args {
            let tail = if a.is_flag {
                String::new()
            } else if let (Some(cs), Some(d)) = (a.choices, &a.default) {
                format!(" <{}>  (default: {d})", cs.join("|"))
            } else if let Some(d) = &a.default {
                format!(" <value>  (default: {d})")
            } else {
                " <value>  (required)".to_string()
            };
            let _ = writeln!(s, "  --{}{}\n      {}", a.name, tail, a.help);
        }
        s
    }

    /// Parse a raw argv slice (without the program/subcommand name).
    pub fn parse(&self, argv: &[String]) -> Result<Matches, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut multi: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positional: Vec<String> = Vec::new();

        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .args
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    flags.push(key);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    multi.entry(key.clone()).or_default().push(v.clone());
                    values.insert(key, v);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }

        for spec in &self.args {
            if spec.required && !values.contains_key(spec.name) {
                return Err(format!("missing required --{}\n\n{}", spec.name, self.usage()));
            }
            if let (false, Some(d)) = (spec.is_flag, &spec.default) {
                values.entry(spec.name.to_string()).or_insert_with(|| d.clone());
            }
            if let (Some(choices), Some(v)) = (spec.choices, values.get(spec.name)) {
                if !choices.contains(&v.as_str()) {
                    return Err(format!(
                        "--{}={v}: expected one of {}",
                        spec.name,
                        choices.join("|")
                    ));
                }
            }
        }

        Ok(Matches { values, multi, flags, positional })
    }
}

#[derive(Debug)]
pub struct Matches {
    values: BTreeMap<String, String>,
    /// Every explicit occurrence of each value option, in argv order
    /// (defaults are not included — only what the user typed).
    multi: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Every explicit occurrence of a repeatable option, in order; empty
    /// when the option never appeared (defaults don't count).
    pub fn all(&self, name: &str) -> Vec<String> {
        self.multi.get(name).cloned().unwrap_or_default()
    }

    pub fn str(&self, name: &str) -> String {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared/defaulted"))
            .clone()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn parse_num<T: std::str::FromStr>(&self, name: &str) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .values
            .get(name)
            .ok_or_else(|| format!("missing --{name}"))?;
        raw.parse::<T>()
            .map_err(|e| format!("--{name}={raw}: {e}"))
    }

    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.parse_num(name)
    }

    pub fn u64(&self, name: &str) -> Result<u64, String> {
        self.parse_num(name)
    }

    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.parse_num(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("test", "a test command")
            .opt("port", "7600", "tcp port")
            .opt("model", "tiny", "model preset")
            .flag("verbose", "chatty mode")
            .req("out", "output path")
    }

    #[test]
    fn defaults_and_values() {
        let m = cmd().parse(&argv(&["--out", "x.txt"])).unwrap();
        assert_eq!(m.str("port"), "7600");
        assert_eq!(m.usize("port").unwrap(), 7600);
        assert_eq!(m.str("out"), "x.txt");
        assert!(!m.flag("verbose"));
    }

    #[test]
    fn equals_form_and_flags() {
        let m = cmd()
            .parse(&argv(&["--port=9000", "--verbose", "--out=o", "pos1"]))
            .unwrap();
        assert_eq!(m.str("port"), "9000");
        assert!(m.flag("verbose"));
        assert_eq!(m.positional, vec!["pos1"]);
    }

    #[test]
    fn repeated_options_collect_in_order() {
        let c = Command::new("t", "about")
            .multi("peer", "cache-box peer (repeatable)")
            .opt("port", "1", "port");
        let m = c
            .parse(&argv(&["--peer", "a:1", "--peer=b:2", "--peer", "c:3"]))
            .unwrap();
        assert_eq!(m.all("peer"), vec!["a:1", "b:2", "c:3"]);
        // last occurrence also wins the scalar view
        assert_eq!(m.str("peer"), "c:3");
        // absent repeatable options and defaults yield no occurrences
        assert!(m.all("port").is_empty());
        assert!(c.parse(&argv(&[])).unwrap().all("peer").is_empty());
    }

    #[test]
    fn choice_options_validated_at_parse_time() {
        let c = || {
            Command::new("t", "about")
                .choice("placement", &["p2c", "ring"], "p2c", "placement policy")
                .req("out", "output path")
        };
        // default applies and is legal
        let m = c().parse(&argv(&["--out", "o"])).unwrap();
        assert_eq!(m.str("placement"), "p2c");
        // both forms accept a legal value
        let m = c().parse(&argv(&["--placement", "ring", "--out", "o"])).unwrap();
        assert_eq!(m.str("placement"), "ring");
        let m = c().parse(&argv(&["--placement=ring", "--out", "o"])).unwrap();
        assert_eq!(m.str("placement"), "ring");
        // an illegal value is rejected with the legal set named
        let err = c()
            .parse(&argv(&["--placement", "consistent", "--out", "o"]))
            .unwrap_err();
        assert!(err.contains("p2c|ring"), "{err}");
        // the usage line shows the choices
        let usage = c().usage();
        assert!(usage.contains("<p2c|ring>"), "{usage}");
    }

    #[test]
    fn missing_required_rejected() {
        assert!(cmd().parse(&argv(&[])).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&argv(&["--nope", "1", "--out", "o"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&argv(&["--verbose=1", "--out", "o"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = cmd().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("tcp port"));
    }

    #[test]
    fn bad_number_reported() {
        let m = cmd().parse(&argv(&["--port", "abc", "--out", "o"])).unwrap();
        assert!(m.usize("port").is_err());
    }
}
