//! Declarative command-line argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands, defaults, and auto-generated `--help`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
    pub required: bool,
}

#[derive(Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    args: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, args: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec { name, help, default: None, is_flag: true, required: false });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
            required: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec { name, help, default: None, is_flag: false, required: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = writeln!(s, "\noptions:");
        for a in &self.args {
            let tail = if a.is_flag {
                String::new()
            } else if let Some(d) = &a.default {
                format!(" <value>  (default: {d})")
            } else {
                " <value>  (required)".to_string()
            };
            let _ = writeln!(s, "  --{}{}\n      {}", a.name, tail, a.help);
        }
        s
    }

    /// Parse a raw argv slice (without the program/subcommand name).
    pub fn parse(&self, argv: &[String]) -> Result<Matches, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positional: Vec<String> = Vec::new();

        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .args
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    flags.push(key);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    values.insert(key, v);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }

        for spec in &self.args {
            if spec.required && !values.contains_key(spec.name) {
                return Err(format!("missing required --{}\n\n{}", spec.name, self.usage()));
            }
            if let (false, Some(d)) = (spec.is_flag, &spec.default) {
                values.entry(spec.name.to_string()).or_insert_with(|| d.clone());
            }
        }

        Ok(Matches { values, flags, positional })
    }
}

#[derive(Debug)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> String {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared/defaulted"))
            .clone()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn parse_num<T: std::str::FromStr>(&self, name: &str) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .values
            .get(name)
            .ok_or_else(|| format!("missing --{name}"))?;
        raw.parse::<T>()
            .map_err(|e| format!("--{name}={raw}: {e}"))
    }

    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.parse_num(name)
    }

    pub fn u64(&self, name: &str) -> Result<u64, String> {
        self.parse_num(name)
    }

    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.parse_num(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("test", "a test command")
            .opt("port", "7600", "tcp port")
            .opt("model", "tiny", "model preset")
            .flag("verbose", "chatty mode")
            .req("out", "output path")
    }

    #[test]
    fn defaults_and_values() {
        let m = cmd().parse(&argv(&["--out", "x.txt"])).unwrap();
        assert_eq!(m.str("port"), "7600");
        assert_eq!(m.usize("port").unwrap(), 7600);
        assert_eq!(m.str("out"), "x.txt");
        assert!(!m.flag("verbose"));
    }

    #[test]
    fn equals_form_and_flags() {
        let m = cmd()
            .parse(&argv(&["--port=9000", "--verbose", "--out=o", "pos1"]))
            .unwrap();
        assert_eq!(m.str("port"), "9000");
        assert!(m.flag("verbose"));
        assert_eq!(m.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_required_rejected() {
        assert!(cmd().parse(&argv(&[])).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&argv(&["--nope", "1", "--out", "o"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&argv(&["--verbose=1", "--out", "o"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = cmd().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("tcp port"));
    }

    #[test]
    fn bad_number_reported() {
        let m = cmd().parse(&argv(&["--port", "abc", "--out", "o"])).unwrap();
        assert!(m.usize("port").is_err());
    }
}
