//! Little-endian byte cursors for the state-blob and wire formats, plus
//! [`SharedBytes`] — the reference-counted buffer view the whole blob
//! pipeline is built on.
//!
//! `SharedBytes` is an `Arc<Vec<u8>>` together with an `(offset, len)`
//! window.  Cloning and [`SharedBytes::slice`] are O(1) refcount bumps, so
//! one allocation can travel from `KvState::serialize` through the RESP
//! encoder, the server's read buffer, the [`Store`](crate::kvstore::Store)
//! and back out of a `GETRANGE` reply without the payload ever being
//! memcpy'd into a fresh allocation.  The [`copymeter`] module counts the
//! payload-sized copies that *do* still happen (wire writes, the final
//! scatter into a live KV cache) so the `substrate_micro` bench can track
//! the copy budget per serialize→restore round trip.

use std::ops::Range;
use std::sync::Arc;

use thiserror::Error;

/// Process-wide accounting of payload bytes copied into fresh allocations
/// on the blob pipeline (diagnostic only; relaxed atomics).
pub mod copymeter {
    use std::sync::atomic::{AtomicU64, Ordering};

    static BYTES: AtomicU64 = AtomicU64::new(0);

    pub fn add(n: usize) {
        BYTES.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn reset() {
        BYTES.store(0, Ordering::Relaxed);
    }

    pub fn get() -> u64 {
        BYTES.load(Ordering::Relaxed)
    }
}

/// Cheaply clonable, sliceable view into a shared byte buffer.
#[derive(Clone, Default)]
pub struct SharedBytes {
    data: Arc<Vec<u8>>,
    off: usize,
    len: usize,
}

impl SharedBytes {
    /// Wrap an owned buffer without copying.
    pub fn new(v: Vec<u8>) -> Self {
        let len = v.len();
        SharedBytes { data: Arc::new(v), off: 0, len }
    }

    pub fn empty() -> Self {
        Self::default()
    }

    /// Copying constructor (counted by [`copymeter`]).
    pub fn copy_from(b: &[u8]) -> Self {
        copymeter::add(b.len());
        Self::new(b.to_vec())
    }

    /// View `[off, off+len)` of an existing shared allocation.
    pub fn from_arc_slice(data: Arc<Vec<u8>>, off: usize, len: usize) -> Self {
        assert!(
            off + len <= data.len(),
            "slice [{off}, {}) out of bounds of backing {}",
            off + len,
            data.len()
        );
        SharedBytes { data, off, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.data[self.off..self.off + self.len]
    }

    /// O(1) subview sharing the same backing allocation.
    pub fn slice(&self, r: Range<usize>) -> SharedBytes {
        assert!(
            r.start <= r.end && r.end <= self.len,
            "slice {}..{} out of view of length {}",
            r.start,
            r.end,
            self.len
        );
        SharedBytes {
            data: Arc::clone(&self.data),
            off: self.off + r.start,
            len: r.end - r.start,
        }
    }

    /// Size of the backing allocation (≥ `len`); the difference is memory
    /// this view pins but does not use.
    pub fn backing_len(&self) -> usize {
        self.data.len()
    }

    /// Copy out to an owned `Vec` (counted).
    pub fn to_vec(&self) -> Vec<u8> {
        copymeter::add(self.len);
        self.as_slice().to_vec()
    }

    /// Unwrap to an owned `Vec`, avoiding the copy when this view is the
    /// sole whole-buffer owner.
    pub fn into_vec(self) -> Vec<u8> {
        if self.off == 0 && self.len == self.data.len() {
            match Arc::try_unwrap(self.data) {
                Ok(v) => return v,
                Err(data) => {
                    copymeter::add(data.len());
                    return data.as_slice().to_vec();
                }
            }
        }
        self.to_vec()
    }

    /// Re-home a view that pins a much larger backing allocation (e.g. one
    /// bulk payload sliced out of a pipelined read buffer).  Keeping such a
    /// view alive — say as an LRU [`Store`](crate::kvstore::Store) entry —
    /// would make the byte accounting lie about real memory use, so callers
    /// that retain buffers long-term compact loose views into tight copies.
    /// A kept view pins at most `1.5 × len` (plus a 4 KB floor so tiny
    /// values off a read buffer don't each trigger a copy).
    pub fn detach_loose(self) -> SharedBytes {
        let waste = self.data.len() - self.len;
        if waste > 4096 && waste > self.len / 2 {
            SharedBytes::copy_from(self.as_slice())
        } else {
            self
        }
    }
}

impl std::ops::Deref for SharedBytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for SharedBytes {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl From<Vec<u8>> for SharedBytes {
    fn from(v: Vec<u8>) -> Self {
        Self::new(v)
    }
}

impl From<&[u8]> for SharedBytes {
    fn from(b: &[u8]) -> Self {
        Self::copy_from(b)
    }
}

impl std::fmt::Debug for SharedBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.len <= 32 {
            write!(f, "SharedBytes({:?})", self.as_slice())
        } else {
            write!(
                f,
                "SharedBytes({} bytes, {:?}…)",
                self.len,
                &self.as_slice()[..16]
            )
        }
    }
}

impl PartialEq for SharedBytes {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for SharedBytes {}

impl PartialEq<[u8]> for SharedBytes {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<&[u8]> for SharedBytes {
    fn eq(&self, other: &&[u8]) -> bool {
        self.as_slice() == *other
    }
}

impl PartialEq<Vec<u8>> for SharedBytes {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<const N: usize> PartialEq<[u8; N]> for SharedBytes {
    fn eq(&self, other: &[u8; N]) -> bool {
        self.as_slice() == other
    }
}

impl<const N: usize> PartialEq<&[u8; N]> for SharedBytes {
    fn eq(&self, other: &&[u8; N]) -> bool {
        self.as_slice() == *other
    }
}

#[derive(Debug, Error)]
pub enum ByteError {
    #[error("unexpected end of buffer (need {need} bytes at offset {at}, have {have})")]
    Eof { at: usize, need: usize, have: usize },
    #[error("invalid utf-8 in length-prefixed string")]
    Utf8,
}

/// Append-only little-endian writer.
#[derive(Default, Debug)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Writer { buf: Vec::with_capacity(n) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }
    /// u32-length-prefixed byte string.
    pub fn lp_bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.bytes(v);
    }
    pub fn lp_str(&mut self, v: &str) {
        self.lp_bytes(v.as_bytes());
    }
    pub fn f32_slice(&mut self, v: &[f32]) {
        // bulk copy; f32::to_le_bytes per element would be 4x slower
        let ptr = v.as_ptr() as *const u8;
        let bytes = unsafe { std::slice::from_raw_parts(ptr, v.len() * 4) };
        #[cfg(target_endian = "big")]
        compile_error!("little-endian host required for f32_slice fast path");
        self.buf.extend_from_slice(bytes);
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian reader over a borrowed slice.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ByteError> {
        if self.remaining() < n {
            return Err(ByteError::Eof { at: self.pos, need: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, ByteError> {
        Ok(self.take(1)?[0])
    }
    pub fn u16(&mut self) -> Result<u16, ByteError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    pub fn u32(&mut self) -> Result<u32, ByteError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> Result<u64, ByteError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn i32(&mut self) -> Result<i32, ByteError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn f32(&mut self) -> Result<f32, ByteError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], ByteError> {
        self.take(n)
    }
    pub fn lp_bytes(&mut self) -> Result<&'a [u8], ByteError> {
        let n = self.u32()? as usize;
        self.take(n)
    }
    pub fn lp_str(&mut self) -> Result<&'a str, ByteError> {
        std::str::from_utf8(self.lp_bytes()?).map_err(|_| ByteError::Utf8)
    }
    /// Bulk-read `n` f32s (little-endian).
    pub fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>, ByteError> {
        let raw = self.take(n * 4)?;
        let mut out = vec![0f32; n];
        // safe bulk copy: make an aligned copy via chunks
        for (o, c) in out.iter_mut().zip(raw.chunks_exact(4)) {
            *o = f32::from_le_bytes(c.try_into().unwrap());
        }
        Ok(out)
    }
}

/// Reinterpret an f32 slice as bytes (LE hosts only; checked at compile time).
pub fn f32_as_bytes(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// Mutable byte view of an f32 slice (LE hosts; the scatter fast path).
pub fn f32_as_bytes_mut(v: &mut [f32]) -> &mut [u8] {
    unsafe { std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, v.len() * 4) }
}

/// Copy bytes into an f32 vec (handles arbitrary alignment).
pub fn bytes_to_f32(b: &[u8]) -> Vec<f32> {
    assert!(b.len() % 4 == 0, "byte length {} not a multiple of 4", b.len());
    let mut out = vec![0f32; b.len() / 4];
    unsafe {
        std::ptr::copy_nonoverlapping(b.as_ptr(), out.as_mut_ptr() as *mut u8, b.len());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = Writer::new();
        w.u8(7);
        w.u16(300);
        w.u32(70_000);
        w.u64(1 << 40);
        w.i32(-5);
        w.f32(1.5);
        w.lp_str("hello");
        w.lp_bytes(&[1, 2, 3]);
        let buf = w.into_vec();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.i32().unwrap(), -5);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.lp_str().unwrap(), "hello");
        assert_eq!(r.lp_bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn eof_reported() {
        let mut r = Reader::new(&[1, 2]);
        assert!(matches!(r.u32(), Err(ByteError::Eof { .. })));
    }

    #[test]
    fn f32_bulk_roundtrip() {
        let xs: Vec<f32> = (0..1000).map(|i| i as f32 * 0.25 - 3.0).collect();
        let mut w = Writer::new();
        w.f32_slice(&xs);
        let buf = w.into_vec();
        let mut r = Reader::new(&buf);
        assert_eq!(r.f32_vec(1000).unwrap(), xs);
        assert_eq!(bytes_to_f32(f32_as_bytes(&xs)), xs);
    }

    #[test]
    fn f32_mut_view_roundtrip() {
        let mut xs = vec![0f32; 4];
        let src = [1.0f32, -2.5, 3.25, 0.0];
        f32_as_bytes_mut(&mut xs).copy_from_slice(f32_as_bytes(&src));
        assert_eq!(xs, src);
    }

    #[test]
    fn truncated_lp_string_fails() {
        let mut w = Writer::new();
        w.u32(100); // claims 100 bytes, provides none
        let buf = w.into_vec();
        let mut r = Reader::new(&buf);
        assert!(r.lp_bytes().is_err());
    }

    #[test]
    fn shared_bytes_slice_is_zero_copy() {
        let sb = SharedBytes::new((0u8..100).collect());
        let a = sb.slice(10..20);
        let b = a.slice(2..5);
        assert_eq!(a, (10u8..20).collect::<Vec<u8>>());
        assert_eq!(b, &[12u8, 13, 14][..]);
        assert_eq!(b.backing_len(), 100);
        // clones share the backing allocation
        let c = sb.clone();
        assert_eq!(c, sb);
        assert_eq!(c.backing_len(), 100);
    }

    #[test]
    fn shared_bytes_into_vec_roundtrips() {
        let v: Vec<u8> = (0u8..50).collect();
        let sb = SharedBytes::new(v.clone());
        assert_eq!(sb.into_vec(), v);
        // a shared view still produces the right bytes (via a copy)
        let sb = SharedBytes::new(v.clone());
        let keep = sb.clone();
        assert_eq!(sb.into_vec(), v);
        assert_eq!(keep, v);
        // and a subview copies just the window
        assert_eq!(keep.slice(10..20).into_vec(), (10u8..20).collect::<Vec<u8>>());
    }

    #[test]
    fn shared_bytes_detach_loose_compacts_big_waste() {
        let big = SharedBytes::new(vec![7u8; 1 << 20]);
        let loose = big.slice(0..100);
        let tight = loose.detach_loose();
        assert_eq!(tight, vec![7u8; 100]);
        assert_eq!(tight.backing_len(), 100, "loose view must re-home");
        // nearly-full views are left alone
        let snug = big.slice(0..(1 << 20) - 16);
        assert_eq!(snug.clone().detach_loose().backing_len(), 1 << 20);
        // a view pinning more than ~1.5x its own size is re-homed — the
        // two-blobs-in-one-read-buffer case must not undercount memory
        let majority = big.slice(0..600_000);
        assert_eq!(majority.detach_loose().backing_len(), 600_000);
    }

    #[test]
    fn shared_bytes_eq_across_types() {
        let sb = SharedBytes::copy_from(b"hello");
        assert_eq!(sb, b"hello");
        assert_eq!(sb, *b"hello");
        assert_eq!(sb, &b"hello"[..]);
        assert_eq!(sb, b"hello".to_vec());
        assert!(sb != SharedBytes::empty());
        assert!(SharedBytes::empty().is_empty());
    }

    #[test]
    #[should_panic]
    fn shared_bytes_slice_out_of_bounds_panics() {
        SharedBytes::new(vec![1, 2, 3]).slice(1..5);
    }

    #[test]
    fn copymeter_counts_explicit_copies() {
        copymeter::reset();
        let sb = SharedBytes::copy_from(&[0u8; 128]);
        let _ = sb.to_vec();
        assert!(copymeter::get() >= 256);
    }
}
