//! Little-endian byte cursors for the state-blob and wire formats.

use thiserror::Error;

#[derive(Debug, Error)]
pub enum ByteError {
    #[error("unexpected end of buffer (need {need} bytes at offset {at}, have {have})")]
    Eof { at: usize, need: usize, have: usize },
    #[error("invalid utf-8 in length-prefixed string")]
    Utf8,
}

/// Append-only little-endian writer.
#[derive(Default, Debug)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Writer { buf: Vec::with_capacity(n) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }
    /// u32-length-prefixed byte string.
    pub fn lp_bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.bytes(v);
    }
    pub fn lp_str(&mut self, v: &str) {
        self.lp_bytes(v.as_bytes());
    }
    pub fn f32_slice(&mut self, v: &[f32]) {
        // bulk copy; f32::to_le_bytes per element would be 4x slower
        let ptr = v.as_ptr() as *const u8;
        let bytes = unsafe { std::slice::from_raw_parts(ptr, v.len() * 4) };
        #[cfg(target_endian = "big")]
        compile_error!("little-endian host required for f32_slice fast path");
        self.buf.extend_from_slice(bytes);
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian reader over a borrowed slice.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ByteError> {
        if self.remaining() < n {
            return Err(ByteError::Eof { at: self.pos, need: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, ByteError> {
        Ok(self.take(1)?[0])
    }
    pub fn u16(&mut self) -> Result<u16, ByteError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    pub fn u32(&mut self) -> Result<u32, ByteError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> Result<u64, ByteError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn i32(&mut self) -> Result<i32, ByteError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn f32(&mut self) -> Result<f32, ByteError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], ByteError> {
        self.take(n)
    }
    pub fn lp_bytes(&mut self) -> Result<&'a [u8], ByteError> {
        let n = self.u32()? as usize;
        self.take(n)
    }
    pub fn lp_str(&mut self) -> Result<&'a str, ByteError> {
        std::str::from_utf8(self.lp_bytes()?).map_err(|_| ByteError::Utf8)
    }
    /// Bulk-read `n` f32s (little-endian).
    pub fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>, ByteError> {
        let raw = self.take(n * 4)?;
        let mut out = vec![0f32; n];
        // safe bulk copy: make an aligned copy via chunks
        for (o, c) in out.iter_mut().zip(raw.chunks_exact(4)) {
            *o = f32::from_le_bytes(c.try_into().unwrap());
        }
        Ok(out)
    }
}

/// Reinterpret an f32 slice as bytes (LE hosts only; checked at compile time).
pub fn f32_as_bytes(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// Copy bytes into an f32 vec (handles arbitrary alignment).
pub fn bytes_to_f32(b: &[u8]) -> Vec<f32> {
    assert!(b.len() % 4 == 0, "byte length {} not a multiple of 4", b.len());
    let mut out = vec![0f32; b.len() / 4];
    unsafe {
        std::ptr::copy_nonoverlapping(b.as_ptr(), out.as_mut_ptr() as *mut u8, b.len());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = Writer::new();
        w.u8(7);
        w.u16(300);
        w.u32(70_000);
        w.u64(1 << 40);
        w.i32(-5);
        w.f32(1.5);
        w.lp_str("hello");
        w.lp_bytes(&[1, 2, 3]);
        let buf = w.into_vec();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.i32().unwrap(), -5);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.lp_str().unwrap(), "hello");
        assert_eq!(r.lp_bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn eof_reported() {
        let mut r = Reader::new(&[1, 2]);
        assert!(matches!(r.u32(), Err(ByteError::Eof { .. })));
    }

    #[test]
    fn f32_bulk_roundtrip() {
        let xs: Vec<f32> = (0..1000).map(|i| i as f32 * 0.25 - 3.0).collect();
        let mut w = Writer::new();
        w.f32_slice(&xs);
        let buf = w.into_vec();
        let mut r = Reader::new(&buf);
        assert_eq!(r.f32_vec(1000).unwrap(), xs);
        assert_eq!(bytes_to_f32(f32_as_bytes(&xs)), xs);
    }

    #[test]
    fn truncated_lp_string_fails() {
        let mut w = Writer::new();
        w.u32(100); // claims 100 bytes, provides none
        let buf = w.into_vec();
        let mut r = Reader::new(&buf);
        assert!(r.lp_bytes().is_err());
    }
}
