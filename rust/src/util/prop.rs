//! Lightweight property-based testing harness (proptest substitute).
//!
//! `run_prop` drives a closure with a seeded RNG for N cases; on failure it
//! re-runs with the failing case's seed to confirm, then reports the seed so
//! the case can be replayed with `check_seed`.  Generators live on [`Gen`].

use super::rng::Rng;

/// Number of cases per property (override with EDGECACHE_PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("EDGECACHE_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}

/// Per-case generator handle.
pub struct Gen {
    pub rng: Rng,
    /// Case index (0..cases); useful for size-ramped generation.
    pub case: u64,
    pub cases: u64,
}

impl Gen {
    /// Size hint that grows with the case index (small cases first, like
    /// proptest's sizing), in `[1, max]`.
    pub fn size(&mut self, max: usize) -> usize {
        let ramp = 1 + (max as u64 * (self.case + 1) / self.cases.max(1)) as usize;
        1 + self.rng.below(ramp.min(max) as u64) as usize
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as u64, hi as u64) as usize
    }

    pub fn bytes(&mut self, len: usize) -> Vec<u8> {
        (0..len).map(|_| self.rng.below(256) as u8).collect()
    }

    pub fn ascii_string(&mut self, len: usize) -> String {
        (0..len)
            .map(|_| (b'a' + self.rng.below(26) as u8) as char)
            .collect()
    }

    pub fn tokens(&mut self, len: usize, vocab: u32) -> Vec<u32> {
        (0..len).map(|_| self.rng.below(vocab as u64) as u32).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }
}

/// Run `f` for `cases` seeded cases; panic with the reproducing seed on the
/// first failure.
pub fn run_prop_n(name: &str, cases: u64, mut f: impl FnMut(&mut Gen)) {
    let base = base_seed(name);
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen { rng: Rng::new(seed), case, cases };
            f(&mut g);
        }));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property {name:?} failed at case {case} (replay: check_seed({name:?}, {seed:#x})):\n{msg}",
            );
        }
    }
}

pub fn run_prop(name: &str, f: impl FnMut(&mut Gen)) {
    run_prop_n(name, default_cases(), f);
}

/// Replay a single failing case reported by `run_prop`.
pub fn check_seed(name: &str, seed: u64, mut f: impl FnMut(&mut Gen)) {
    let mut g = Gen { rng: Rng::new(seed), case: 0, cases: 1 };
    let _ = name;
    f(&mut g);
}

fn base_seed(name: &str) -> u64 {
    // stable per-property seed unless EDGECACHE_PROP_SEED overrides
    if let Ok(v) = std::env::var("EDGECACHE_PROP_SEED") {
        if let Ok(s) = v.parse() {
            return s;
        }
    }
    let mut h = 0xcbf29ce484222325u64; // FNV-1a
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run_prop_n("add-commutes", 64, |g| {
            let a = g.rng.below(1000);
            let b = g.rng.below(1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "replay: check_seed")]
    fn failing_property_reports_seed() {
        run_prop_n("always-fails-eventually", 64, |g| {
            // fails whenever the generated value is >= 100 (most cases)
            assert!(g.rng.below(1000) < 100);
        });
    }

    #[test]
    fn size_ramp_within_bounds() {
        run_prop_n("size-ramps", 64, |g| {
            let s = g.size(40);
            assert!((1..=40).contains(&s));
        });
    }
}
