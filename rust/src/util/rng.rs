//! Deterministic pseudo-random number generation (xoshiro256** seeded via
//! SplitMix64).  Replaces the `rand` crate; every simulator component takes an
//! explicit seed so runs are reproducible bit-for-bit.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** 1.0 — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. one per simulated client).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (no caching; simple and adequate).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_plausible() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
