//! Hex encoding/decoding (for hashes and catalog keys).

pub fn encode(bytes: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(HEX[(b >> 4) as usize] as char);
        s.push(HEX[(b & 0xf) as usize] as char);
    }
    s
}

pub fn decode(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    let mut out = Vec::with_capacity(s.len() / 2);
    let b = s.as_bytes();
    for pair in b.chunks_exact(2) {
        let hi = (pair[0] as char).to_digit(16)?;
        let lo = (pair[1] as char).to_digit(16)?;
        out.push(((hi << 4) | lo) as u8);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let data: Vec<u8> = (0..=255).collect();
        assert_eq!(decode(&encode(&data)).unwrap(), data);
    }

    #[test]
    fn known_vector() {
        assert_eq!(encode(b"\x00\xffab"), "00ff6162");
        assert_eq!(decode("00ff6162").unwrap(), b"\x00\xffab");
    }

    #[test]
    fn rejects_bad_input() {
        assert!(decode("abc").is_none()); // odd length
        assert!(decode("zz").is_none()); // non-hex
    }
}
