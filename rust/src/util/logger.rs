//! Leveled stderr logger with per-run elapsed timestamps.
//!
//! Replaces `log`/`env_logger`: edgecache components log through the
//! `log_*!` macros, level is controlled by `EDGECACHE_LOG` (error, warn,
//! info, debug, trace) or programmatically.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info default

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Initialise from the EDGECACHE_LOG env var (call once from main; safe to
/// skip — defaults to Info).
pub fn init_from_env() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("EDGECACHE_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed();
    eprintln!(
        "[{:>9.3}s {} {}] {}",
        t.as_secs_f64(),
        l.tag(),
        target,
        msg
    );
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Trace, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_gating() {
        let before = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(before);
    }
}
