//! Minimal JSON parser/writer (serde_json substitute for the offline build).
//!
//! Supports the full JSON grammar; numbers are kept as `f64` plus a lossless
//! `i64` fast path (enough for `meta.json`, configs and reports).  The parser
//! is recursive-descent with a depth limit; the writer emits both compact and
//! pretty forms.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integer fast-path (preserves u63-range values exactly).
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap for deterministic key order when re-serialising.
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

const MAX_DEPTH: usize = 128;

// ---------------------------------------------------------------------------
// Accessors
// ---------------------------------------------------------------------------

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key (for configs).
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("missing key {key:?}"),
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) if f.fract() == 0.0 && f.abs() < 9e15 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<i64> for Json {
    fn from(i: i64) -> Self {
        Json::Int(i)
    }
}
impl From<usize> for Json {
    fn from(i: usize) -> Self {
        Json::Int(i as i64)
    }
}
impl From<f64> for Json {
    fn from(f: f64) -> Self {
        Json::Num(f)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected {word}")))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value(depth + 1)?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unexpected low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid utf-8 lead byte")),
                        };
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                        }
                        let chunk = std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                    }
                }
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_compact(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Int(i) => out.push_str(&i.to_string()),
        Json::Num(f) => {
            if f.is_finite() {
                out.push_str(&format!("{f}"));
            } else {
                out.push_str("null"); // JSON has no inf/nan
            }
        }
        Json::Str(s) => escape_into(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_compact(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_compact(x, out);
            }
            out.push('}');
        }
    }
}

fn write_pretty(v: &Json, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    let pad_in = "  ".repeat(indent + 1);
    match v {
        Json::Arr(a) if !a.is_empty() => {
            out.push_str("[\n");
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&pad_in);
                write_pretty(x, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&pad);
            out.push(']');
        }
        Json::Obj(m) if !m.is_empty() => {
            out.push_str("{\n");
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&pad_in);
                escape_into(k, out);
                out.push_str(": ");
                write_pretty(x, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&pad);
            out.push('}');
        }
        other => write_compact(other, out),
    }
}

impl Json {
    pub fn to_compact(&self) -> String {
        let mut s = String::new();
        write_compact(self, &mut s);
        s
    }

    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        write_pretty(self, 0, &mut s);
        s
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_compact())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -42 ").unwrap(), Json::Int(-42));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line1\nline2\t\"q\" \\ \u{1F600} ユニコード";
        let j = Json::Str(s.to_string());
        let text = j.to_compact();
        assert_eq!(parse(&text).unwrap(), j);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
        // surrogate pair for 😀
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "\"", "{\"a\" 1}", "1 2", "\"\\x\""] {
            assert!(parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn compact_pretty_roundtrip() {
        let v = parse(r#"{"a": [1, 2.5, "s"], "b": {"c": true}, "empty": [], "eo": {}}"#).unwrap();
        assert_eq!(parse(&v.to_compact()).unwrap(), v);
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn big_ints_preserved() {
        let v = parse("9007199254740993").unwrap(); // 2^53 + 1, not f64-exact
        assert_eq!(v.as_i64(), Some(9007199254740993));
        assert_eq!(v.to_compact(), "9007199254740993");
    }

    #[test]
    fn deep_nesting_rejected() {
        let s = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&s).is_err());
    }

    #[test]
    fn real_meta_json_parses() {
        // the actual artifact meta written by aot.py, if present
        let p = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny/meta.json"));
        if p.exists() {
            let m = parse_file(p).unwrap();
            assert!(m.get("model_hash").unwrap().as_str().is_some());
            assert!(m.get("entries").unwrap().as_arr().unwrap().len() >= 2);
        }
    }
}
