//! Small self-built substrates that replace crates unavailable in the
//! offline vendor set (serde, clap, log, proptest — see DESIGN.md
//! §Substitutions).

pub mod bytes;
pub mod cli;
pub mod hex;
pub mod json;
pub mod logger;
pub mod prop;
pub mod rng;
