//! Micro-benchmark harness (criterion substitute for the offline build).
//!
//! Methodology mirrors criterion's core loop: warmup phase, then repeated
//! timed iterations until both a minimum iteration count and a minimum
//! measurement time are reached; reports mean / p50 / p95 / min / max and
//! derived throughput.  Bench binaries are `[[bench]] harness = false`
//! targets that call [`Bench::run`] and print [`Report`] tables.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
    /// optional bytes processed per iteration (for MB/s reporting)
    pub bytes_per_iter: Option<u64>,
}

impl Stats {
    pub fn mb_per_s(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| {
            (b as f64 / (1024.0 * 1024.0)) / self.mean.as_secs_f64()
        })
    }

    pub fn line(&self) -> String {
        let tp = match self.mb_per_s() {
            Some(t) => format!("  {:9.1} MB/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} {:>7} it  mean {:>11}  p50 {:>11}  p95 {:>11}{}",
            self.name,
            self.iters,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            tp
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Configuration for one measured benchmark.
pub struct Bench {
    pub name: String,
    pub warmup: Duration,
    pub min_time: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    pub bytes_per_iter: Option<u64>,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Bench {
            name: name.into(),
            warmup: Duration::from_millis(150),
            min_time: Duration::from_millis(500),
            min_iters: 10,
            max_iters: 1_000_000,
            bytes_per_iter: None,
        }
    }

    /// For slow end-to-end cases (seconds per iteration).
    pub fn slow(mut self) -> Self {
        self.warmup = Duration::ZERO;
        self.min_time = Duration::ZERO;
        self.min_iters = 3;
        self.max_iters = 3;
        self
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.min_iters = n;
        self.max_iters = n;
        self.min_time = Duration::ZERO;
        self
    }

    pub fn throughput_bytes(mut self, b: u64) -> Self {
        self.bytes_per_iter = Some(b);
        self
    }

    /// Run the closure repeatedly and gather stats.  The closure's return
    /// value is passed through `std::hint::black_box` to defeat DCE.
    pub fn run<T>(self, mut f: impl FnMut() -> T) -> Stats {
        // warmup
        let wstart = Instant::now();
        while wstart.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // measure
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while (samples.len() < self.min_iters || start.elapsed() < self.min_time)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        stats_from(&self.name, &mut samples, self.bytes_per_iter)
    }
}

fn stats_from(name: &str, samples: &mut [Duration], bytes: Option<u64>) -> Stats {
    samples.sort_unstable();
    let n = samples.len();
    let total: Duration = samples.iter().sum();
    let pct = |p: f64| samples[((n as f64 * p) as usize).min(n - 1)];
    Stats {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        p50: pct(0.50),
        p95: pct(0.95),
        min: samples[0],
        max: samples[n - 1],
        bytes_per_iter: bytes,
    }
}

/// Collects results and prints a section-formatted report; also appends
/// machine-readable lines to a CSV when `EDGECACHE_BENCH_CSV` is set.
#[derive(Default)]
pub struct Report {
    pub title: String,
    pub stats: Vec<Stats>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Self {
        Report { title: title.into(), ..Default::default() }
    }

    pub fn push(&mut self, s: Stats) {
        println!("  {}", s.line());
        self.stats.push(s);
    }

    pub fn note(&mut self, n: impl Into<String>) {
        let n = n.into();
        println!("  # {n}");
        self.notes.push(n);
    }

    pub fn section(&self, name: &str) {
        println!("\n== {} — {} ==", self.title, name);
    }

    pub fn finish(&self) {
        if let Ok(path) = std::env::var("EDGECACHE_BENCH_CSV") {
            let mut out = String::new();
            for s in &self.stats {
                out.push_str(&format!(
                    "{},{},{},{},{},{}\n",
                    self.title,
                    s.name.replace(',', ";"),
                    s.iters,
                    s.mean.as_nanos(),
                    s.p50.as_nanos(),
                    s.p95.as_nanos()
                ));
            }
            use std::io::Write;
            if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
                let _ = f.write_all(out.as_bytes());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering_sane() {
        let s = Bench::new("noop").iters(50).run(|| 1 + 1);
        assert_eq!(s.iters, 50);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
    }

    #[test]
    fn throughput_computed() {
        let buf = vec![0u8; 1 << 20];
        let s = Bench::new("sum-1mb")
            .iters(20)
            .throughput_bytes(buf.len() as u64)
            .run(|| buf.iter().map(|&b| b as u64).sum::<u64>());
        assert!(s.mb_per_s().unwrap() > 1.0);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(5)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with("s"));
    }
}
