//! Deterministic subword tokenizer (the client-side "Token" phase).
//!
//! The paper tokenizes with llama.cpp's Gemma tokenizer (262k SentencePiece
//! vocab, gated download).  We build a self-contained equivalent with the
//! same *interface properties* the experiments rely on:
//!
//! * deterministic: identical text → identical token-id sequence on every
//!   client (prompt-cache keys hash token ids, so this is load-bearing);
//! * prefix-stable: tokenising `a + b` yields the tokens of `a` as a strict
//!   prefix whenever `a` ends at a word boundary — the partial-matching
//!   ranges in §3.2 cut prompts at logical (word) boundaries;
//! * invertible: `decode(encode(s)) == s`;
//! * realistic granularity: common English words are single tokens, rare
//!   words split into subwords/bytes (~1.3 tokens/word on MMLU-style text).
//!
//! Scheme: greedy longest-match over a static vocab of frequent words and
//! suffix fragments, with single-byte fallback.  Ids: `0..SPECIALS` control
//! tokens, then 256 byte tokens, then subwords (shortest-first table order
//! so small budgets keep broadly-useful pieces).  A `vocab_budget` caps ids
//! so small model presets stay in range.

mod vocab;

use std::collections::HashMap;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
const N_SPECIALS: u32 = 3;
const BYTE_BASE: u32 = N_SPECIALS; // byte b -> BYTE_BASE + b
const SUBWORD_BASE: u32 = BYTE_BASE + 256;

/// Greedy longest-match subword tokenizer with byte fallback.
pub struct Tokenizer {
    /// piece string -> token id (subwords only)
    lookup: HashMap<&'static str, u32>,
    /// token id -> piece (subwords only, indexed by id - SUBWORD_BASE)
    pieces: Vec<&'static str>,
    /// longest piece length in bytes (bounds the greedy scan window)
    max_piece_len: usize,
    vocab_size: u32,
}

impl Tokenizer {
    /// Build a tokenizer whose ids all fit in `vocab_budget` (the model's
    /// vocab size).  Budgets below `SUBWORD_BASE + 1` degrade to pure
    /// byte-level encoding; the budget must at least cover the byte range.
    pub fn with_budget(vocab_budget: u32) -> Self {
        assert!(
            vocab_budget >= SUBWORD_BASE,
            "vocab budget {vocab_budget} cannot cover specials + bytes ({SUBWORD_BASE})"
        );
        let room = (vocab_budget - SUBWORD_BASE) as usize;
        // vocab::SUBWORDS is ordered shortest-first so truncation keeps the
        // most broadly-applicable pieces; ids are assigned in this fixed order
        // so every client builds the identical table.
        let mut lookup = HashMap::new();
        let mut pieces = Vec::new();
        let mut max_piece_len = 1;
        for (i, &p) in vocab::SUBWORDS.iter().take(room).enumerate() {
            lookup.insert(p, SUBWORD_BASE + i as u32);
            pieces.push(p);
            max_piece_len = max_piece_len.max(p.len());
        }
        let vocab_size = SUBWORD_BASE + pieces.len() as u32;
        Tokenizer { lookup, pieces, max_piece_len, vocab_size }
    }

    /// Full vocabulary (all embedded subwords).
    pub fn full() -> Self {
        Self::with_budget(SUBWORD_BASE + vocab::SUBWORDS.len() as u32)
    }

    /// Number of distinct ids this tokenizer can emit (= required model vocab).
    pub fn vocab_size(&self) -> u32 {
        self.vocab_size
    }

    /// Encode text to token ids (no BOS/EOS added).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let bytes = text.as_bytes();
        let mut out = Vec::with_capacity(bytes.len() / 3 + 4);
        let mut i = 0;
        while i < bytes.len() {
            // greedy longest match, scanning window sizes descending
            let maxl = self.max_piece_len.min(bytes.len() - i);
            let mut matched = 0usize;
            for l in (2..=maxl).rev() {
                if let Ok(s) = std::str::from_utf8(&bytes[i..i + l]) {
                    if let Some(&id) = self.lookup.get(s) {
                        out.push(id);
                        matched = l;
                        break;
                    }
                }
            }
            if matched == 0 {
                out.push(BYTE_BASE + bytes[i] as u32);
                matched = 1;
            }
            i += matched;
        }
        out
    }

    /// Encode with BOS prefix (what the engine feeds the model).
    pub fn encode_with_bos(&self, text: &str) -> Vec<u32> {
        let mut v = vec![BOS];
        v.extend(self.encode(text));
        v
    }

    /// Decode token ids back to text.  Unknown/special ids render as
    /// replacement markers rather than failing (decode is diagnostic-only on
    /// the serving path).
    pub fn decode(&self, tokens: &[u32]) -> String {
        let mut bytes: Vec<u8> = Vec::with_capacity(tokens.len() * 3);
        for &t in tokens {
            if t < N_SPECIALS {
                // specials render as nothing
            } else if t < SUBWORD_BASE {
                bytes.push((t - BYTE_BASE) as u8);
            } else if let Some(p) = self.pieces.get((t - SUBWORD_BASE) as usize) {
                bytes.extend_from_slice(p.as_bytes());
            } else {
                bytes.extend_from_slice("\u{FFFD}".as_bytes());
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Tokens per word on a reference text (diagnostic for DESIGN.md).
    pub fn granularity(&self, text: &str) -> f64 {
        let words = text.split_whitespace().count().max(1);
        self.encode(text).len() as f64 / words as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop_n;

    fn tk() -> Tokenizer {
        Tokenizer::full()
    }

    #[test]
    fn roundtrip_simple() {
        let t = tk();
        for s in [
            "the answer is (B)",
            "The following are multiple choice questions about astronomy.",
            "Q: What is 2+2?\nA. 3\nB. 4\nC. 5\nD. 6\nAnswer: B",
            "",
            "unusualxyzzywords splitting into bytes ÿ ü 日本語",
        ] {
            assert_eq!(t.decode(&t.encode(s)), s, "roundtrip failed for {s:?}");
        }
    }

    #[test]
    fn roundtrip_property() {
        let t = tk();
        run_prop_n("tokenizer-roundtrip", 128, |g| {
            let n = g.size(120);
            let s = g.ascii_string(n);
            assert_eq!(t.decode(&t.encode(&s)), s);
        });
    }

    #[test]
    fn roundtrip_arbitrary_bytes_via_lossy() {
        // non-UTF8 can't be input (encode takes &str), but any UTF-8 string
        // must survive, including multi-byte chars
        let t = tk();
        let s = "καλημέρα 😀 Grüße";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn deterministic_across_instances() {
        let a = Tokenizer::full();
        let b = Tokenizer::full();
        let s = "Astronomy questions about stellar parallax and redshift.";
        assert_eq!(a.encode(s), b.encode(s));
    }

    #[test]
    fn prefix_stability_at_word_boundaries() {
        let t = tk();
        let a = "The following are multiple choice questions. ";
        let b = "What is the photon?";
        let ta = t.encode(a);
        let tab = t.encode(&format!("{a}{b}"));
        assert!(
            tab.starts_with(&ta),
            "prefix tokens must be stable: {ta:?} vs {tab:?}"
        );
    }

    #[test]
    fn common_words_are_single_tokens() {
        let t = tk();
        // one leading space variant is the common in-sentence form
        for w in [" the", " and", " question", " answer", " about"] {
            let ids = t.encode(w);
            assert_eq!(ids.len(), 1, "{w:?} tokenised as {ids:?}");
        }
    }

    #[test]
    fn granularity_realistic() {
        let t = tk();
        let text = "The following are multiple choice questions with answers about \
                    high school physics. A ball is thrown upward with initial velocity \
                    twenty meters per second. What is the maximum height it reaches? \
                    The answer depends on gravitational acceleration near the surface.";
        let g = t.granularity(text);
        // SentencePiece Gemma is ~1.3 tok/word; our static vocab lands ~2.2.
        // Token *counts* only scale all experiments uniformly (documented in
        // DESIGN.md §Substitutions) — the bound here just guards regressions.
        assert!(g < 2.5, "granularity {g:.2} tokens/word too coarse");
        assert!(g >= 1.0, "granularity {g:.2} impossible");
    }

    #[test]
    fn budget_caps_ids() {
        for budget in [SUBWORD_BASE, SUBWORD_BASE + 10, 512, 4096] {
            let t = Tokenizer::with_budget(budget);
            let ids = t.encode("the quick brown fox jumps over the lazy dog");
            assert!(ids.iter().all(|&i| i < budget), "budget {budget} violated");
            assert_eq!(
                t.decode(&ids),
                "the quick brown fox jumps over the lazy dog"
            );
        }
    }

    #[test]
    #[should_panic(expected = "cannot cover")]
    fn budget_below_bytes_panics() {
        Tokenizer::with_budget(100);
    }

    #[test]
    fn specials_roundtrip_silently() {
        let t = tk();
        assert_eq!(t.decode(&[BOS, EOS, PAD]), "");
        let mut ids = vec![BOS];
        ids.extend(t.encode("hi"));
        assert_eq!(t.decode(&ids), "hi");
    }

    #[test]
    fn encode_speed_budget() {
        // paper Table 3: Token = 3.46 ms for a 65-token prompt on a Pi Zero.
        // On the host this must be microseconds — assert a generous bound.
        let t = tk();
        let text = "The following are multiple choice questions (with answers) about \
                    astronomy. What is true for a type-Ia supernova? Answer: A"
            .repeat(4);
        let t0 = std::time::Instant::now();
        for _ in 0..100 {
            std::hint::black_box(t.encode(&text));
        }
        let per = t0.elapsed() / 100;
        assert!(per.as_millis() < 10, "encode took {per:?} per call");
    }
}
