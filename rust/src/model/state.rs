//! KV-cache state blobs — the unit the distributed prompt cache moves.
//!
//! [`KvState`] is the live form: dense `[L, S, Kh, D]` K/V tensors plus the
//! number of valid tokens.  [`KvState::serialize`] produces the blob the
//! paper uploads with `llama_state_get_data()`.  Format v2 (`"ECS2"`) is
//! **token-major and row-indexed** so that any token prefix of a blob is a
//! contiguous byte range a cache box can serve with `GETRANGE`:
//!
//! ```text
//!   magic "ECS2"
//!   header: lp model hash | L S Kh D n_tokens (u32 each) | flags (u8)
//!           | crc32 over (row index ++ body)
//!   row index: n_tokens × u32 — crc32 of each token's row chunk
//!   body (lp): token 0 [K rows layer 0..L | V rows layer 0..L]
//!              token 1 [..] ... token n-1 [..]      (deflated if flag set)
//! ```
//!
//! Every token occupies one fixed-size chunk of `2·L·Kh·D·4` bytes
//! ([`BlobLayout::token_stride`]), so the first `m` tokens of an `n`-token
//! blob are exactly bytes `[payload_off(n), payload_off(n) + m·stride)` —
//! the property the coordinator's range-aware downloads and suffix-delta
//! uploads (`SPLICE`) rely on.  The per-token crc32 row index lets a client
//! verify a partially fetched prefix without the whole-blob checksum.
//! Offsets are computed client-side from [`BlobLayout`]; the cache box
//! stays byte-oriented.
//!
//! Only the first `n_tokens` sequence rows are shipped, so blob size scales
//! linearly with the cached prompt length — the paper's 2.25 MB (65-token,
//! 270M) and 9.94 MB (334-token, 1B) entries are exactly this scaling.
//! An optional deflate pass (CacheGen-style, §2 related work) is behind
//! [`Compression::Deflate`]; compressed bodies cannot be range-served (see
//! ROADMAP open items).  Restore verifies magic, model hash, dims and
//! checksum before touching the live cache: a corrupt or mismatched blob is
//! rejected, the client falls back to local prefill (paper §3.3 — wrong
//! bytes must never poison an inference).
//!
//! A second tiny record type, the **range alias** (`"ECSA"`, see
//! [`encode_range_alias`]), lets one stored blob serve all four catalog
//! ranges: shorter prefix keys map to an alias naming the long entry and
//! its row count, and the client fetches just the rows it needs.

use crc32fast::Hasher as Crc32;
use thiserror::Error;

use crate::util::bytes::{copymeter, f32_as_bytes, f32_as_bytes_mut, Reader, SharedBytes};

const MAGIC: &[u8; 4] = b"ECS2";

/// Magic for range-alias records stored under short-prefix keys.
pub const ALIAS_MAGIC: &[u8; 4] = b"ECSA";

#[derive(Debug, Error, PartialEq)]
pub enum StateError {
    #[error("bad magic (not a state blob)")]
    BadMagic,
    #[error("model mismatch: blob for {blob}, engine runs {engine}")]
    ModelMismatch { blob: String, engine: String },
    #[error("dimension mismatch: {0}")]
    DimMismatch(String),
    #[error("checksum mismatch (corrupt blob)")]
    BadChecksum,
    #[error("blob truncated or malformed: {0}")]
    Malformed(String),
    #[error("n_tokens {n} exceeds cache capacity {cap}")]
    TooLong { n: usize, cap: usize },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Compression {
    None,
    /// DEFLATE (flate2) — trades CPU for Wi-Fi bytes, the CacheGen direction.
    Deflate,
}

/// Parsed blob header (exposed for diagnostics and tests).
#[derive(Debug, Clone, PartialEq)]
pub struct StateHeader {
    pub model_hash: String,
    pub n_layers: usize,
    pub max_seq: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub n_tokens: usize,
    pub compressed: bool,
}

/// Byte-offset arithmetic for the v2 blob layout.  Everything is derivable
/// from the model identity, so clients compute `GETRANGE`/`SPLICE` offsets
/// without asking the server anything about the format.
#[derive(Debug, Clone)]
pub struct BlobLayout {
    pub hash_len: usize,
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

impl BlobLayout {
    pub fn new(model_hash: &str, n_layers: usize, n_kv_heads: usize, head_dim: usize) -> Self {
        BlobLayout { hash_len: model_hash.len(), n_layers, n_kv_heads, head_dim }
    }

    /// Bytes per token chunk: K and V rows across all layers.
    pub fn token_stride(&self) -> usize {
        2 * self.n_layers * self.n_kv_heads * self.head_dim * 4
    }

    /// Offset of the per-token crc32 row index (end of the fixed header).
    pub fn index_off(&self) -> usize {
        4 + 4 + self.hash_len + 5 * 4 + 1 + 4
    }

    /// Offset of the first payload byte in a blob holding `total_rows`
    /// tokens (the row index and the body length prefix sit in between).
    pub fn payload_off(&self, total_rows: usize) -> usize {
        self.index_off() + 4 * total_rows + 4
    }

    /// Total uncompressed blob size for `rows` tokens.
    pub fn blob_len(&self, rows: usize) -> usize {
        self.payload_off(rows) + rows * self.token_stride()
    }
}

/// Encode a range alias: "the state for this prefix key lives as the first
/// `prefix_rows ≤ total_rows` rows of the entry stored at `target_store_key`".
/// Carries its own crc32 so tampering degrades to a cache miss, never a
/// wrong restore.
pub fn encode_range_alias(target_store_key: &[u8], total_rows: usize, compressed: bool) -> Vec<u8> {
    let mut buf = Vec::with_capacity(4 + 4 + target_store_key.len() + 4 + 1 + 4);
    buf.extend_from_slice(ALIAS_MAGIC);
    buf.extend_from_slice(&(target_store_key.len() as u32).to_le_bytes());
    buf.extend_from_slice(target_store_key);
    buf.extend_from_slice(&(total_rows as u32).to_le_bytes());
    buf.push(compressed as u8);
    let mut crc = Crc32::new();
    crc.update(&buf[4..]);
    buf.extend_from_slice(&crc.finalize().to_le_bytes());
    buf
}

/// Decode a range alias; `None` when `blob` is not a (well-formed) alias.
pub fn decode_range_alias(blob: &[u8]) -> Option<(Vec<u8>, usize, bool)> {
    if blob.len() < 4 || &blob[..4] != ALIAS_MAGIC {
        return None;
    }
    let mut r = Reader::new(&blob[4..]);
    let key = r.lp_bytes().ok()?.to_vec();
    let rows = r.u32().ok()? as usize;
    let compressed = r.u8().ok()? != 0;
    let stored = r.u32().ok()?;
    if r.remaining() != 0 {
        return None;
    }
    let mut crc = Crc32::new();
    crc.update(&blob[4..blob.len() - 4]);
    if crc.finalize() != stored {
        return None;
    }
    Some((key, rows, compressed))
}

/// Live KV cache: what the engine threads through every PJRT call.
#[derive(Debug, Clone, PartialEq)]
pub struct KvState {
    /// dims: [n_layers, max_seq, n_kv_heads, head_dim]
    pub n_layers: usize,
    pub max_seq: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// Valid prefix length (tokens already prefilled/decoded).
    pub n_tokens: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl KvState {
    pub fn zeroed(n_layers: usize, max_seq: usize, n_kv_heads: usize, head_dim: usize) -> Self {
        let n = n_layers * max_seq * n_kv_heads * head_dim;
        KvState {
            n_layers,
            max_seq,
            n_kv_heads,
            head_dim,
            n_tokens: 0,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    pub fn for_config(cfg: &crate::runtime::ModelConfig) -> Self {
        Self::zeroed(cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
    }

    /// Elements per sequence row within one layer (Kh * D).
    fn row_elems(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Elements per layer (S * Kh * D).
    fn layer_elems(&self) -> usize {
        self.max_seq * self.row_elems()
    }

    /// Serialized payload bytes for `n` cached tokens (uncompressed).
    pub fn payload_bytes(&self, n_tokens: usize) -> usize {
        2 * self.n_layers * n_tokens * self.row_elems() * 4
    }

    fn layout_for(&self, model_hash: &str) -> BlobLayout {
        BlobLayout::new(model_hash, self.n_layers, self.n_kv_heads, self.head_dim)
    }

    /// Gather the first `m` token chunks (token-major) into `dst`,
    /// returning each chunk's crc32.
    fn gather_rows_into(&self, m: usize, dst: &mut Vec<u8>) -> Vec<u32> {
        let row = self.row_elems();
        let le = self.layer_elems();
        let mut crcs = Vec::with_capacity(m);
        for t in 0..m {
            let cs = dst.len();
            for l in 0..self.n_layers {
                let o = l * le + t * row;
                dst.extend_from_slice(f32_as_bytes(&self.k[o..o + row]));
            }
            for l in 0..self.n_layers {
                let o = l * le + t * row;
                dst.extend_from_slice(f32_as_bytes(&self.v[o..o + row]));
            }
            let mut c = Crc32::new();
            c.update(&dst[cs..]);
            crcs.push(c.finalize());
        }
        crcs
    }

    /// Scatter `m` token chunks of payload back into the `[L, S, Kh, D]`
    /// live tensors (inverse of [`KvState::gather_rows_into`]).
    fn scatter_rows(&mut self, payload: &[u8], m: usize) {
        let row = self.row_elems();
        let le = self.layer_elems();
        let rb = row * 4;
        let mut src = 0usize;
        for t in 0..m {
            for l in 0..self.n_layers {
                let o = l * le + t * row;
                f32_as_bytes_mut(&mut self.k[o..o + row])
                    .copy_from_slice(&payload[src..src + rb]);
                src += rb;
            }
            for l in 0..self.n_layers {
                let o = l * le + t * row;
                f32_as_bytes_mut(&mut self.v[o..o + row])
                    .copy_from_slice(&payload[src..src + rb]);
                src += rb;
            }
        }
        copymeter::add(src);
    }

    /// Single-pass blob writer: the header, row index and payload land in
    /// one allocation (the uncompressed path writes every payload byte
    /// exactly once — there is no intermediate payload buffer to copy out
    /// of, which is half of the zero-copy pipeline's budget).
    fn write_blob(&self, m: usize, model_hash: &str, compression: Compression) -> Vec<u8> {
        assert!(m <= self.n_tokens, "prefix {m} > valid {}", self.n_tokens);
        let flags: u8 = match compression {
            Compression::None => 0,
            Compression::Deflate => 1,
        };
        let lo = self.layout_for(model_hash);
        let mut buf: Vec<u8> = Vec::with_capacity(lo.blob_len(m));
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(model_hash.len() as u32).to_le_bytes());
        buf.extend_from_slice(model_hash.as_bytes());
        for v in [self.n_layers, self.max_seq, self.n_kv_heads, self.head_dim, m] {
            buf.extend_from_slice(&(v as u32).to_le_bytes());
        }
        buf.push(flags);
        let crc_pos = buf.len();
        buf.extend_from_slice(&[0u8; 4]);
        let idx_pos = buf.len();
        buf.resize(idx_pos + 4 * m, 0);
        let lp_pos = buf.len();
        buf.extend_from_slice(&[0u8; 4]);
        let pay_pos = buf.len();

        let crcs = match compression {
            Compression::None => {
                let crcs = self.gather_rows_into(m, &mut buf);
                copymeter::add(buf.len() - pay_pos);
                crcs
            }
            Compression::Deflate => {
                use flate2::write::DeflateEncoder;
                use flate2::Compression as Level;
                use std::io::Write as _;
                let mut payload = Vec::with_capacity(self.payload_bytes(m));
                let crcs = self.gather_rows_into(m, &mut payload);
                copymeter::add(payload.len());
                let mut enc = DeflateEncoder::new(buf, Level::fast());
                enc.write_all(&payload).expect("in-memory deflate");
                buf = enc.finish().expect("in-memory deflate");
                crcs
            }
        };
        for (t, c) in crcs.iter().enumerate() {
            buf[idx_pos + 4 * t..idx_pos + 4 * t + 4].copy_from_slice(&c.to_le_bytes());
        }
        let body_len = buf.len() - pay_pos;
        buf[lp_pos..lp_pos + 4].copy_from_slice(&(body_len as u32).to_le_bytes());
        let mut crc = Crc32::new();
        crc.update(&buf[idx_pos..idx_pos + 4 * m]);
        crc.update(&buf[pay_pos..]);
        let crc = crc.finalize();
        buf[crc_pos..crc_pos + 4].copy_from_slice(&crc.to_le_bytes());
        buf
    }

    /// Snapshot only the first `m` tokens of this state (m ≤ n_tokens).
    /// Causality makes any prefix of a valid state itself a valid state —
    /// this is what lets one prefill serve all four catalog ranges (§3.2).
    pub fn serialize_prefix(
        &self,
        m: usize,
        model_hash: &str,
        compression: Compression,
    ) -> Vec<u8> {
        self.write_blob(m, model_hash, compression)
    }

    /// `llama_state_get_data()` analog: snapshot the valid prefix.
    pub fn serialize(&self, model_hash: &str, compression: Compression) -> Vec<u8> {
        self.write_blob(self.n_tokens, model_hash, compression)
    }

    /// Like [`KvState::serialize`] but handing back a [`SharedBytes`] so the
    /// blob can be sliced (header / row ranges) and queued on the wire
    /// without further copies.
    pub fn serialize_shared(&self, model_hash: &str, compression: Compression) -> SharedBytes {
        SharedBytes::new(self.write_blob(self.n_tokens, model_hash, compression))
    }

    /// [`KvState::serialize_prefix`] into a [`SharedBytes`].
    pub fn serialize_prefix_shared(
        &self,
        m: usize,
        model_hash: &str,
        compression: Compression,
    ) -> SharedBytes {
        SharedBytes::new(self.write_blob(m, model_hash, compression))
    }

    /// Parse and verify a blob header without restoring (cheap peek).  Works
    /// on any prefix of the blob that covers the fixed header, so the
    /// range-download path can validate a `GETRANGE` head slice.
    pub fn peek_header(blob: &[u8]) -> Result<StateHeader, StateError> {
        let mut r = Reader::new(blob);
        let magic = r.bytes(4).map_err(|e| StateError::Malformed(e.to_string()))?;
        if magic != MAGIC {
            return Err(StateError::BadMagic);
        }
        let model_hash = r
            .lp_str()
            .map_err(|e| StateError::Malformed(e.to_string()))?
            .to_string();
        let mut u = || -> Result<usize, StateError> {
            Ok(r.u32().map_err(|e| StateError::Malformed(e.to_string()))? as usize)
        };
        let n_layers = u()?;
        let max_seq = u()?;
        let n_kv_heads = u()?;
        let head_dim = u()?;
        let n_tokens = u()?;
        let flags = r.u8().map_err(|e| StateError::Malformed(e.to_string()))?;
        Ok(StateHeader {
            model_hash,
            n_layers,
            max_seq,
            n_kv_heads,
            head_dim,
            n_tokens,
            compressed: flags & 1 != 0,
        })
    }

    fn check_identity(
        hdr: &StateHeader,
        expect_model_hash: &str,
        expect_dims: (usize, usize, usize, usize),
    ) -> Result<(), StateError> {
        if hdr.model_hash != expect_model_hash {
            return Err(StateError::ModelMismatch {
                blob: hdr.model_hash.clone(),
                engine: expect_model_hash.to_string(),
            });
        }
        let (l, s, kh, d) = expect_dims;
        if (hdr.n_layers, hdr.max_seq, hdr.n_kv_heads, hdr.head_dim) != (l, s, kh, d) {
            return Err(StateError::DimMismatch(format!(
                "blob [{},{},{},{}] vs engine [{l},{s},{kh},{d}]",
                hdr.n_layers, hdr.max_seq, hdr.n_kv_heads, hdr.head_dim
            )));
        }
        if hdr.n_tokens > s {
            return Err(StateError::TooLong { n: hdr.n_tokens, cap: s });
        }
        Ok(())
    }

    /// `llama_state_set_data()` analog: verify + restore into a fresh state.
    pub fn restore(
        blob: &[u8],
        expect_model_hash: &str,
        expect_dims: (usize, usize, usize, usize),
    ) -> Result<KvState, StateError> {
        let hdr = Self::peek_header(blob)?;
        Self::check_identity(&hdr, expect_model_hash, expect_dims)?;
        let (l, s, kh, d) = expect_dims;

        // re-walk the header to find index and body
        let mut r = Reader::new(blob);
        r.bytes(4).unwrap();
        r.lp_bytes().unwrap();
        for _ in 0..5 {
            r.u32().unwrap();
        }
        r.u8().unwrap();
        let crc_stored = r.u32().map_err(|e| StateError::Malformed(e.to_string()))?;
        let index = r
            .bytes(4 * hdr.n_tokens)
            .map_err(|e| StateError::Malformed(e.to_string()))?;
        let body = r
            .lp_bytes()
            .map_err(|e| StateError::Malformed(e.to_string()))?;
        if r.remaining() != 0 {
            return Err(StateError::Malformed("trailing bytes".into()));
        }
        let mut crc = Crc32::new();
        crc.update(index);
        crc.update(body);
        if crc.finalize() != crc_stored {
            return Err(StateError::BadChecksum);
        }

        let inflated;
        let payload: &[u8] = if hdr.compressed {
            use flate2::read::DeflateDecoder;
            use std::io::Read as _;
            let mut out = Vec::new();
            DeflateDecoder::new(body)
                .read_to_end(&mut out)
                .map_err(|e| StateError::Malformed(format!("deflate: {e}")))?;
            inflated = out;
            &inflated
        } else {
            body
        };

        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = hdr.n_tokens;
        let expect_len = st.payload_bytes(hdr.n_tokens);
        if payload.len() != expect_len {
            return Err(StateError::Malformed(format!(
                "payload {} bytes, expected {expect_len}",
                payload.len()
            )));
        }
        st.scatter_rows(payload, hdr.n_tokens);
        Ok(st)
    }

    /// Restore the first `m` tokens from a *partially fetched* blob:
    /// `head` is a byte prefix of the stored blob covering the fixed header
    /// plus at least `m` row-index entries; `rows` is the payload slice for
    /// token chunks `[0, m)` (`GETRANGE`-fetched).  Each chunk is verified
    /// against its indexed crc32, so a truncated, stale or corrupted range
    /// degrades to an error — never a poisoned cache.
    pub fn restore_prefix_from_parts(
        head: &[u8],
        rows: &[u8],
        m: usize,
        expect_model_hash: &str,
        expect_dims: (usize, usize, usize, usize),
    ) -> Result<KvState, StateError> {
        let hdr = Self::peek_header(head)?;
        Self::check_identity(&hdr, expect_model_hash, expect_dims)?;
        if hdr.compressed {
            return Err(StateError::Malformed(
                "compressed blob cannot be range-restored".into(),
            ));
        }
        if hdr.n_tokens < m {
            return Err(StateError::Malformed(format!(
                "entry holds {} rows, need {m}",
                hdr.n_tokens
            )));
        }
        let (l, s, kh, d) = expect_dims;
        if m > s {
            return Err(StateError::TooLong { n: m, cap: s });
        }
        let lo = BlobLayout::new(expect_model_hash, l, kh, d);
        let idx_off = lo.index_off();
        if head.len() < idx_off + 4 * m {
            return Err(StateError::Malformed("row index truncated".into()));
        }
        let stride = lo.token_stride();
        if rows.len() != m * stride {
            return Err(StateError::Malformed(format!(
                "row payload {} bytes, expected {}",
                rows.len(),
                m * stride
            )));
        }
        for t in 0..m {
            let want = u32::from_le_bytes(
                head[idx_off + 4 * t..idx_off + 4 * t + 4].try_into().unwrap(),
            );
            let mut c = Crc32::new();
            c.update(&rows[t * stride..(t + 1) * stride]);
            if c.finalize() != want {
                return Err(StateError::BadChecksum);
            }
        }
        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = m;
        st.scatter_rows(rows, m);
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop_n;
    use crate::util::rng::Rng;

    fn filled(l: usize, s: usize, kh: usize, d: usize, n_tokens: usize, seed: u64) -> KvState {
        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = n_tokens;
        let mut rng = Rng::new(seed);
        let row = st.row_elems();
        let le = st.layer_elems();
        for li in 0..l {
            for e in 0..n_tokens * row {
                st.k[li * le + e] = rng.f64() as f32;
                st.v[li * le + e] = rng.f64() as f32 - 0.5;
            }
        }
        st
    }

    #[test]
    fn roundtrip_uncompressed() {
        let st = filled(2, 16, 2, 8, 5, 1);
        let blob = st.serialize("hashA", Compression::None);
        let back = KvState::restore(&blob, "hashA", (2, 16, 2, 8)).unwrap();
        assert_eq!(back.n_tokens, 5);
        assert_eq!(back.k, st.k);
        assert_eq!(back.v, st.v);
    }

    #[test]
    fn roundtrip_deflate() {
        let st = filled(3, 32, 1, 16, 20, 2);
        let blob = st.serialize("h", Compression::Deflate);
        let back = KvState::restore(&blob, "h", (3, 32, 1, 16)).unwrap();
        assert_eq!(back.k, st.k);
        assert_eq!(back.v, st.v);
        let hdr = KvState::peek_header(&blob).unwrap();
        assert!(hdr.compressed);
    }

    #[test]
    fn size_scales_with_tokens_like_paper() {
        // paper: 2.25 MB at 65 tokens (270M) — size must be linear in tokens
        let st20 = filled(2, 64, 2, 8, 20, 3);
        let st40 = filled(2, 64, 2, 8, 40, 3);
        let b20 = st20.serialize("h", Compression::None).len();
        let b40 = st40.serialize("h", Compression::None).len();
        let overhead = 64;
        assert!(b40 - overhead > (b20 - overhead) * 19 / 10, "{b20} -> {b40}");
        assert_eq!(st20.payload_bytes(20), 2 * 2 * 20 * 2 * 8 * 4);
    }

    #[test]
    fn blob_layout_matches_serialized_bytes() {
        let st = filled(2, 16, 2, 8, 7, 9);
        let blob = st.serialize("hash!", Compression::None);
        let lo = BlobLayout::new("hash!", 2, 2, 8);
        assert_eq!(blob.len(), lo.blob_len(7));
        assert_eq!(lo.token_stride(), 2 * 2 * 2 * 8 * 4);
        // the token-major property: the payload of a shorter prefix blob is
        // a byte-prefix of the longer blob's payload
        let blob3 = st.serialize_prefix(3, "hash!", Compression::None);
        assert_eq!(
            &blob3[lo.payload_off(3)..],
            &blob[lo.payload_off(7)..lo.payload_off(7) + 3 * lo.token_stride()]
        );
    }

    #[test]
    fn restore_prefix_from_parts_matches_truncated_blob() {
        let st = filled(3, 16, 1, 8, 10, 11);
        let blob = st.serialize("h", Compression::None);
        let lo = BlobLayout::new("h", 3, 1, 8);
        for m in [1usize, 4, 10] {
            let head = &blob[..lo.index_off() + 4 * m];
            let rows =
                &blob[lo.payload_off(10)..lo.payload_off(10) + m * lo.token_stride()];
            let part =
                KvState::restore_prefix_from_parts(head, rows, m, "h", (3, 16, 1, 8)).unwrap();
            let trunc = KvState::restore(
                &st.serialize_prefix(m, "h", Compression::None),
                "h",
                (3, 16, 1, 8),
            )
            .unwrap();
            assert_eq!(part, trunc, "m={m}");
        }
    }

    #[test]
    fn restore_prefix_rejects_corrupt_rows() {
        let st = filled(2, 8, 1, 4, 6, 13);
        let blob = st.serialize("h", Compression::None);
        let lo = BlobLayout::new("h", 2, 1, 4);
        let m = 4;
        let head = &blob[..lo.index_off() + 4 * m];
        let mut rows =
            blob[lo.payload_off(6)..lo.payload_off(6) + m * lo.token_stride()].to_vec();
        rows[7] ^= 0x10;
        assert_eq!(
            KvState::restore_prefix_from_parts(head, &rows, m, "h", (2, 8, 1, 4)).unwrap_err(),
            StateError::BadChecksum
        );
        // wrong payload length is malformed, not a panic
        assert!(matches!(
            KvState::restore_prefix_from_parts(head, &rows[..8], m, "h", (2, 8, 1, 4))
                .unwrap_err(),
            StateError::Malformed(_)
        ));
    }

    #[test]
    fn range_alias_roundtrip_and_tamper() {
        let enc = encode_range_alias(b"state:deadbeef", 42, false);
        assert_eq!(
            decode_range_alias(&enc),
            Some((b"state:deadbeef".to_vec(), 42, false))
        );
        let enc_c = encode_range_alias(b"k", 7, true);
        assert_eq!(decode_range_alias(&enc_c), Some((b"k".to_vec(), 7, true)));
        // any flipped byte kills the alias instead of redirecting it
        for i in 0..enc.len() {
            let mut bad = enc.clone();
            bad[i] ^= 0x01;
            assert_eq!(decode_range_alias(&bad), None, "flip at {i}");
        }
        // a state blob is not an alias
        let st = filled(1, 8, 1, 4, 2, 5);
        assert_eq!(
            decode_range_alias(&st.serialize("h", Compression::None)),
            None
        );
    }

    #[test]
    fn model_hash_mismatch_rejected() {
        let st = filled(2, 16, 2, 8, 3, 4);
        let blob = st.serialize("modelA", Compression::None);
        let err = KvState::restore(&blob, "modelB", (2, 16, 2, 8)).unwrap_err();
        assert!(matches!(err, StateError::ModelMismatch { .. }));
    }

    #[test]
    fn dim_mismatch_rejected() {
        let st = filled(2, 16, 2, 8, 3, 5);
        let blob = st.serialize("h", Compression::None);
        assert!(matches!(
            KvState::restore(&blob, "h", (2, 32, 2, 8)).unwrap_err(),
            StateError::DimMismatch(_)
        ));
    }

    #[test]
    fn corruption_detected() {
        let st = filled(2, 16, 2, 8, 4, 6);
        let mut blob = st.serialize("h", Compression::None);
        // flip a payload byte (past the header + row index)
        let idx = blob.len() - 10;
        blob[idx] ^= 0x40;
        assert_eq!(
            KvState::restore(&blob, "h", (2, 16, 2, 8)).unwrap_err(),
            StateError::BadChecksum
        );
    }

    #[test]
    fn truncation_detected() {
        let st = filled(2, 16, 2, 8, 4, 7);
        let blob = st.serialize("h", Compression::None);
        for cut in [0, 3, 10, blob.len() - 1] {
            let err = KvState::restore(&blob[..cut], "h", (2, 16, 2, 8));
            assert!(err.is_err(), "cut at {cut} must fail");
        }
    }

    #[test]
    fn garbage_rejected() {
        assert_eq!(
            KvState::restore(b"not a blob at all", "h", (1, 1, 1, 1)).unwrap_err(),
            StateError::BadMagic
        );
    }

    #[test]
    fn n_tokens_beyond_capacity_rejected() {
        // hand-craft: serialize with a small cache, restore claiming bigger n
        let st = filled(1, 8, 1, 4, 8, 8);
        let blob = st.serialize("h", Compression::None);
        // restore into the same dims works
        assert!(KvState::restore(&blob, "h", (1, 8, 1, 4)).is_ok());
    }

    #[test]
    fn property_roundtrip_arbitrary_dims() {
        run_prop_n("state-roundtrip", 32, |g| {
            let l = g.usize_in(1, 4);
            let s = g.usize_in(4, 32);
            let kh = g.usize_in(1, 3);
            let d = [4, 8, 16][g.usize_in(0, 2)];
            let n = g.usize_in(0, s);
            let st = filled(l, s, kh, d, n, g.rng.next_u64());
            let comp = if g.bool() { Compression::Deflate } else { Compression::None };
            let blob = st.serialize("ph", comp);
            let back = KvState::restore(&blob, "ph", (l, s, kh, d)).unwrap();
            assert_eq!(back, st);
        });
    }

    #[test]
    fn deflate_smaller_on_structured_state() {
        // zero-padded rows compress well; random rows don't — use a state
        // with many identical rows to show the codec actually deflates
        let mut st = KvState::zeroed(4, 64, 2, 16);
        st.n_tokens = 64;
        for x in st.k.iter_mut() {
            *x = 1.0;
        }
        let plain = st.serialize("h", Compression::None).len();
        let packed = st.serialize("h", Compression::Deflate).len();
        assert!(packed < plain / 4, "{packed} vs {plain}");
    }

    #[test]
    fn serialize_shared_slices_without_copy() {
        let st = filled(2, 16, 1, 8, 6, 21);
        let shared = st.serialize_shared("h", Compression::None);
        let lo = BlobLayout::new("h", 2, 1, 8);
        let head = shared.slice(0..lo.payload_off(6));
        let rows = shared.slice(lo.payload_off(6)..shared.len());
        assert_eq!(head.backing_len(), shared.len(), "same backing allocation");
        assert_eq!(rows.len(), 6 * lo.token_stride());
        let part = KvState::restore_prefix_from_parts(
            &head,
            &rows,
            6,
            "h",
            (2, 16, 1, 8),
        )
        .unwrap();
        assert_eq!(part.n_tokens, 6);
        assert_eq!(part.k, st.k);
    }
}
