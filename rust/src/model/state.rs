//! KV-cache state blobs — the unit the distributed prompt cache moves.
//!
//! [`KvState`] is the live form: dense `[L, S, Kh, D]` K/V tensors plus the
//! number of valid tokens.  [`KvState::serialize`] produces the blob the
//! paper uploads with `llama_state_get_data()`:
//!
//! ```text
//!   magic "ECS1" | header (model hash, dims, n_tokens, flags) |
//!   K rows [L, n_tokens, Kh, D] | V rows [..] | crc32 of payload
//! ```
//!
//! Only the first `n_tokens` sequence rows are shipped, so blob size scales
//! linearly with the cached prompt length — the paper's 2.25 MB (65-token,
//! 270M) and 9.94 MB (334-token, 1B) entries are exactly this scaling.
//! An optional deflate pass (CacheGen-style, §2 related work) is behind
//! [`Compression::Deflate`].  Restore verifies magic, model hash, dims and
//! checksum before touching the live cache: a corrupt or mismatched blob is
//! rejected, the client falls back to local prefill (paper §3.3 — wrong
//! bytes must never poison an inference).

use crc32fast::Hasher as Crc32;
use thiserror::Error;

use crate::util::bytes::{f32_as_bytes, Reader, Writer};

const MAGIC: &[u8; 4] = b"ECS1";

#[derive(Debug, Error, PartialEq)]
pub enum StateError {
    #[error("bad magic (not a state blob)")]
    BadMagic,
    #[error("model mismatch: blob for {blob}, engine runs {engine}")]
    ModelMismatch { blob: String, engine: String },
    #[error("dimension mismatch: {0}")]
    DimMismatch(String),
    #[error("checksum mismatch (corrupt blob)")]
    BadChecksum,
    #[error("blob truncated or malformed: {0}")]
    Malformed(String),
    #[error("n_tokens {n} exceeds cache capacity {cap}")]
    TooLong { n: usize, cap: usize },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Compression {
    None,
    /// DEFLATE (flate2) — trades CPU for Wi-Fi bytes, the CacheGen direction.
    Deflate,
}

/// Parsed blob header (exposed for diagnostics and tests).
#[derive(Debug, Clone, PartialEq)]
pub struct StateHeader {
    pub model_hash: String,
    pub n_layers: usize,
    pub max_seq: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub n_tokens: usize,
    pub compressed: bool,
}

/// Live KV cache: what the engine threads through every PJRT call.
#[derive(Debug, Clone, PartialEq)]
pub struct KvState {
    /// dims: [n_layers, max_seq, n_kv_heads, head_dim]
    pub n_layers: usize,
    pub max_seq: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// Valid prefix length (tokens already prefilled/decoded).
    pub n_tokens: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl KvState {
    pub fn zeroed(n_layers: usize, max_seq: usize, n_kv_heads: usize, head_dim: usize) -> Self {
        let n = n_layers * max_seq * n_kv_heads * head_dim;
        KvState {
            n_layers,
            max_seq,
            n_kv_heads,
            head_dim,
            n_tokens: 0,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    pub fn for_config(cfg: &crate::runtime::ModelConfig) -> Self {
        Self::zeroed(cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
    }

    /// Elements per sequence row within one layer (Kh * D).
    fn row_elems(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Elements per layer (S * Kh * D).
    fn layer_elems(&self) -> usize {
        self.max_seq * self.row_elems()
    }

    /// Serialized payload bytes for `n` cached tokens (uncompressed).
    pub fn payload_bytes(&self, n_tokens: usize) -> usize {
        2 * self.n_layers * n_tokens * self.row_elems() * 4
    }

    /// Copy the valid `[.., :n_tokens]` rows of `src` into `dst`, layer by
    /// layer (the caches are `[L, S, Kh, D]`, so valid rows are not
    /// contiguous across layers).
    fn gather_valid(&self, src: &[f32], out: &mut Vec<u8>) {
        let le = self.layer_elems();
        let take = self.n_tokens * self.row_elems();
        for l in 0..self.n_layers {
            let s = &src[l * le..l * le + take];
            out.extend_from_slice(f32_as_bytes(s));
        }
    }

    /// Snapshot only the first `m` tokens of this state (m ≤ n_tokens).
    /// Causality makes any prefix of a valid state itself a valid state —
    /// this is what lets one prefill serve all four catalog ranges (§3.2).
    pub fn serialize_prefix(
        &self,
        m: usize,
        model_hash: &str,
        compression: Compression,
    ) -> Vec<u8> {
        assert!(m <= self.n_tokens, "prefix {m} > valid {}", self.n_tokens);
        let mut clone = self.clone();
        clone.n_tokens = m;
        clone.serialize(model_hash, compression)
    }

    /// `llama_state_get_data()` analog: snapshot the valid prefix.
    pub fn serialize(&self, model_hash: &str, compression: Compression) -> Vec<u8> {
        let mut payload = Vec::with_capacity(self.payload_bytes(self.n_tokens));
        self.gather_valid(&self.k, &mut payload);
        self.gather_valid(&self.v, &mut payload);

        let (flags, body) = match compression {
            Compression::None => (0u8, payload),
            Compression::Deflate => {
                use flate2::write::DeflateEncoder;
                use flate2::Compression as Level;
                use std::io::Write as _;
                let mut enc = DeflateEncoder::new(
                    Vec::with_capacity(payload.len() / 2),
                    Level::fast(),
                );
                enc.write_all(&payload).expect("in-memory deflate");
                (1u8, enc.finish().expect("in-memory deflate"))
            }
        };

        let mut crc = Crc32::new();
        crc.update(&body);

        let mut w = Writer::with_capacity(body.len() + 64);
        w.bytes(MAGIC);
        w.lp_str(model_hash);
        w.u32(self.n_layers as u32);
        w.u32(self.max_seq as u32);
        w.u32(self.n_kv_heads as u32);
        w.u32(self.head_dim as u32);
        w.u32(self.n_tokens as u32);
        w.u8(flags);
        w.u32(crc.finalize());
        w.lp_bytes(&body);
        w.into_vec()
    }

    /// Parse and verify a blob header without restoring (cheap peek).
    pub fn peek_header(blob: &[u8]) -> Result<StateHeader, StateError> {
        let mut r = Reader::new(blob);
        let magic = r.bytes(4).map_err(|e| StateError::Malformed(e.to_string()))?;
        if magic != MAGIC {
            return Err(StateError::BadMagic);
        }
        let model_hash = r
            .lp_str()
            .map_err(|e| StateError::Malformed(e.to_string()))?
            .to_string();
        let mut u = || -> Result<usize, StateError> {
            Ok(r.u32().map_err(|e| StateError::Malformed(e.to_string()))? as usize)
        };
        let n_layers = u()?;
        let max_seq = u()?;
        let n_kv_heads = u()?;
        let head_dim = u()?;
        let n_tokens = u()?;
        let flags = r.u8().map_err(|e| StateError::Malformed(e.to_string()))?;
        Ok(StateHeader {
            model_hash,
            n_layers,
            max_seq,
            n_kv_heads,
            head_dim,
            n_tokens,
            compressed: flags & 1 != 0,
        })
    }

    /// `llama_state_set_data()` analog: verify + restore into a fresh state.
    pub fn restore(
        blob: &[u8],
        expect_model_hash: &str,
        expect_dims: (usize, usize, usize, usize),
    ) -> Result<KvState, StateError> {
        let hdr = Self::peek_header(blob)?;
        if hdr.model_hash != expect_model_hash {
            return Err(StateError::ModelMismatch {
                blob: hdr.model_hash,
                engine: expect_model_hash.to_string(),
            });
        }
        let (l, s, kh, d) = expect_dims;
        if (hdr.n_layers, hdr.max_seq, hdr.n_kv_heads, hdr.head_dim) != (l, s, kh, d) {
            return Err(StateError::DimMismatch(format!(
                "blob [{},{},{},{}] vs engine [{l},{s},{kh},{d}]",
                hdr.n_layers, hdr.max_seq, hdr.n_kv_heads, hdr.head_dim
            )));
        }
        if hdr.n_tokens > s {
            return Err(StateError::TooLong { n: hdr.n_tokens, cap: s });
        }

        // re-walk the header to find the body
        let mut r = Reader::new(blob);
        r.bytes(4).unwrap();
        r.lp_bytes().unwrap();
        for _ in 0..5 {
            r.u32().unwrap();
        }
        r.u8().unwrap();
        let crc_stored = r.u32().map_err(|e| StateError::Malformed(e.to_string()))?;
        let body = r
            .lp_bytes()
            .map_err(|e| StateError::Malformed(e.to_string()))?;
        if r.remaining() != 0 {
            return Err(StateError::Malformed("trailing bytes".into()));
        }
        let mut crc = Crc32::new();
        crc.update(body);
        if crc.finalize() != crc_stored {
            return Err(StateError::BadChecksum);
        }

        let payload: Vec<u8> = if hdr.compressed {
            use flate2::read::DeflateDecoder;
            use std::io::Read as _;
            let mut out = Vec::new();
            DeflateDecoder::new(body)
                .read_to_end(&mut out)
                .map_err(|e| StateError::Malformed(format!("deflate: {e}")))?;
            out
        } else {
            body.to_vec()
        };

        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = hdr.n_tokens;
        let take = hdr.n_tokens * st.row_elems();
        let expect_len = 2 * l * take * 4;
        if payload.len() != expect_len {
            return Err(StateError::Malformed(format!(
                "payload {} bytes, expected {expect_len}",
                payload.len()
            )));
        }
        let le = st.layer_elems();
        let floats = crate::util::bytes::bytes_to_f32(&payload);
        for li in 0..l {
            let src = &floats[li * take..(li + 1) * take];
            st.k[li * le..li * le + take].copy_from_slice(src);
        }
        let off = l * take;
        for li in 0..l {
            let src = &floats[off + li * take..off + (li + 1) * take];
            st.v[li * le..li * le + take].copy_from_slice(src);
        }
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop_n;
    use crate::util::rng::Rng;

    fn filled(l: usize, s: usize, kh: usize, d: usize, n_tokens: usize, seed: u64) -> KvState {
        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = n_tokens;
        let mut rng = Rng::new(seed);
        let row = st.row_elems();
        let le = st.layer_elems();
        for li in 0..l {
            for e in 0..n_tokens * row {
                st.k[li * le + e] = rng.f64() as f32;
                st.v[li * le + e] = rng.f64() as f32 - 0.5;
            }
        }
        st
    }

    #[test]
    fn roundtrip_uncompressed() {
        let st = filled(2, 16, 2, 8, 5, 1);
        let blob = st.serialize("hashA", Compression::None);
        let back = KvState::restore(&blob, "hashA", (2, 16, 2, 8)).unwrap();
        assert_eq!(back.n_tokens, 5);
        assert_eq!(back.k, st.k);
        assert_eq!(back.v, st.v);
    }

    #[test]
    fn roundtrip_deflate() {
        let st = filled(3, 32, 1, 16, 20, 2);
        let blob = st.serialize("h", Compression::Deflate);
        let back = KvState::restore(&blob, "h", (3, 32, 1, 16)).unwrap();
        assert_eq!(back.k, st.k);
        assert_eq!(back.v, st.v);
        let hdr = KvState::peek_header(&blob).unwrap();
        assert!(hdr.compressed);
    }

    #[test]
    fn size_scales_with_tokens_like_paper() {
        // paper: 2.25 MB at 65 tokens (270M) — size must be linear in tokens
        let st20 = filled(2, 64, 2, 8, 20, 3);
        let st40 = filled(2, 64, 2, 8, 40, 3);
        let b20 = st20.serialize("h", Compression::None).len();
        let b40 = st40.serialize("h", Compression::None).len();
        let overhead = 64;
        assert!(b40 - overhead > (b20 - overhead) * 19 / 10, "{b20} -> {b40}");
        assert_eq!(st20.payload_bytes(20), 2 * 2 * 20 * 2 * 8 * 4);
    }

    #[test]
    fn model_hash_mismatch_rejected() {
        let st = filled(2, 16, 2, 8, 3, 4);
        let blob = st.serialize("modelA", Compression::None);
        let err = KvState::restore(&blob, "modelB", (2, 16, 2, 8)).unwrap_err();
        assert!(matches!(err, StateError::ModelMismatch { .. }));
    }

    #[test]
    fn dim_mismatch_rejected() {
        let st = filled(2, 16, 2, 8, 3, 5);
        let blob = st.serialize("h", Compression::None);
        assert!(matches!(
            KvState::restore(&blob, "h", (2, 32, 2, 8)).unwrap_err(),
            StateError::DimMismatch(_)
        ));
    }

    #[test]
    fn corruption_detected() {
        let st = filled(2, 16, 2, 8, 4, 6);
        let mut blob = st.serialize("h", Compression::None);
        // flip a payload byte (past the ~64-byte header)
        let idx = blob.len() - 10;
        blob[idx] ^= 0x40;
        assert_eq!(
            KvState::restore(&blob, "h", (2, 16, 2, 8)).unwrap_err(),
            StateError::BadChecksum
        );
    }

    #[test]
    fn truncation_detected() {
        let st = filled(2, 16, 2, 8, 4, 7);
        let blob = st.serialize("h", Compression::None);
        for cut in [0, 3, 10, blob.len() - 1] {
            let err = KvState::restore(&blob[..cut], "h", (2, 16, 2, 8));
            assert!(err.is_err(), "cut at {cut} must fail");
        }
    }

    #[test]
    fn garbage_rejected() {
        assert_eq!(
            KvState::restore(b"not a blob at all", "h", (1, 1, 1, 1)).unwrap_err(),
            StateError::BadMagic
        );
    }

    #[test]
    fn n_tokens_beyond_capacity_rejected() {
        // hand-craft: serialize with a small cache, restore claiming bigger n
        let st = filled(1, 8, 1, 4, 8, 8);
        let blob = st.serialize("h", Compression::None);
        // restore into the same dims works
        assert!(KvState::restore(&blob, "h", (1, 8, 1, 4)).is_ok());
    }

    #[test]
    fn property_roundtrip_arbitrary_dims() {
        run_prop_n("state-roundtrip", 32, |g| {
            let l = g.usize_in(1, 4);
            let s = g.usize_in(4, 32);
            let kh = g.usize_in(1, 3);
            let d = [4, 8, 16][g.usize_in(0, 2)];
            let n = g.usize_in(0, s);
            let st = filled(l, s, kh, d, n, g.rng.next_u64());
            let comp = if g.bool() { Compression::Deflate } else { Compression::None };
            let blob = st.serialize("ph", comp);
            let back = KvState::restore(&blob, "ph", (l, s, kh, d)).unwrap();
            assert_eq!(back, st);
        });
    }

    #[test]
    fn deflate_smaller_on_structured_state() {
        // zero-padded rows compress well; random rows don't — use a state
        // with many identical rows to show the codec actually deflates
        let mut st = KvState::zeroed(4, 64, 2, 16);
        st.n_tokens = 64;
        for x in st.k.iter_mut() {
            *x = 1.0;
        }
        let plain = st.serialize("h", Compression::None).len();
        let packed = st.serialize("h", Compression::Deflate).len();
        assert!(packed < plain / 4, "{packed} vs {plain}");
    }
}
