//! KV-cache state blobs — the unit the distributed prompt cache moves.
//!
//! [`KvState`] is the live form: dense `[L, S, Kh, D]` K/V tensors plus the
//! number of valid tokens.  [`KvState::serialize`] produces the blob the
//! paper uploads with `llama_state_get_data()`.  Format v3 (`"ECS3"`) is
//! **token-major, chunked and chunk-compressed** so that any token prefix of
//! a blob maps to a contiguous byte range of *whole chunks* a cache box can
//! serve with `GETRANGE` — even when the body is deflated:
//!
//! ```text
//!   magic "ECS3"
//!   header: lp model hash | L S Kh D n_tokens (u32 each) | flags (u8)
//!           | chunk_tokens (u32) | crc32 over the chunk index
//!   chunk index: n_chunks × (u32 byte length, u32 crc32)   — one entry per
//!           chunk, crc taken over the *stored* (possibly deflated) bytes
//!   body (lp): chunk 0 bytes ‖ chunk 1 bytes ‖ …           — each chunk is
//!           `chunk_tokens` token rows (the last may be partial), deflated
//!           independently when the compression flag is set
//! ```
//!
//! Every token row occupies `2·L·Kh·D·4` bytes ([`BlobLayout::token_stride`])
//! and chunk `c` covers tokens `[c·ct, min((c+1)·ct, n))`.  Because each
//! chunk is an independent deflate stream with its own crc32, the first `m`
//! tokens of an entry are exactly the first `ceil(m/ct)` chunks — a byte
//! range whose offsets the client computes from the chunk index in the
//! header, with **no whole-blob inflate on either side** (the CacheGen
//! per-chunk-compression argument, §2 related work).  The header crc covers
//! the chunk index; body integrity is per-chunk, which is what lets a
//! corrupted chunk be rejected *chunk-granularly* while clean prefixes keep
//! restoring, and what lets `SPLICE` suffix-delta uploads reuse a base
//! entry's compressed prefix chunks verbatim (their index entries are copied
//! into the new header via [`KvState::serialize_for_splice`]).
//!
//! Offsets are computed client-side from [`BlobLayout`]; the cache box stays
//! byte-oriented.  Restore verifies magic, model hash, dims, the index crc
//! and every chunk crc before touching the live cache: a corrupt, truncated
//! or mismatched blob is rejected and the client falls back — first to a
//! full-blob download, then to local prefill (paper §3.3 — wrong bytes must
//! never poison an inference).  Because every chunk is independently
//! verifiable and decodable, restore also runs **incrementally**:
//! [`StateAssembler`] accepts the head once and then each chunk the moment
//! its bytes arrive, so the range-download path decodes chunk `i` while
//! chunk `i+1` is still on the wire ([`KvState::restore_prefix_from_parts`]
//! is its thin feed-everything wrapper).  Readers negotiate by magic: the
//! previous format v2 (`"ECS2"`, whole-body compression + per-token crc row
//! index) still deserializes, both whole and — uncompressed only — via
//! [`KvState::restore_prefix_from_parts`].
//!
//! Only the first `n_tokens` sequence rows are shipped, so blob size scales
//! linearly with the cached prompt length — the paper's 2.25 MB (65-token,
//! 270M) and 9.94 MB (334-token, 1B) entries are exactly this scaling.
//!
//! A second tiny record type, the **range alias** (`"ECSA"`, see
//! [`encode_range_alias`]), lets one stored blob serve all four catalog
//! ranges: shorter prefix keys map to an alias naming the long entry, its
//! row count and — so that `GETRANGE` requests never round to a non-chunk
//! boundary — the target's `chunk_tokens`.  Aliases written before chunking
//! (no chunk size field) still decode, with `chunk_tokens: None`.

use std::borrow::Cow;

use crc32fast::Hasher as Crc32;
use thiserror::Error;

use crate::util::bytes::{copymeter, f32_as_bytes, f32_as_bytes_mut, Reader, SharedBytes};

const MAGIC_V3: &[u8; 4] = b"ECS3";
const MAGIC_V2: &[u8; 4] = b"ECS2";

/// Magic for range-alias records stored under short-prefix keys.
pub const ALIAS_MAGIC: &[u8; 4] = b"ECSA";

/// Default tokens per chunk.  Small enough that a partial match over-fetches
/// at most 7 rows past the matched prefix, large enough that the per-chunk
/// deflate streams still see repeated f32 structure.  (Adaptive sizing is a
/// ROADMAP follow-on.)
pub const DEFAULT_CHUNK_TOKENS: usize = 8;

#[derive(Debug, Error, PartialEq)]
pub enum StateError {
    #[error("bad magic (not a state blob)")]
    BadMagic,
    #[error("model mismatch: blob for {blob}, engine runs {engine}")]
    ModelMismatch { blob: String, engine: String },
    #[error("dimension mismatch: {0}")]
    DimMismatch(String),
    #[error("checksum mismatch (corrupt blob)")]
    BadChecksum,
    #[error("checksum mismatch in chunk {chunk} (corrupt chunk)")]
    ChunkChecksum { chunk: usize },
    #[error("blob truncated or malformed: {0}")]
    Malformed(String),
    #[error("n_tokens {n} exceeds cache capacity {cap}")]
    TooLong { n: usize, cap: usize },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Compression {
    None,
    /// DEFLATE (flate2), applied per chunk — trades CPU for Wi-Fi bytes
    /// while keeping every chunk independently decodable (CacheGen-style).
    Deflate,
}

/// One chunk-index entry: stored byte length and crc32 of the stored
/// (possibly deflated) chunk bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkEntry {
    pub len: u32,
    pub crc: u32,
}

/// Parsed blob header (exposed for diagnostics and tests).
#[derive(Debug, Clone, PartialEq)]
pub struct StateHeader {
    pub model_hash: String,
    pub n_layers: usize,
    pub max_seq: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub n_tokens: usize,
    pub compressed: bool,
    /// Blob format version (2 = `"ECS2"`, 3 = `"ECS3"`).
    pub version: u8,
    /// Tokens per chunk (0 for v2 blobs, which index per token).
    pub chunk_tokens: usize,
}

/// Byte-offset arithmetic for the v3 blob layout.  Everything is derivable
/// from the model identity plus the chunk size, so clients compute
/// `GETRANGE`/`SPLICE` offsets without asking the server anything about the
/// format.
#[derive(Debug, Clone)]
pub struct BlobLayout {
    pub hash_len: usize,
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub chunk_tokens: usize,
}

impl BlobLayout {
    pub fn new(model_hash: &str, n_layers: usize, n_kv_heads: usize, head_dim: usize) -> Self {
        BlobLayout {
            hash_len: model_hash.len(),
            n_layers,
            n_kv_heads,
            head_dim,
            chunk_tokens: DEFAULT_CHUNK_TOKENS,
        }
    }

    pub fn with_chunk_tokens(mut self, chunk_tokens: usize) -> Self {
        assert!(chunk_tokens >= 1, "chunk_tokens must be >= 1");
        self.chunk_tokens = chunk_tokens;
        self
    }

    /// Bytes per token row: K and V rows across all layers.
    pub fn token_stride(&self) -> usize {
        2 * self.n_layers * self.n_kv_heads * self.head_dim * 4
    }

    /// Number of chunks holding `rows` tokens.
    pub fn n_chunks(&self, rows: usize) -> usize {
        rows.div_ceil(self.chunk_tokens)
    }

    /// Token rows held by chunk `c` of an entry with `total` rows.
    pub fn chunk_rows(&self, c: usize, total: usize) -> usize {
        self.chunk_tokens.min(total - c * self.chunk_tokens)
    }

    /// Offset of the chunk index (end of the fixed header).
    pub fn index_off(&self) -> usize {
        4 + 4 + self.hash_len + 5 * 4 + 1 + 4 + 4
    }

    /// Offset of the first body byte in a blob holding `total_rows` tokens
    /// (the chunk index and the body length prefix sit in between).  This is
    /// also the length of the *head* — the header-plus-index prefix a range
    /// download fetches first.
    pub fn payload_off(&self, total_rows: usize) -> usize {
        self.index_off() + 8 * self.n_chunks(total_rows) + 4
    }

    /// Total blob size for `rows` tokens in the uncompressed encoding
    /// (deflated bodies are data-dependent; read their chunk index instead).
    pub fn blob_len(&self, rows: usize) -> usize {
        self.payload_off(rows) + rows * self.token_stride()
    }

    /// Chunks covering an `m`-token prefix.
    pub fn prefix_chunks(&self, m: usize) -> usize {
        self.n_chunks(m)
    }

    /// Token rows actually held by the whole chunks covering an `m`-token
    /// prefix of a `total`-row entry — `m` rounded up to a chunk boundary,
    /// clamped to `total`.  A `GETRANGE` for a prefix must fetch exactly
    /// these rows: per-chunk crcs (and deflate streams) only verify whole
    /// chunks, so requests never land mid-chunk.
    pub fn prefix_rows(&self, m: usize, total: usize) -> usize {
        (self.prefix_chunks(m) * self.chunk_tokens).min(total)
    }
}

/// A decoded range alias: "the state for this prefix key lives as the first
/// `total_rows` rows of the entry stored at `target_key`".
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RangeAlias {
    pub target_key: Vec<u8>,
    pub total_rows: usize,
    pub compressed: bool,
    /// Chunk size (tokens) of the ECS3 target entry, so range requests can
    /// be chunk-aligned without fetching the target's header first.  `None`
    /// for alias records written before chunking (v2 targets) — those fall
    /// back to the legacy per-token range path (uncompressed) or a full-blob
    /// download (compressed).
    pub chunk_tokens: Option<usize>,
}

/// Encode a range alias.  Carries its own crc32 so tampering degrades to a
/// cache miss, never a wrong restore.
pub fn encode_range_alias(
    target_store_key: &[u8],
    total_rows: usize,
    compressed: bool,
    chunk_tokens: usize,
) -> Vec<u8> {
    assert!(chunk_tokens >= 1, "chunk_tokens must be >= 1");
    let mut buf = Vec::with_capacity(4 + 4 + target_store_key.len() + 4 + 1 + 4 + 4);
    buf.extend_from_slice(ALIAS_MAGIC);
    buf.extend_from_slice(&(target_store_key.len() as u32).to_le_bytes());
    buf.extend_from_slice(target_store_key);
    buf.extend_from_slice(&(total_rows as u32).to_le_bytes());
    buf.push(compressed as u8);
    buf.extend_from_slice(&(chunk_tokens as u32).to_le_bytes());
    let mut crc = Crc32::new();
    crc.update(&buf[4..]);
    buf.extend_from_slice(&crc.finalize().to_le_bytes());
    buf
}

/// Decode a range alias; `None` when `blob` is not a (well-formed) alias.
/// Accepts both the chunked record and the pre-chunking legacy record
/// (which lacks the chunk size field).
pub fn decode_range_alias(blob: &[u8]) -> Option<RangeAlias> {
    if blob.len() < 4 || &blob[..4] != ALIAS_MAGIC {
        return None;
    }
    let mut r = Reader::new(&blob[4..]);
    let key = r.lp_bytes().ok()?.to_vec();
    let rows = r.u32().ok()? as usize;
    let compressed = r.u8().ok()? != 0;
    let chunk_tokens = match r.remaining() {
        8 => match r.u32().ok()? as usize {
            0 => return None, // a zero chunk size is never written
            ct => Some(ct),
        },
        4 => None, // legacy record: crc only
        _ => return None,
    };
    let stored = r.u32().ok()?;
    if r.remaining() != 0 {
        return None;
    }
    let mut crc = Crc32::new();
    crc.update(&blob[4..blob.len() - 4]);
    if crc.finalize() != stored {
        return None;
    }
    Some(RangeAlias { target_key: key, total_rows: rows, compressed, chunk_tokens })
}

/// Parse an ECS3 head (any byte prefix of a blob covering the header and the
/// whole chunk index): returns the chunk size and the verified chunk index.
/// `None` for v2 blobs, garbage, a truncated index or an index crc mismatch.
pub fn read_chunk_index(head: &[u8]) -> Option<(usize, Vec<ChunkEntry>)> {
    let hdr = KvState::peek_header(head).ok()?;
    if hdr.version != 3 || hdr.chunk_tokens == 0 {
        return None;
    }
    let lo = BlobLayout::new(&hdr.model_hash, hdr.n_layers, hdr.n_kv_heads, hdr.head_dim)
        .with_chunk_tokens(hdr.chunk_tokens);
    let idx_off = lo.index_off();
    let nch = lo.n_chunks(hdr.n_tokens);
    if head.len() < idx_off + 8 * nch {
        return None;
    }
    let stored = u32::from_le_bytes(head[idx_off - 4..idx_off].try_into().unwrap());
    let index = &head[idx_off..idx_off + 8 * nch];
    let mut crc = Crc32::new();
    crc.update(index);
    if crc.finalize() != stored {
        return None;
    }
    let entries = index
        .chunks_exact(8)
        .map(|e| ChunkEntry {
            len: u32::from_le_bytes(e[..4].try_into().unwrap()),
            crc: u32::from_le_bytes(e[4..].try_into().unwrap()),
        })
        .collect();
    Some((hdr.chunk_tokens, entries))
}

/// Inflate (or borrow) one stored chunk, expecting exactly `expect` payload
/// bytes.  The decoder is bounded at `expect + 1` bytes so a deflate-bomb
/// chunk (small stored bytes, huge inflated size — its crc still matches,
/// since crcs cover the *stored* bytes) is rejected after one extra byte
/// instead of exhausting an edge device's memory.
fn chunk_payload(bytes: &[u8], compressed: bool, expect: usize) -> Result<Cow<'_, [u8]>, StateError> {
    if !compressed {
        return Ok(Cow::Borrowed(bytes));
    }
    use flate2::read::DeflateDecoder;
    use std::io::Read as _;
    let mut out = Vec::with_capacity(expect.min(1 << 20));
    DeflateDecoder::new(bytes)
        .take(expect as u64 + 1)
        .read_to_end(&mut out)
        .map_err(|e| StateError::Malformed(format!("deflate: {e}")))?;
    if out.len() != expect {
        return Err(StateError::Malformed(format!(
            "chunk inflates to {} bytes, expected {expect}",
            out.len()
        )));
    }
    copymeter::add(out.len());
    Ok(Cow::Owned(out))
}

/// Incremental verifier/decoder for a chunked (v3) range download — the
/// streaming half of the restore path.  Built once from the blob *head*
/// (fixed header + crc-verified chunk index), then fed each stored chunk
/// **in arrival order** as its bytes land: [`StateAssembler::feed_chunk`]
/// crc-checks, bounded-inflates and scatters that chunk immediately, so the
/// decode of chunk `i` overlaps the wire time of chunk `i+1` instead of
/// waiting for the whole range to buffer.  [`StateAssembler::finish`] hands
/// back the assembled state only once every expected chunk was fed; any
/// failure (wrong length, crc mismatch, deflate bomb, short payload) aborts
/// the whole assembly and the caller falls back to a full-blob download —
/// never a partial or questionable restore.
///
/// A single-source stream feeds chunks in order ([`StateAssembler::feed_chunk`]:
/// the lowest unfed index names the only acceptable next chunk, so
/// out-of-order or substituted chunk bytes fail its crc/length check instead
/// of scattering rows to the wrong tokens).  A **multi-source** fetch — the
/// peer fabric pulling disjoint chunk stripes from several cache boxes
/// concurrently — addresses chunks explicitly instead
/// ([`StateAssembler::feed_chunk_at`]): every chunk still verifies against
/// its own index entry, each index may be fed exactly once, and `finish`
/// only succeeds when the fed set covers the whole prefix, so interleaved
/// arrival order across sources can never corrupt nor skip a chunk.
#[derive(Debug)]
pub struct StateAssembler {
    st: KvState,
    entries: Vec<ChunkEntry>,
    compressed: bool,
    chunk_tokens: usize,
    /// Row count of the stored entry (chunk geometry is defined against it).
    total_rows: usize,
    stride: usize,
    /// Target prefix rows (what `finish` returns).
    m: usize,
    /// Whole chunks covering the `m`-row prefix.
    k: usize,
    /// Which of the `k` expected chunks have been fed (multi-source fetches
    /// fill this out of order).
    fed_mask: Vec<bool>,
    fed_count: usize,
}

impl StateAssembler {
    /// Parse + verify a blob head for an `m`-token prefix restore.  `head`
    /// must cover the fixed header and the whole chunk index; identity, the
    /// index crc and the chunk geometry are all checked here, before any
    /// body byte is accepted.  v2 heads are rejected (streamed assembly is a
    /// v3 capability; the legacy path lives in
    /// [`KvState::restore_prefix_from_parts`]).
    pub fn new(
        head: &[u8],
        m: usize,
        expect_model_hash: &str,
        expect_dims: (usize, usize, usize, usize),
    ) -> Result<StateAssembler, StateError> {
        let hdr = KvState::peek_header(head)?;
        KvState::check_identity(&hdr, expect_model_hash, expect_dims)?;
        if hdr.n_tokens < m {
            return Err(StateError::Malformed(format!(
                "entry holds {} rows, need {m}",
                hdr.n_tokens
            )));
        }
        let (l, s, kh, d) = expect_dims;
        if m > s {
            return Err(StateError::TooLong { n: m, cap: s });
        }
        if hdr.version != 3 {
            return Err(StateError::Malformed(
                "streamed assembly needs a v3 (chunked) head".into(),
            ));
        }
        if hdr.chunk_tokens == 0 {
            return Err(StateError::Malformed("chunk_tokens 0".into()));
        }
        let ct = hdr.chunk_tokens;
        let lo = BlobLayout::new(expect_model_hash, l, kh, d).with_chunk_tokens(ct);
        let idx_off = lo.index_off();
        let nch_total = lo.n_chunks(hdr.n_tokens);
        if head.len() < idx_off + 8 * nch_total {
            return Err(StateError::Malformed("chunk index truncated".into()));
        }
        let crc_stored =
            u32::from_le_bytes(head[idx_off - 4..idx_off].try_into().unwrap());
        let index = &head[idx_off..idx_off + 8 * nch_total];
        let mut crc = Crc32::new();
        crc.update(index);
        if crc.finalize() != crc_stored {
            return Err(StateError::BadChecksum);
        }
        let entries: Vec<ChunkEntry> = index
            .chunks_exact(8)
            .map(|e| ChunkEntry {
                len: u32::from_le_bytes(e[..4].try_into().unwrap()),
                crc: u32::from_le_bytes(e[4..].try_into().unwrap()),
            })
            .collect();
        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = m;
        let k = lo.prefix_chunks(m);
        Ok(StateAssembler {
            st,
            entries,
            compressed: hdr.compressed,
            chunk_tokens: ct,
            total_rows: hdr.n_tokens,
            stride: lo.token_stride(),
            m,
            k,
            fed_mask: vec![false; k],
            fed_count: 0,
        })
    }

    /// Chunk size (tokens) the entry's own header declares.
    pub fn chunk_tokens(&self) -> usize {
        self.chunk_tokens
    }

    /// Whether the stored chunks are deflated.
    pub fn compressed(&self) -> bool {
        self.compressed
    }

    /// Whole chunks the `m`-row prefix needs.
    pub fn expected_chunks(&self) -> usize {
        self.k
    }

    pub fn fed_chunks(&self) -> usize {
        self.fed_count
    }

    pub fn is_complete(&self) -> bool {
        self.fed_count == self.k
    }

    /// Whether chunk `c` has already been fed.
    pub fn fed_at(&self, c: usize) -> bool {
        self.fed_mask.get(c).copied().unwrap_or(false)
    }

    /// Expected chunks not yet fed — the re-plan worklist after a source
    /// dies mid-fetch.
    pub fn unfed_chunks(&self) -> Vec<usize> {
        (0..self.k).filter(|&c| !self.fed_mask[c]).collect()
    }

    /// Stored byte length of chunk `c` per the verified index.
    pub fn chunk_len(&self, c: usize) -> usize {
        self.entries[c].len as usize
    }

    /// Total stored bytes of the chunks covering the prefix (what a
    /// batch-mode caller fetches in one range).
    pub fn prefix_span(&self) -> usize {
        self.entries[..self.k].iter().map(|e| e.len as usize).sum()
    }

    /// The entry's full chunk index (future `SPLICE` base metadata).
    pub fn entries(&self) -> &[ChunkEntry] {
        &self.entries
    }

    /// Accept the next in-order chunk's stored bytes — the single-stream
    /// path: the lowest unfed index is the only acceptable chunk, so a
    /// stream that delivers replies in request order needs no addressing.
    /// Errors leave the assembler unusable for a *successful* finish —
    /// callers abort to the full-blob fallback.
    pub fn feed_chunk(&mut self, bytes: &[u8]) -> Result<(), StateError> {
        let c = (0..self.k).find(|&c| !self.fed_mask[c]).ok_or_else(|| {
            StateError::Malformed(format!("all {} chunks already fed", self.k))
        })?;
        self.feed_chunk_at(c, bytes)
    }

    /// Accept chunk `c`'s stored bytes, in any order — the multi-source
    /// path: verify its index length + crc, inflate (bounded) and scatter
    /// its rows.  Each index may be fed exactly once; a chunk outside the
    /// expected prefix or fed twice is an error.
    pub fn feed_chunk_at(&mut self, c: usize, bytes: &[u8]) -> Result<(), StateError> {
        if self.fed_mask.get(c).copied().unwrap_or(true) {
            // bail before the crc/inflate work; commit_chunk re-checks
            return self.commit_chunk(c, &[]);
        }
        let raw = verify_chunk_bytes(
            &self.entries,
            self.compressed,
            self.chunk_tokens,
            self.total_rows,
            self.stride,
            self.k,
            c,
            bytes,
        )?;
        self.commit_chunk(c, &raw)
    }

    /// Snapshot the verification geometry so the CPU-heavy half of a feed
    /// (crc + bounded inflate) can run *outside* whatever lock guards this
    /// assembler — concurrent multi-source fetches would otherwise
    /// serialize every peer's chunk decode behind one mutex.
    pub fn verifier(&self) -> ChunkVerifier {
        ChunkVerifier {
            entries: self.entries.clone(),
            compressed: self.compressed,
            chunk_tokens: self.chunk_tokens,
            total_rows: self.total_rows,
            stride: self.stride,
            k: self.k,
        }
    }

    /// Scatter an already-verified chunk payload (the cheap half of a
    /// feed — a bounded memcpy) and mark the chunk fed.  `payload` must be
    /// the exact bytes [`ChunkVerifier::verify`] returned for chunk `c` of
    /// this assembler's entry; the length is re-checked so a mismatched
    /// verifier cannot scatter rows to the wrong tokens.
    pub fn commit_chunk(&mut self, c: usize, payload: &[u8]) -> Result<(), StateError> {
        if c >= self.k {
            return Err(StateError::Malformed(format!(
                "chunk {c} outside the {}-chunk prefix",
                self.k
            )));
        }
        if self.fed_mask[c] {
            return Err(StateError::Malformed(format!("chunk {c} already fed")));
        }
        let stored_rows = self.chunk_tokens.min(self.total_rows - c * self.chunk_tokens);
        if payload.len() != stored_rows * self.stride {
            return Err(StateError::Malformed(format!(
                "chunk {c}: {} payload bytes, expected {}",
                payload.len(),
                stored_rows * self.stride
            )));
        }
        let need = stored_rows.min(self.m - c * self.chunk_tokens);
        self.st
            .scatter_rows_at(&payload[..need * self.stride], c * self.chunk_tokens, need);
        self.fed_mask[c] = true;
        self.fed_count += 1;
        Ok(())
    }

    /// Rows already restored as a *contiguous prefix*: the leading run of
    /// fed chunks × `chunk_tokens`, capped at `m`.  Chunks committed out of
    /// order past a gap don't count — the engine can only resume prefill
    /// from a gap-free row prefix.
    pub fn seeded_rows(&self) -> usize {
        let lead = self.fed_mask.iter().take_while(|&&f| f).count();
        (lead * self.chunk_tokens).min(self.m)
    }

    /// Clone the partially-assembled state, trimmed to [`Self::seeded_rows`],
    /// as a seed for incremental local recompute: a rescue that prefills
    /// from `seeded_rows()` onward instead of token 0 pays only for the
    /// orphan span, not its end offset.  Returns `None` when nothing
    /// contiguous has been committed (a seed of 0 rows is just a fresh
    /// state).
    pub fn seed_state(&self) -> Option<KvState> {
        let rows = self.seeded_rows();
        if rows == 0 {
            return None;
        }
        let mut st = self.st.clone();
        st.n_tokens = rows;
        Some(st)
    }

    /// Return the assembled `m`-row state; an error if any expected chunk
    /// was never fed.
    pub fn finish(self) -> Result<KvState, StateError> {
        if self.fed_count != self.k {
            return Err(StateError::Malformed(format!(
                "assembly incomplete: {} of {} chunks fed",
                self.fed_count, self.k
            )));
        }
        Ok(self.st)
    }
}

/// The lock-free half of a [`StateAssembler`] feed: an owned snapshot of
/// the verified chunk geometry, so a worker thread can crc-check and
/// inflate a chunk's stored bytes without touching (or locking) the
/// assembler itself, then hand the payload to
/// [`StateAssembler::commit_chunk`] under the lock.
#[derive(Debug, Clone)]
pub struct ChunkVerifier {
    entries: Vec<ChunkEntry>,
    compressed: bool,
    chunk_tokens: usize,
    total_rows: usize,
    stride: usize,
    k: usize,
}

impl ChunkVerifier {
    /// Verify chunk `c`'s stored bytes against the index (length + crc) and
    /// inflate them (bounded).  Returns the raw token-row payload ready for
    /// [`StateAssembler::commit_chunk`]; borrowed for uncompressed chunks,
    /// owned for deflated ones.
    pub fn verify<'a>(&self, c: usize, bytes: &'a [u8]) -> Result<Cow<'a, [u8]>, StateError> {
        verify_chunk_bytes(
            &self.entries,
            self.compressed,
            self.chunk_tokens,
            self.total_rows,
            self.stride,
            self.k,
            c,
            bytes,
        )
    }
}

/// The one implementation of chunk verification (index length + crc +
/// bounded inflate), shared by the in-place [`StateAssembler::feed_chunk_at`]
/// and the lock-free [`ChunkVerifier::verify`].
#[allow(clippy::too_many_arguments)]
fn verify_chunk_bytes<'a>(
    entries: &[ChunkEntry],
    compressed: bool,
    chunk_tokens: usize,
    total_rows: usize,
    stride: usize,
    k: usize,
    c: usize,
    bytes: &'a [u8],
) -> Result<Cow<'a, [u8]>, StateError> {
    if c >= k {
        return Err(StateError::Malformed(format!(
            "chunk {c} outside the {k}-chunk prefix"
        )));
    }
    let e = entries[c];
    if bytes.len() != e.len as usize {
        return Err(StateError::Malformed(format!(
            "chunk {c}: {} stored bytes, index says {}",
            bytes.len(),
            e.len
        )));
    }
    let mut crc = Crc32::new();
    crc.update(bytes);
    if crc.finalize() != e.crc {
        return Err(StateError::ChunkChecksum { chunk: c });
    }
    // the stored chunk belongs to the total_rows-row entry; the final
    // fetched chunk may extend past the target prefix — the committer
    // scatters only what it needs
    let stored_rows = chunk_tokens.min(total_rows - c * chunk_tokens);
    let raw = chunk_payload(bytes, compressed, stored_rows * stride)?;
    if raw.len() != stored_rows * stride {
        return Err(StateError::Malformed(format!(
            "chunk {c}: {} payload bytes, expected {}",
            raw.len(),
            stored_rows * stride
        )));
    }
    Ok(raw)
}

/// Live KV cache: what the engine threads through every PJRT call.
#[derive(Debug, Clone, PartialEq)]
pub struct KvState {
    /// dims: [n_layers, max_seq, n_kv_heads, head_dim]
    pub n_layers: usize,
    pub max_seq: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// Valid prefix length (tokens already prefilled/decoded).
    pub n_tokens: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl KvState {
    pub fn zeroed(n_layers: usize, max_seq: usize, n_kv_heads: usize, head_dim: usize) -> Self {
        let n = n_layers * max_seq * n_kv_heads * head_dim;
        KvState {
            n_layers,
            max_seq,
            n_kv_heads,
            head_dim,
            n_tokens: 0,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    pub fn for_config(cfg: &crate::runtime::ModelConfig) -> Self {
        Self::zeroed(cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
    }

    /// Elements per sequence row within one layer (Kh * D).
    fn row_elems(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Elements per layer (S * Kh * D).
    fn layer_elems(&self) -> usize {
        self.max_seq * self.row_elems()
    }

    /// Serialized payload bytes for `n` cached tokens (uncompressed).
    pub fn payload_bytes(&self, n_tokens: usize) -> usize {
        2 * self.n_layers * n_tokens * self.row_elems() * 4
    }

    fn layout_for(&self, model_hash: &str, chunk_tokens: usize) -> BlobLayout {
        BlobLayout::new(model_hash, self.n_layers, self.n_kv_heads, self.head_dim)
            .with_chunk_tokens(chunk_tokens)
    }

    /// Raw token-major payload for rows `[t0, t0+rows)` — exactly the
    /// `rows * token_stride()` bytes [`StateAssembler::commit_chunk`]
    /// expects for a chunk covering those rows, uncompressed.  This is how
    /// a locally recomputed state feeds chunks into a streaming assembly
    /// alongside per-peer reply streams (`coordinator::plan`).
    pub fn chunk_payload(&self, t0: usize, rows: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(rows * 2 * self.n_layers * self.row_elems() * 4);
        self.gather_rows_into(t0, rows, &mut out);
        out
    }

    /// Gather token rows `[t0, t0+rows)` (token-major) into `dst`.
    fn gather_rows_into(&self, t0: usize, rows: usize, dst: &mut Vec<u8>) {
        let row = self.row_elems();
        let le = self.layer_elems();
        for t in t0..t0 + rows {
            for l in 0..self.n_layers {
                let o = l * le + t * row;
                dst.extend_from_slice(f32_as_bytes(&self.k[o..o + row]));
            }
            for l in 0..self.n_layers {
                let o = l * le + t * row;
                dst.extend_from_slice(f32_as_bytes(&self.v[o..o + row]));
            }
        }
    }

    /// Scatter `m` token rows of payload into the `[L, S, Kh, D]` live
    /// tensors starting at token `t0` (inverse of [`KvState::gather_rows_into`]).
    fn scatter_rows_at(&mut self, payload: &[u8], t0: usize, m: usize) {
        let row = self.row_elems();
        let le = self.layer_elems();
        let rb = row * 4;
        let mut src = 0usize;
        for t in t0..t0 + m {
            for l in 0..self.n_layers {
                let o = l * le + t * row;
                f32_as_bytes_mut(&mut self.k[o..o + row])
                    .copy_from_slice(&payload[src..src + rb]);
                src += rb;
            }
            for l in 0..self.n_layers {
                let o = l * le + t * row;
                f32_as_bytes_mut(&mut self.v[o..o + row])
                    .copy_from_slice(&payload[src..src + rb]);
                src += rb;
            }
        }
        copymeter::add(src);
    }

    /// Single-pass v3 blob writer.  Token rows are grouped into chunks of
    /// `chunk_tokens`; each chunk is written (and, for `Deflate`, compressed)
    /// independently and indexed by (stored length, crc32).  When `prefix`
    /// is non-empty, those entries describe already-stored chunks `[0,
    /// prefix.len())` of a base entry with identical geometry/compression:
    /// their bytes are *not* written — the caller splices them in
    /// server-side — but their index entries land in the header so the
    /// assembled entry is self-consistent.  Returns the buffer and the
    /// offset where the body starts (the head/tail split for `SPLICE`).
    fn write_blob_v3(
        &self,
        m: usize,
        model_hash: &str,
        compression: Compression,
        chunk_tokens: usize,
        prefix: &[ChunkEntry],
    ) -> (Vec<u8>, usize) {
        assert!(m <= self.n_tokens, "prefix {m} > valid {}", self.n_tokens);
        assert!(chunk_tokens >= 1, "chunk_tokens must be >= 1");
        assert!(
            prefix.len() * chunk_tokens <= m,
            "{} reused chunks exceed the {m}-row blob",
            prefix.len()
        );
        let flags: u8 = match compression {
            Compression::None => 0,
            Compression::Deflate => 1,
        };
        let lo = self.layout_for(model_hash, chunk_tokens);
        let n_chunks = lo.n_chunks(m);
        let stride = lo.token_stride();
        let mut buf: Vec<u8> = Vec::with_capacity(lo.blob_len(m));
        buf.extend_from_slice(MAGIC_V3);
        buf.extend_from_slice(&(model_hash.len() as u32).to_le_bytes());
        buf.extend_from_slice(model_hash.as_bytes());
        for v in [self.n_layers, self.max_seq, self.n_kv_heads, self.head_dim, m] {
            buf.extend_from_slice(&(v as u32).to_le_bytes());
        }
        buf.push(flags);
        buf.extend_from_slice(&(chunk_tokens as u32).to_le_bytes());
        let crc_pos = buf.len();
        buf.extend_from_slice(&[0u8; 4]);
        let idx_pos = buf.len();
        buf.resize(idx_pos + 8 * n_chunks, 0);
        let lp_pos = buf.len();
        buf.extend_from_slice(&[0u8; 4]);
        let pay_pos = buf.len();

        let mut entries: Vec<ChunkEntry> = prefix.to_vec();
        let prefix_span: usize = prefix.iter().map(|e| e.len as usize).sum();
        for c in prefix.len()..n_chunks {
            let rows = lo.chunk_rows(c, m);
            let cs = buf.len();
            match compression {
                Compression::None => {
                    self.gather_rows_into(c * chunk_tokens, rows, &mut buf);
                    copymeter::add(rows * stride);
                }
                Compression::Deflate => {
                    use flate2::write::DeflateEncoder;
                    use flate2::Compression as Level;
                    use std::io::Write as _;
                    let mut raw = Vec::with_capacity(rows * stride);
                    self.gather_rows_into(c * chunk_tokens, rows, &mut raw);
                    copymeter::add(raw.len());
                    let mut enc = DeflateEncoder::new(buf, Level::fast());
                    enc.write_all(&raw).expect("in-memory deflate");
                    buf = enc.finish().expect("in-memory deflate");
                }
            }
            let mut crc = Crc32::new();
            crc.update(&buf[cs..]);
            entries.push(ChunkEntry { len: (buf.len() - cs) as u32, crc: crc.finalize() });
        }
        for (c, e) in entries.iter().enumerate() {
            buf[idx_pos + 8 * c..idx_pos + 8 * c + 4].copy_from_slice(&e.len.to_le_bytes());
            buf[idx_pos + 8 * c + 4..idx_pos + 8 * c + 8]
                .copy_from_slice(&e.crc.to_le_bytes());
        }
        let body_len = prefix_span + (buf.len() - pay_pos);
        buf[lp_pos..lp_pos + 4].copy_from_slice(&(body_len as u32).to_le_bytes());
        let mut crc = Crc32::new();
        crc.update(&buf[idx_pos..idx_pos + 8 * n_chunks]);
        let crc = crc.finalize();
        buf[crc_pos..crc_pos + 4].copy_from_slice(&crc.to_le_bytes());
        (buf, pay_pos)
    }

    /// Snapshot only the first `m` tokens of this state (m ≤ n_tokens).
    /// Causality makes any prefix of a valid state itself a valid state —
    /// this is what lets one prefill serve all four catalog ranges (§3.2).
    pub fn serialize_prefix(
        &self,
        m: usize,
        model_hash: &str,
        compression: Compression,
    ) -> Vec<u8> {
        self.write_blob_v3(m, model_hash, compression, DEFAULT_CHUNK_TOKENS, &[]).0
    }

    /// [`KvState::serialize_prefix`] with an explicit chunk size.
    pub fn serialize_prefix_opts(
        &self,
        m: usize,
        model_hash: &str,
        compression: Compression,
        chunk_tokens: usize,
    ) -> Vec<u8> {
        self.write_blob_v3(m, model_hash, compression, chunk_tokens, &[]).0
    }

    /// `llama_state_get_data()` analog: snapshot the valid prefix.
    pub fn serialize(&self, model_hash: &str, compression: Compression) -> Vec<u8> {
        self.serialize_prefix(self.n_tokens, model_hash, compression)
    }

    /// Like [`KvState::serialize`] but handing back a [`SharedBytes`] so the
    /// blob can be sliced (head / chunk ranges) and queued on the wire
    /// without further copies.
    pub fn serialize_shared(&self, model_hash: &str, compression: Compression) -> SharedBytes {
        SharedBytes::new(self.serialize(model_hash, compression))
    }

    /// [`KvState::serialize_prefix_opts`] into a [`SharedBytes`].
    pub fn serialize_prefix_shared_opts(
        &self,
        m: usize,
        model_hash: &str,
        compression: Compression,
        chunk_tokens: usize,
    ) -> SharedBytes {
        SharedBytes::new(self.serialize_prefix_opts(m, model_hash, compression, chunk_tokens))
    }

    /// [`KvState::serialize_prefix`] into a [`SharedBytes`].
    pub fn serialize_prefix_shared(
        &self,
        m: usize,
        model_hash: &str,
        compression: Compression,
    ) -> SharedBytes {
        SharedBytes::new(self.serialize_prefix(m, model_hash, compression))
    }

    /// Build the `SPLICE` halves of an `n`-row blob whose first
    /// `prefix.len()` chunks are reused verbatim from a base entry with the
    /// same geometry, chunk size and compression: returns `(head, tail)`
    /// where `head` is the new header + chunk index + body length prefix and
    /// `tail` is the freshly written suffix chunks.  The server assembles
    /// `head ++ base_chunk_bytes ++ tail`; only the suffix is ever gathered
    /// or compressed here — the delta upload's CPU *and* wire saving.
    pub fn serialize_for_splice(
        &self,
        n: usize,
        model_hash: &str,
        compression: Compression,
        chunk_tokens: usize,
        prefix: &[ChunkEntry],
    ) -> (SharedBytes, SharedBytes) {
        let (buf, pay_pos) = self.write_blob_v3(n, model_hash, compression, chunk_tokens, prefix);
        let whole = SharedBytes::new(buf);
        let len = whole.len();
        (whole.slice(0..pay_pos), whole.slice(pay_pos..len))
    }

    /// Parse and verify a blob header without restoring (cheap peek).  Works
    /// on any prefix of the blob that covers the fixed header, so the
    /// range-download path can validate a `GETRANGE` head slice.  Accepts
    /// both v3 (`"ECS3"`) and legacy v2 (`"ECS2"`) headers.
    pub fn peek_header(blob: &[u8]) -> Result<StateHeader, StateError> {
        let mut r = Reader::new(blob);
        let magic = r.bytes(4).map_err(|e| StateError::Malformed(e.to_string()))?;
        let version = if magic == MAGIC_V3 {
            3u8
        } else if magic == MAGIC_V2 {
            2u8
        } else {
            return Err(StateError::BadMagic);
        };
        let model_hash = r
            .lp_str()
            .map_err(|e| StateError::Malformed(e.to_string()))?
            .to_string();
        let mut u = || -> Result<usize, StateError> {
            Ok(r.u32().map_err(|e| StateError::Malformed(e.to_string()))? as usize)
        };
        let n_layers = u()?;
        let max_seq = u()?;
        let n_kv_heads = u()?;
        let head_dim = u()?;
        let n_tokens = u()?;
        let flags = r.u8().map_err(|e| StateError::Malformed(e.to_string()))?;
        let chunk_tokens = if version == 3 {
            r.u32().map_err(|e| StateError::Malformed(e.to_string()))? as usize
        } else {
            0
        };
        Ok(StateHeader {
            model_hash,
            n_layers,
            max_seq,
            n_kv_heads,
            head_dim,
            n_tokens,
            compressed: flags & 1 != 0,
            version,
            chunk_tokens,
        })
    }

    fn check_identity(
        hdr: &StateHeader,
        expect_model_hash: &str,
        expect_dims: (usize, usize, usize, usize),
    ) -> Result<(), StateError> {
        if hdr.model_hash != expect_model_hash {
            return Err(StateError::ModelMismatch {
                blob: hdr.model_hash.clone(),
                engine: expect_model_hash.to_string(),
            });
        }
        let (l, s, kh, d) = expect_dims;
        if (hdr.n_layers, hdr.max_seq, hdr.n_kv_heads, hdr.head_dim) != (l, s, kh, d) {
            return Err(StateError::DimMismatch(format!(
                "blob [{},{},{},{}] vs engine [{l},{s},{kh},{d}]",
                hdr.n_layers, hdr.max_seq, hdr.n_kv_heads, hdr.head_dim
            )));
        }
        if hdr.n_tokens > s {
            return Err(StateError::TooLong { n: hdr.n_tokens, cap: s });
        }
        Ok(())
    }

    /// `llama_state_set_data()` analog: verify + restore into a fresh state.
    /// Dispatches on the header magic: v3 blobs verify the index crc and
    /// every chunk crc; legacy v2 blobs take the whole-body path.
    pub fn restore(
        blob: &[u8],
        expect_model_hash: &str,
        expect_dims: (usize, usize, usize, usize),
    ) -> Result<KvState, StateError> {
        let hdr = Self::peek_header(blob)?;
        Self::check_identity(&hdr, expect_model_hash, expect_dims)?;
        if hdr.version == 2 {
            return Self::restore_v2(blob, &hdr, expect_dims);
        }
        if hdr.chunk_tokens == 0 {
            return Err(StateError::Malformed("chunk_tokens 0".into()));
        }
        let (l, s, kh, d) = expect_dims;
        let lo = BlobLayout::new(expect_model_hash, l, kh, d)
            .with_chunk_tokens(hdr.chunk_tokens);
        let nch = lo.n_chunks(hdr.n_tokens);

        // re-walk the header to find the index and the body
        let mut r = Reader::new(blob);
        r.bytes(4).unwrap();
        r.lp_bytes().unwrap();
        for _ in 0..5 {
            r.u32().unwrap();
        }
        r.u8().unwrap();
        r.u32().unwrap(); // chunk_tokens
        let crc_stored = r.u32().map_err(|e| StateError::Malformed(e.to_string()))?;
        let index = r
            .bytes(8 * nch)
            .map_err(|e| StateError::Malformed(e.to_string()))?;
        let body = r
            .lp_bytes()
            .map_err(|e| StateError::Malformed(e.to_string()))?;
        if r.remaining() != 0 {
            return Err(StateError::Malformed("trailing bytes".into()));
        }
        let mut crc = Crc32::new();
        crc.update(index);
        if crc.finalize() != crc_stored {
            return Err(StateError::BadChecksum);
        }
        let total_span: usize = index
            .chunks_exact(8)
            .map(|e| u32::from_le_bytes(e[..4].try_into().unwrap()) as usize)
            .sum();
        if total_span != body.len() {
            return Err(StateError::Malformed(format!(
                "chunk lengths sum to {total_span}, body holds {}",
                body.len()
            )));
        }

        let stride = lo.token_stride();
        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = hdr.n_tokens;
        let mut off = 0usize;
        for (c, e) in index.chunks_exact(8).enumerate() {
            let clen = u32::from_le_bytes(e[..4].try_into().unwrap()) as usize;
            let want = u32::from_le_bytes(e[4..].try_into().unwrap());
            let bytes = &body[off..off + clen];
            off += clen;
            let mut crc = Crc32::new();
            crc.update(bytes);
            if crc.finalize() != want {
                return Err(StateError::ChunkChecksum { chunk: c });
            }
            let rows = lo.chunk_rows(c, hdr.n_tokens);
            let raw = chunk_payload(bytes, hdr.compressed, rows * stride)?;
            if raw.len() != rows * stride {
                return Err(StateError::Malformed(format!(
                    "chunk {c}: {} payload bytes, expected {}",
                    raw.len(),
                    rows * stride
                )));
            }
            st.scatter_rows_at(&raw, c * hdr.chunk_tokens, rows);
        }
        Ok(st)
    }

    /// Legacy v2 (`"ECS2"`) whole-blob restore: per-token crc row index,
    /// whole-body compression, header crc over index ++ body.
    fn restore_v2(
        blob: &[u8],
        hdr: &StateHeader,
        expect_dims: (usize, usize, usize, usize),
    ) -> Result<KvState, StateError> {
        let (l, s, kh, d) = expect_dims;
        let mut r = Reader::new(blob);
        r.bytes(4).unwrap();
        r.lp_bytes().unwrap();
        for _ in 0..5 {
            r.u32().unwrap();
        }
        r.u8().unwrap();
        let crc_stored = r.u32().map_err(|e| StateError::Malformed(e.to_string()))?;
        let index = r
            .bytes(4 * hdr.n_tokens)
            .map_err(|e| StateError::Malformed(e.to_string()))?;
        let body = r
            .lp_bytes()
            .map_err(|e| StateError::Malformed(e.to_string()))?;
        if r.remaining() != 0 {
            return Err(StateError::Malformed("trailing bytes".into()));
        }
        let mut crc = Crc32::new();
        crc.update(index);
        crc.update(body);
        if crc.finalize() != crc_stored {
            return Err(StateError::BadChecksum);
        }
        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = hdr.n_tokens;
        let expect_len = st.payload_bytes(hdr.n_tokens);
        let payload = chunk_payload(body, hdr.compressed, expect_len)?;
        if payload.len() != expect_len {
            return Err(StateError::Malformed(format!(
                "payload {} bytes, expected {expect_len}",
                payload.len()
            )));
        }
        st.scatter_rows_at(&payload, 0, hdr.n_tokens);
        Ok(st)
    }

    /// Restore the first `m` tokens from a *partially fetched* blob: `head`
    /// is a byte prefix of the stored blob covering the fixed header plus
    /// the whole chunk index; `rows` is the body slice holding the whole
    /// chunks that cover tokens `[0, m)` (`GETRANGE`-fetched — see
    /// [`BlobLayout::prefix_rows`]).  A thin feed-everything wrapper over
    /// [`StateAssembler`]: the index crc and each chunk's crc are verified,
    /// so a truncated, stale or corrupted range degrades to an error — never
    /// a poisoned cache — and a corrupt chunk is reported chunk-granularly
    /// ([`StateError::ChunkChecksum`]): prefixes that stop short of it still
    /// restore.  v2 heads (uncompressed only) take the legacy per-token
    /// path.
    pub fn restore_prefix_from_parts(
        head: &[u8],
        rows: &[u8],
        m: usize,
        expect_model_hash: &str,
        expect_dims: (usize, usize, usize, usize),
    ) -> Result<KvState, StateError> {
        let hdr = Self::peek_header(head)?;
        if hdr.version == 2 {
            Self::check_identity(&hdr, expect_model_hash, expect_dims)?;
            if hdr.n_tokens < m {
                return Err(StateError::Malformed(format!(
                    "entry holds {} rows, need {m}",
                    hdr.n_tokens
                )));
            }
            if m > expect_dims.1 {
                return Err(StateError::TooLong { n: m, cap: expect_dims.1 });
            }
            return Self::restore_prefix_v2(head, rows, m, &hdr, expect_dims);
        }
        let mut asm = StateAssembler::new(head, m, expect_model_hash, expect_dims)?;
        if rows.len() != asm.prefix_span() {
            return Err(StateError::Malformed(format!(
                "chunk payload {} bytes, expected {}",
                rows.len(),
                asm.prefix_span()
            )));
        }
        let mut off = 0usize;
        for c in 0..asm.expected_chunks() {
            let clen = asm.chunk_len(c);
            asm.feed_chunk(&rows[off..off + clen])?;
            off += clen;
        }
        asm.finish()
    }

    /// Legacy v2 partial restore (uncompressed per-token rows).
    fn restore_prefix_v2(
        head: &[u8],
        rows: &[u8],
        m: usize,
        hdr: &StateHeader,
        expect_dims: (usize, usize, usize, usize),
    ) -> Result<KvState, StateError> {
        if hdr.compressed {
            return Err(StateError::Malformed(
                "v2 compressed blob cannot be range-restored".into(),
            ));
        }
        let (l, s, kh, d) = expect_dims;
        let idx_off = 4 + 4 + hdr.model_hash.len() + 5 * 4 + 1 + 4;
        if head.len() < idx_off + 4 * m {
            return Err(StateError::Malformed("row index truncated".into()));
        }
        let stride = 2 * l * kh * d * 4;
        if rows.len() != m * stride {
            return Err(StateError::Malformed(format!(
                "row payload {} bytes, expected {}",
                rows.len(),
                m * stride
            )));
        }
        for t in 0..m {
            let want = u32::from_le_bytes(
                head[idx_off + 4 * t..idx_off + 4 * t + 4].try_into().unwrap(),
            );
            let mut c = Crc32::new();
            c.update(&rows[t * stride..(t + 1) * stride]);
            if c.finalize() != want {
                return Err(StateError::BadChecksum);
            }
        }
        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = m;
        st.scatter_rows_at(rows, 0, m);
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop_n;
    use crate::util::rng::Rng;

    fn filled(l: usize, s: usize, kh: usize, d: usize, n_tokens: usize, seed: u64) -> KvState {
        let mut st = KvState::zeroed(l, s, kh, d);
        st.n_tokens = n_tokens;
        let mut rng = Rng::new(seed);
        let row = st.row_elems();
        let le = st.layer_elems();
        for li in 0..l {
            for e in 0..n_tokens * row {
                st.k[li * le + e] = rng.f64() as f32;
                st.v[li * le + e] = rng.f64() as f32 - 0.5;
            }
        }
        st
    }

    /// Hand-written legacy v2 (`"ECS2"`) uncompressed writer, kept test-side
    /// only: pins the promise that old blobs keep deserializing.
    fn write_v2_blob(st: &KvState, model_hash: &str) -> Vec<u8> {
        let m = st.n_tokens;
        let mut buf = Vec::new();
        buf.extend_from_slice(b"ECS2");
        buf.extend_from_slice(&(model_hash.len() as u32).to_le_bytes());
        buf.extend_from_slice(model_hash.as_bytes());
        for v in [st.n_layers, st.max_seq, st.n_kv_heads, st.head_dim, m] {
            buf.extend_from_slice(&(v as u32).to_le_bytes());
        }
        buf.push(0u8); // flags: uncompressed
        let crc_pos = buf.len();
        buf.extend_from_slice(&[0u8; 4]);
        let idx_pos = buf.len();
        buf.resize(idx_pos + 4 * m, 0);
        let mut payload = Vec::new();
        let mut crcs = Vec::with_capacity(m);
        for t in 0..m {
            let cs = payload.len();
            st.gather_rows_into(t, 1, &mut payload);
            let mut c = Crc32::new();
            c.update(&payload[cs..]);
            crcs.push(c.finalize());
        }
        for (t, c) in crcs.iter().enumerate() {
            buf[idx_pos + 4 * t..idx_pos + 4 * t + 4].copy_from_slice(&c.to_le_bytes());
        }
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&payload);
        let mut crc = Crc32::new();
        crc.update(&buf[idx_pos..idx_pos + 4 * m]);
        crc.update(&payload);
        let crc = crc.finalize();
        buf[crc_pos..crc_pos + 4].copy_from_slice(&crc.to_le_bytes());
        buf
    }

    #[test]
    fn roundtrip_uncompressed() {
        let st = filled(2, 16, 2, 8, 5, 1);
        let blob = st.serialize("hashA", Compression::None);
        let back = KvState::restore(&blob, "hashA", (2, 16, 2, 8)).unwrap();
        assert_eq!(back.n_tokens, 5);
        assert_eq!(back.k, st.k);
        assert_eq!(back.v, st.v);
    }

    #[test]
    fn roundtrip_deflate() {
        let st = filled(3, 32, 1, 16, 20, 2);
        let blob = st.serialize("h", Compression::Deflate);
        let back = KvState::restore(&blob, "h", (3, 32, 1, 16)).unwrap();
        assert_eq!(back.k, st.k);
        assert_eq!(back.v, st.v);
        let hdr = KvState::peek_header(&blob).unwrap();
        assert!(hdr.compressed);
        assert_eq!(hdr.version, 3);
        assert_eq!(hdr.chunk_tokens, DEFAULT_CHUNK_TOKENS);
    }

    #[test]
    fn legacy_v2_blob_still_restores() {
        let st = filled(2, 16, 1, 8, 9, 33);
        let blob = write_v2_blob(&st, "h2");
        let hdr = KvState::peek_header(&blob).unwrap();
        assert_eq!(hdr.version, 2);
        assert_eq!(hdr.chunk_tokens, 0);
        let back = KvState::restore(&blob, "h2", (2, 16, 1, 8)).unwrap();
        assert_eq!(back, st);
        // and the v2 per-token range path still assembles prefixes
        let idx_off = 4 + 4 + 2 + 5 * 4 + 1 + 4;
        let stride = 2 * 2 * 1 * 8 * 4;
        let pay_off = idx_off + 4 * 9 + 4;
        let m = 4;
        let head = &blob[..idx_off + 4 * m];
        let rows = &blob[pay_off..pay_off + m * stride];
        let part =
            KvState::restore_prefix_from_parts(head, rows, m, "h2", (2, 16, 1, 8)).unwrap();
        let trunc = {
            // the expected truncated state: rows past m zeroed in every layer
            let mut t = st.clone();
            let row = t.row_elems();
            let le = t.layer_elems();
            for li in 0..t.n_layers {
                for e in m * row..le {
                    t.k[li * le + e] = 0.0;
                    t.v[li * le + e] = 0.0;
                }
            }
            t.n_tokens = m;
            t
        };
        assert_eq!(part, trunc);
    }

    #[test]
    fn size_scales_with_tokens_like_paper() {
        // paper: 2.25 MB at 65 tokens (270M) — size must be linear in tokens
        let st20 = filled(2, 64, 2, 8, 20, 3);
        let st40 = filled(2, 64, 2, 8, 40, 3);
        let b20 = st20.serialize("h", Compression::None).len();
        let b40 = st40.serialize("h", Compression::None).len();
        let overhead = 128;
        assert!(b40 - overhead > (b20 - overhead) * 19 / 10, "{b20} -> {b40}");
        assert_eq!(st20.payload_bytes(20), 2 * 2 * 20 * 2 * 8 * 4);
    }

    #[test]
    fn blob_layout_matches_serialized_bytes() {
        let st = filled(2, 16, 2, 8, 7, 9);
        let blob = st.serialize("hash!", Compression::None);
        let lo = BlobLayout::new("hash!", 2, 2, 8);
        assert_eq!(blob.len(), lo.blob_len(7));
        assert_eq!(lo.token_stride(), 2 * 2 * 2 * 8 * 4);
        // the token-major property: the payload of a shorter prefix blob is
        // a byte-prefix of the longer blob's payload (uncompressed bodies
        // are raw token-major rows regardless of chunking)
        let blob3 = st.serialize_prefix(3, "hash!", Compression::None);
        assert_eq!(
            &blob3[lo.payload_off(3)..],
            &blob[lo.payload_off(7)..lo.payload_off(7) + 3 * lo.token_stride()]
        );
    }

    #[test]
    fn chunk_layout_math() {
        let lo = BlobLayout::new("h", 1, 1, 4).with_chunk_tokens(4);
        assert_eq!(lo.n_chunks(0), 0);
        assert_eq!(lo.n_chunks(1), 1);
        assert_eq!(lo.n_chunks(4), 1);
        assert_eq!(lo.n_chunks(5), 2);
        assert_eq!(lo.chunk_rows(0, 10), 4);
        assert_eq!(lo.chunk_rows(2, 10), 2);
        // prefix fetches are chunk-aligned, clamped to the entry
        assert_eq!(lo.prefix_rows(1, 10), 4);
        assert_eq!(lo.prefix_rows(4, 10), 4);
        assert_eq!(lo.prefix_rows(5, 10), 8);
        assert_eq!(lo.prefix_rows(9, 10), 10);
        for m in 1..=10usize {
            let pr = lo.prefix_rows(m, 10);
            assert!(pr >= m);
            assert!(pr % 4 == 0 || pr == 10, "prefix_rows({m}) = {pr} mid-chunk");
        }
    }

    #[test]
    fn restore_prefix_from_parts_matches_truncated_blob() {
        for comp in [Compression::None, Compression::Deflate] {
            let st = filled(3, 16, 1, 8, 10, 11);
            let ct = 4;
            let blob = st.serialize_prefix_opts(10, "h", comp, ct);
            let lo = BlobLayout::new("h", 3, 1, 8).with_chunk_tokens(ct);
            let (ct2, entries) = read_chunk_index(&blob).unwrap();
            assert_eq!(ct2, ct);
            for m in [1usize, 4, 7, 10] {
                let head = &blob[..lo.payload_off(10)];
                let span: usize = entries
                    .iter()
                    .take(lo.prefix_chunks(m))
                    .map(|e| e.len as usize)
                    .sum();
                let rows = &blob[lo.payload_off(10)..lo.payload_off(10) + span];
                let part = KvState::restore_prefix_from_parts(head, rows, m, "h", (3, 16, 1, 8))
                    .unwrap();
                let trunc = KvState::restore(
                    &st.serialize_prefix_opts(m, "h", comp, ct),
                    "h",
                    (3, 16, 1, 8),
                )
                .unwrap();
                assert_eq!(part, trunc, "m={m} comp={comp:?}");
            }
        }
    }

    #[test]
    fn restore_prefix_rejects_corrupt_chunk_granularly() {
        let st = filled(2, 16, 1, 4, 12, 13);
        let ct = 4;
        let blob = st.serialize_prefix_opts(12, "h", Compression::Deflate, ct);
        let lo = BlobLayout::new("h", 2, 1, 4).with_chunk_tokens(ct);
        let (_, entries) = read_chunk_index(&blob).unwrap();
        assert_eq!(entries.len(), 3);
        // flip a byte inside chunk 1's stored bytes
        let mut bad = blob.clone();
        let c1_off = lo.payload_off(12) + entries[0].len as usize;
        bad[c1_off + 2] ^= 0x10;
        // whole-blob restore pins the guilty chunk
        assert_eq!(
            KvState::restore(&bad, "h", (2, 16, 1, 4)).unwrap_err(),
            StateError::ChunkChecksum { chunk: 1 }
        );
        let head = &bad[..lo.payload_off(12)];
        // a prefix range covering the corrupt chunk is rejected...
        let span2: usize = entries.iter().take(2).map(|e| e.len as usize).sum();
        let rows2 = &bad[lo.payload_off(12)..lo.payload_off(12) + span2];
        assert_eq!(
            KvState::restore_prefix_from_parts(head, rows2, 8, "h", (2, 16, 1, 4))
                .unwrap_err(),
            StateError::ChunkChecksum { chunk: 1 }
        );
        // ...while a prefix that stops short of it still restores
        let span1 = entries[0].len as usize;
        let rows1 = &bad[lo.payload_off(12)..lo.payload_off(12) + span1];
        let part =
            KvState::restore_prefix_from_parts(head, rows1, 4, "h", (2, 16, 1, 4)).unwrap();
        assert_eq!(part.n_tokens, 4);
        // wrong payload length is malformed, not a panic
        assert!(matches!(
            KvState::restore_prefix_from_parts(head, &rows1[..span1 - 1], 4, "h", (2, 16, 1, 4))
                .unwrap_err(),
            StateError::Malformed(_)
        ));
    }

    #[test]
    fn serialize_for_splice_reassembles_byte_identically() {
        for comp in [Compression::None, Compression::Deflate] {
            let st = filled(2, 32, 1, 8, 20, 17);
            let ct = 4;
            // the "base" entry holds the first 12 rows (3 full chunks)
            let base = st.serialize_prefix_opts(12, "h", comp, ct);
            let lo = BlobLayout::new("h", 2, 1, 8).with_chunk_tokens(ct);
            let (_, base_entries) = read_chunk_index(&base).unwrap();
            let k = 3; // reuse all 3 base chunks (12 rows, chunk-aligned)
            let prefix_span: usize =
                base_entries.iter().take(k).map(|e| e.len as usize).sum();
            let base_pay = lo.payload_off(12);
            let (head, tail) = st.serialize_for_splice(20, "h", comp, ct, &base_entries[..k]);
            // server-side assembly: head ++ base chunk bytes ++ tail
            let mut assembled = head.to_vec();
            assembled.extend_from_slice(&base[base_pay..base_pay + prefix_span]);
            assembled.extend_from_slice(&tail);
            let direct = st.serialize_prefix_opts(20, "h", comp, ct);
            assert_eq!(assembled, direct, "comp={comp:?}");
            let back = KvState::restore(&assembled, "h", (2, 32, 1, 8)).unwrap();
            assert_eq!(back.n_tokens, 20);
            assert_eq!(back.k, st.k);
        }
    }

    #[test]
    fn assembler_streams_chunks_to_the_same_state_as_batch_restore() {
        for comp in [Compression::None, Compression::Deflate] {
            let st = filled(3, 16, 1, 8, 10, 19);
            let ct = 4;
            let blob = st.serialize_prefix_opts(10, "h", comp, ct);
            let lo = BlobLayout::new("h", 3, 1, 8).with_chunk_tokens(ct);
            let head = &blob[..lo.payload_off(10)];
            let pay = lo.payload_off(10);
            for m in [1usize, 4, 7, 10] {
                let mut asm = StateAssembler::new(head, m, "h", (3, 16, 1, 8)).unwrap();
                assert_eq!(asm.chunk_tokens(), ct);
                assert_eq!(asm.compressed(), comp == Compression::Deflate);
                assert_eq!(asm.expected_chunks(), lo.prefix_chunks(m));
                assert!(!asm.is_complete());
                let mut off = pay;
                for c in 0..asm.expected_chunks() {
                    let clen = asm.chunk_len(c);
                    asm.feed_chunk(&blob[off..off + clen]).unwrap();
                    off += clen;
                }
                assert!(asm.is_complete());
                let streamed = asm.finish().unwrap();
                let span = off - pay;
                let batch = KvState::restore_prefix_from_parts(
                    head,
                    &blob[pay..pay + span],
                    m,
                    "h",
                    (3, 16, 1, 8),
                )
                .unwrap();
                assert_eq!(streamed, batch, "m={m} comp={comp:?}");
            }
        }
    }

    #[test]
    fn assembler_incomplete_or_overfed_assembly_is_rejected() {
        let st = filled(2, 16, 1, 8, 10, 23);
        let ct = 4;
        let blob = st.serialize_prefix_opts(10, "h", Compression::None, ct);
        let lo = BlobLayout::new("h", 2, 1, 8).with_chunk_tokens(ct);
        let head = &blob[..lo.payload_off(10)];
        let pay = lo.payload_off(10);
        // finish before the last chunk: error, never a partial state
        let mut asm = StateAssembler::new(head, 10, "h", (2, 16, 1, 8)).unwrap();
        let c0 = asm.chunk_len(0);
        asm.feed_chunk(&blob[pay..pay + c0]).unwrap();
        assert!(matches!(asm.finish().unwrap_err(), StateError::Malformed(_)));
        // feeding past the expected count is rejected too
        let mut asm = StateAssembler::new(head, 4, "h", (2, 16, 1, 8)).unwrap();
        asm.feed_chunk(&blob[pay..pay + c0]).unwrap();
        assert!(asm.is_complete());
        assert!(matches!(
            asm.feed_chunk(&blob[pay..pay + c0]).unwrap_err(),
            StateError::Malformed(_)
        ));
        // a v2 head is refused (streamed assembly is a v3 capability)
        let v2 = write_v2_blob(&filled(2, 16, 1, 8, 6, 2), "h");
        assert!(StateAssembler::new(&v2, 4, "h", (2, 16, 1, 8)).is_err());
    }

    #[test]
    fn assembler_feed_chunk_at_accepts_any_order_once() {
        // the multi-source path: disjoint stripes land interleaved, each
        // chunk addressed explicitly — result identical to in-order feeding
        for comp in [Compression::None, Compression::Deflate] {
            let st = filled(2, 32, 1, 8, 18, 41);
            let ct = 4;
            let blob = st.serialize_prefix_opts(18, "h", comp, ct);
            let lo = BlobLayout::new("h", 2, 1, 8).with_chunk_tokens(ct);
            let head = &blob[..lo.payload_off(18)];
            let pay = lo.payload_off(18);
            let mut asm = StateAssembler::new(head, 18, "h", (2, 32, 1, 8)).unwrap();
            let k = asm.expected_chunks();
            let offs: Vec<usize> = (0..k)
                .scan(pay, |o, c| {
                    let cur = *o;
                    *o += asm.chunk_len(c);
                    Some(cur)
                })
                .collect();
            // stripe A = even chunks, stripe B = odd chunks, B first
            for c in (0..k).filter(|c| c % 2 == 1).chain((0..k).filter(|c| c % 2 == 0)) {
                assert!(!asm.fed_at(c));
                asm.feed_chunk_at(c, &blob[offs[c]..offs[c] + asm.chunk_len(c)])
                    .unwrap();
                assert!(asm.fed_at(c));
            }
            assert!(asm.is_complete());
            assert!(asm.unfed_chunks().is_empty());
            let streamed = asm.finish().unwrap();
            let whole = KvState::restore(&blob, "h", (2, 32, 1, 8)).unwrap();
            assert_eq!(streamed, whole, "comp={comp:?}");

            // double-feed and out-of-prefix indices are rejected
            let mut asm = StateAssembler::new(head, 18, "h", (2, 32, 1, 8)).unwrap();
            asm.feed_chunk_at(0, &blob[offs[0]..offs[0] + asm.chunk_len(0)])
                .unwrap();
            assert!(matches!(
                asm.feed_chunk_at(0, &blob[offs[0]..offs[0] + asm.chunk_len(0)]),
                Err(StateError::Malformed(_))
            ));
            assert!(matches!(
                asm.feed_chunk_at(k, b""),
                Err(StateError::Malformed(_))
            ));
            // the unfed worklist names exactly the missing chunks
            assert_eq!(asm.unfed_chunks(), (1..k).collect::<Vec<_>>());
            // chunk bytes fed under the wrong index fail that index's crc
            if k >= 2 {
                let err = asm
                    .feed_chunk_at(1, &blob[offs[0]..offs[0] + asm.chunk_len(0)])
                    .unwrap_err();
                assert!(
                    matches!(
                        err,
                        StateError::ChunkChecksum { chunk: 1 } | StateError::Malformed(_)
                    ),
                    "{err:?}"
                );
            }
        }
    }

    #[test]
    fn assembler_property_out_of_order_and_corrupt_chunks_abort() {
        run_prop_n("assembler-abort", 24, |g| {
            let l = g.usize_in(1, 3);
            let s = g.usize_in(8, 24);
            let kh = g.usize_in(1, 2);
            let d = [4, 8][g.usize_in(0, 1)];
            let n = g.usize_in(5, s);
            let ct = g.usize_in(1, n.div_ceil(2).max(1));
            let comp = if g.bool() { Compression::Deflate } else { Compression::None };
            let st = filled(l, s, kh, d, n, g.rng.next_u64());
            let blob = st.serialize_prefix_opts(n, "ph", comp, ct);
            let lo = BlobLayout::new("ph", l, kh, d).with_chunk_tokens(ct);
            let head = &blob[..lo.payload_off(n)];
            let pay = lo.payload_off(n);
            let dims = (l, s, kh, d);

            let mut asm = StateAssembler::new(head, n, "ph", dims).unwrap();
            let k = asm.expected_chunks();
            let offs: Vec<usize> = (0..k)
                .scan(pay, |o, c| {
                    let cur = *o;
                    *o += asm.chunk_len(c);
                    Some(cur)
                })
                .collect();
            if k >= 2 {
                // arbitrary arrival order is rejected: chunk 1's bytes fed
                // as chunk 0 fail the index length/crc check (the two chunks
                // hold different random rows)
                let c1 = &blob[offs[1]..offs[1] + asm.chunk_len(1)];
                let err = asm.feed_chunk(c1);
                assert!(
                    err.is_err(),
                    "swapped chunk arrival must be rejected (ct={ct} n={n})"
                );
            }
            // mid-stream corruption: flip a byte in a random chunk; feeding
            // reaches it, fails chunk-granularly, and the assembly aborts
            let bad_c = g.usize_in(0, k - 1);
            let mut bad = blob.clone();
            let flip = offs[bad_c] + g.usize_in(0, asm.chunk_len(bad_c) - 1);
            bad[flip] ^= 0x20;
            let mut asm = StateAssembler::new(head, n, "ph", dims).unwrap();
            let mut failed_at = None;
            for c in 0..k {
                let clen = asm.chunk_len(c);
                match asm.feed_chunk(&bad[offs[c]..offs[c] + clen]) {
                    Ok(()) => {}
                    Err(e) => {
                        assert_eq!(
                            e,
                            StateError::ChunkChecksum { chunk: c },
                            "corruption must be pinned to its chunk"
                        );
                        failed_at = Some(c);
                        break;
                    }
                }
            }
            assert_eq!(failed_at, Some(bad_c), "exactly the corrupt chunk fails");
            // ...and the fallback path (the pristine whole blob) still works
            let full = KvState::restore(&blob, "ph", dims).unwrap();
            assert_eq!(full.n_tokens, n);
        });
    }

    #[test]
    fn range_alias_roundtrip_and_tamper() {
        let enc = encode_range_alias(b"state:deadbeef", 42, false, 8);
        assert_eq!(
            decode_range_alias(&enc),
            Some(RangeAlias {
                target_key: b"state:deadbeef".to_vec(),
                total_rows: 42,
                compressed: false,
                chunk_tokens: Some(8),
            })
        );
        let enc_c = encode_range_alias(b"k", 7, true, 1);
        assert_eq!(
            decode_range_alias(&enc_c).map(|a| (a.compressed, a.chunk_tokens)),
            Some((true, Some(1)))
        );
        // any flipped byte kills the alias instead of redirecting it
        for i in 0..enc.len() {
            let mut bad = enc.clone();
            bad[i] ^= 0x01;
            assert_eq!(decode_range_alias(&bad), None, "flip at {i}");
        }
        // a state blob is not an alias
        let st = filled(1, 8, 1, 4, 2, 5);
        assert_eq!(
            decode_range_alias(&st.serialize("h", Compression::None)),
            None
        );
    }

    #[test]
    fn legacy_alias_without_chunk_size_still_decodes() {
        // hand-build the pre-chunking record: key, rows, compressed, crc
        let mut buf = Vec::new();
        buf.extend_from_slice(ALIAS_MAGIC);
        buf.extend_from_slice(&(5u32).to_le_bytes());
        buf.extend_from_slice(b"k-old");
        buf.extend_from_slice(&(31u32).to_le_bytes());
        buf.push(1u8);
        let mut crc = Crc32::new();
        crc.update(&buf[4..]);
        buf.extend_from_slice(&crc.finalize().to_le_bytes());
        assert_eq!(
            decode_range_alias(&buf),
            Some(RangeAlias {
                target_key: b"k-old".to_vec(),
                total_rows: 31,
                compressed: true,
                chunk_tokens: None,
            })
        );
    }

    #[test]
    fn model_hash_mismatch_rejected() {
        let st = filled(2, 16, 2, 8, 3, 4);
        let blob = st.serialize("modelA", Compression::None);
        let err = KvState::restore(&blob, "modelB", (2, 16, 2, 8)).unwrap_err();
        assert!(matches!(err, StateError::ModelMismatch { .. }));
    }

    #[test]
    fn dim_mismatch_rejected() {
        let st = filled(2, 16, 2, 8, 3, 5);
        let blob = st.serialize("h", Compression::None);
        assert!(matches!(
            KvState::restore(&blob, "h", (2, 32, 2, 8)).unwrap_err(),
            StateError::DimMismatch(_)
        ));
    }

    #[test]
    fn corruption_detected() {
        let st = filled(2, 16, 2, 8, 4, 6);
        let mut blob = st.serialize("h", Compression::None);
        // flip a payload byte (past the header + chunk index)
        let idx = blob.len() - 10;
        blob[idx] ^= 0x40;
        assert!(matches!(
            KvState::restore(&blob, "h", (2, 16, 2, 8)).unwrap_err(),
            StateError::ChunkChecksum { .. }
        ));
    }

    #[test]
    fn truncation_detected() {
        for comp in [Compression::None, Compression::Deflate] {
            let st = filled(2, 16, 2, 8, 4, 7);
            let blob = st.serialize("h", comp);
            for cut in [0, 3, 10, blob.len() / 2, blob.len() - 1] {
                let err = KvState::restore(&blob[..cut], "h", (2, 16, 2, 8));
                assert!(err.is_err(), "cut at {cut} must fail ({comp:?})");
            }
        }
    }

    #[test]
    fn garbage_rejected() {
        assert_eq!(
            KvState::restore(b"not a blob at all", "h", (1, 1, 1, 1)).unwrap_err(),
            StateError::BadMagic
        );
    }

    #[test]
    fn n_tokens_beyond_capacity_rejected() {
        // hand-craft: serialize with a small cache, restore claiming bigger n
        let st = filled(1, 8, 1, 4, 8, 8);
        let blob = st.serialize("h", Compression::None);
        // restore into the same dims works
        assert!(KvState::restore(&blob, "h", (1, 8, 1, 4)).is_ok());
    }

    #[test]
    fn property_roundtrip_arbitrary_dims_and_chunks() {
        run_prop_n("state-roundtrip", 32, |g| {
            let l = g.usize_in(1, 4);
            let s = g.usize_in(4, 32);
            let kh = g.usize_in(1, 3);
            let d = [4, 8, 16][g.usize_in(0, 2)];
            let n = g.usize_in(0, s);
            let ct = g.usize_in(1, s + 2);
            let st = filled(l, s, kh, d, n, g.rng.next_u64());
            let comp = if g.bool() { Compression::Deflate } else { Compression::None };
            let blob = st.serialize_prefix_opts(n, "ph", comp, ct);
            let back = KvState::restore(&blob, "ph", (l, s, kh, d)).unwrap();
            assert_eq!(back, st);
        });
    }

    #[test]
    fn deflate_smaller_on_structured_state() {
        // zero-padded rows compress well; random rows don't — use a state
        // with many identical rows to show the codec actually deflates
        let mut st = KvState::zeroed(4, 64, 2, 16);
        st.n_tokens = 64;
        for x in st.k.iter_mut() {
            *x = 1.0;
        }
        let plain = st.serialize("h", Compression::None).len();
        let packed = st.serialize("h", Compression::Deflate).len();
        assert!(packed < plain / 4, "{packed} vs {plain}");
    }

    #[test]
    fn serialize_shared_slices_without_copy() {
        let st = filled(2, 16, 1, 8, 6, 21);
        let shared = st.serialize_shared("h", Compression::None);
        let lo = BlobLayout::new("h", 2, 1, 8);
        let head = shared.slice(0..lo.payload_off(6));
        let rows = shared.slice(lo.payload_off(6)..shared.len());
        assert_eq!(head.backing_len(), shared.len(), "same backing allocation");
        assert_eq!(rows.len(), 6 * lo.token_stride());
        let part = KvState::restore_prefix_from_parts(
            &head,
            &rows,
            6,
            "h",
            (2, 16, 1, 8),
        )
        .unwrap();
        assert_eq!(part.n_tokens, 6);
        assert_eq!(part.k, st.k);
    }
}
