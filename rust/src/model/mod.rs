//! Model-side state management: the KV cache the engine owns between PJRT
//! calls, its wire format (the `llama_state_get_data()` /
//! `llama_state_set_data()` analog the paper ships over Redis), and token
//! sampling.

pub mod sampler;
pub mod state;

pub use sampler::{argmax, Sampler};
pub use state::{
    BlobLayout, ChunkEntry, Compression, KvState, RangeAlias, StateError, StateHeader,
    DEFAULT_CHUNK_TOKENS,
};
