//! Token sampling — the paper's "Sample" phase.
//!
//! The paper uses greedy sampling throughout; temperature/top-k are included
//! because the engine is a general serving component (and for ablations).

use crate::util::rng::Rng;

/// Greedy argmax over logits (ties broken toward the lower id, like
/// llama.cpp's deterministic greedy sampler).
pub fn argmax(logits: &[f32]) -> u32 {
    debug_assert!(!logits.is_empty());
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as u32
}

#[derive(Debug, Clone)]
pub enum Sampler {
    Greedy,
    /// softmax(logits / temperature), restricted to the top-k ids.
    TopK { temperature: f32, k: usize, rng: Rng },
}

impl Sampler {
    pub fn greedy() -> Self {
        Sampler::Greedy
    }

    pub fn top_k(temperature: f32, k: usize, seed: u64) -> Self {
        assert!(temperature > 0.0 && k > 0);
        Sampler::TopK { temperature, k, rng: Rng::new(seed) }
    }

    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        match self {
            Sampler::Greedy => argmax(logits),
            Sampler::TopK { temperature, k, rng } => {
                let k = (*k).min(logits.len());
                // indices of the k largest logits
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.select_nth_unstable_by(k - 1, |&a, &b| {
                    logits[b].partial_cmp(&logits[a]).unwrap()
                });
                idx.truncate(k);
                let maxv = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
                let weights: Vec<f64> = idx
                    .iter()
                    .map(|&i| (((logits[i] - maxv) / *temperature) as f64).exp())
                    .collect();
                let total: f64 = weights.iter().sum();
                let mut r = rng.f64() * total;
                for (w, &i) in weights.iter().zip(&idx) {
                    if r < *w {
                        return i as u32;
                    }
                    r -= w;
                }
                *idx.last().unwrap() as u32
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic_and_ties() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax(&[5.0, 5.0, 1.0]), 0, "ties -> lower id");
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn greedy_matches_argmax() {
        let mut s = Sampler::greedy();
        assert_eq!(s.sample(&[0.0, 1.0, 0.5]), 1);
    }

    #[test]
    fn topk_respects_support() {
        let mut s = Sampler::top_k(1.0, 2, 42);
        let logits = vec![10.0, 9.0, -50.0, -50.0];
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t == 0 || t == 1, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn topk_low_temperature_is_almost_greedy() {
        let mut s = Sampler::top_k(0.01, 4, 7);
        let logits = vec![1.0, 2.0, 30.0, 4.0];
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 2);
        }
    }

    #[test]
    fn topk_deterministic_per_seed() {
        let logits: Vec<f32> = (0..100).map(|i| (i % 13) as f32).collect();
        let mut a = Sampler::top_k(1.0, 10, 3);
        let mut b = Sampler::top_k(1.0, 10, 3);
        for _ in 0..20 {
            assert_eq!(a.sample(&logits), b.sample(&logits));
        }
    }
}
