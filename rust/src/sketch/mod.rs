//! Similarity sketches — the *semantic tier* beside the exact Bloom
//! catalog.
//!
//! The paper's partial matching fires only on exact token-prefix equality,
//! so a paraphrased prompt misses the entire fleet cache and pays full
//! prefill.  This module adds a compact per-entry **SimHash** computed at
//! upload time from cheap token-bucket shingle features (no model
//! inference, no embedding service): every W-token window of the entry's
//! token ids is bucketed and hashed, each hash votes ±1 on 64 accumulator
//! bits, and the sign pattern becomes the sketch.  Two prompts that share
//! most of their shingles land within a few Hamming bits of each other, so
//! a nearest-sketch scan over a fleet's [`SketchTable`] proposes donor
//! entries for a prompt the exact catalog missed.
//!
//! **The sketch is advisory, never trusted.**  Correctness comes from the
//! verification gate: before any state is reused, the client fetches the
//! donor's cheap token-id header ([`encode_token_ids`], stored under
//! `tok:<hex>` beside the state blob) and computes the *actual* longest
//! common token prefix ([`common_prefix_len`]).  Only the verified prefix
//! rows are fetched and restored — causal attention makes the first `lcp`
//! rows of the donor's KV state bit-identical to what local prefill of the
//! same `lcp` tokens would produce, so a maliciously-close sketch with
//! zero real overlap can cost at most one wasted header probe.
//!
//! Sketches travel fleet-wide as **versioned sections**
//! ([`encode_section`] / [`decode_section`]) appended to each box's
//! master sketch log and pulled incrementally by `CatalogSync`
//! (`CAT.SREGISTER` / `CAT.SDELTA`).  A peer that predates the verbs
//! answers with an error the sync loop swallows, and a section whose
//! magic/version is unknown decodes to "nothing" — either way the tier
//! degrades to exact-only matching, never to a broken sync round.

use std::collections::HashMap;

use crate::catalog::KEY_LEN;

/// Sketch width in bits (one `u64`).  64 bits keeps the per-entry cost at
/// 8 bytes and a fleet-wide table of thousands of entries under a page,
/// while leaving same-domain paraphrases ~tens of bits from unrelated
/// prompts on the MMLU-style workload.
pub const SKETCH_BITS: usize = 64;

/// Shingle window: features are overlapping `W`-token windows, so local
/// token swaps perturb only the `W` shingles that cover them.
const SHINGLE_W: usize = 3;

/// Token-bucket count: token ids are folded to `t % BUCKETS` before
/// shingling, so the feature space stays small and a tokenizer's exact id
/// assignment (beyond bucket collisions) stops mattering.
const BUCKETS: u32 = 1024;

/// SplitMix64 finalizer — cheap, well-mixed 64-bit hash per shingle.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// SimHash over token-bucket shingles: each `SHINGLE_W`-wide window of
/// bucketed token ids hashes to 64 bits that vote ±1 per accumulator; the
/// accumulator signs are the sketch.  Deterministic — identical token
/// sequences (identical shingle multisets) always sketch identically —
/// and cheap: one pass, no allocation beyond the fixed accumulator.
pub fn sketch_tokens(tokens: &[u32]) -> u64 {
    let mut acc = [0i32; SKETCH_BITS];
    let mut vote = |h: u64| {
        for (b, a) in acc.iter_mut().enumerate() {
            if (h >> b) & 1 == 1 {
                *a += 1;
            } else {
                *a -= 1;
            }
        }
    };
    if tokens.len() < SHINGLE_W {
        // degenerate short input: one shingle over what exists, padded
        // with a sentinel so the empty prompt still sketches stably
        let mut h = 0xE1u64;
        for &t in tokens {
            h = mix64(h ^ (t % BUCKETS) as u64);
        }
        vote(mix64(h));
    } else {
        for w in tokens.windows(SHINGLE_W) {
            let mut h = 0xE1u64;
            for &t in w {
                h = mix64(h ^ (t % BUCKETS) as u64);
            }
            vote(h);
        }
    }
    let mut out = 0u64;
    for (b, &a) in acc.iter().enumerate() {
        if a >= 0 {
            out |= 1 << b;
        }
    }
    out
}

/// Hamming distance between two sketches (0..=64).
pub fn hamming(a: u64, b: u64) -> u32 {
    (a ^ b).count_ones()
}

/// One fleet-visible sketch entry: the catalog key it annotates plus the
/// entry geometry a semantic fetch needs (what an exact hit would read
/// out of the range alias).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SketchRecord {
    /// Catalog key of the donor entry (the *longest* range of its upload —
    /// an LCP against the full entry subsumes every alias prefix).
    pub key: [u8; KEY_LEN],
    pub sketch: u64,
    /// Donor entry length in tokens (rows held at its store key).
    pub token_len: u32,
    /// ECS3 chunk size of the donor blob.
    pub chunk_tokens: u32,
    /// Whether the donor blob is per-chunk deflated.
    pub compressed: bool,
}

/// Section wire format: magic+version tag, then fixed-width records.  The
/// tag is the whole compatibility story — a future v2 changes the magic
/// and today's decoder ignores it (returns `None`), degrading that peer
/// to exact-only for v2 entries instead of misparsing them.
const SECTION_MAGIC: &[u8; 4] = b"SKS1";
/// key + sketch + token_len + chunk_tokens + flags
const RECORD_LEN: usize = KEY_LEN + 8 + 4 + 4 + 1;

/// Encode records as one versioned sketch section (the `CAT.SREGISTER`
/// payload and `CAT.SDELTA` reply unit).
pub fn encode_section(records: &[SketchRecord]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + records.len() * RECORD_LEN);
    out.extend_from_slice(SECTION_MAGIC);
    out.extend_from_slice(&(records.len() as u32).to_le_bytes());
    for r in records {
        out.extend_from_slice(&r.key);
        out.extend_from_slice(&r.sketch.to_le_bytes());
        out.extend_from_slice(&r.token_len.to_le_bytes());
        out.extend_from_slice(&r.chunk_tokens.to_le_bytes());
        out.push(r.compressed as u8);
    }
    out
}

/// Decode a sketch section; `None` for unknown magic/version or a
/// malformed body (legacy peers, future formats — the caller skips it).
pub fn decode_section(bytes: &[u8]) -> Option<Vec<SketchRecord>> {
    if bytes.len() < 8 || &bytes[..4] != SECTION_MAGIC {
        return None;
    }
    let n = u32::from_le_bytes(bytes[4..8].try_into().ok()?) as usize;
    if bytes.len() != 8 + n * RECORD_LEN {
        return None;
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let b = &bytes[8 + i * RECORD_LEN..8 + (i + 1) * RECORD_LEN];
        let mut key = [0u8; KEY_LEN];
        key.copy_from_slice(&b[..KEY_LEN]);
        let sketch = u64::from_le_bytes(b[KEY_LEN..KEY_LEN + 8].try_into().ok()?);
        let token_len =
            u32::from_le_bytes(b[KEY_LEN + 8..KEY_LEN + 12].try_into().ok()?);
        let chunk_tokens =
            u32::from_le_bytes(b[KEY_LEN + 12..KEY_LEN + 16].try_into().ok()?);
        let compressed = b[KEY_LEN + 16] != 0;
        out.push(SketchRecord { key, sketch, token_len, chunk_tokens, compressed });
    }
    Some(out)
}

/// A sketch candidate returned by [`SketchTable::nearest`].
#[derive(Debug, Clone, Copy)]
pub struct SketchCandidate {
    pub record: SketchRecord,
    pub distance: u32,
}

/// Per-peer sketch table: every sketch record this client has pulled from
/// one box's master sketch log, keyed by catalog key.  Mirrors
/// `LocalCatalog` — a sync cursor plus the merged state — but stores the
/// records themselves (8+ bytes each) because nearest-sketch search needs
/// them, where the Bloom filter only answers membership.
#[derive(Debug, Default)]
pub struct SketchTable {
    records: HashMap<[u8; KEY_LEN], SketchRecord>,
    /// Master sketch-log version this table has incorporated.
    pub synced_version: u64,
    /// Sections merged over the table's lifetime (sync telemetry).
    pub synced_sections: u64,
}

impl SketchTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Insert/overwrite one record (upload-time local registration and
    /// section merges both land here; last write wins, like re-registering
    /// a catalog key).
    pub fn insert(&mut self, rec: SketchRecord) {
        self.records.insert(rec.key, rec);
    }

    pub fn get(&self, key: &[u8; KEY_LEN]) -> Option<&SketchRecord> {
        self.records.get(key)
    }

    /// Merge one decoded delta: apply every parseable section, ignore the
    /// rest (forward compatibility), advance the cursor monotonically.
    pub fn apply_delta(&mut self, new_version: u64, sections: &[impl AsRef<[u8]>]) {
        for s in sections {
            if let Some(recs) = decode_section(s.as_ref()) {
                self.synced_sections += 1;
                for r in recs {
                    self.insert(r);
                }
            }
        }
        self.synced_version = self.synced_version.max(new_version);
    }

    /// The `k` nearest records to `sketch` within `max_dist` Hamming bits,
    /// longest-entry-first among ties (a longer donor can only verify to a
    /// longer overlap).  Linear scan — the table holds one record per
    /// fleet entry, and 64-bit XOR+popcount makes even 10⁵ entries a
    /// sub-millisecond scan, far below one prefill token.
    pub fn nearest(
        &self,
        sketch: u64,
        k: usize,
        max_dist: u32,
        min_tokens: usize,
    ) -> Vec<SketchCandidate> {
        let mut hits: Vec<SketchCandidate> = self
            .records
            .values()
            .filter(|r| r.token_len as usize >= min_tokens)
            .map(|r| SketchCandidate { record: *r, distance: hamming(sketch, r.sketch) })
            .filter(|c| c.distance <= max_dist)
            .collect();
        hits.sort_by(|a, b| {
            a.distance
                .cmp(&b.distance)
                .then(b.record.token_len.cmp(&a.record.token_len))
        });
        hits.truncate(k);
        hits
    }
}

/// Token-id header stored under `tok:<hex>` beside each uploaded entry —
/// the cheap artifact the verification gate fetches instead of trusting
/// the sketch.  ~4 bytes per token: a few hundred bytes where the state
/// blob is hundreds of kilobytes.
const TOKENS_MAGIC: &[u8; 4] = b"TOK1";

pub fn encode_token_ids(tokens: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + tokens.len() * 4);
    out.extend_from_slice(TOKENS_MAGIC);
    out.extend_from_slice(&(tokens.len() as u32).to_le_bytes());
    for &t in tokens {
        out.extend_from_slice(&t.to_le_bytes());
    }
    out
}

pub fn decode_token_ids(bytes: &[u8]) -> Option<Vec<u32>> {
    if bytes.len() < 8 || &bytes[..4] != TOKENS_MAGIC {
        return None;
    }
    let n = u32::from_le_bytes(bytes[4..8].try_into().ok()?) as usize;
    if bytes.len() != 8 + n * 4 {
        return None;
    }
    Some(
        bytes[8..]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect(),
    )
}

/// Longest common token prefix — the *verified* overlap a semantic reuse
/// is allowed to restore.  Correctness never depends on the sketch: this
/// comparison is against the donor's real token ids.
pub fn common_prefix_len(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tokens(seed: u64, n: usize) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(30_000) as u32).collect()
    }

    /// Substitute each token independently with probability `rate`.
    fn perturb(toks: &[u32], rate: f64, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed ^ 0x9E37);
        toks.iter()
            .map(|&t| if rng.chance(rate) { rng.below(30_000) as u32 } else { t })
            .collect()
    }

    #[test]
    fn identical_inputs_sketch_identically() {
        for seed in 0..16 {
            let t = tokens(seed, 120);
            assert_eq!(sketch_tokens(&t), sketch_tokens(&t));
            assert_eq!(hamming(sketch_tokens(&t), sketch_tokens(&t)), 0);
        }
        // degenerate lengths stay stable too
        for n in 0..4 {
            let t = tokens(99, n);
            assert_eq!(sketch_tokens(&t), sketch_tokens(&t.clone()));
        }
    }

    #[test]
    fn distance_monotone_under_growing_perturbation() {
        // SimHash law, pinned on seeded sweeps: average Hamming distance
        // grows with the perturbation rate, and unrelated prompts sit far
        // from light paraphrases
        let rates = [0.02, 0.1, 0.3, 0.8];
        let mut avg = [0f64; 4];
        let trials = 48;
        for seed in 0..trials {
            let base = tokens(seed, 160);
            let s0 = sketch_tokens(&base);
            for (i, &r) in rates.iter().enumerate() {
                let p = perturb(&base, r, seed * 31 + i as u64);
                avg[i] += hamming(s0, sketch_tokens(&p)) as f64;
            }
        }
        for a in avg.iter_mut() {
            *a /= trials as f64;
        }
        for w in avg.windows(2) {
            assert!(
                w[0] < w[1],
                "distance must grow with perturbation: {avg:?}"
            );
        }
        // a light paraphrase stays meaningfully closer than random noise
        assert!(avg[0] < 12.0, "2% perturbation drifted {} bits", avg[0]);
        assert!(avg[3] > 16.0, "80% perturbation only {} bits", avg[3]);
    }

    #[test]
    fn unrelated_prompts_are_far() {
        let mut far = 0u32;
        for seed in 0..24 {
            let a = sketch_tokens(&tokens(seed, 150));
            let b = sketch_tokens(&tokens(seed + 1000, 150));
            far += hamming(a, b);
        }
        assert!(far / 24 > 20, "unrelated avg distance {}", far / 24);
    }

    #[test]
    fn section_roundtrip() {
        let recs: Vec<SketchRecord> = (0..5u8)
            .map(|i| SketchRecord {
                key: [i; KEY_LEN],
                sketch: 0xDEAD_BEEF_u64.rotate_left(i as u32),
                token_len: 100 + i as u32,
                chunk_tokens: 8,
                compressed: i % 2 == 0,
            })
            .collect();
        let wire = encode_section(&recs);
        assert_eq!(decode_section(&wire).unwrap(), recs);
        // empty section roundtrips too
        assert_eq!(decode_section(&encode_section(&[])).unwrap(), vec![]);
    }

    #[test]
    fn decode_rejects_foreign_bytes() {
        assert!(decode_section(b"").is_none());
        assert!(decode_section(b"SKS2\x00\x00\x00\x00").is_none(), "future version");
        assert!(decode_section(b"nonsense-bytes").is_none());
        let mut truncated = encode_section(&[SketchRecord {
            key: [1; KEY_LEN],
            sketch: 7,
            token_len: 10,
            chunk_tokens: 8,
            compressed: false,
        }]);
        truncated.pop();
        assert!(decode_section(&truncated).is_none());
    }

    #[test]
    fn table_merge_and_nearest() {
        let mut t = SketchTable::new();
        let base = tokens(1, 120);
        let near = perturb(&base, 0.05, 2);
        let far = tokens(5000, 120);
        let mk = |key: u8, toks: &[u32], len: u32| SketchRecord {
            key: [key; KEY_LEN],
            sketch: sketch_tokens(toks),
            token_len: len,
            chunk_tokens: 8,
            compressed: false,
        };
        t.apply_delta(2, &[encode_section(&[mk(1, &near, 100), mk(2, &far, 100)])]);
        assert_eq!((t.len(), t.synced_version, t.synced_sections), (2, 2, 1));
        // unknown sections are skipped, the cursor still advances
        t.apply_delta(3, &[b"SKS9junk".to_vec()]);
        assert_eq!((t.len(), t.synced_version), (2, 3));
        t.apply_delta(1, &[] as &[Vec<u8>]); // stale delta: no regression
        assert_eq!(t.synced_version, 3);

        let q = sketch_tokens(&base);
        let hits = t.nearest(q, 4, 16, 1);
        assert_eq!(hits[0].record.key, [1; KEY_LEN], "paraphrase ranks first");
        assert!(hits.iter().all(|c| c.distance <= 16));
        // the distance threshold really filters
        assert!(t.nearest(q, 4, 0, 1).len() <= 1);
        // min_tokens filters short donors
        assert!(t.nearest(q, 4, 64, 101).is_empty());
        // tie-break prefers the longer donor
        let mut t2 = SketchTable::new();
        t2.insert(mk(3, &base, 50));
        t2.insert(mk(4, &base, 90));
        assert_eq!(t2.nearest(q, 1, 64, 1)[0].record.key, [4; KEY_LEN]);
    }

    #[test]
    fn token_header_roundtrip_and_lcp() {
        let t = tokens(3, 77);
        let wire = encode_token_ids(&t);
        assert_eq!(decode_token_ids(&wire).unwrap(), t);
        assert!(decode_token_ids(b"TOK2aaaa").is_none());
        assert!(decode_token_ids(&wire[..wire.len() - 1]).is_none());
        assert_eq!(decode_token_ids(&encode_token_ids(&[])).unwrap(), Vec::<u32>::new());

        assert_eq!(common_prefix_len(&t, &t), 77);
        let mut d = t.clone();
        d[40] ^= 1;
        assert_eq!(common_prefix_len(&t, &d), 40);
        assert_eq!(common_prefix_len(&t, &[]), 0);
        assert_eq!(common_prefix_len(&t[..10], &d), 10);
    }
}
