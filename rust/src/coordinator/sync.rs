//! Asynchronous local-catalog synchronization (paper §3.1, Figure 2 green
//! arrow): a background thread pulls master-catalog deltas on an interval
//! and merges them into the client's local Bloom filter, off the inference
//! path ("synchronized with the server asynchronously ... so as not to
//! impact inference latency").

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::catalog::LocalCatalog;
use crate::kvstore::KvClient;
use crate::log_debug;

pub struct CatalogSync {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    /// Completed sync rounds (diagnostics / test synchronisation).
    pub rounds: Arc<AtomicU64>,
}

impl CatalogSync {
    /// Spawn the sync loop against `server_addr`, merging into `catalog`
    /// every `interval`.  The loop opens its own connection so it never
    /// contends with the client's request-path connection.
    pub fn spawn(
        server_addr: String,
        catalog: Arc<Mutex<LocalCatalog>>,
        interval: Duration,
    ) -> Result<CatalogSync> {
        let stop = Arc::new(AtomicBool::new(false));
        let rounds = Arc::new(AtomicU64::new(0));
        let stop2 = Arc::clone(&stop);
        let rounds2 = Arc::clone(&rounds);
        let thread = std::thread::Builder::new()
            .name("catalog-sync".into())
            .spawn(move || {
                let mut conn: Option<KvClient> = None;
                while !stop2.load(Ordering::SeqCst) {
                    if conn.is_none() {
                        conn = KvClient::connect(&server_addr).ok();
                    }
                    if let Some(c) = conn.as_mut() {
                        if let Err(e) = Self::sync_once(c, &catalog) {
                            log_debug!("catalog-sync", "round failed: {e}; reconnecting");
                            conn = None;
                        } else {
                            rounds2.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    // sleep in small steps so shutdown is prompt
                    let mut left = interval;
                    while !left.is_zero() && !stop2.load(Ordering::SeqCst) {
                        let step = left.min(Duration::from_millis(20));
                        std::thread::sleep(step);
                        left -= step;
                    }
                }
            })?;
        Ok(CatalogSync { stop, thread: Some(thread), rounds })
    }

    /// One pull-merge round (also used synchronously in tests).
    pub fn sync_once(conn: &mut KvClient, catalog: &Arc<Mutex<LocalCatalog>>) -> Result<()> {
        let since = catalog.lock().unwrap().synced_version;
        let remote = conn.catalog_version()?;
        if remote <= since {
            return Ok(());
        }
        let (mut ver, mut keys) = conn.catalog_delta(since)?;
        loop {
            {
                let mut cat = catalog.lock().unwrap();
                cat.apply_delta(ver, &keys);
            }
            if ver >= remote {
                break;
            }
            let (v2, k2) = conn.catalog_delta(ver)?;
            ver = v2;
            keys = k2;
            if keys.is_empty() && ver >= remote {
                break;
            }
        }
        Ok(())
    }

    pub fn stop(mut self) {
        self.do_stop();
    }

    fn do_stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for CatalogSync {
    fn drop(&mut self) {
        self.do_stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cachebox::CacheBox;

    #[test]
    fn background_sync_propagates_keys() {
        let cb = CacheBox::start_local().unwrap();
        let catalog = Arc::new(Mutex::new(LocalCatalog::new()));
        let sync = CatalogSync::spawn(
            cb.addr(),
            Arc::clone(&catalog),
            Duration::from_millis(10),
        )
        .unwrap();

        // another client registers keys on the master
        let mut c = KvClient::connect(&cb.addr()).unwrap();
        c.catalog_register(b"remote-key-1").unwrap();
        c.catalog_register(b"remote-key-2").unwrap();

        // wait for the loop to pick them up
        let t0 = std::time::Instant::now();
        loop {
            {
                let cat = catalog.lock().unwrap();
                if cat.synced_version >= 2 {
                    assert!(cat.filter.contains(b"remote-key-1"));
                    assert!(cat.filter.contains(b"remote-key-2"));
                    break;
                }
            }
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "sync did not converge"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        sync.stop();
        cb.shutdown();
    }

    #[test]
    fn sync_once_is_incremental() {
        let cb = CacheBox::start_local().unwrap();
        let catalog = Arc::new(Mutex::new(LocalCatalog::new()));
        let mut reg = KvClient::connect(&cb.addr()).unwrap();
        let mut conn = KvClient::connect(&cb.addr()).unwrap();

        reg.catalog_register(b"k1").unwrap();
        CatalogSync::sync_once(&mut conn, &catalog).unwrap();
        assert_eq!(catalog.lock().unwrap().synced_version, 1);

        reg.catalog_register(b"k2").unwrap();
        CatalogSync::sync_once(&mut conn, &catalog).unwrap();
        let cat = catalog.lock().unwrap();
        assert_eq!(cat.synced_version, 2);
        assert!(cat.filter.contains(b"k1") && cat.filter.contains(b"k2"));
        drop(cat);

        // no-op round when nothing changed
        CatalogSync::sync_once(&mut conn, &catalog).unwrap();
        assert_eq!(catalog.lock().unwrap().synced_version, 2);
        cb.shutdown();
    }

    #[test]
    fn sync_survives_server_restart_cycle() {
        // server down -> loop keeps retrying without panicking
        let catalog = Arc::new(Mutex::new(LocalCatalog::new()));
        let sync = CatalogSync::spawn(
            "127.0.0.1:1".into(), // nothing listens here
            Arc::clone(&catalog),
            Duration::from_millis(5),
        )
        .unwrap();
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(catalog.lock().unwrap().synced_version, 0);
        sync.stop();
    }
}
