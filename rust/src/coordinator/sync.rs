//! Asynchronous local-catalog synchronization (paper §3.1, Figure 2 green
//! arrow): a background thread pulls master-catalog deltas on an interval
//! and merges them into the client's local Bloom filter, off the inference
//! path ("synchronized with the server asynchronously ... so as not to
//! impact inference latency").

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::catalog::LocalCatalog;
use crate::coordinator::membership::{HealthSink, Membership, MembershipDigest, Outcome};
use crate::kvstore::KvClient;
use crate::log_debug;
use crate::sketch::SketchTable;
use crate::util::rng::Rng;

/// Ceiling for the failure backoff: a dead peer is re-probed at least this
/// often, so recovery is never more than a few seconds away, but the sync
/// thread stops hammering a socket that keeps refusing.
const MAX_BACKOFF: Duration = Duration::from_secs(5);

pub struct CatalogSync {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    /// Completed sync rounds (diagnostics / test synchronisation).
    pub rounds: Arc<AtomicU64>,
    /// Connect/sync attempts, successful or not — under backoff this grows
    /// much slower than `elapsed / interval` while a peer is down.
    pub attempts: Arc<AtomicU64>,
}

impl CatalogSync {
    /// Spawn the sync loop against `server_addr`, merging into `catalog`
    /// every `interval`.  The loop opens its own connection so it never
    /// contends with the client's request-path connection.
    ///
    /// A peer that keeps failing (dead box, partitioned link) does not spin
    /// the thread at the full interval rate: each consecutive failure
    /// doubles the sleep, capped at [`MAX_BACKOFF`], with ±25 % jitter so a
    /// fleet of clients whose peer died together does not reconnect as a
    /// thundering herd.  The first success snaps back to `interval`.
    pub fn spawn(
        server_addr: String,
        catalog: Arc<Mutex<LocalCatalog>>,
        interval: Duration,
    ) -> Result<CatalogSync> {
        Self::spawn_with(server_addr, catalog, interval, None)
    }

    /// [`CatalogSync::spawn`] plus a liveness [`HealthSink`]: every round's
    /// outcome doubles as a heartbeat (`HeartbeatOk` on a completed sync,
    /// `HeartbeatMiss` on a failed connect or round), so membership learns
    /// about reboots from the backoff probes this loop already makes — no
    /// extra connections, no extra cadence.
    pub fn spawn_with(
        server_addr: String,
        catalog: Arc<Mutex<LocalCatalog>>,
        interval: Duration,
        health: Option<HealthSink>,
    ) -> Result<CatalogSync> {
        Self::spawn_gossip(server_addr, catalog, interval, health, None)
    }

    /// [`CatalogSync::spawn_with`] plus SWIM-style gossip piggybacked on the
    /// same wire: after each successful sync round the loop swaps membership
    /// digests with the box (`GOSSIP`), merging the reply into the local
    /// [`Membership`] — one client's verdict reaches the fleet in
    /// O(sync-period) instead of every client re-paying its own strike
    /// budget.  Gossip failures are swallowed (an old box without the
    /// `GOSSIP` verb degrades to PR 6 per-client detection, never to a
    /// failed sync round).
    pub fn spawn_gossip(
        server_addr: String,
        catalog: Arc<Mutex<LocalCatalog>>,
        interval: Duration,
        health: Option<HealthSink>,
        gossip: Option<Arc<Membership>>,
    ) -> Result<CatalogSync> {
        Self::spawn_semantic(server_addr, catalog, interval, health, gossip, None)
    }

    /// [`CatalogSync::spawn_gossip`] plus the semantic tier's sketch
    /// sections: after a successful exact-catalog round the loop pulls
    /// `CAT.SDELTA` into the shared [`SketchTable`].  Like gossip, sketch
    /// pulls are best-effort — a legacy box without the verb answers with an
    /// error and the tier degrades to exact-only matching against that
    /// peer, never to a failed sync round.
    pub fn spawn_semantic(
        server_addr: String,
        catalog: Arc<Mutex<LocalCatalog>>,
        interval: Duration,
        health: Option<HealthSink>,
        gossip: Option<Arc<Membership>>,
        sketches: Option<Arc<Mutex<SketchTable>>>,
    ) -> Result<CatalogSync> {
        let stop = Arc::new(AtomicBool::new(false));
        let rounds = Arc::new(AtomicU64::new(0));
        let attempts = Arc::new(AtomicU64::new(0));
        let stop2 = Arc::clone(&stop);
        let rounds2 = Arc::clone(&rounds);
        let attempts2 = Arc::clone(&attempts);
        // jitter seeded from the peer address so each peer's loop drifts
        // differently but deterministically
        let mut jitter_rng = Rng::new(
            server_addr.bytes().fold(0x5CA1AB1Eu64, |h, b| {
                h.wrapping_mul(31).wrapping_add(b as u64)
            }),
        );
        let thread = std::thread::Builder::new()
            .name("catalog-sync".into())
            .spawn(move || {
                let mut conn: Option<KvClient> = None;
                let mut delay = interval;
                while !stop2.load(Ordering::SeqCst) {
                    attempts2.fetch_add(1, Ordering::SeqCst);
                    if conn.is_none() {
                        conn = KvClient::connect(&server_addr).ok();
                    }
                    let ok = match conn.as_mut() {
                        Some(c) => match Self::sync_once(c, &catalog) {
                            Ok(()) => {
                                if let Some(m) = &gossip {
                                    // best-effort: a box that predates the
                                    // GOSSIP verb answers with an error, not
                                    // a broken sync round
                                    let _ = Self::gossip_once(c, m);
                                }
                                if let Some(t) = &sketches {
                                    // same contract for sketch sections: a
                                    // legacy box degrades the tier, never
                                    // the round
                                    let _ = Self::sketch_once(c, t);
                                }
                                true
                            }
                            Err(e) => {
                                log_debug!(
                                    "catalog-sync",
                                    "round failed: {e}; reconnecting"
                                );
                                conn = None;
                                false
                            }
                        },
                        None => false,
                    };
                    if let Some(h) = &health {
                        h.report(if ok {
                            Outcome::HeartbeatOk
                        } else {
                            Outcome::HeartbeatMiss
                        });
                    }
                    if ok {
                        rounds2.fetch_add(1, Ordering::SeqCst);
                        delay = interval;
                    } else {
                        // exponential backoff with ±25 % jitter, the
                        // jittered result itself capped so MAX_BACKOFF is
                        // a true re-probe ceiling
                        let doubled = delay.saturating_mul(2).min(MAX_BACKOFF);
                        let jitter = 0.75 + 0.5 * jitter_rng.f64();
                        delay = doubled
                            .mul_f64(jitter)
                            .min(MAX_BACKOFF)
                            .max(interval);
                    }
                    // sleep in small steps so shutdown is prompt
                    let mut left = delay;
                    while !left.is_zero() && !stop2.load(Ordering::SeqCst) {
                        let step = left.min(Duration::from_millis(20));
                        std::thread::sleep(step);
                        left -= step;
                    }
                }
            })?;
        Ok(CatalogSync { stop, thread: Some(thread), rounds, attempts })
    }

    /// One digest exchange (also used synchronously in tests): push the
    /// local membership view, merge the box's blackboard reply.  Returns
    /// how many peer states the reply changed locally.
    pub fn gossip_once(conn: &mut KvClient, membership: &Membership) -> Result<usize> {
        let payload = membership.digest().encode();
        let reply = conn.gossip_exchange(&payload)?;
        match MembershipDigest::decode(&reply) {
            Some(d) => Ok(membership.apply_digest(&d)),
            // unparseable reply degrades to "no gossip this round"
            None => Ok(0),
        }
    }

    /// One sketch-section pull (also used synchronously in tests): fetch
    /// every section appended after the table's synced version and merge the
    /// decodable ones.  Returns how many sections arrived.
    pub fn sketch_once(conn: &mut KvClient, table: &Arc<Mutex<SketchTable>>) -> Result<usize> {
        let since = table.lock().unwrap().synced_version;
        let (ver, sections) = conn.sketch_delta(since)?;
        if ver <= since {
            return Ok(0);
        }
        let n = sections.len();
        table.lock().unwrap().apply_delta(ver, &sections);
        Ok(n)
    }

    /// One pull-merge round (also used synchronously in tests).
    pub fn sync_once(conn: &mut KvClient, catalog: &Arc<Mutex<LocalCatalog>>) -> Result<()> {
        let since = catalog.lock().unwrap().synced_version;
        let remote = conn.catalog_version()?;
        if remote <= since {
            return Ok(());
        }
        let (mut ver, mut keys) = conn.catalog_delta(since)?;
        loop {
            {
                let mut cat = catalog.lock().unwrap();
                cat.apply_delta(ver, &keys);
            }
            if ver >= remote {
                break;
            }
            let (v2, k2) = conn.catalog_delta(ver)?;
            ver = v2;
            keys = k2;
            if keys.is_empty() && ver >= remote {
                break;
            }
        }
        Ok(())
    }

    pub fn stop(mut self) {
        self.do_stop();
    }

    fn do_stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for CatalogSync {
    fn drop(&mut self) {
        self.do_stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cachebox::CacheBox;

    #[test]
    fn background_sync_propagates_keys() {
        let cb = CacheBox::start_local().unwrap();
        let catalog = Arc::new(Mutex::new(LocalCatalog::new()));
        let sync = CatalogSync::spawn(
            cb.addr(),
            Arc::clone(&catalog),
            Duration::from_millis(10),
        )
        .unwrap();

        // another client registers keys on the master
        let mut c = KvClient::connect(&cb.addr()).unwrap();
        c.catalog_register(b"remote-key-1").unwrap();
        c.catalog_register(b"remote-key-2").unwrap();

        // wait for the loop to pick them up
        let t0 = std::time::Instant::now();
        loop {
            {
                let cat = catalog.lock().unwrap();
                if cat.synced_version >= 2 {
                    assert!(cat.filter.contains(b"remote-key-1"));
                    assert!(cat.filter.contains(b"remote-key-2"));
                    break;
                }
            }
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "sync did not converge"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        sync.stop();
        cb.shutdown();
    }

    #[test]
    fn sync_once_is_incremental() {
        let cb = CacheBox::start_local().unwrap();
        let catalog = Arc::new(Mutex::new(LocalCatalog::new()));
        let mut reg = KvClient::connect(&cb.addr()).unwrap();
        let mut conn = KvClient::connect(&cb.addr()).unwrap();

        reg.catalog_register(b"k1").unwrap();
        CatalogSync::sync_once(&mut conn, &catalog).unwrap();
        assert_eq!(catalog.lock().unwrap().synced_version, 1);

        reg.catalog_register(b"k2").unwrap();
        CatalogSync::sync_once(&mut conn, &catalog).unwrap();
        let cat = catalog.lock().unwrap();
        assert_eq!(cat.synced_version, 2);
        assert!(cat.filter.contains(b"k1") && cat.filter.contains(b"k2"));
        drop(cat);

        // no-op round when nothing changed
        CatalogSync::sync_once(&mut conn, &catalog).unwrap();
        assert_eq!(catalog.lock().unwrap().synced_version, 2);
        cb.shutdown();
    }

    #[test]
    fn sync_survives_server_restart_cycle() {
        // server down -> loop keeps retrying without panicking
        let catalog = Arc::new(Mutex::new(LocalCatalog::new()));
        let sync = CatalogSync::spawn(
            "127.0.0.1:1".into(), // nothing listens here
            Arc::clone(&catalog),
            Duration::from_millis(5),
        )
        .unwrap();
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(catalog.lock().unwrap().synced_version, 0);
        sync.stop();
    }

    #[test]
    fn dead_peer_backoff_caps_attempt_rate() {
        use std::sync::atomic::Ordering;
        // a 1 ms interval against a dead port: without backoff the loop
        // would attempt hundreds of connects in 250 ms (loopback refusal is
        // immediate); with capped exponential backoff the delays double
        // (2, 4, 8, ... ms) so only a handful of attempts fit
        let catalog = Arc::new(Mutex::new(LocalCatalog::new()));
        let sync = CatalogSync::spawn(
            "127.0.0.1:1".into(),
            Arc::clone(&catalog),
            Duration::from_millis(1),
        )
        .unwrap();
        std::thread::sleep(Duration::from_millis(250));
        let attempts = sync.attempts.load(Ordering::SeqCst);
        assert!(attempts >= 2, "loop must keep retrying: {attempts}");
        assert!(
            attempts <= 20,
            "backoff must slow the retry spin: {attempts} attempts in 250 ms"
        );
        assert_eq!(sync.rounds.load(Ordering::SeqCst), 0);
        sync.stop();
    }

    #[test]
    fn gossip_round_converges_two_clients_through_one_box() {
        use crate::coordinator::membership::{HealthPolicy, Outcome, PeerHealth};
        // client A convicts peer "b" first-hand; one gossip round through a
        // shared box's blackboard and client B — which never probed "b" —
        // holds the same verdict.
        let cb = CacheBox::start_local().unwrap();
        let addrs = vec![cb.addr(), "10.9.9.9:1".to_string()];
        let ma = crate::coordinator::membership::Membership::with_addrs(
            addrs.clone(),
            HealthPolicy::default(),
        );
        let mb = crate::coordinator::membership::Membership::with_addrs(
            addrs,
            HealthPolicy::default(),
        );
        ma.report(1, Outcome::IoDead);
        assert_eq!(ma.state(1), PeerHealth::Dead);
        assert_eq!(mb.state(1), PeerHealth::Up);

        let mut ca = KvClient::connect(&cb.addr()).unwrap();
        let mut cbn = KvClient::connect(&cb.addr()).unwrap();
        CatalogSync::gossip_once(&mut ca, &ma).unwrap();
        let changed = CatalogSync::gossip_once(&mut cbn, &mb).unwrap();
        assert!(changed >= 1, "B must adopt A's verdict from the board");
        assert_eq!(mb.state(1), PeerHealth::Dead);
        // the box advertises itself Up on the same board, so neither client
        // ever flags it from gossip alone
        assert_eq!(ma.state(0), PeerHealth::Up);
        cb.shutdown();
    }

    #[test]
    fn backoff_resets_after_recovery() {
        use std::sync::atomic::Ordering;
        // against a live box the loop syncs at the plain interval: rounds
        // accumulate and attempts track them 1:1 (no failures, no backoff)
        let cb = CacheBox::start_local().unwrap();
        let catalog = Arc::new(Mutex::new(LocalCatalog::new()));
        let sync = CatalogSync::spawn(
            cb.addr(),
            Arc::clone(&catalog),
            Duration::from_millis(5),
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        while sync.rounds.load(Ordering::SeqCst) < 5 {
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "healthy peer must sync at interval rate"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        // compare only after the loop has fully stopped — mid-iteration the
        // attempt counter legitimately leads the round counter by one
        let rounds = Arc::clone(&sync.rounds);
        let attempts = Arc::clone(&sync.attempts);
        sync.stop();
        assert_eq!(
            rounds.load(Ordering::SeqCst),
            attempts.load(Ordering::SeqCst),
            "healthy rounds must not burn backoff attempts"
        );
        cb.shutdown();
    }
}
