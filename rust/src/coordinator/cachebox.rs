//! The *cache box* (Figure 1, middle node): a single process hosting the
//! prompt-cache keyspace and the master catalog.  The paper uses an
//! off-the-shelf Redis on a Raspberry Pi 5 16 GB; ours is the [`KvServer`]
//! substrate with a configurable memory budget.

use anyhow::Result;

use crate::kvstore::server::ServerHandle;
use crate::kvstore::{KvServer, ServeMode};

pub struct CacheBox {
    pub handle: ServerHandle,
}

impl CacheBox {
    /// Start a cache box on `addr` (`"127.0.0.1:0"` for an ephemeral port).
    /// `max_bytes` bounds the prompt-cache keyspace (the Pi 5 in the paper
    /// has 16 GB; eviction is exact-LRU).
    pub fn start(addr: &str, max_bytes: usize) -> Result<CacheBox> {
        Self::start_tuned(addr, max_bytes, 1, 0, ServeMode::Threads)
    }

    /// [`CacheBox::start`] with the serving-core knobs exposed: `shards`
    /// independent store shards under one fleet-consistent byte budget,
    /// `max_pending` admission slots (0 = unbounded; overflow is shed with
    /// `BUSY`), and the serving core (`ServeMode::Threads` per-connection
    /// threads, or `ServeMode::Poll` for the non-blocking readiness loop).
    pub fn start_tuned(
        addr: &str,
        max_bytes: usize,
        shards: usize,
        max_pending: usize,
        mode: ServeMode,
    ) -> Result<CacheBox> {
        let server = KvServer::configure(max_bytes, shards, max_pending);
        let handle = server.serve_with(addr, mode)?;
        Ok(CacheBox { handle })
    }

    /// Default-sized cache box on an ephemeral localhost port.
    pub fn start_local() -> Result<CacheBox> {
        Self::start("127.0.0.1:0", 14 << 30)
    }

    pub fn addr(&self) -> String {
        self.handle.addr_string()
    }

    pub fn stats(&self) -> (usize, usize, u64) {
        let s = &self.handle.server.store;
        (s.len(), s.used_bytes(), s.evictions())
    }

    /// Stored length of one entry (None when absent).  Does not refresh
    /// LRU — a pure inspection hook for tests and tooling; range aliases
    /// show up here as tiny (tens-of-bytes) entries next to the one real
    /// state blob per prompt.
    pub fn entry_len(&self, key: &[u8]) -> Option<usize> {
        self.handle.server.store.strlen(key)
    }

    /// Bytes currently held by the keyspace (`Store::used_bytes`).
    pub fn used_bytes(&self) -> usize {
        self.handle.server.store.used_bytes()
    }

    pub fn catalog_version(&self) -> u64 {
        self.handle.server.catalog.lock().unwrap().version()
    }

    pub fn shutdown(self) {
        self.handle.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::KvClient;

    #[test]
    fn start_query_shutdown() {
        let cb = CacheBox::start_local().unwrap();
        let mut c = KvClient::connect(&cb.addr()).unwrap();
        c.ping().unwrap();
        c.set(b"x", b"y").unwrap();
        let (keys, bytes, ev) = cb.stats();
        assert_eq!(keys, 1);
        assert!(bytes >= 2);
        assert_eq!(ev, 0);
        assert_eq!(cb.entry_len(b"x"), Some(1));
        assert_eq!(cb.entry_len(b"absent"), None);
        assert_eq!(cb.used_bytes(), bytes);
        assert_eq!(cb.catalog_version(), 0);
        cb.shutdown();
    }
}
