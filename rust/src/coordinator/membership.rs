//! Fleet liveness: a per-peer health state machine fed by heartbeats and
//! hot-path I/O outcomes.
//!
//! The fabric's failure handling used to be purely reactive — a peer was
//! only discovered dead when a hot-path read errored, and a dead-marked box
//! that rebooted was never rediscovered except by a lucky fallback probe.
//! [`Membership`] closes both gaps with one small state machine per peer:
//!
//! ```text
//!        ok                 failure                striking out
//!   Up ───────► Up     Up ───────────► Suspect ───────────────► Dead
//!                      ▲   (timeout /      │                      │
//!                      │    hb miss)       │ io dead              │ heartbeat ok
//!                      │                   ▼                      ▼
//!                      └──────────── proofs ≥ up_after       Recovering
//!                                                             │       │
//!                                            proofs ≥ recover_after   │ any failure
//!                                                             ▼       ▼
//!                                                             Up     Dead
//! ```
//!
//! Two signal sources feed [`Membership::report`] through [`HealthSink`]
//! handles:
//!
//! * **Heartbeats** piggybacked on the existing `CatalogSync` loop — every
//!   sync round doubles as a PING (no new connections), and a dead peer's
//!   backoff reconnect probes double as recovery detection.  A heartbeat is
//!   the **only** exit from `Dead`: hot-path success against a supposedly
//!   dead peer is treated as stale (`no Dead→Up without heartbeat`).
//! * **Hot-path I/O outcomes** reported by the fabric: a timeout
//!   (`WouldBlock`/`TimedOut` from an armed [`DeadlineBudget`]) is a
//!   *suspicion*, not a death — the box may just be slow — while a closed
//!   or reset connection is `IoDead`.
//!
//! Hysteresis damps flapping links: `Suspect` requires `up_after`
//! consecutive successes to climb back to `Up`, strikes survive interleaved
//! successes, and a flapper therefore ratchets toward `Dead` instead of
//! oscillating.  `Suspect` and `Recovering` peers still count as *alive*
//! (they stay in ring owner sets); only `Dead` drops a peer from placement.
//!
//! Every state change bumps a global [epoch](Membership::epoch) so callers
//! (e.g. `EdgeClient`) can cheaply invalidate memoized owner sets and call
//! `Placement::on_membership_change` exactly when the view shifted.
//!
//! # Gossip (SWIM-style fleet convergence)
//!
//! Per-client detection alone makes every client re-pay the full strike
//! budget for the same dead box.  The gossip layer fixes that with three
//! SWIM ingredients, carried on the wire the fleet already has (the
//! catalog-sync frames; see `CatalogSync` and the server's `GOSSIP`
//! command):
//!
//! * every peer view carries an **incarnation number**; views merge by the
//!   pure law in [`PeerView::merge`] — higher incarnation wins outright, at
//!   equal incarnation the more severe state wins (`Dead > Suspect >
//!   Recovering > Up`).  The law is commutative, idempotent and
//!   associative, so any delivery order of any digest set converges to the
//!   same view (property-tested in `tests/gossip_laws.rs`).
//! * **refutation**: a box that hears itself suspected/declared dead at
//!   incarnation `i` re-advertises `Up` at `i+1`, which out-competes the
//!   stale claim under the merge law.  On the client side, *first-hand*
//!   contact with the subject (a heal transition) bumps the local
//!   incarnation too — the evidence came from the subject answering, which
//!   is the subject's refutation by proxy.
//! * adopting a gossiped claim is **damped**: a second-hand non-`Dead`
//!   claim about a locally-`Dead` peer enters through `Recovering`
//!   probation, never straight to `Up` — the PR 6 invariant (`no Dead→Up
//!   without first-hand confirmation`) survives gossip.
//!
//! Before committing a *circumstantial* `Suspect → Dead` promotion (strike
//! budget exhausted by timeouts/missed heartbeats, not a reset socket), an
//! [`IndirectProbe`] asks a third peer to relay a reachability check; if
//! the subject answers the relay, the verdict is withheld and the strikes
//! reset — an asymmetric partition between one client and one box can no
//! longer kill that box fleet-wide.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Health of one peer as seen by this client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum PeerHealth {
    /// Healthy: full participant in placement and fetch planning.
    Up = 0,
    /// Recent timeout or missed heartbeat; still alive (still an owner),
    /// but one more strike sequence away from `Dead`.
    Suspect = 1,
    /// Out of the fleet: excluded from owner sets until a heartbeat lands.
    Dead = 2,
    /// A heartbeat reached a dead-marked peer; probation until
    /// `recover_after` consecutive successes confirm the reboot stuck.
    Recovering = 3,
}

impl PeerHealth {
    fn from_u8(v: u8) -> Self {
        match v {
            0 => PeerHealth::Up,
            1 => PeerHealth::Suspect,
            3 => PeerHealth::Recovering,
            _ => PeerHealth::Dead,
        }
    }
}

/// One observation about a peer, from either signal source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// A `CatalogSync` round (connect + delta fetch) succeeded.
    HeartbeatOk,
    /// A sync round failed — connect refused, reset, or sync error.
    HeartbeatMiss,
    /// A hot-path operation (fetch share, upload, probe) succeeded.
    IoOk,
    /// A hot-path operation hit its [`DeadlineBudget`]
    /// (`WouldBlock`/`TimedOut`): slow, not necessarily gone.
    IoTimeout,
    /// A hot-path operation found the connection dead (reset, EOF, refused).
    IoDead,
    /// The peer shed the operation with a `BUSY` reply (admission control):
    /// alive but saturated.  Health-neutral by design — striking an
    /// overloaded peer toward `Suspect`/`Dead` would amplify overload into
    /// false churn; the fabric instead treats it as a replan signal.
    Overloaded,
}

impl Outcome {
    fn is_success(self) -> bool {
        matches!(self, Outcome::HeartbeatOk | Outcome::IoOk)
    }
}

/// Hysteresis thresholds for the state machine.
#[derive(Debug, Clone)]
pub struct HealthPolicy {
    /// Strikes accumulated in `Suspect` before the peer is declared `Dead`.
    pub dead_after: u32,
    /// Consecutive successes in `Suspect` before the peer returns to `Up`.
    pub up_after: u32,
    /// Consecutive successes in `Recovering` before the reboot is trusted.
    pub recover_after: u32,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy { dead_after: 3, up_after: 2, recover_after: 2 }
    }
}

/// Per-operation socket deadlines for pooled fabric connections: `connect`
/// bounds the dial (`TcpStream::connect_timeout`), `op` arms
/// `set_read_timeout`/`set_write_timeout` so a *stalled* (accepted but
/// silent) peer costs at most one budget, never a hang.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeadlineBudget {
    pub connect: Duration,
    pub op: Duration,
}

impl DeadlineBudget {
    pub fn new(connect: Duration, op: Duration) -> Self {
        DeadlineBudget { connect, op }
    }

    pub fn from_millis(connect_ms: u64, op_ms: u64) -> Self {
        DeadlineBudget {
            connect: Duration::from_millis(connect_ms),
            op: Duration::from_millis(op_ms),
        }
    }

    /// Derive a per-op budget from the link model's expected transfer time
    /// for this op's byte size: `k ×` expected seconds, floored by the
    /// static budget (`--deadline-ms` stays a lower bound, never a fleet
    /// constant), and doubled while the peer is `Suspect` so a
    /// slow-but-alive box is not convicted by its own link model.
    /// `k <= 0` disables adaptation (the static budget passes through).
    pub fn adaptive(self, expected_s: f64, k: f64, widen: bool) -> DeadlineBudget {
        if k <= 0.0 || !expected_s.is_finite() || expected_s <= 0.0 {
            return self;
        }
        let mut op_s = (expected_s * k).max(self.op.as_secs_f64());
        if widen {
            op_s *= 2.0;
        }
        DeadlineBudget { connect: self.connect, op: Duration::from_secs_f64(op_s) }
    }
}

impl Default for DeadlineBudget {
    fn default() -> Self {
        // generous against the modelled Wi-Fi RTT (~270 ms/op) yet small
        // enough that a wedged restore rotates to a survivor within one
        // human-perceptible beat
        DeadlineBudget::from_millis(500, 2_000)
    }
}

/// Classify a failed peer operation: a timeout from an armed deadline is
/// [`Outcome::IoTimeout`] (→ `Suspect`), anything else is
/// [`Outcome::IoDead`] (→ `Dead`).  Walks the whole error chain so
/// `anyhow` context wrapping does not hide the underlying `io::Error`.
pub fn classify_io_err(e: &anyhow::Error) -> Outcome {
    for cause in e.chain() {
        // a shed op surfaces as a server error whose text carries the BUSY
        // prefix (`exec_req` wraps `Value::Error` into "server error: BUSY
        // ..."); it must classify as Overloaded before any io inspection —
        // the socket is healthy, the box is just saturated
        if cause.to_string().contains("BUSY") {
            return Outcome::Overloaded;
        }
        if let Some(io) = cause.downcast_ref::<std::io::Error>() {
            return match io.kind() {
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                    Outcome::IoTimeout
                }
                _ => Outcome::IoDead,
            };
        }
    }
    Outcome::IoDead
}

/// One peer's gossiped view: an incarnation number plus the claimed state.
/// This is the unit the SWIM merge law operates on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeerView {
    pub incarnation: u64,
    pub state: PeerHealth,
}

impl PeerView {
    pub fn new(incarnation: u64, state: PeerHealth) -> Self {
        PeerView { incarnation, state }
    }

    /// Claim severity at equal incarnation: `Dead > Suspect > Recovering >
    /// Up`.  More severe claims win ties because a false death is refutable
    /// (bump the incarnation) while a suppressed death is not.
    pub fn severity(state: PeerHealth) -> u8 {
        match state {
            PeerHealth::Up => 0,
            PeerHealth::Recovering => 1,
            PeerHealth::Suspect => 2,
            PeerHealth::Dead => 3,
        }
    }

    /// The SWIM merge law: lexicographic max over `(incarnation,
    /// severity)`.  Pure, total, commutative, idempotent and associative —
    /// `tests/gossip_laws.rs` proves all three across seeded delivery
    /// orders, which is what makes fleet views *converge* rather than
    /// merely change.
    pub fn merge(a: PeerView, b: PeerView) -> PeerView {
        let ka = (a.incarnation, Self::severity(a.state));
        let kb = (b.incarnation, Self::severity(b.state));
        if kb > ka {
            b
        } else {
            a
        }
    }
}

/// A compact, addr-keyed snapshot of one node's membership view — the
/// payload piggybacked on catalog-sync frames (`GOSSIP` command).  Keys are
/// canonical peer addresses (not peer-table indices) so digests align
/// across clients whose peer tables list the fleet in different orders.
///
/// Wire form is line-based text: a `G1 <epoch>` header, then one
/// `<addr> <incarnation> <state-u8>` line per peer.  Addresses are
/// host:port strings and never contain whitespace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MembershipDigest {
    /// The sender's view epoch at encode time (freshness hint only; the
    /// merge law itself is epoch-free).
    pub epoch: u64,
    /// Sorted by address so encoding is canonical.
    entries: Vec<(String, PeerView)>,
}

impl MembershipDigest {
    pub fn new(epoch: u64) -> Self {
        MembershipDigest { epoch, entries: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, addr: &str) -> Option<PeerView> {
        self.entries
            .binary_search_by(|(a, _)| a.as_str().cmp(addr))
            .ok()
            .map(|i| self.entries[i].1)
    }

    /// Upsert through the merge law: an existing entry for `addr` is
    /// merged, a new one inserted (keeping the sort).
    pub fn merge_entry(&mut self, addr: &str, view: PeerView) {
        match self.entries.binary_search_by(|(a, _)| a.as_str().cmp(addr)) {
            Ok(i) => self.entries[i].1 = PeerView::merge(self.entries[i].1, view),
            Err(i) => self.entries.insert(i, (addr.to_string(), view)),
        }
    }

    /// Merge every entry of `other` into `self` (set union under
    /// [`PeerView::merge`]); epochs take the max.
    pub fn merge_from(&mut self, other: &MembershipDigest) {
        self.epoch = self.epoch.max(other.epoch);
        for (addr, view) in &other.entries {
            self.merge_entry(addr, *view);
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, PeerView)> {
        self.entries.iter().map(|(a, v)| (a.as_str(), *v))
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = format!("G1 {}\n", self.epoch);
        for (addr, v) in &self.entries {
            out.push_str(&format!("{addr} {} {}\n", v.incarnation, v.state as u8));
        }
        out.into_bytes()
    }

    /// Parse a wire digest; `None` on any malformed header/line so a
    /// corrupted frame degrades to "no gossip this round", never to a
    /// poisoned view.
    pub fn decode(bytes: &[u8]) -> Option<MembershipDigest> {
        let text = std::str::from_utf8(bytes).ok()?;
        let mut lines = text.lines();
        let header = lines.next()?;
        let epoch = header.strip_prefix("G1 ")?.trim().parse::<u64>().ok()?;
        let mut d = MembershipDigest::new(epoch);
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let addr = parts.next()?;
            let inc = parts.next()?.parse::<u64>().ok()?;
            let st = parts.next()?.parse::<u8>().ok()?;
            if parts.next().is_some() {
                return None;
            }
            d.merge_entry(addr, PeerView::new(inc, PeerHealth::from_u8(st)));
        }
        Some(d)
    }
}

/// Relay a reachability check for `target` through third-party peers —
/// the network half of the indirect-probe rule, kept behind a trait so
/// [`Membership`] itself stays free of sockets.  `via` holds candidate
/// relay peer indices (already filtered to `Up`, already rotated for
/// variety); implementations try them in order.
///
/// Returns `Some(true)` if any relay reached the target, `Some(false)` if
/// a relay answered definitively "unreachable", and `None` if no relay
/// could be consulted at all (no route ≠ proof of death, but it cannot
/// block the verdict either — SWIM commits in that case).
pub trait IndirectProbe: Send + Sync {
    fn probe_via(&self, via: &[usize], target: usize) -> Option<bool>;
}

/// The pure transition function — `(state, strikes, proofs) × input →
/// (state, strikes, proofs)`.  Kept free of clocks and I/O so the property
/// tests can drive it with seeded input streams and assert determinism.
///
/// Invariants the tests pin:
/// * `Dead` exits **only** on `HeartbeatOk` (into `Recovering`).
/// * Strikes survive interleaved successes in `Suspect`, so an
///   alternating flapper ratchets to `Dead` instead of oscillating.
/// * Both counters reset on every state change.
pub fn step(
    state: PeerHealth,
    strikes: u32,
    proofs: u32,
    input: Outcome,
    policy: &HealthPolicy,
) -> (PeerHealth, u32, u32) {
    use Outcome::*;
    use PeerHealth::*;
    match state {
        Up => match input {
            HeartbeatOk | IoOk => (Up, 0, 0),
            HeartbeatMiss | IoTimeout => (Suspect, 1, 0),
            IoDead => (Dead, 0, 0),
            // shed load is health-neutral: alive, just saturated
            Overloaded => (Up, strikes, proofs),
        },
        Suspect => match input {
            HeartbeatOk | IoOk => {
                if proofs + 1 >= policy.up_after {
                    (Up, 0, 0)
                } else {
                    // strikes deliberately kept: the hysteresis memory
                    (Suspect, strikes, proofs + 1)
                }
            }
            HeartbeatMiss | IoTimeout => {
                if strikes + 1 >= policy.dead_after {
                    (Dead, 0, 0)
                } else {
                    (Suspect, strikes + 1, 0)
                }
            }
            IoDead => (Dead, 0, 0),
            // neither a strike nor an exonerating proof: BUSY says nothing
            // about whether the suspicion was deserved
            Overloaded => (Suspect, strikes, proofs),
        },
        Dead => match input {
            // the only way out of Dead: a heartbeat (sync-loop probe)
            HeartbeatOk => {
                if policy.recover_after <= 1 {
                    (Up, 0, 0)
                } else {
                    (Recovering, 0, 1)
                }
            }
            _ => (Dead, 0, 0),
        },
        Recovering => match input {
            HeartbeatOk | IoOk => {
                if proofs + 1 >= policy.recover_after {
                    (Up, 0, 0)
                } else {
                    (Recovering, 0, proofs + 1)
                }
            }
            // probation is strict: any failure sends the peer straight back
            HeartbeatMiss | IoTimeout | IoDead => (Dead, 0, 0),
            // but shed load is not a failure — probation neither advances
            // nor resets on a box that answered (with BUSY) at all
            Overloaded => (Recovering, 0, proofs),
        },
    }
}

#[derive(Debug)]
struct Cell {
    state: PeerHealth,
    strikes: u32,
    proofs: u32,
}

/// Per-peer counters surfaced into `PeerLedger` at stats time.
#[derive(Debug, Default, Clone, Copy)]
pub struct PeerCounters {
    /// Successful heartbeats observed (sync rounds that completed).
    pub heartbeats: u64,
    /// `Dead → Recovering` transitions: a rebooted box rediscovered.
    pub heals: u64,
    /// Deadline-budget expiries (`IoTimeout` reports) on the hot path.
    pub timeouts: u64,
}

/// Fleet-wide liveness view shared (via `Arc`) between the client, its
/// per-peer `CatalogSync` threads and the fabric's fetch workers.
///
/// Transitions run under one tiny per-peer mutex; reads
/// ([`Membership::alive`], [`Membership::state`]) go through lock-free
/// atomic mirrors so the hot path never contends with a heartbeat.
pub struct Membership {
    cells: Vec<Mutex<Cell>>,
    /// Lock-free mirror of each cell's state (`PeerHealth as u8`).
    states: Vec<AtomicU8>,
    /// Bumped on every state change; compare-and-refresh cheaply.
    epoch: AtomicU64,
    policy: HealthPolicy,
    /// Canonical gossip identity per peer, index-aligned with `cells`.
    /// Placeholder `#i` names when constructed without addresses — digests
    /// only travel between nodes that share real addresses.
    addrs: Vec<String>,
    /// Per-peer incarnation numbers (the SWIM refutation counter).
    incs: Vec<AtomicU64>,
    /// Indirect-probe hook: `(prober, max relays per verdict)`.
    prober: Mutex<Option<(Arc<dyn IndirectProbe>, usize)>>,
    /// Round-robin cursor rotating which `Up` peer relays first.
    probe_rr: AtomicU64,
    per_heartbeats: Vec<AtomicU64>,
    per_heals: Vec<AtomicU64>,
    per_timeouts: Vec<AtomicU64>,
    suspects: AtomicU64,
    deaths: AtomicU64,
    heals: AtomicU64,
    recoveries: AtomicU64,
    gossip_adoptions: AtomicU64,
    refutations: AtomicU64,
    indirect_probes: AtomicU64,
    probe_saves: AtomicU64,
}

impl std::fmt::Debug for Membership {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Membership")
            .field("peers", &self.addrs)
            .field("epoch", &self.epoch)
            .finish_non_exhaustive()
    }
}

impl Membership {
    pub fn new(n_peers: usize, policy: HealthPolicy) -> Arc<Self> {
        Self::with_addrs((0..n_peers).map(|i| format!("#{i}")).collect(), policy)
    }

    /// Construct with canonical per-peer gossip addresses (what
    /// `EdgeClient` does) so emitted digests carry fleet-meaningful keys.
    pub fn with_addrs(addrs: Vec<String>, policy: HealthPolicy) -> Arc<Self> {
        let n_peers = addrs.len();
        let mk_cells = || {
            (0..n_peers)
                .map(|_| Mutex::new(Cell { state: PeerHealth::Up, strikes: 0, proofs: 0 }))
                .collect()
        };
        let mk_u64s = || (0..n_peers).map(|_| AtomicU64::new(0)).collect();
        Arc::new(Membership {
            cells: mk_cells(),
            states: (0..n_peers).map(|_| AtomicU8::new(PeerHealth::Up as u8)).collect(),
            epoch: AtomicU64::new(0),
            policy,
            addrs,
            incs: mk_u64s(),
            prober: Mutex::new(None),
            probe_rr: AtomicU64::new(0),
            per_heartbeats: mk_u64s(),
            per_heals: mk_u64s(),
            per_timeouts: mk_u64s(),
            suspects: AtomicU64::new(0),
            deaths: AtomicU64::new(0),
            heals: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
            gossip_adoptions: AtomicU64::new(0),
            refutations: AtomicU64::new(0),
            indirect_probes: AtomicU64::new(0),
            probe_saves: AtomicU64::new(0),
        })
    }

    /// Register the indirect-probe relay used before circumstantial
    /// `Suspect → Dead` verdicts; `max_vias = 0` unregisters (verdicts
    /// commit directly, the PR 6 behaviour).
    pub fn set_prober(&self, prober: Arc<dyn IndirectProbe>, max_vias: usize) {
        let mut p = self.prober.lock().unwrap();
        *p = (max_vias > 0).then_some((prober, max_vias));
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// A cloneable per-peer reporting handle for sync loops and fabric
    /// workers.
    pub fn sink(self: &Arc<Self>, peer: usize) -> HealthSink {
        HealthSink { membership: Arc::clone(self), peer }
    }

    /// Apply a transition to a locked cell: mirror store, transition
    /// counters, epoch bump — the one place state changes become visible.
    /// Callers hold the cell lock.
    fn commit(
        &self,
        peer: usize,
        c: &mut Cell,
        next: PeerHealth,
        strikes: u32,
        proofs: u32,
    ) -> PeerHealth {
        let old = c.state;
        c.state = next;
        c.strikes = strikes;
        c.proofs = proofs;
        if next != old {
            self.states[peer].store(next as u8, Ordering::Release);
            match next {
                PeerHealth::Suspect => {
                    self.suspects.fetch_add(1, Ordering::Relaxed);
                }
                PeerHealth::Dead => {
                    self.deaths.fetch_add(1, Ordering::Relaxed);
                }
                PeerHealth::Recovering => {
                    // only reachable from Dead: a heal
                    self.heals.fetch_add(1, Ordering::Relaxed);
                    self.per_heals[peer].fetch_add(1, Ordering::Relaxed);
                }
                PeerHealth::Up => {
                    if old == PeerHealth::Recovering {
                        self.recoveries.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            // first-hand contact with the subject refutes stale suspicion:
            // a heal bumps the incarnation so the refreshed view wins the
            // merge against any gossiped claim at the old incarnation
            let healed = matches!(
                (old, next),
                (PeerHealth::Suspect, PeerHealth::Up)
                    | (PeerHealth::Dead, PeerHealth::Recovering)
                    | (PeerHealth::Recovering, PeerHealth::Up)
            );
            if healed {
                self.incs[peer].fetch_add(1, Ordering::Relaxed);
            }
            // bumped last so an epoch-triggered refresh reads the new state
            self.epoch.fetch_add(1, Ordering::Release);
        }
        next
    }

    /// Feed one observation through the state machine; returns the
    /// (possibly unchanged) resulting state.
    ///
    /// A *circumstantial* `Suspect → Dead` promotion — the strike budget
    /// exhausted by timeouts/missed heartbeats rather than a reset socket
    /// — is held for an [`IndirectProbe`] when one is registered: if a
    /// third peer can still reach the subject, the verdict is withheld and
    /// the strikes reset (an asymmetric partition, not a death).  `IoDead`
    /// stays conclusive and commits without a probe.
    pub fn report(&self, peer: usize, input: Outcome) -> PeerHealth {
        let Some(cell) = self.cells.get(peer) else {
            return PeerHealth::Dead;
        };
        match input {
            Outcome::HeartbeatOk => {
                self.per_heartbeats[peer].fetch_add(1, Ordering::Relaxed);
            }
            Outcome::IoTimeout => {
                self.per_timeouts[peer].fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
        let mut c = cell.lock().unwrap();
        let (next, strikes, proofs) =
            step(c.state, c.strikes, c.proofs, input, &self.policy);

        let circumstantial = matches!(input, Outcome::IoTimeout | Outcome::HeartbeatMiss);
        if next == PeerHealth::Dead && c.state == PeerHealth::Suspect && circumstantial {
            let hook = self.prober.lock().unwrap().clone();
            if let Some((prober, max_vias)) = hook {
                // probe with no membership locks held: the relay does real
                // socket I/O and may itself report outcomes
                drop(c);
                let vias = self.relay_candidates(peer, max_vias);
                self.indirect_probes.fetch_add(1, Ordering::Relaxed);
                let reachable = prober.probe_via(&vias, peer) == Some(true);
                let mut c = cell.lock().unwrap();
                if c.state != PeerHealth::Suspect {
                    // raced with a heal or another verdict while unlocked
                    return c.state;
                }
                if reachable {
                    // the subject answered a third peer: withhold the
                    // verdict, clear the strike budget, count the save
                    self.probe_saves.fetch_add(1, Ordering::Relaxed);
                    self.refutations.fetch_add(1, Ordering::Relaxed);
                    return self.commit(peer, &mut c, PeerHealth::Suspect, 0, 0);
                }
                return self.commit(peer, &mut c, PeerHealth::Dead, 0, 0);
            }
        }
        self.commit(peer, &mut c, next, strikes, proofs)
    }

    /// `Up` peers other than `target`, rotated by a round-robin cursor so
    /// successive verdicts consult different relays, truncated to
    /// `max_vias`.
    fn relay_candidates(&self, target: usize, max_vias: usize) -> Vec<usize> {
        let ups: Vec<usize> = (0..self.len())
            .filter(|&i| i != target && self.state(i) == PeerHealth::Up)
            .collect();
        if ups.is_empty() {
            return ups;
        }
        let start = self.probe_rr.fetch_add(1, Ordering::Relaxed) as usize % ups.len();
        let mut rotated: Vec<usize> = ups[start..].to_vec();
        rotated.extend_from_slice(&ups[..start]);
        rotated.truncate(max_vias);
        rotated
    }

    pub fn state(&self, peer: usize) -> PeerHealth {
        self.states
            .get(peer)
            .map(|s| PeerHealth::from_u8(s.load(Ordering::Acquire)))
            .unwrap_or(PeerHealth::Dead)
    }

    /// Alive = participates in placement. `Suspect` and `Recovering` stay
    /// in owner sets — only `Dead` is excluded.
    pub fn alive(&self, peer: usize) -> bool {
        self.state(peer) != PeerHealth::Dead
    }

    /// The placement view: one flag per peer, index-aligned with the
    /// client's peer table.
    pub fn alive_flags(&self) -> Vec<bool> {
        (0..self.len()).map(|i| self.alive(i)).collect()
    }

    /// Monotone view version: changes iff some peer changed state.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// The peer's current incarnation (the SWIM refutation counter; bumps
    /// on first-hand heals and on gossip adoptions of higher incarnations).
    pub fn incarnation(&self, peer: usize) -> u64 {
        self.incs.get(peer).map(|i| i.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// The canonical gossip identity for `peer` (a placeholder `#i` when
    /// constructed without addresses).
    pub fn addr(&self, peer: usize) -> &str {
        &self.addrs[peer]
    }

    fn peer_index(&self, addr: &str) -> Option<usize> {
        self.addrs.iter().position(|a| a == addr)
    }

    /// Snapshot the local view as an addr-keyed digest, ready to
    /// piggyback on the next catalog-sync frame.
    pub fn digest(&self) -> MembershipDigest {
        let mut d = MembershipDigest::new(self.epoch());
        for i in 0..self.len() {
            d.merge_entry(&self.addrs[i], PeerView::new(self.incarnation(i), self.state(i)));
        }
        d
    }

    /// Merge a gossiped digest into the local view; returns how many peers
    /// changed state.  Per entry the merge law decides, then adoption is
    /// damped: second-hand non-`Dead` evidence about a locally-`Dead` peer
    /// enters through `Recovering` probation (the PR 6 `no Dead→Up without
    /// first-hand confirmation` invariant survives gossip).  A gossiped
    /// `Dead` adopts directly — the remote verdict already passed *its*
    /// indirect probe, and re-probing at every hop would reintroduce the
    /// per-client detection latency gossip exists to remove.
    pub fn apply_digest(&self, d: &MembershipDigest) -> usize {
        let mut adopted = 0;
        for (addr, remote) in d.iter() {
            let Some(i) = self.peer_index(addr) else { continue };
            let mut c = self.cells[i].lock().unwrap();
            let local = PeerView::new(self.incs[i].load(Ordering::Relaxed), c.state);
            let merged = PeerView::merge(local, remote);
            if merged == local {
                continue;
            }
            if PeerView::severity(merged.state) < PeerView::severity(local.state) {
                // a higher-incarnation, less-severe claim: stale local
                // suspicion refuted through gossip
                self.refutations.fetch_add(1, Ordering::Relaxed);
            }
            let adopt = if local.state == PeerHealth::Dead && merged.state != PeerHealth::Dead
            {
                PeerHealth::Recovering
            } else {
                merged.state
            };
            if adopt != local.state {
                adopted += 1;
                self.gossip_adoptions.fetch_add(1, Ordering::Relaxed);
                self.commit(i, &mut c, adopt, 0, 0);
            }
            // after commit: the merged incarnation is authoritative, even
            // over commit's own first-hand heal bump
            self.incs[i].store(merged.incarnation, Ordering::Relaxed);
        }
        adopted
    }

    /// Peers whose state changed because of a gossiped digest.
    pub fn gossip_adoptions(&self) -> u64 {
        self.gossip_adoptions.load(Ordering::Relaxed)
    }

    /// Stale suspicions overturned — by a higher-incarnation gossip claim
    /// or by an indirect probe reaching the subject.
    pub fn refutations(&self) -> u64 {
        self.refutations.load(Ordering::Relaxed)
    }

    /// Indirect probes attempted before circumstantial death verdicts.
    pub fn indirect_probes(&self) -> u64 {
        self.indirect_probes.load(Ordering::Relaxed)
    }

    /// Death verdicts withheld because a relay still reached the subject.
    pub fn probe_saves(&self) -> u64 {
        self.probe_saves.load(Ordering::Relaxed)
    }

    pub fn peer_counters(&self, peer: usize) -> PeerCounters {
        PeerCounters {
            heartbeats: self.per_heartbeats[peer].load(Ordering::Relaxed),
            heals: self.per_heals[peer].load(Ordering::Relaxed),
            timeouts: self.per_timeouts[peer].load(Ordering::Relaxed),
        }
    }

    /// Total `* → Suspect` transitions.
    pub fn suspect_transitions(&self) -> u64 {
        self.suspects.load(Ordering::Relaxed)
    }

    /// Total `* → Dead` transitions.
    pub fn deaths(&self) -> u64 {
        self.deaths.load(Ordering::Relaxed)
    }

    /// Total `Dead → Recovering` transitions (rebooted boxes rediscovered).
    pub fn heals(&self) -> u64 {
        self.heals.load(Ordering::Relaxed)
    }

    /// Total `Recovering → Up` transitions (reboots that stuck).
    pub fn recoveries(&self) -> u64 {
        self.recoveries.load(Ordering::Relaxed)
    }

    /// Total deadline-budget expiries across the fleet.
    pub fn timeouts(&self) -> u64 {
        self.per_timeouts
            .iter()
            .map(|t| t.load(Ordering::Relaxed))
            .sum()
    }
}

/// A cheap cloneable handle binding one peer index to the shared
/// [`Membership`]; handed to `CatalogSync` threads and fabric workers so
/// they can report without knowing the peer table.
#[derive(Debug, Clone)]
pub struct HealthSink {
    membership: Arc<Membership>,
    peer: usize,
}

impl HealthSink {
    pub fn report(&self, input: Outcome) -> PeerHealth {
        self.membership.report(self.peer, input)
    }

    pub fn peer(&self) -> usize {
        self.peer
    }

    /// The bound peer's current state (lock-free mirror read) — what the
    /// adaptive deadline derivation keys its `Suspect` widening on.
    pub fn state(&self) -> PeerHealth {
        self.membership.state(self.peer)
    }

    /// The shared fleet view this sink reports into.
    pub fn membership(&self) -> &Arc<Membership> {
        &self.membership
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn policy() -> HealthPolicy {
        HealthPolicy::default()
    }

    fn draw_outcome(r: &mut Rng) -> Outcome {
        match r.below(5) {
            0 => Outcome::HeartbeatOk,
            1 => Outcome::HeartbeatMiss,
            2 => Outcome::IoOk,
            3 => Outcome::IoTimeout,
            _ => Outcome::IoDead,
        }
    }

    #[test]
    fn step_is_deterministic_over_seeded_streams() {
        for seed in [1u64, 7, 42, 1234] {
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let (mut a, mut b) =
                ((PeerHealth::Up, 0u32, 0u32), (PeerHealth::Up, 0u32, 0u32));
            for _ in 0..500 {
                let (o1, o2) = (draw_outcome(&mut r1), draw_outcome(&mut r2));
                assert_eq!(o1, o2);
                a = step(a.0, a.1, a.2, o1, &policy());
                b = step(b.0, b.1, b.2, o2, &policy());
                assert_eq!(a, b, "same seed must walk the same trajectory");
            }
        }
    }

    #[test]
    fn no_dead_to_up_without_heartbeat() {
        // property: from Dead, any input stream *without* HeartbeatOk stays
        // Dead forever — hot-path successes against a dead peer are stale
        let mut r = Rng::new(99);
        let non_heartbeat = [
            Outcome::HeartbeatMiss,
            Outcome::IoOk,
            Outcome::IoTimeout,
            Outcome::IoDead,
        ];
        let mut st = (PeerHealth::Dead, 0u32, 0u32);
        for _ in 0..1000 {
            let o = non_heartbeat[r.below(4) as usize];
            st = step(st.0, st.1, st.2, o, &policy());
            assert_eq!(st.0, PeerHealth::Dead, "only a heartbeat may revive");
        }
        // and the heartbeat path goes through Recovering, never straight Up
        let (s, ..) = step(PeerHealth::Dead, 0, 0, Outcome::HeartbeatOk, &policy());
        assert_eq!(s, PeerHealth::Recovering);
    }

    #[test]
    fn flapping_peer_is_damped_not_oscillating() {
        // alternate failure/success: strikes survive the interleaved
        // successes (up_after=2 never reached consecutively), so the peer
        // never bounces back to Up and instead ratchets to Dead
        let mut st = (PeerHealth::Up, 0u32, 0u32);
        let mut seen_up_again = false;
        for i in 0..2 * policy().dead_after {
            let o = if i % 2 == 0 { Outcome::IoTimeout } else { Outcome::IoOk };
            st = step(st.0, st.1, st.2, o, &policy());
            if st.0 == PeerHealth::Up {
                seen_up_again = true;
            }
        }
        assert!(!seen_up_again, "hysteresis must hold the flapper in Suspect");
        assert_eq!(st.0, PeerHealth::Dead, "a persistent flapper strikes out");
    }

    #[test]
    fn suspect_recovers_after_consecutive_successes() {
        let p = policy();
        let mut st = step(PeerHealth::Up, 0, 0, Outcome::IoTimeout, &p);
        assert_eq!(st.0, PeerHealth::Suspect);
        for _ in 0..p.up_after {
            st = step(st.0, st.1, st.2, Outcome::IoOk, &p);
        }
        assert_eq!(st.0, PeerHealth::Up, "consecutive successes must heal");
    }

    #[test]
    fn recovery_probation_is_strict() {
        let p = policy();
        let st = step(PeerHealth::Dead, 0, 0, Outcome::HeartbeatOk, &p);
        assert_eq!(st.0, PeerHealth::Recovering);
        // one failure during probation → straight back to Dead
        let back = step(st.0, st.1, st.2, Outcome::IoTimeout, &p);
        assert_eq!(back.0, PeerHealth::Dead);
        // enough consecutive proof → Up
        let mut ok = st;
        for _ in 0..p.recover_after {
            ok = step(ok.0, ok.1, ok.2, Outcome::HeartbeatOk, &p);
        }
        assert_eq!(ok.0, PeerHealth::Up);
    }

    #[test]
    fn io_dead_kills_immediately_timeout_only_suspects() {
        let p = policy();
        let (s, ..) = step(PeerHealth::Up, 0, 0, Outcome::IoDead, &p);
        assert_eq!(s, PeerHealth::Dead, "a closed connection is conclusive");
        let (s, ..) = step(PeerHealth::Up, 0, 0, Outcome::IoTimeout, &p);
        assert_eq!(s, PeerHealth::Suspect, "a deadline expiry is only a hint");
    }

    #[test]
    fn membership_epoch_and_counters_track_transitions() {
        let m = Membership::new(2, HealthPolicy::default());
        assert_eq!(m.epoch(), 0);
        assert!(m.alive(0) && m.alive(1));

        // peer 0: time out → Suspect (epoch bump, suspect counted)
        assert_eq!(m.report(0, Outcome::IoTimeout), PeerHealth::Suspect);
        let e1 = m.epoch();
        assert!(e1 > 0);
        assert_eq!(m.suspect_transitions(), 1);
        assert!(m.alive(0), "Suspect still counts as alive");
        assert_eq!(m.peer_counters(0).timeouts, 1);

        // a success without reaching up_after: no state change, no bump
        m.report(0, Outcome::IoOk);
        assert_eq!(m.epoch(), e1);

        // peer 1 dies, then a heartbeat heals it through Recovering
        assert_eq!(m.report(1, Outcome::IoDead), PeerHealth::Dead);
        assert!(!m.alive(1));
        assert_eq!(m.alive_flags(), vec![true, false]);
        assert_eq!(m.deaths(), 1);
        assert_eq!(m.report(1, Outcome::HeartbeatOk), PeerHealth::Recovering);
        assert_eq!(m.heals(), 1);
        assert_eq!(m.peer_counters(1).heals, 1);
        assert!(m.alive(1), "Recovering rejoins the owner sets");
        assert_eq!(m.report(1, Outcome::HeartbeatOk), PeerHealth::Up);
        assert_eq!(m.recoveries(), 1);
        assert_eq!(m.peer_counters(1).heartbeats, 2);

        // sinks report through the same shared view
        let sink = m.sink(0);
        assert_eq!(sink.peer(), 0);
        sink.report(Outcome::IoOk);
        assert_eq!(m.state(0), PeerHealth::Up);
    }

    #[test]
    fn out_of_range_peer_is_dead_and_ignored() {
        let m = Membership::new(1, HealthPolicy::default());
        assert_eq!(m.report(7, Outcome::IoOk), PeerHealth::Dead);
        assert!(!m.alive(7));
        assert_eq!(m.epoch(), 0);
    }

    #[test]
    fn classify_io_errors() {
        use std::io::{Error, ErrorKind};
        let timeout: anyhow::Error =
            anyhow::Error::new(Error::new(ErrorKind::TimedOut, "slow"))
                .context("fetch share");
        assert_eq!(classify_io_err(&timeout), Outcome::IoTimeout);
        let would_block: anyhow::Error =
            Error::new(ErrorKind::WouldBlock, "armed deadline").into();
        assert_eq!(classify_io_err(&would_block), Outcome::IoTimeout);
        let reset: anyhow::Error =
            anyhow::Error::new(Error::new(ErrorKind::ConnectionReset, "gone"))
                .context("ctx");
        assert_eq!(classify_io_err(&reset), Outcome::IoDead);
        let plain = anyhow::anyhow!("not an io error at all");
        assert_eq!(classify_io_err(&plain), Outcome::IoDead);
    }

    #[test]
    fn busy_replies_classify_as_overloaded_not_a_strike() {
        // the shape exec_req produces for a BUSY error reply, with context
        let busy: anyhow::Error =
            anyhow::anyhow!("server error: BUSY server queue full").context("fetch share");
        assert_eq!(classify_io_err(&busy), Outcome::Overloaded);
        // context layers above the BUSY text must not hide it
        let wrapped = anyhow::anyhow!("server error: BUSY server queue full")
            .context("stripe 2")
            .context("while reading reply");
        assert_eq!(classify_io_err(&wrapped), Outcome::Overloaded);
    }

    #[test]
    fn overloaded_is_health_neutral_in_every_state() {
        let p = policy();
        use PeerHealth::*;
        // no state moves, no counters move — shed load is not evidence
        for (state, strikes, proofs) in
            [(Up, 0, 0), (Suspect, 1, 1), (Dead, 0, 0), (Recovering, 0, 1)]
        {
            let (s2, k2, f2) = step(state, strikes, proofs, Outcome::Overloaded, &p);
            assert_eq!(s2, state, "{state:?} must not transition on BUSY");
            if state != Dead {
                assert_eq!((k2, f2), (strikes, proofs), "{state:?} counters frozen");
            }
        }
        // a Suspect peer one strike from Dead survives any number of BUSYs
        let mut st = (Suspect, p.dead_after - 1, 0);
        for _ in 0..10 {
            st = step(st.0, st.1, st.2, Outcome::Overloaded, &p);
        }
        assert_eq!(st.0, Suspect, "BUSY storm must never promote to Dead");

        // and through Membership::report: no epoch bump, no transitions
        let m = Membership::new(1, p);
        let e0 = m.epoch();
        assert_eq!(m.report(0, Outcome::Overloaded), Up);
        assert_eq!(m.epoch(), e0);
        assert_eq!(m.suspect_transitions(), 0);
        assert_eq!(m.deaths(), 0);
    }

    #[test]
    fn default_budget_sane() {
        let b = DeadlineBudget::default();
        assert!(b.connect >= Duration::from_millis(100));
        assert!(b.op >= b.connect);
        let c = DeadlineBudget::from_millis(100, 250);
        assert_eq!(c.connect, Duration::from_millis(100));
        assert_eq!(c.op, Duration::from_millis(250));
    }

    #[test]
    fn adaptive_budget_floors_scales_and_widens() {
        let b = DeadlineBudget::from_millis(100, 300);
        // k=0 disables: the static budget passes through untouched
        assert_eq!(b.adaptive(10.0, 0.0, false), b);
        // a fast op stays floored at the static budget
        assert_eq!(b.adaptive(0.001, 3.0, false).op, Duration::from_millis(300));
        // a slow-link op scales to k x expected
        let slow = b.adaptive(1.0, 3.0, false);
        assert_eq!(slow.op, Duration::from_secs_f64(3.0));
        assert_eq!(slow.connect, b.connect, "connect budget is not adaptive");
        // Suspect widens by 2x so a slow-but-alive peer is not convicted
        assert_eq!(b.adaptive(1.0, 3.0, true).op, Duration::from_secs_f64(6.0));
        // garbage expected times degrade to the static budget
        assert_eq!(b.adaptive(f64::NAN, 3.0, false), b);
    }

    #[test]
    fn digest_roundtrips_and_rejects_garbage() {
        let mut d = MembershipDigest::new(7);
        d.merge_entry("127.0.0.1:9001", PeerView::new(2, PeerHealth::Suspect));
        d.merge_entry("127.0.0.1:9000", PeerView::new(0, PeerHealth::Up));
        d.merge_entry("127.0.0.1:9002", PeerView::new(5, PeerHealth::Dead));
        let back = MembershipDigest::decode(&d.encode()).expect("roundtrip");
        assert_eq!(back, d);
        assert_eq!(back.get("127.0.0.1:9001"), Some(PeerView::new(2, PeerHealth::Suspect)));
        assert!(MembershipDigest::decode(b"").is_none());
        assert!(MembershipDigest::decode(b"G2 0\n").is_none(), "unknown version");
        assert!(MembershipDigest::decode(b"G1 x\n").is_none());
        assert!(MembershipDigest::decode(b"G1 0\naddr 1\n").is_none(), "short line");
        assert!(MembershipDigest::decode(b"G1 0\naddr 1 0 extra\n").is_none());
        assert!(MembershipDigest::decode(&[0xff, 0xfe]).is_none(), "not utf-8");
    }

    #[test]
    fn merge_law_higher_incarnation_beats_severity() {
        use PeerHealth::*;
        let dead_old = PeerView::new(3, Dead);
        let up_new = PeerView::new(4, Up);
        assert_eq!(PeerView::merge(dead_old, up_new), up_new, "refutation wins");
        assert_eq!(PeerView::merge(up_new, dead_old), up_new, "in either order");
        // equal incarnation: severity decides, Dead > Suspect > Recovering > Up
        let s = PeerView::new(4, Suspect);
        assert_eq!(PeerView::merge(up_new, s), s);
        assert_eq!(PeerView::merge(s, PeerView::new(4, Dead)), PeerView::new(4, Dead));
    }

    #[test]
    fn gossip_adoption_spreads_death_and_damps_resurrection() {
        let m = Membership::with_addrs(
            vec!["a:1".into(), "b:2".into()],
            HealthPolicy::default(),
        );
        // a remote digest carries a death verdict for b:2
        let mut d = MembershipDigest::new(1);
        d.merge_entry("b:2", PeerView::new(0, PeerHealth::Dead));
        d.merge_entry("c:3", PeerView::new(9, PeerHealth::Dead)); // unknown addr: ignored
        assert_eq!(m.apply_digest(&d), 1);
        assert_eq!(m.state(1), PeerHealth::Dead, "gossiped death adopted");
        assert_eq!(m.gossip_adoptions(), 1);
        assert_eq!(m.deaths(), 1);

        // re-applying the same digest is idempotent (no second adoption)
        assert_eq!(m.apply_digest(&d), 0);

        // a higher-incarnation Up claim refutes — but lands as Recovering
        // probation, never straight Up (second-hand evidence)
        let mut r = MembershipDigest::new(2);
        r.merge_entry("b:2", PeerView::new(1, PeerHealth::Up));
        assert_eq!(m.apply_digest(&r), 1);
        assert_eq!(m.state(1), PeerHealth::Recovering);
        assert_eq!(m.incarnation(1), 1, "merged incarnation is authoritative");
        assert!(m.refutations() >= 1);

        // stale lower-incarnation suspicion can no longer re-infect
        let mut stale = MembershipDigest::new(3);
        stale.merge_entry("b:2", PeerView::new(0, PeerHealth::Dead));
        assert_eq!(m.apply_digest(&stale), 0);
        assert_eq!(m.state(1), PeerHealth::Recovering);
    }

    #[test]
    fn first_hand_heal_bumps_incarnation() {
        let m = Membership::with_addrs(vec!["a:1".into()], HealthPolicy::default());
        assert_eq!(m.incarnation(0), 0);
        m.report(0, Outcome::IoTimeout); // Up -> Suspect: no bump
        assert_eq!(m.incarnation(0), 0);
        m.report(0, Outcome::IoOk);
        m.report(0, Outcome::IoOk); // Suspect -> Up: first-hand heal
        assert_eq!(m.state(0), PeerHealth::Up);
        assert_eq!(m.incarnation(0), 1, "heal refutes the suspicion epoch");
        // the local digest now out-competes the stale Suspect claim
        let v = m.digest().get("a:1").unwrap();
        assert_eq!(
            PeerView::merge(v, PeerView::new(0, PeerHealth::Suspect)),
            v,
            "bumped incarnation wins the merge"
        );
    }

    struct FixedProbe(Option<bool>, std::sync::atomic::AtomicU64);
    impl IndirectProbe for FixedProbe {
        fn probe_via(&self, _via: &[usize], _target: usize) -> Option<bool> {
            self.1.fetch_add(1, Ordering::Relaxed);
            self.0
        }
    }

    #[test]
    fn indirect_probe_withholds_circumstantial_death() {
        let m = Membership::with_addrs(
            vec!["a:1".into(), "b:2".into(), "c:3".into()],
            HealthPolicy::default(),
        );
        let probe = Arc::new(FixedProbe(Some(true), AtomicU64::new(0)));
        m.set_prober(probe.clone(), 1);
        // strike peer 0 out on timeouts alone: the relay reaches it, so the
        // verdict is withheld every time and the peer stays Suspect
        for _ in 0..4 * m.policy.dead_after {
            m.report(0, Outcome::IoTimeout);
        }
        assert_eq!(m.state(0), PeerHealth::Suspect, "reachable subject never dies");
        assert!(probe.1.load(Ordering::Relaxed) >= 2, "probe consulted per verdict");
        assert_eq!(m.deaths(), 0);
        assert!(m.probe_saves() >= 2);

        // IoDead stays conclusive: no probe can save a reset socket
        m.report(0, Outcome::IoDead);
        assert_eq!(m.state(0), PeerHealth::Dead);

        // an unreachable subject commits Dead through the probe path
        for _ in 0..m.policy.dead_after + 1 {
            m.report(1, Outcome::HeartbeatMiss);
        }
        assert_eq!(m.state(1), PeerHealth::Suspect, "probe still saving");
        m.set_prober(Arc::new(FixedProbe(Some(false), AtomicU64::new(0))), 1);
        for _ in 0..m.policy.dead_after {
            m.report(1, Outcome::HeartbeatMiss);
        }
        assert_eq!(m.state(1), PeerHealth::Dead, "relay-confirmed unreachable dies");
    }

    #[test]
    fn relay_candidates_skip_target_and_non_up() {
        let m = Membership::with_addrs(
            vec!["a:1".into(), "b:2".into(), "c:3".into(), "d:4".into()],
            HealthPolicy::default(),
        );
        m.report(2, Outcome::IoDead);
        let vias = m.relay_candidates(0, 8);
        assert!(!vias.contains(&0), "target never relays for itself");
        assert!(!vias.contains(&2), "dead peers cannot relay");
        assert_eq!(vias.len(), 2);
        // rotation: successive calls start from different relays
        let a = m.relay_candidates(0, 1);
        let b = m.relay_candidates(0, 1);
        assert_ne!(a, b, "round-robin cursor rotates the first relay");
    }
}
