//! Fleet liveness: a per-peer health state machine fed by heartbeats and
//! hot-path I/O outcomes.
//!
//! The fabric's failure handling used to be purely reactive — a peer was
//! only discovered dead when a hot-path read errored, and a dead-marked box
//! that rebooted was never rediscovered except by a lucky fallback probe.
//! [`Membership`] closes both gaps with one small state machine per peer:
//!
//! ```text
//!        ok                 failure                striking out
//!   Up ───────► Up     Up ───────────► Suspect ───────────────► Dead
//!                      ▲   (timeout /      │                      │
//!                      │    hb miss)       │ io dead              │ heartbeat ok
//!                      │                   ▼                      ▼
//!                      └──────────── proofs ≥ up_after       Recovering
//!                                                             │       │
//!                                            proofs ≥ recover_after   │ any failure
//!                                                             ▼       ▼
//!                                                             Up     Dead
//! ```
//!
//! Two signal sources feed [`Membership::report`] through [`HealthSink`]
//! handles:
//!
//! * **Heartbeats** piggybacked on the existing `CatalogSync` loop — every
//!   sync round doubles as a PING (no new connections), and a dead peer's
//!   backoff reconnect probes double as recovery detection.  A heartbeat is
//!   the **only** exit from `Dead`: hot-path success against a supposedly
//!   dead peer is treated as stale (`no Dead→Up without heartbeat`).
//! * **Hot-path I/O outcomes** reported by the fabric: a timeout
//!   (`WouldBlock`/`TimedOut` from an armed [`DeadlineBudget`]) is a
//!   *suspicion*, not a death — the box may just be slow — while a closed
//!   or reset connection is `IoDead`.
//!
//! Hysteresis damps flapping links: `Suspect` requires `up_after`
//! consecutive successes to climb back to `Up`, strikes survive interleaved
//! successes, and a flapper therefore ratchets toward `Dead` instead of
//! oscillating.  `Suspect` and `Recovering` peers still count as *alive*
//! (they stay in ring owner sets); only `Dead` drops a peer from placement.
//!
//! Every state change bumps a global [epoch](Membership::epoch) so callers
//! (e.g. `EdgeClient`) can cheaply invalidate memoized owner sets and call
//! `Placement::on_membership_change` exactly when the view shifted.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Health of one peer as seen by this client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum PeerHealth {
    /// Healthy: full participant in placement and fetch planning.
    Up = 0,
    /// Recent timeout or missed heartbeat; still alive (still an owner),
    /// but one more strike sequence away from `Dead`.
    Suspect = 1,
    /// Out of the fleet: excluded from owner sets until a heartbeat lands.
    Dead = 2,
    /// A heartbeat reached a dead-marked peer; probation until
    /// `recover_after` consecutive successes confirm the reboot stuck.
    Recovering = 3,
}

impl PeerHealth {
    fn from_u8(v: u8) -> Self {
        match v {
            0 => PeerHealth::Up,
            1 => PeerHealth::Suspect,
            3 => PeerHealth::Recovering,
            _ => PeerHealth::Dead,
        }
    }
}

/// One observation about a peer, from either signal source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// A `CatalogSync` round (connect + delta fetch) succeeded.
    HeartbeatOk,
    /// A sync round failed — connect refused, reset, or sync error.
    HeartbeatMiss,
    /// A hot-path operation (fetch share, upload, probe) succeeded.
    IoOk,
    /// A hot-path operation hit its [`DeadlineBudget`]
    /// (`WouldBlock`/`TimedOut`): slow, not necessarily gone.
    IoTimeout,
    /// A hot-path operation found the connection dead (reset, EOF, refused).
    IoDead,
}

impl Outcome {
    fn is_success(self) -> bool {
        matches!(self, Outcome::HeartbeatOk | Outcome::IoOk)
    }
}

/// Hysteresis thresholds for the state machine.
#[derive(Debug, Clone)]
pub struct HealthPolicy {
    /// Strikes accumulated in `Suspect` before the peer is declared `Dead`.
    pub dead_after: u32,
    /// Consecutive successes in `Suspect` before the peer returns to `Up`.
    pub up_after: u32,
    /// Consecutive successes in `Recovering` before the reboot is trusted.
    pub recover_after: u32,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy { dead_after: 3, up_after: 2, recover_after: 2 }
    }
}

/// Per-operation socket deadlines for pooled fabric connections: `connect`
/// bounds the dial (`TcpStream::connect_timeout`), `op` arms
/// `set_read_timeout`/`set_write_timeout` so a *stalled* (accepted but
/// silent) peer costs at most one budget, never a hang.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeadlineBudget {
    pub connect: Duration,
    pub op: Duration,
}

impl DeadlineBudget {
    pub fn new(connect: Duration, op: Duration) -> Self {
        DeadlineBudget { connect, op }
    }

    pub fn from_millis(connect_ms: u64, op_ms: u64) -> Self {
        DeadlineBudget {
            connect: Duration::from_millis(connect_ms),
            op: Duration::from_millis(op_ms),
        }
    }
}

impl Default for DeadlineBudget {
    fn default() -> Self {
        // generous against the modelled Wi-Fi RTT (~270 ms/op) yet small
        // enough that a wedged restore rotates to a survivor within one
        // human-perceptible beat
        DeadlineBudget::from_millis(500, 2_000)
    }
}

/// Classify a failed peer operation: a timeout from an armed deadline is
/// [`Outcome::IoTimeout`] (→ `Suspect`), anything else is
/// [`Outcome::IoDead`] (→ `Dead`).  Walks the whole error chain so
/// `anyhow` context wrapping does not hide the underlying `io::Error`.
pub fn classify_io_err(e: &anyhow::Error) -> Outcome {
    for cause in e.chain() {
        if let Some(io) = cause.downcast_ref::<std::io::Error>() {
            return match io.kind() {
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                    Outcome::IoTimeout
                }
                _ => Outcome::IoDead,
            };
        }
    }
    Outcome::IoDead
}

/// The pure transition function — `(state, strikes, proofs) × input →
/// (state, strikes, proofs)`.  Kept free of clocks and I/O so the property
/// tests can drive it with seeded input streams and assert determinism.
///
/// Invariants the tests pin:
/// * `Dead` exits **only** on `HeartbeatOk` (into `Recovering`).
/// * Strikes survive interleaved successes in `Suspect`, so an
///   alternating flapper ratchets to `Dead` instead of oscillating.
/// * Both counters reset on every state change.
pub fn step(
    state: PeerHealth,
    strikes: u32,
    proofs: u32,
    input: Outcome,
    policy: &HealthPolicy,
) -> (PeerHealth, u32, u32) {
    use Outcome::*;
    use PeerHealth::*;
    match state {
        Up => match input {
            HeartbeatOk | IoOk => (Up, 0, 0),
            HeartbeatMiss | IoTimeout => (Suspect, 1, 0),
            IoDead => (Dead, 0, 0),
        },
        Suspect => match input {
            HeartbeatOk | IoOk => {
                if proofs + 1 >= policy.up_after {
                    (Up, 0, 0)
                } else {
                    // strikes deliberately kept: the hysteresis memory
                    (Suspect, strikes, proofs + 1)
                }
            }
            HeartbeatMiss | IoTimeout => {
                if strikes + 1 >= policy.dead_after {
                    (Dead, 0, 0)
                } else {
                    (Suspect, strikes + 1, 0)
                }
            }
            IoDead => (Dead, 0, 0),
        },
        Dead => match input {
            // the only way out of Dead: a heartbeat (sync-loop probe)
            HeartbeatOk => {
                if policy.recover_after <= 1 {
                    (Up, 0, 0)
                } else {
                    (Recovering, 0, 1)
                }
            }
            _ => (Dead, 0, 0),
        },
        Recovering => match input {
            HeartbeatOk | IoOk => {
                if proofs + 1 >= policy.recover_after {
                    (Up, 0, 0)
                } else {
                    (Recovering, 0, proofs + 1)
                }
            }
            // probation is strict: any failure sends the peer straight back
            HeartbeatMiss | IoTimeout | IoDead => (Dead, 0, 0),
        },
    }
}

#[derive(Debug)]
struct Cell {
    state: PeerHealth,
    strikes: u32,
    proofs: u32,
}

/// Per-peer counters surfaced into `PeerLedger` at stats time.
#[derive(Debug, Default, Clone, Copy)]
pub struct PeerCounters {
    /// Successful heartbeats observed (sync rounds that completed).
    pub heartbeats: u64,
    /// `Dead → Recovering` transitions: a rebooted box rediscovered.
    pub heals: u64,
    /// Deadline-budget expiries (`IoTimeout` reports) on the hot path.
    pub timeouts: u64,
}

/// Fleet-wide liveness view shared (via `Arc`) between the client, its
/// per-peer `CatalogSync` threads and the fabric's fetch workers.
///
/// Transitions run under one tiny per-peer mutex; reads
/// ([`Membership::alive`], [`Membership::state`]) go through lock-free
/// atomic mirrors so the hot path never contends with a heartbeat.
#[derive(Debug)]
pub struct Membership {
    cells: Vec<Mutex<Cell>>,
    /// Lock-free mirror of each cell's state (`PeerHealth as u8`).
    states: Vec<AtomicU8>,
    /// Bumped on every state change; compare-and-refresh cheaply.
    epoch: AtomicU64,
    policy: HealthPolicy,
    per_heartbeats: Vec<AtomicU64>,
    per_heals: Vec<AtomicU64>,
    per_timeouts: Vec<AtomicU64>,
    suspects: AtomicU64,
    deaths: AtomicU64,
    heals: AtomicU64,
    recoveries: AtomicU64,
}

impl Membership {
    pub fn new(n_peers: usize, policy: HealthPolicy) -> Arc<Self> {
        let mk_cells = || {
            (0..n_peers)
                .map(|_| Mutex::new(Cell { state: PeerHealth::Up, strikes: 0, proofs: 0 }))
                .collect()
        };
        let mk_u64s = || (0..n_peers).map(|_| AtomicU64::new(0)).collect();
        Arc::new(Membership {
            cells: mk_cells(),
            states: (0..n_peers).map(|_| AtomicU8::new(PeerHealth::Up as u8)).collect(),
            epoch: AtomicU64::new(0),
            policy,
            per_heartbeats: mk_u64s(),
            per_heals: mk_u64s(),
            per_timeouts: mk_u64s(),
            suspects: AtomicU64::new(0),
            deaths: AtomicU64::new(0),
            heals: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
        })
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// A cloneable per-peer reporting handle for sync loops and fabric
    /// workers.
    pub fn sink(self: &Arc<Self>, peer: usize) -> HealthSink {
        HealthSink { membership: Arc::clone(self), peer }
    }

    /// Feed one observation through the state machine; returns the
    /// (possibly unchanged) resulting state.
    pub fn report(&self, peer: usize, input: Outcome) -> PeerHealth {
        let Some(cell) = self.cells.get(peer) else {
            return PeerHealth::Dead;
        };
        match input {
            Outcome::HeartbeatOk => {
                self.per_heartbeats[peer].fetch_add(1, Ordering::Relaxed);
            }
            Outcome::IoTimeout => {
                self.per_timeouts[peer].fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
        let mut c = cell.lock().unwrap();
        let old = c.state;
        let (next, strikes, proofs) =
            step(c.state, c.strikes, c.proofs, input, &self.policy);
        c.state = next;
        c.strikes = strikes;
        c.proofs = proofs;
        if next != old {
            self.states[peer].store(next as u8, Ordering::Release);
            match next {
                PeerHealth::Suspect => {
                    self.suspects.fetch_add(1, Ordering::Relaxed);
                }
                PeerHealth::Dead => {
                    self.deaths.fetch_add(1, Ordering::Relaxed);
                }
                PeerHealth::Recovering => {
                    // only reachable from Dead: a heal
                    self.heals.fetch_add(1, Ordering::Relaxed);
                    self.per_heals[peer].fetch_add(1, Ordering::Relaxed);
                }
                PeerHealth::Up => {
                    if old == PeerHealth::Recovering {
                        self.recoveries.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            // bumped last so an epoch-triggered refresh reads the new state
            self.epoch.fetch_add(1, Ordering::Release);
        }
        next
    }

    pub fn state(&self, peer: usize) -> PeerHealth {
        self.states
            .get(peer)
            .map(|s| PeerHealth::from_u8(s.load(Ordering::Acquire)))
            .unwrap_or(PeerHealth::Dead)
    }

    /// Alive = participates in placement. `Suspect` and `Recovering` stay
    /// in owner sets — only `Dead` is excluded.
    pub fn alive(&self, peer: usize) -> bool {
        self.state(peer) != PeerHealth::Dead
    }

    /// The placement view: one flag per peer, index-aligned with the
    /// client's peer table.
    pub fn alive_flags(&self) -> Vec<bool> {
        (0..self.len()).map(|i| self.alive(i)).collect()
    }

    /// Monotone view version: changes iff some peer changed state.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    pub fn peer_counters(&self, peer: usize) -> PeerCounters {
        PeerCounters {
            heartbeats: self.per_heartbeats[peer].load(Ordering::Relaxed),
            heals: self.per_heals[peer].load(Ordering::Relaxed),
            timeouts: self.per_timeouts[peer].load(Ordering::Relaxed),
        }
    }

    /// Total `* → Suspect` transitions.
    pub fn suspect_transitions(&self) -> u64 {
        self.suspects.load(Ordering::Relaxed)
    }

    /// Total `* → Dead` transitions.
    pub fn deaths(&self) -> u64 {
        self.deaths.load(Ordering::Relaxed)
    }

    /// Total `Dead → Recovering` transitions (rebooted boxes rediscovered).
    pub fn heals(&self) -> u64 {
        self.heals.load(Ordering::Relaxed)
    }

    /// Total `Recovering → Up` transitions (reboots that stuck).
    pub fn recoveries(&self) -> u64 {
        self.recoveries.load(Ordering::Relaxed)
    }

    /// Total deadline-budget expiries across the fleet.
    pub fn timeouts(&self) -> u64 {
        self.per_timeouts
            .iter()
            .map(|t| t.load(Ordering::Relaxed))
            .sum()
    }
}

/// A cheap cloneable handle binding one peer index to the shared
/// [`Membership`]; handed to `CatalogSync` threads and fabric workers so
/// they can report without knowing the peer table.
#[derive(Debug, Clone)]
pub struct HealthSink {
    membership: Arc<Membership>,
    peer: usize,
}

impl HealthSink {
    pub fn report(&self, input: Outcome) -> PeerHealth {
        self.membership.report(self.peer, input)
    }

    pub fn peer(&self) -> usize {
        self.peer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn policy() -> HealthPolicy {
        HealthPolicy::default()
    }

    fn draw_outcome(r: &mut Rng) -> Outcome {
        match r.below(5) {
            0 => Outcome::HeartbeatOk,
            1 => Outcome::HeartbeatMiss,
            2 => Outcome::IoOk,
            3 => Outcome::IoTimeout,
            _ => Outcome::IoDead,
        }
    }

    #[test]
    fn step_is_deterministic_over_seeded_streams() {
        for seed in [1u64, 7, 42, 1234] {
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let (mut a, mut b) =
                ((PeerHealth::Up, 0u32, 0u32), (PeerHealth::Up, 0u32, 0u32));
            for _ in 0..500 {
                let (o1, o2) = (draw_outcome(&mut r1), draw_outcome(&mut r2));
                assert_eq!(o1, o2);
                a = step(a.0, a.1, a.2, o1, &policy());
                b = step(b.0, b.1, b.2, o2, &policy());
                assert_eq!(a, b, "same seed must walk the same trajectory");
            }
        }
    }

    #[test]
    fn no_dead_to_up_without_heartbeat() {
        // property: from Dead, any input stream *without* HeartbeatOk stays
        // Dead forever — hot-path successes against a dead peer are stale
        let mut r = Rng::new(99);
        let non_heartbeat = [
            Outcome::HeartbeatMiss,
            Outcome::IoOk,
            Outcome::IoTimeout,
            Outcome::IoDead,
        ];
        let mut st = (PeerHealth::Dead, 0u32, 0u32);
        for _ in 0..1000 {
            let o = non_heartbeat[r.below(4) as usize];
            st = step(st.0, st.1, st.2, o, &policy());
            assert_eq!(st.0, PeerHealth::Dead, "only a heartbeat may revive");
        }
        // and the heartbeat path goes through Recovering, never straight Up
        let (s, ..) = step(PeerHealth::Dead, 0, 0, Outcome::HeartbeatOk, &policy());
        assert_eq!(s, PeerHealth::Recovering);
    }

    #[test]
    fn flapping_peer_is_damped_not_oscillating() {
        // alternate failure/success: strikes survive the interleaved
        // successes (up_after=2 never reached consecutively), so the peer
        // never bounces back to Up and instead ratchets to Dead
        let mut st = (PeerHealth::Up, 0u32, 0u32);
        let mut seen_up_again = false;
        for i in 0..2 * policy().dead_after {
            let o = if i % 2 == 0 { Outcome::IoTimeout } else { Outcome::IoOk };
            st = step(st.0, st.1, st.2, o, &policy());
            if st.0 == PeerHealth::Up {
                seen_up_again = true;
            }
        }
        assert!(!seen_up_again, "hysteresis must hold the flapper in Suspect");
        assert_eq!(st.0, PeerHealth::Dead, "a persistent flapper strikes out");
    }

    #[test]
    fn suspect_recovers_after_consecutive_successes() {
        let p = policy();
        let mut st = step(PeerHealth::Up, 0, 0, Outcome::IoTimeout, &p);
        assert_eq!(st.0, PeerHealth::Suspect);
        for _ in 0..p.up_after {
            st = step(st.0, st.1, st.2, Outcome::IoOk, &p);
        }
        assert_eq!(st.0, PeerHealth::Up, "consecutive successes must heal");
    }

    #[test]
    fn recovery_probation_is_strict() {
        let p = policy();
        let st = step(PeerHealth::Dead, 0, 0, Outcome::HeartbeatOk, &p);
        assert_eq!(st.0, PeerHealth::Recovering);
        // one failure during probation → straight back to Dead
        let back = step(st.0, st.1, st.2, Outcome::IoTimeout, &p);
        assert_eq!(back.0, PeerHealth::Dead);
        // enough consecutive proof → Up
        let mut ok = st;
        for _ in 0..p.recover_after {
            ok = step(ok.0, ok.1, ok.2, Outcome::HeartbeatOk, &p);
        }
        assert_eq!(ok.0, PeerHealth::Up);
    }

    #[test]
    fn io_dead_kills_immediately_timeout_only_suspects() {
        let p = policy();
        let (s, ..) = step(PeerHealth::Up, 0, 0, Outcome::IoDead, &p);
        assert_eq!(s, PeerHealth::Dead, "a closed connection is conclusive");
        let (s, ..) = step(PeerHealth::Up, 0, 0, Outcome::IoTimeout, &p);
        assert_eq!(s, PeerHealth::Suspect, "a deadline expiry is only a hint");
    }

    #[test]
    fn membership_epoch_and_counters_track_transitions() {
        let m = Membership::new(2, HealthPolicy::default());
        assert_eq!(m.epoch(), 0);
        assert!(m.alive(0) && m.alive(1));

        // peer 0: time out → Suspect (epoch bump, suspect counted)
        assert_eq!(m.report(0, Outcome::IoTimeout), PeerHealth::Suspect);
        let e1 = m.epoch();
        assert!(e1 > 0);
        assert_eq!(m.suspect_transitions(), 1);
        assert!(m.alive(0), "Suspect still counts as alive");
        assert_eq!(m.peer_counters(0).timeouts, 1);

        // a success without reaching up_after: no state change, no bump
        m.report(0, Outcome::IoOk);
        assert_eq!(m.epoch(), e1);

        // peer 1 dies, then a heartbeat heals it through Recovering
        assert_eq!(m.report(1, Outcome::IoDead), PeerHealth::Dead);
        assert!(!m.alive(1));
        assert_eq!(m.alive_flags(), vec![true, false]);
        assert_eq!(m.deaths(), 1);
        assert_eq!(m.report(1, Outcome::HeartbeatOk), PeerHealth::Recovering);
        assert_eq!(m.heals(), 1);
        assert_eq!(m.peer_counters(1).heals, 1);
        assert!(m.alive(1), "Recovering rejoins the owner sets");
        assert_eq!(m.report(1, Outcome::HeartbeatOk), PeerHealth::Up);
        assert_eq!(m.recoveries(), 1);
        assert_eq!(m.peer_counters(1).heartbeats, 2);

        // sinks report through the same shared view
        let sink = m.sink(0);
        assert_eq!(sink.peer(), 0);
        sink.report(Outcome::IoOk);
        assert_eq!(m.state(0), PeerHealth::Up);
    }

    #[test]
    fn out_of_range_peer_is_dead_and_ignored() {
        let m = Membership::new(1, HealthPolicy::default());
        assert_eq!(m.report(7, Outcome::IoOk), PeerHealth::Dead);
        assert!(!m.alive(7));
        assert_eq!(m.epoch(), 0);
    }

    #[test]
    fn classify_io_errors() {
        use std::io::{Error, ErrorKind};
        let timeout: anyhow::Error =
            anyhow::Error::new(Error::new(ErrorKind::TimedOut, "slow"))
                .context("fetch share");
        assert_eq!(classify_io_err(&timeout), Outcome::IoTimeout);
        let would_block: anyhow::Error =
            Error::new(ErrorKind::WouldBlock, "armed deadline").into();
        assert_eq!(classify_io_err(&would_block), Outcome::IoTimeout);
        let reset: anyhow::Error =
            anyhow::Error::new(Error::new(ErrorKind::ConnectionReset, "gone"))
                .context("ctx");
        assert_eq!(classify_io_err(&reset), Outcome::IoDead);
        let plain = anyhow::anyhow!("not an io error at all");
        assert_eq!(classify_io_err(&plain), Outcome::IoDead);
    }

    #[test]
    fn default_budget_sane() {
        let b = DeadlineBudget::default();
        assert!(b.connect >= Duration::from_millis(100));
        assert!(b.op >= b.connect);
        let c = DeadlineBudget::from_millis(100, 250);
        assert_eq!(c.connect, Duration::from_millis(100));
        assert_eq!(c.op, Duration::from_millis(250));
    }
}
