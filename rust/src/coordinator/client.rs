//! [`EdgeClient`] — one edge device running a local LLM with distributed
//! prompt caching.  Implements the paper's §3.1 four-step flow:
//!
//! 1. **Token** — tokenize the prompt (and its Figure-3 prefix ranges);
//! 2. **Bloom** — query the local catalogs for the longest probable hit;
//! 3. on hit: **Redis**-download the state and restore it; on miss (or a
//!    Bloom false positive, detected when the GET comes back empty): decode
//!    locally, then upload the resulting states *after* the response and
//!    register them in both catalogs;
//! 4. **R-decode/Sample** — generate the response.
//!
//! The client talks to a **peer fabric** of N cache boxes, not a single
//! middle node (`coordinator::fabric`): each configured [`PeerConfig`] gets
//! its own pooled connection, link shaper, Bloom catalog and sync loop, so
//! a step-2 hit names the peer(s) that claim the range
//! ([`crate::catalog::lookup_tagged`]).  A partial hit's matched chunks are
//! then striped across the claiming peers and downloaded concurrently —
//! aggregate goodput scales with peer count, and a peer dying mid-stream
//! re-plans its orphaned chunks onto the survivors before ever falling
//! back to a full blob or local prefill.  Uploads place through the
//! pluggable [`Placement`] policy (`coordinator::placement`): the default
//! power-of-two-choices probes `used_bytes` and balances load, while the
//! rendezvous ring places deterministically — a catalog miss then falls
//! back to probing the key's designated owners (catalog-less recovery
//! after a reboot) and a hit's owner set is swept post-response to
//! re-publish lost replicas ([`crate::coordinator::fabric::repair_entry`]).
//! A one-peer configuration is simply the degenerate one-stripe plan —
//! there is no separate single-box code path.
//!
//! Transfers are **range-aware** (the SparKV argument: move only bytes whose
//! transfer cost beats recompute) and **streamed**:
//!
//! * *Download*: a prompt's shorter catalog ranges are stored as tiny
//!   aliases pointing into the one real blob.  A partial match resolves the
//!   alias, then fetches just the blob head (header + chunk index) and the
//!   whole ECS3 chunks covering the matched rows — **one `GETRANGE` per
//!   chunk**, pipelined in a single write and consumed as a reply *stream*:
//!   each chunk is crc-verified, inflated and scattered into the live state
//!   ([`StateAssembler`]) the moment its bytes land, while later chunks are
//!   still on the modelled wire.  TTFT therefore pays
//!   `max(transfer, decode)` instead of `transfer + decode`, and the suffix
//!   prefill starts the instant the last chunk is fed — there is no
//!   buffered-then-restored monolith left on the hot path.  The saving is
//!   ledgered honestly in `overlap_saved` (see [`Shaper::shaped_stream`]).
//!   Raw bodies ride one round trip (chunk spans are layout arithmetic);
//!   deflated bodies fetch the head first and pay one extra round trip.
//!   Any range-path verification failure drains the reply stream and falls
//!   back to a full-blob download, never to a questionable restore.
//! * *Upload*: one blob (the longest new range) is published per prompt;
//!   shorter ranges become aliases.  When the query downloaded a state, the
//!   upload ships only the chunks past the matched prefix and has the
//!   server `SPLICE` them onto the base chunks it already holds — deflated
//!   bases included, since every chunk is an independent stream.  The chunk
//!   size itself is either fixed (`chunk_tokens`) or picked per entry from
//!   the link's goodput/RTT break-even ([`adaptive_chunk_tokens`]) and
//!   recorded in the entry header + alias, so mixed-size fleets interop.
//!
//! Latency attribution follows Table 3 exactly; uploads happen off the
//! latency path (the paper's Case-1 Redis column shows only false-positive
//! cost, so uploads are post-response).  All remote bytes flow through the
//! Wi-Fi [`Shaper`] and all compute through the device [`Pacer`].

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::catalog::{
    lookup_tagged, ranges_for, state_store_key, token_store_key, LocalCatalog, ModelMeta,
    PromptRange, KEY_LEN,
};
use crate::coordinator::fabric::{
    fetch_full_entry, fetch_prefix_multi, repair_entry, LocalRecompute, Peer,
    PeerConfig, RelayProber,
};
use crate::coordinator::membership::{
    classify_io_err, DeadlineBudget, HealthPolicy, Membership, Outcome,
};
use crate::coordinator::placement::{
    Placement, PlacementKind, PowerOfTwoChoices, RendezvousRing, Unplaced,
};
use crate::coordinator::plan::PlanMode;
use crate::coordinator::policy::{FetchPolicy, PeerPlanner};
use crate::coordinator::sync::CatalogSync;
use crate::devicemodel::{DeviceProfile, Pacer};
use crate::engine::Engine;
use crate::kvstore::resp::{request_shared, Value};
use crate::log_debug;
use crate::metrics::{PeerLedger, Phase, PhaseBreakdown};
use crate::model::sampler::Sampler;
use crate::model::state::{
    decode_range_alias, encode_range_alias, read_chunk_index, BlobLayout, ChunkEntry,
    Compression, KvState, DEFAULT_CHUNK_TOKENS,
};
use crate::netsim::LinkModel;
use crate::sketch::{
    common_prefix_len, decode_token_ids, encode_section, encode_token_ids, sketch_tokens,
    SketchCandidate, SketchRecord,
};
use crate::util::bytes::SharedBytes;
use crate::workload::Prompt;

/// Which of the paper's five evaluation cases a query landed in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HitCase {
    /// Case 1: no cache hit.
    Miss,
    /// Case 2: instruction only.
    Instruction,
    /// Case 3: instruction + first example.
    FirstExample,
    /// Case 4: instruction + all examples.
    AllExamples,
    /// Case 5: the entire prompt.
    Full,
}

impl HitCase {
    pub fn number(self) -> usize {
        match self {
            HitCase::Miss => 1,
            HitCase::Instruction => 2,
            HitCase::FirstExample => 3,
            HitCase::AllExamples => 4,
            HitCase::Full => 5,
        }
    }
}

/// Pick an ECS3 chunk size (tokens) for an `entry_rows`-row entry from the
/// link's goodput/RTT break-even.
///
/// Two costs pull in opposite directions, both in wire bytes (goodput
/// divides out of the ratio):
///
/// * **over-fetch** — a partial hit rounds up to a chunk boundary, moving
///   ~`ct/2` extra rows (`ct·stride/2` bytes) past the matched prefix, so
///   small chunks win when per-byte time dominates;
/// * **per-chunk overhead** — every chunk adds a fixed cost `OH` (its
///   8-byte index entry, deflate stream framing, the pipelined per-chunk
///   `GETRANGE` exchange) *plus* a slice of the link's bandwidth–delay
///   product: on fat-RTT links each extra in-flight request adds scheduling
///   slop that eats goodput, so expensive RTTs push chunks larger (and
///   larger chunks also give the per-chunk deflate streams more context to
///   compress).
///
/// `cost(ct) = ct·stride/2 + (rows/ct)·OH` is minimized at
/// `ct* = sqrt(2·rows·OH/stride)`; the result is quantized to a power of
/// two so entries of similar length agree on a size and stay
/// `SPLICE`-compatible.  On the paper's Wi-Fi 4 link with the 270M-class
/// state stride this lands exactly on the old fixed default
/// ([`DEFAULT_CHUNK_TOKENS`] = 8); a wired link shrinks chunks, a
/// long-fat link grows them.
pub fn adaptive_chunk_tokens(
    link: &LinkModel,
    token_stride: usize,
    entry_rows: usize,
) -> usize {
    let rows = entry_rows.max(1) as f64;
    let bdp = if link.goodput_bps.is_finite() {
        link.goodput_bps * link.rtt.as_secs_f64()
    } else {
        0.0
    };
    let oh = 64.0 + bdp / 1024.0;
    let ct = (2.0 * rows * oh / token_stride.max(1) as f64).sqrt();
    let ct = ct.max(1.0).log2().round().exp2() as usize;
    ct.clamp(1, 1024)
}

#[derive(Debug, Clone)]
pub struct EdgeClientConfig {
    pub name: String,
    /// The cache-box peer fabric: zero peers runs fully standalone (paper
    /// §5.3: local inference keeps working when the middle nodes are
    /// down), one peer is the paper's topology, and N peers share the
    /// prompt-cache load — each peer gets its own pooled connection, link
    /// shaper, Bloom catalog and sync loop.
    pub peers: Vec<PeerConfig>,
    /// Default link model for peers without a per-peer override
    /// ([`PeerConfig::link`]).
    pub link: LinkModel,
    /// Extra full copies each upload ships to distinct peers beyond the
    /// placement primary (clamped to the fleet size).  Replication trades
    /// upload bytes for read fan-out and failure resilience: a replicated
    /// range survives its primary dying mid-trace, because the surviving
    /// claimers re-serve the orphaned chunks.  With the ring policy the
    /// replica set is the key's `1 + replicas` HRW owners — derivable by
    /// any client, which is what enables catalog-less fallback probing
    /// and replica repair.
    pub replicas: usize,
    /// Which placement policy decides where uploads land
    /// (`coordinator::placement`): `PowerOfTwoChoices` probes loads and
    /// balances bytes (the historical behaviour), `RendezvousRing` places
    /// deterministically so a catalog miss can still probe the designated
    /// owners and repair can restore lost replicas.
    pub placement: PlacementKind,
    pub device: DeviceProfile,
    /// Response-token budget; `None` uses the device profile's typical
    /// length (64 for the low-end 270M setting, 1 for the high-end 1B).
    pub max_new_tokens: Option<usize>,
    pub compression: Compression,
    /// Tokens per ECS3 chunk in uploaded state blobs.  Chunks are the unit
    /// of (per-chunk) compression, crc verification and range transfer —
    /// see `model::state`.  Must be ≥ 1.
    pub chunk_tokens: usize,
    /// Pick the chunk size per entry from the link's goodput/RTT break-even
    /// ([`adaptive_chunk_tokens`]) instead of the fixed `chunk_tokens`.  The
    /// chosen size is recorded in the entry header and its aliases, so
    /// readers never need this flag to agree — mixed fleets interoperate.
    pub adaptive_chunk: bool,
    /// Register/look up the four Figure-3 prefix ranges (§3.2).  When false
    /// only the full prompt is cached (prefix-caching ablation).
    pub partial_matching: bool,
    /// Use the local Bloom catalog (§5.2.3 ablation: false = probe the
    /// server with EXISTS for every candidate range, over the shaped link).
    pub use_catalog: bool,
    pub fetch_policy: FetchPolicy,
    /// Chunk-level fetch planning (`coordinator::plan`).  `Chunk` compares
    /// modelled transfer time against the device's prefill rate per matched
    /// ECS3 chunk and may emit a *mixed* plan — recompute the cheap prefix
    /// locally while fetching the expensive suffix from peers, the two
    /// overlapped through the stream assembler.  `Range` keeps the
    /// all-or-nothing whole-range decision (`fetch_policy` alone) as the
    /// PR-3 ablation.  Planning only engages on devices whose prefill side
    /// is modelled ([`DeviceProfile::models_recompute`]); the host profile
    /// always fetches whole ranges regardless of this knob.
    pub plan: PlanMode,
    /// Ignore probable hits shorter than this many tokens (§3.2 "match of
    /// sufficient length").
    pub min_hit_tokens: usize,
    /// Background catalog-sync interval; `None` = sync manually/never.
    pub sync_interval: Option<Duration>,
    /// Per-op deadline budget armed on every pooled peer connection
    /// (`set_read_timeout`/`set_write_timeout` plus a bounded connect).
    /// `None` leaves sockets blocking — a *stalled* peer can then hold a
    /// restore for as long as the OS lets it.  With a budget, a stall
    /// costs at most one `op` timeout before the fabric re-plans, and the
    /// peer is marked *Suspect* (not Dead) in membership.  Per-peer
    /// [`PeerConfig::deadline`] overrides win over this fleet default.
    pub deadline: Option<DeadlineBudget>,
    /// How long a probed-and-missed store key suppresses re-probing its
    /// ring owners (the fallback-probe negative cache).  Long enough to
    /// cover a burst of repeat misses, short enough that a fresh upload by
    /// another client becomes probe-visible within a couple of sync
    /// intervals.  `Duration::ZERO` disables the cache entirely — every
    /// cold lookup re-probes.
    pub probe_negative_ttl: Duration,
    /// SWIM-style gossip: piggyback membership digests on every catalog
    /// sync round, so one client's liveness verdict reaches the rest of
    /// the fleet in O(sync-period) via the boxes' blackboards, and a
    /// suspected box refutes with a bumped incarnation
    /// (`coordinator::membership` module docs).  `false` is the
    /// per-client-heartbeat ablation (PR 6 behaviour).
    pub gossip: bool,
    /// Relays consulted by the indirect probe before `Suspect → Dead` is
    /// committed on circumstantial evidence (timeouts/missed heartbeats):
    /// up to this many *other* Up boxes are asked to `PING` the suspect
    /// over their own network path, so an asymmetric client↔box partition
    /// cannot convict a healthy box.  `0` disables indirect probing.
    pub indirect_probes: usize,
    /// Adaptive-deadline multiplier `k` ([`PeerConfig::deadline_k`]): arm
    /// each sized op's timeout at `k ×` the peer link's expected transfer
    /// time, floored by `deadline.op` and widened ×2 under `Suspect`.
    /// `<= 0` keeps the static fleet-wide budget.
    pub adaptive_deadline_k: f64,
    /// The semantic similarity tier (`crate::sketch`): register a SimHash
    /// sketch + token-id header with every upload, and on a **total** exact
    /// catalog miss search the per-peer sketch tables for paraphrase
    /// donors, each verified by its real token prefix before any state is
    /// reused.  Never engages when the exact tier matched anything — an
    /// exact workload sees zero semantic wire traffic.  `false` is the
    /// `--no-semantic` ablation: no registration, no sync, no probes.
    pub semantic: bool,
    /// Max Hamming distance (of [`crate::sketch::SKETCH_BITS`]) a sketch
    /// candidate may sit from the query sketch.  Unrelated prompts
    /// concentrate near 32 bits; the default 16 keeps false candidates
    /// ~4σ away while admitting moderate paraphrases.
    pub semantic_dist: u32,
    /// Max donor candidates verified (token-header probes) per miss.
    pub semantic_k: usize,
    /// Proactive repair sweep period: at most once per this interval, one
    /// post-response sweep step SCANs a slice of one box's key space and
    /// re-publishes entries whose ring owners lost their copy — healing
    /// cold entries without waiting for a client hit.  `ZERO` = off.
    /// Deterministic placement only (owners must be derivable).
    pub repair_sweep: Duration,
    pub seed: u64,
}

impl EdgeClientConfig {
    /// The paper's low-end setting: Pi Zero 2W + 270M-class model, Wi-Fi 4.
    /// `server` configures a one-peer fabric (the paper's topology).
    pub fn low_end(server: Option<String>) -> Self {
        EdgeClientConfig {
            name: "low-end".into(),
            peers: server.into_iter().map(PeerConfig::new).collect(),
            replicas: 0,
            placement: PlacementKind::PowerOfTwoChoices,
            link: LinkModel::wifi4_2g4(),
            device: DeviceProfile::pi_zero_2w(),
            max_new_tokens: None,
            compression: Compression::None,
            chunk_tokens: DEFAULT_CHUNK_TOKENS,
            adaptive_chunk: false,
            partial_matching: true,
            use_catalog: true,
            fetch_policy: FetchPolicy::Always,
            plan: PlanMode::Chunk,
            min_hit_tokens: 1,
            sync_interval: Some(Duration::from_millis(200)),
            deadline: None,
            probe_negative_ttl: Duration::from_millis(1500),
            gossip: true,
            indirect_probes: 1,
            adaptive_deadline_k: 0.0,
            semantic: true,
            semantic_dist: 16,
            semantic_k: 3,
            repair_sweep: Duration::ZERO,
            seed: 1,
        }
    }

    /// The paper's high-end setting: Pi 5 + 1B-class model.
    pub fn high_end(server: Option<String>) -> Self {
        EdgeClientConfig {
            name: "high-end".into(),
            device: DeviceProfile::pi5_4gb(),
            ..Self::low_end(server)
        }
    }

    /// Unpaced, unshaped: native host measurement mode.
    pub fn native(server: Option<String>) -> Self {
        EdgeClientConfig {
            name: "native".into(),
            link: LinkModel::loopback(),
            device: DeviceProfile::host(),
            ..Self::low_end(server)
        }
    }
}

/// Outcome of one query through the distributed cache.
#[derive(Debug, Clone)]
pub struct QueryResult {
    pub case: HitCase,
    pub matched_tokens: usize,
    pub prompt_tokens: usize,
    pub response_tokens: Vec<u32>,
    pub response_text: String,
    pub breakdown: PhaseBreakdown,
    /// A catalog hit whose server GET came back empty (Bloom false positive
    /// or evicted entry) — fell back to local prefill.
    pub false_positive: bool,
    pub downloaded_bytes: usize,
    pub uploaded_bytes: usize,
    /// Wire bytes the range-aware transfer path avoided moving, against the
    /// full-blob-per-range model (uncompressed layout arithmetic).
    pub saved_bytes: usize,
    /// Post-response upload duration (excluded from TTFT/TTLT, like the
    /// paper's Case-1 Redis column).
    pub upload_time: Duration,
}

/// Aggregate client counters.
#[derive(Debug, Default, Clone)]
pub struct ClientStats {
    pub queries: u64,
    pub hits_by_case: [u64; 5],
    pub false_positives: u64,
    pub bytes_down: u64,
    pub bytes_up: u64,
    /// Cumulative modelled wire bytes saved by range downloads + delta/alias
    /// uploads vs the full-blob-per-range baseline.
    pub bytes_saved: u64,
    pub fetches_declined: u64,
    /// Chunk-aligned range downloads that completed without moving the
    /// whole entry (the ECS3 path, compressed or not).
    pub range_fetches: u64,
    /// Range-path failures (stale alias geometry, short replies, corrupt
    /// chunks) that re-fetched and re-verified the whole entry instead.
    pub full_fetch_fallbacks: u64,
    /// Range downloads that actually striped chunks across 2+ peers.
    pub multi_source_fetches: u64,
    /// Re-plan rounds the fabric ran after mid-fetch share failures
    /// (orphaned chunks reassigned to surviving peers).
    pub re_plans: u64,
    /// Peer-level failures observed (dead connections, failed shares,
    /// failed head acquisitions) across downloads and uploads.
    pub peer_failures: u64,
    /// Replica copies shipped by the upload placement policy.
    pub replica_uploads: u64,
    /// EXISTS probes actually sent to ring-designated owners during
    /// lookup: the catalog-miss fallback plus the `--no-catalog` ablation
    /// under deterministic placement (both bounded to primary + replicas
    /// per candidate range).  Repair-sweep probes are *not* counted here —
    /// they show up per peer in `PeerLedger::fallback_probes`.
    pub fallback_probes: u64,
    /// Catalog misses the owner-probe fallback turned into hits (the
    /// post-reboot recovery path).
    pub fallback_probe_hits: u64,
    /// Entries re-published by ring-driven replica repair to owners that
    /// had lost their copy.
    pub repair_republishes: u64,
    /// Deadline-budget expiries (`WouldBlock`/`TimedOut`) observed on
    /// pooled peer connections, summed over peers.  A timeout marks the
    /// peer *Suspect*, never Dead (`coordinator::membership`).
    pub timeouts: u64,
    /// Membership transitions into `Suspect` — first strikes against a
    /// peer that was healthy a moment ago.
    pub suspect_transitions: u64,
    /// Dead peers whose heartbeat came back (`Dead → Recovering`) — the
    /// membership heal loop closing after a reboot.
    pub heals: u64,
    /// Ring-owner fallback probes skipped because every peer catalog was
    /// warm (a Bloom miss is then trustworthy) or because the key sits in
    /// the TTL'd probed-and-missed negative cache.
    pub probes_suppressed: u64,
    /// ECS3 chunks the fetch plan pulled over the wire (completed range
    /// fetches only).
    pub chunks_fetched: u64,
    /// ECS3 chunks the fetch plan assigned to local recompute — whether by
    /// up-front cost comparison (`--plan chunk`) or by mid-fetch rescue of
    /// orphaned/corrupt chunks.
    pub chunks_recomputed: u64,
    /// Range fetches whose final plan genuinely mixed both sources (≥ 1
    /// chunk fetched *and* ≥ 1 recomputed).
    pub plan_mixed: u64,
    /// Peer-state changes adopted second-hand from gossip digests (another
    /// client's verdict arriving via a box's blackboard).
    pub gossip_adoptions: u64,
    /// Local suspicion/death verdicts *refuted* — by a higher-incarnation
    /// gossip entry or by a positive indirect probe.
    pub gossip_refutations: u64,
    /// Indirect probes launched before committing a circumstantial
    /// `Suspect → Dead`.
    pub indirect_probes: u64,
    /// Indirect probes that found the suspect reachable via a relay and
    /// withheld the death verdict (a false positive prevented).
    pub probe_saves: u64,
    /// Requests the fleet shed with `BUSY` at admission gates, as observed
    /// by this client (mirror of the per-peer [`PeerLedger::sheds`] sums —
    /// health-neutral, these never count as peer failures).
    pub busy_rejections: u64,
    /// Free re-plan rounds fetches were granted because a saturated peer
    /// shed a share (capped at one per fetch).
    pub replans_on_busy: u64,
    /// Token-header verification probes the semantic tier sent (one per
    /// sketch candidate actually checked; engaged only on total exact
    /// misses).
    pub semantic_probes: u64,
    /// Semantic donor reuses that completed: a verified token prefix was
    /// fetched and restored where the exact tier saw nothing.
    pub semantic_hits: u64,
    /// Verification probes whose real token overlap came in below the
    /// usable threshold — the sketch proposed, the token header refuted
    /// (wasted wire, never wasted correctness).
    pub semantic_false_probes: u64,
    /// Prompt tokens recovered across all semantic hits (the prefill the
    /// tier saved a paraphrased workload).
    pub semantic_tokens_recovered: u64,
}

/// Where a downloaded state physically lives on the fabric — the anchor
/// the post-response upload splices suffix chunks onto.
#[derive(Debug, Clone)]
struct DeltaBase {
    store_key: Vec<u8>,
    /// Which peer certainly holds the base entry (the head peer of the
    /// download) — splices target it for data locality.
    peer: usize,
    total_rows: usize,
    compressed: bool,
    /// ECS3 chunk size of the base entry (`None` = legacy v2 entry, which
    /// is never spliced onto).
    chunk_tokens: Option<usize>,
    /// The base's chunk-index entries, in order — a splice reuses the whole
    /// chunks below the matched prefix by copying these into the new header.
    chunk_index: Vec<ChunkEntry>,
}

/// Describe a fully fetched entry as a future `SPLICE` base, reading the
/// authoritative geometry out of its own header/index (not the alias).
fn delta_base_for_entry(store_key: Vec<u8>, peer: usize, blob: &[u8]) -> DeltaBase {
    let hdr = KvState::peek_header(blob).ok();
    let (chunk_tokens, chunk_index) = match read_chunk_index(blob) {
        Some((ct, entries)) => (Some(ct), entries),
        None => (None, Vec::new()),
    };
    DeltaBase {
        store_key,
        peer,
        total_rows: hdr.as_ref().map_or(0, |h| h.n_tokens),
        compressed: hdr.as_ref().is_some_and(|h| h.compressed),
        chunk_tokens,
        chunk_index,
    }
}

/// Result of a successful state download.
struct Download {
    state: KvState,
    wire_bytes: usize,
    saved_bytes: usize,
    base: DeltaBase,
}

pub struct EdgeClient {
    pub cfg: EdgeClientConfig,
    engine: Arc<Engine>,
    meta: ModelMeta,
    /// Peer 0's local catalog (or a free-standing one when no peers are
    /// configured) — kept as a public field so single-box tooling and
    /// tests keep their direct handle; the fabric lookup consults every
    /// peer's catalog via [`Peer::catalog`].
    pub catalog: Arc<Mutex<LocalCatalog>>,
    peers: Vec<Peer>,
    planner: PeerPlanner,
    /// The pluggable placement policy (`cfg.placement`): where uploads
    /// land, which owners a catalog miss may probe, where repair
    /// re-publishes.
    policy: Box<dyn Placement>,
    /// Repair memo: store keys whose owner set was sweep-verified intact,
    /// keyed to the exact owner set.  Invalidated when membership changes
    /// the owner set or when a fetch observes a lost copy (empty GET,
    /// failed share); a silent eviction on an owner the fetch never
    /// touched heals only via a future sweep trigger (ROADMAP: proactive
    /// repair sweep).  One entry per distinct hit entry — bounded by the
    /// working set of reused prompts.
    verified_owners: HashMap<Vec<u8>, Vec<usize>>,
    /// Fleet liveness: the shared per-peer health state machine every
    /// sink (sync-loop heartbeats, hot-path I/O verdicts) reports into.
    membership: Arc<Membership>,
    /// Last membership epoch pushed into the placement policy; owner
    /// sets, the repair memo and the probe negative cache are refreshed
    /// only when the epoch moves — steady-state queries pay one atomic
    /// load.
    last_epoch: u64,
    /// Fallback-probe suppression: store keys whose ring owners were
    /// probed and answered "not here", with the probe time.  While the
    /// entry is younger than [`EdgeClientConfig::probe_negative_ttl`] the
    /// key is not re-probed; any membership transition clears the cache (a
    /// heal or death changes who should hold what).
    probe_negative: HashMap<Vec<u8>, std::time::Instant>,
    /// Proactive repair sweep state ([`EdgeClientConfig::repair_sweep`]):
    /// last sweep time, the SCAN cursor into the current box's key space,
    /// and which box is being walked (round-robin when a walk wraps).
    last_sweep: std::time::Instant,
    sweep_cursor: usize,
    sweep_peer: usize,
    pacer: Pacer,
    sampler: Sampler,
    pub stats: ClientStats,
}

/// Whether a probed-and-missed entry recorded at `probed_at` still
/// suppresses re-probing at `now` under `ttl`
/// ([`EdgeClientConfig::probe_negative_ttl`]).  A zero TTL never
/// suppresses — the strict `<` makes `Duration::ZERO` an exact off
/// switch, not a 1-tick cache.
fn negcache_suppresses(
    ttl: Duration,
    probed_at: std::time::Instant,
    now: std::time::Instant,
) -> bool {
    now.duration_since(probed_at) < ttl
}

impl EdgeClient {
    pub fn new(engine: Arc<Engine>, cfg: EdgeClientConfig) -> Result<Self> {
        anyhow::ensure!(cfg.chunk_tokens >= 1, "chunk_tokens must be >= 1");
        let meta = ModelMeta::new(engine.model_hash());
        // membership is keyed by each box's fleet-wide *gossip identity*
        // (usually its dial address), so every client gossiping about the
        // same fleet names the same peers in its digests
        let membership = Membership::with_addrs(
            cfg.peers
                .iter()
                .map(|p| p.gossip_identity().to_string())
                .collect(),
            HealthPolicy::default(),
        );
        // indirect probes: before a circumstantial Suspect → Dead commits,
        // ask up to `indirect_probes` other Up boxes to PING the suspect
        // over their own path (needs at least one possible relay)
        if cfg.indirect_probes > 0 && cfg.peers.len() >= 2 {
            let budget = cfg.deadline.unwrap_or(DeadlineBudget::new(
                Duration::from_millis(250),
                Duration::from_millis(250),
            ));
            membership.set_prober(
                Arc::new(RelayProber::new(&cfg.peers, budget)),
                cfg.indirect_probes,
            );
        }
        let mut peers = Vec::with_capacity(cfg.peers.len());
        for (i, pc) in cfg.peers.iter().enumerate() {
            let link = pc.link.clone().unwrap_or_else(|| cfg.link.clone());
            // per-peer deadline overrides win; else the fleet default
            let mut pc = pc.clone();
            if pc.deadline.is_none() {
                pc.deadline = cfg.deadline;
            }
            if pc.deadline_k <= 0.0 {
                pc.deadline_k = cfg.adaptive_deadline_k;
            }
            // per-peer shaper seed: peer 0 keeps the historical stream
            let mut peer = Peer::connect(
                pc,
                link,
                cfg.seed ^ (0x5AFE + i as u64),
                cfg.min_hit_tokens,
            )?;
            peer.set_health(membership.sink(i));
            if let Some(iv) = cfg.sync_interval {
                peer.spawn_sync_semantic(
                    iv,
                    Some(membership.sink(i)),
                    cfg.gossip.then(|| Arc::clone(&membership)),
                    cfg.semantic,
                )?;
            }
            peers.push(peer);
        }
        // peer 0's catalog doubles as the public single-box handle; a
        // standalone client gets a free-standing (never-hit) one
        let catalog = match peers.first() {
            Some(p) => Arc::clone(&p.catalog),
            None => {
                let mut c = LocalCatalog::new();
                c.min_hit_tokens = cfg.min_hit_tokens;
                Arc::new(Mutex::new(c))
            }
        };
        let pacer = Pacer::new(cfg.device.clone());
        let planner = PeerPlanner::default();
        // ring nodes hash by *address*, so every client sharing a fleet
        // computes the same owner sets regardless of peer listing order;
        // p2c keeps its historical seeded draw sequence
        let policy: Box<dyn Placement> = match cfg.placement {
            PlacementKind::PowerOfTwoChoices => Box::new(PowerOfTwoChoices::new(
                cfg.peers.len(),
                planner,
                cfg.seed ^ 0x9EE8,
            )),
            PlacementKind::RendezvousRing => Box::new(RendezvousRing::weighted(
                cfg.peers.iter().map(|p| (p.addr.clone(), p.weight)).collect(),
            )),
        };
        Ok(EdgeClient {
            sampler: Sampler::greedy(),
            meta,
            catalog,
            peers,
            planner,
            policy,
            verified_owners: HashMap::new(),
            membership,
            last_epoch: 0,
            probe_negative: HashMap::new(),
            last_sweep: std::time::Instant::now(),
            sweep_cursor: 0,
            sweep_peer: 0,
            pacer,
            stats: ClientStats::default(),
            engine,
            cfg,
        })
    }

    /// Push the membership view into the placement policy whenever it has
    /// moved: owner sets skip Dead boxes (their ring successors take
    /// over) and *heal back* automatically once a rebooted box's
    /// heartbeats clear probation — no lucky fallback probe required.
    /// Suspect and Recovering peers stay in the owner sets; only Dead is
    /// excluded.  Any transition also invalidates the repair memo and
    /// the probe negative cache, because both describe a fleet that no
    /// longer exists.  The telemetry mirrors are plain atomic loads and
    /// refresh on every call.
    fn refresh_membership(&mut self) {
        self.stats.suspect_transitions = self.membership.suspect_transitions();
        self.stats.heals = self.membership.heals();
        self.stats.timeouts = self.peers.iter().map(|p| p.ledger.timeouts).sum();
        self.stats.busy_rejections = self.peers.iter().map(|p| p.ledger.sheds).sum();
        self.stats.gossip_adoptions = self.membership.gossip_adoptions();
        self.stats.gossip_refutations = self.membership.refutations();
        self.stats.indirect_probes = self.membership.indirect_probes();
        self.stats.probe_saves = self.membership.probe_saves();
        let epoch = self.membership.epoch();
        if epoch == self.last_epoch {
            return;
        }
        self.last_epoch = epoch;
        self.policy.on_membership_change(&self.membership.alive_flags());
        self.verified_owners.clear();
        self.probe_negative.clear();
    }

    /// The fleet liveness view (heartbeat + hot-path fed) — benches and
    /// tests poll this to observe deaths and heals.
    pub fn membership(&self) -> &Arc<Membership> {
        &self.membership
    }

    /// Bring the liveness mirrors in [`ClientStats`] (and the placement
    /// policy's membership view) up to date — also happens automatically
    /// at every query start; call before reading `stats` after the last
    /// query of a trace.
    pub fn refresh_stats(&mut self) {
        self.refresh_membership();
    }

    /// The active placement policy's name (telemetry).
    pub fn placement_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Force a synchronous catalog pull from every peer over the pooled
    /// request-path connections (tests / deterministic benches).  Every
    /// reachable peer is synced even when another is down — surviving
    /// peers' entries must stay visible through a peer death — and the
    /// first failure is reported after the sweep.
    pub fn sync_catalog_now(&mut self) -> Result<()> {
        let mut first_err: Option<anyhow::Error> = None;
        let semantic = self.cfg.semantic;
        for peer in &mut self.peers {
            let catalog = Arc::clone(&peer.catalog);
            let sketches = Arc::clone(&peer.sketches);
            let res = match peer.conn_parts() {
                Some((conn, _)) => CatalogSync::sync_once(conn, &catalog).map(|()| {
                    if semantic {
                        // best-effort, like the background loop: a legacy
                        // box degrades the semantic tier, not the sync
                        let _ = CatalogSync::sketch_once(conn, &sketches);
                    }
                }),
                None => Err(anyhow::anyhow!(
                    "cache box at {} unreachable",
                    peer.cfg.addr
                )),
            };
            // a manual sync is a manual heartbeat: tests that drive the
            // catalog synchronously still feed the liveness view, so a
            // rebooted box heals without a background loop
            match res {
                Ok(()) => peer.note_io(Outcome::HeartbeatOk),
                Err(e) => {
                    peer.mark_dead_conn();
                    peer.note_io(Outcome::HeartbeatMiss);
                    first_err.get_or_insert(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Per-peer transfer/latency ledgers, in peer order.  Liveness
    /// counters (heartbeats, heals) are mirrored in from membership at
    /// read time, like `sync_rounds` — they are produced on the sync
    /// threads, not the query path.
    pub fn peer_ledgers(&self) -> Vec<PeerLedger> {
        self.peers
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let mut l = p.ledger.clone();
                l.sync_rounds = p.sync_rounds();
                if let Ok(s) = p.sketches.lock() {
                    l.sketch_entries = s.len() as u64;
                    l.sketch_sections = s.synced_sections;
                }
                let c = self.membership.peer_counters(i);
                l.heartbeats = c.heartbeats;
                l.heals = c.heals;
                l
            })
            .collect()
    }

    /// Number of configured cache-box peers.
    pub fn n_peers(&self) -> usize {
        self.peers.len()
    }

    fn max_new(&self) -> usize {
        self.cfg
            .max_new_tokens
            .unwrap_or(self.cfg.device.typical_response_tokens)
    }

    fn blob_layout(&self) -> BlobLayout {
        let cfg = &self.engine.model.config;
        BlobLayout::new(
            self.engine.model_hash(),
            cfg.n_layers,
            cfg.n_kv_heads,
            cfg.head_dim,
        )
        .with_chunk_tokens(self.cfg.chunk_tokens)
    }

    /// ECS3 chunk size to serialize an `entry_rows`-row entry with: the
    /// static config value, or — with adaptive sizing on — the link's
    /// break-even, preferring a compatible delta base's size (within 2× of
    /// optimal) because reusing its stored chunks verbatim via `SPLICE`
    /// beats a marginally better-sized full re-upload.
    fn chunk_tokens_for(&self, entry_rows: usize, delta_base: Option<&DeltaBase>) -> usize {
        if !self.cfg.adaptive_chunk {
            return self.cfg.chunk_tokens;
        }
        // break-even against the link the entry will actually ride: the
        // delta base's peer when splicing, else the first peer's link
        let link = delta_base
            .and_then(|b| self.peers.get(b.peer))
            .or_else(|| self.peers.first())
            .map(|p| &p.link)
            .unwrap_or(&self.cfg.link);
        let ct = adaptive_chunk_tokens(link, self.blob_layout().token_stride(), entry_rows);
        if let Some(b) = delta_base {
            if let Some(bct) = b.chunk_tokens {
                if b.compressed == (self.cfg.compression == Compression::Deflate)
                    && bct >= ct / 2
                    && bct <= ct * 2
                {
                    return bct;
                }
            }
        }
        ct
    }

    /// Total payload bytes this client has moved over the modelled links
    /// of every peer (both directions) — the honest wire ledger range
    /// transfers shrink.
    pub fn link_moved_bytes(&self) -> u64 {
        self.peers.iter().map(|p| p.shaper.moved_bytes).sum()
    }

    /// Logical (uncompressed) state bytes those transfers represent; with
    /// `Compression::Deflate` this exceeds [`EdgeClient::link_moved_bytes`]
    /// whenever the codec actually saves wire bytes.
    pub fn link_inflated_bytes(&self) -> u64 {
        self.peers.iter().map(|p| p.shaper.inflated_bytes).sum()
    }

    /// Latency the streaming download path hid by decoding chunks while
    /// later chunks were still on the modelled wire, summed over peers
    /// (see `netsim::Shaper::shaped_stream`).
    pub fn link_overlap_saved(&self) -> Duration {
        self.peers
            .iter()
            .map(|p| p.shaper.overlap_saved)
            .sum()
    }

    /// Tokenize the prompt and derive its Figure-3 range prefix lengths.
    fn tokenize_with_ranges(&mut self, prompt: &Prompt) -> (Vec<u32>, Vec<PromptRange>) {
        let engine = Arc::clone(&self.engine);
        let est = prompt.full_text().len() / 3;
        let tokens = self
            .pacer
            .paced_tokenize(est, || engine.tokenize_prompt(&prompt.full_text()));

        let mut lens: Vec<usize> = Vec::with_capacity(4);
        if self.cfg.partial_matching {
            for ptext in prompt.prefix_texts() {
                let ptoks = engine.tokenize_prompt(&ptext);
                // prefix-stability of the tokenizer guarantees this is a
                // token-prefix of `tokens`; clamp defensively anyway
                lens.push(ptoks.len().min(tokens.len()));
            }
        }
        lens.push(tokens.len());
        let ranges = ranges_for(&self.meta, &tokens, &lens);
        (tokens, ranges)
    }

    fn classify(ranges: &[PromptRange], matched: usize, full_len: usize) -> HitCase {
        if matched == 0 {
            return HitCase::Miss;
        }
        if matched >= full_len {
            return HitCase::Full;
        }
        // position of the matched range among the proper prefixes
        let idx = ranges.iter().position(|r| r.token_len == matched);
        let n_prefixes = ranges.len().saturating_sub(1); // exclude full
        match (idx, n_prefixes) {
            (Some(0), _) => HitCase::Instruction,
            (Some(i), n) if i + 1 == n => HitCase::AllExamples,
            (Some(_), _) => HitCase::FirstExample,
            (None, _) => HitCase::Miss,
        }
    }

    /// EXISTS-probe `peer_set` for `range`'s store key over each peer's
    /// shaped link, returning the claiming peers.  `fallback` counts the
    /// probes into the catalog-less fallback telemetry.
    fn probe_peers_exists(
        &mut self,
        peer_set: &[usize],
        range: &PromptRange,
        fallback: bool,
    ) -> Vec<usize> {
        let key = state_store_key(&range.key);
        let mut claimers = Vec::new();
        for &i in peer_set {
            if i >= self.peers.len() {
                continue;
            }
            let probe = {
                let peer = &mut self.peers[i];
                let Some((conn, shaper)) = peer.conn_parts() else {
                    peer.note_io(Outcome::IoDead);
                    continue; // unreachable peer: no probe was sent
                };
                shaper.shaped(0, || conn.exists(&key))
            };
            if fallback {
                self.stats.fallback_probes += 1;
                self.peers[i].ledger.fallback_probes += 1;
            }
            match probe {
                Ok(held) => {
                    self.peers[i].note_io(Outcome::IoOk);
                    if held {
                        claimers.push(i);
                    }
                }
                Err(e) => {
                    self.peers[i].mark_dead_conn();
                    self.peers[i].note_io(classify_io_err(&e));
                    self.stats.peer_failures += 1;
                }
            }
        }
        claimers
    }

    /// Catalog-less fallback (deterministic placement only): probe each
    /// candidate range's ring-designated owners, longest range first —
    /// bounded to primary + replicas per range, never the whole fleet.
    /// This is how a client that rebooted with an empty Bloom filter (or
    /// whose catalog sync is lagging) recovers warm-fleet hits a Bloom
    /// false negative would otherwise lose for good.  A probe-confirmed
    /// hit re-warms the claimers' local catalogs so the next query skips
    /// the probes entirely.
    fn probe_owner_sets(
        &mut self,
        ranges: &[PromptRange],
    ) -> Option<(PromptRange, Vec<usize>)> {
        // Coldness gate: probing exists to recover what a *cold* catalog
        // cannot see (a reboot emptied the Bloom filter, or sync never
        // ran).  Once every peer catalog has synced at least one master
        // delta, a Bloom miss is trustworthy — probing the owners on
        // every genuinely-new prompt would find nothing, so those probes
        // are suppressed and counted instead.
        let warm = !self.peers.is_empty()
            && self
                .peers
                .iter()
                .all(|p| p.catalog.lock().unwrap().synced_version > 0);
        let now = std::time::Instant::now();
        for r in ranges.iter().rev() {
            if r.token_len < self.cfg.min_hit_tokens {
                continue;
            }
            if warm {
                self.stats.probes_suppressed += 1;
                continue;
            }
            let skey = state_store_key(&r.key);
            // TTL'd negative cache: this key's owners recently answered
            // "not here" — don't ask again until the TTL lapses (or
            // membership moves, which clears the cache wholesale).  A zero
            // TTL disables the cache: every cold lookup re-probes.
            if let Some(&t) = self.probe_negative.get(&skey) {
                if negcache_suppresses(self.cfg.probe_negative_ttl, t, now) {
                    self.stats.probes_suppressed += 1;
                    continue;
                }
            }
            self.refresh_membership();
            // owners are hashed on the *store* key — the same identity the
            // upload placed by and an alias target names, so every layer
            // computes the same boxes
            let owners = self.policy.owners(&skey, self.cfg.replicas);
            if owners.is_empty() {
                return None; // non-deterministic policy: nothing to probe
            }
            let claimers = self.probe_peers_exists(&owners, r, true);
            if !claimers.is_empty() {
                self.stats.fallback_probe_hits += 1;
                for &i in &claimers {
                    self.peers[i].catalog.lock().unwrap().register_key(&r.key);
                }
                return Some((r.clone(), claimers));
            }
            self.probe_negative.insert(skey, now);
        }
        None
    }

    /// Step 2: consult every peer's local catalog — the hit names the
    /// peer(s) that claim the range ([`lookup_tagged`]).  On a catalog
    /// miss under deterministic placement, fall back to probing the
    /// ring-designated owners ([`EdgeClient::probe_owner_sets`]).  In the
    /// no-catalog ablation, probe with EXISTS for every candidate range
    /// over the shaped links — against the owner set when placement is
    /// deterministic, against every peer otherwise.
    fn lookup(
        &mut self,
        ranges: &[PromptRange],
        bd: &mut PhaseBreakdown,
    ) -> Option<(PromptRange, Vec<usize>)> {
        if self.peers.is_empty() {
            return None;
        }
        if self.cfg.use_catalog {
            let t0 = std::time::Instant::now();
            let bloom_cost = self.cfg.device.bloom_time(self.peers.len());
            let peers = &self.peers;
            let res = self.pacer.paced(bloom_cost, || {
                let guards: Vec<_> =
                    peers.iter().map(|p| p.catalog.lock().unwrap()).collect();
                let refs: Vec<&LocalCatalog> = guards.iter().map(|g| &**g).collect();
                lookup_tagged(&refs, ranges)
            });
            bd.add(Phase::Bloom, t0.elapsed());
            if res.is_some() || !self.policy.is_deterministic() {
                return res;
            }
            let t0 = std::time::Instant::now();
            let res = self.probe_owner_sets(ranges);
            bd.add(Phase::Redis, t0.elapsed());
            res
        } else {
            // §5.2.3 ablation: every inference pays remote round trips,
            // once per probed peer per candidate range until a claimer is
            // found — the ring bounds the probed set to the designated
            // owners instead of the whole fleet
            let t0 = std::time::Instant::now();
            let deterministic = self.policy.is_deterministic();
            let mut best: Option<(PromptRange, Vec<usize>)> = None;
            for r in ranges.iter().rev() {
                let peer_set: Vec<usize> = if deterministic {
                    self.refresh_membership();
                    self.policy
                        .owners(&state_store_key(&r.key), self.cfg.replicas)
                } else {
                    (0..self.peers.len()).collect()
                };
                let claimers = self.probe_peers_exists(&peer_set, r, deterministic);
                if !claimers.is_empty() {
                    best = Some((r.clone(), claimers));
                    break;
                }
            }
            bd.add(Phase::Redis, t0.elapsed());
            best
        }
    }

    /// Step 3 (hit path): download + verify + restore from the claiming
    /// peers.  `None` on false positive / eviction / corruption — caller
    /// falls back to local prefill.
    ///
    /// The first GET returns either the state blob itself (the hit range is
    /// the stored entry) or a range alias; an alias is resolved through the
    /// fabric — the matched ECS3 chunks striped across every claiming peer
    /// and streamed concurrently, with failures re-planned onto survivors
    /// (see [`fetch_prefix_multi`]).
    fn try_download(
        &mut self,
        range: &PromptRange,
        claimers: &[usize],
        tokens: &[u32],
        bd: &mut PhaseBreakdown,
    ) -> Option<Download> {
        let key = state_store_key(&range.key);
        let t0 = std::time::Instant::now();
        let out = self.fetch_state(&key, range, claimers, tokens);
        bd.add(Phase::Redis, t0.elapsed());
        match out {
            Some(d) if d.state.n_tokens == range.token_len => {
                self.stats.bytes_saved += d.saved_bytes as u64;
                Some(d)
            }
            Some(d) => {
                log_debug!(
                    "edge-client",
                    "state token count {} != range {}; discarding",
                    d.state.n_tokens,
                    range.token_len
                );
                None
            }
            None => None,
        }
    }

    /// GET the hit key from the claiming peers in order, rotating past
    /// dead or evicted copies.  Returns the alias/entry blob plus the slot
    /// of the peer that served it.
    fn fetch_alias_blob(&mut self, key: &[u8], claimers: &[usize]) -> Option<(usize, SharedBytes)> {
        for &i in claimers {
            let peer = &mut self.peers[i];
            let got = {
                let Some((conn, shaper)) = peer.conn_parts() else {
                    peer.note_io(Outcome::IoDead);
                    self.stats.peer_failures += 1;
                    continue;
                };
                shaper.shaped_post(|| {
                    let r = conn.get(key);
                    let n = r
                        .as_ref()
                        .map(|o| o.as_ref().map_or(0, |b| b.len()))
                        .unwrap_or(0);
                    (r, n)
                })
            };
            match got {
                Ok(Some(b)) => {
                    peer.note_io(Outcome::IoOk);
                    peer.ledger.bytes_down += b.len() as u64;
                    return Some((i, b));
                }
                Ok(None) => {
                    peer.note_io(Outcome::IoOk);
                    // this peer claimed the range but no longer holds it
                    // (evicted / Bloom FP); another claimer may still.
                    // An observed lost copy also invalidates the repair
                    // memo so the post-response sweep re-verifies owners.
                    self.verified_owners.remove(key);
                    log_debug!(
                        "edge-client",
                        "claimer {} lost the entry; rotating",
                        peer.cfg.addr
                    );
                }
                Err(e) => {
                    log_debug!("edge-client", "download failed: {e}");
                    peer.mark_dead_conn();
                    peer.note_io(classify_io_err(&e));
                    self.stats.peer_failures += 1;
                }
            }
        }
        None
    }

    fn fetch_state(
        &mut self,
        key: &[u8],
        range: &PromptRange,
        claimers: &[usize],
        tokens: &[u32],
    ) -> Option<Download> {
        let (alias_peer, blob) = self.fetch_alias_blob(key, claimers)?;
        let cfg = &self.engine.model.config;
        let dims = (cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim);
        let hash = self.engine.model_hash().to_string();
        let m = range.token_len;

        let Some(alias) = decode_range_alias(&blob) else {
            // the hit range is the stored entry itself: full restore
            return match KvState::restore(&blob, &hash, dims) {
                Ok(state) => {
                    self.peers[alias_peer]
                        .shaper
                        .note_inflated(state.payload_bytes(state.n_tokens));
                    Some(Download {
                        base: delta_base_for_entry(key.to_vec(), alias_peer, &blob),
                        wire_bytes: blob.len(),
                        saved_bytes: 0,
                        state,
                    })
                }
                Err(e) => {
                    log_debug!("edge-client", "restore rejected: {e}");
                    None
                }
            };
        };

        if alias.total_rows < m {
            log_debug!(
                "edge-client",
                "alias target holds {} rows < matched {m}; discarding",
                alias.total_rows
            );
            return None;
        }
        let target = alias.target_key;

        // fetch order: the alias-serving peer leads (historically it held
        // the blob too; under ring alias indirection it may hold only the
        // pointer — head rotation skips past it), the other Bloom claimers
        // follow; `fetch_entry_rows` appends the target key's ring owners,
        // so an alias discovered by catalog-less probing can still reach
        // the box that actually holds the blob.
        let order: Vec<usize> = std::iter::once(alias_peer)
            .chain(claimers.iter().copied().filter(|&i| i != alias_peer))
            .collect();
        self.fetch_entry_rows(
            target,
            alias.total_rows,
            alias.compressed,
            alias.chunk_tokens,
            m,
            order,
            tokens,
            blob.len(),
        )
    }

    /// Fetch the first `m` rows of the entry stored under `target` —
    /// geometry (`total_rows`, `compressed`, ECS3 `ct`) supplied by the
    /// caller: the exact path reads it out of a range alias, the semantic
    /// path out of a verified [`SketchRecord`].  `order` is the preferred
    /// peer order (claimers first); under deterministic placement the
    /// target's ring owners are appended.  `alias_wire` is whatever wire
    /// the caller already spent discovering the entry (alias GET / token
    /// header probe) and is folded into the download's byte ledger.
    #[allow(clippy::too_many_arguments)]
    fn fetch_entry_rows(
        &mut self,
        target: Vec<u8>,
        total_rows: usize,
        compressed: bool,
        chunk_tokens: Option<usize>,
        m: usize,
        mut order: Vec<usize>,
        tokens: &[u32],
        alias_wire: usize,
    ) -> Option<Download> {
        let cfg = &self.engine.model.config;
        let dims = (cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim);
        let hash = self.engine.model_hash().to_string();
        if self.policy.is_deterministic() {
            self.refresh_membership();
            for o in self.policy.owners(&target, self.cfg.replicas) {
                if !order.contains(&o) {
                    order.push(o);
                }
            }
        }

        // chunk-aligned fabric path: ECS3 aliases carry the target's chunk
        // size, so whole-chunk byte ranges never round to a mid-chunk
        // boundary — and deflated entries are range-served like any other.
        if let Some(ct) = chunk_tokens {
            // chunk-level fetch plan feeder (`coordinator::plan`):
            // regenerate cheap prefix chunks from the prompt tokens while
            // the expensive suffix streams from the peers.  Only engaged
            // under `--plan chunk` on devices whose prefill side is
            // modelled — the host profile would recompute "for free" and
            // must keep the historical all-fetch path.
            let stride = BlobLayout::new(&hash, dims.0, dims.2, dims.3).token_stride();
            let engine = Arc::clone(&self.engine);
            let pacer = &mut self.pacer;
            let mut feed = move |chunks: &[usize],
                                 seed: Option<KvState>|
                  -> Option<Vec<(usize, Vec<u8>)>> {
                let hi = *chunks.iter().max()?;
                let rows = m.min((hi + 1) * ct);
                // incremental rescue: resume prefill from the assembler's
                // already-committed contiguous row prefix instead of token
                // 0, so a mid-restore rescue pays for the orphan span only
                let st = match seed.filter(|s| s.n_tokens > 0 && s.n_tokens <= rows) {
                    Some(mut s) => {
                        let mut bd = PhaseBreakdown::default();
                        match engine.prefill_suffix(&mut s, &tokens[..rows], pacer, &mut bd)
                        {
                            Ok(_) => s,
                            Err(e) => {
                                log_debug!("edge-client", "seeded recompute failed: {e}");
                                return None;
                            }
                        }
                    }
                    None => match engine.prefill_prefix(&tokens[..m], rows, pacer) {
                        Ok(st) => st,
                        Err(e) => {
                            log_debug!("edge-client", "local recompute failed: {e}");
                            return None;
                        }
                    },
                };
                let mut out = Vec::with_capacity(chunks.len());
                for &c in chunks {
                    let t0 = c * ct;
                    let real = st.n_tokens.saturating_sub(t0).min(ct.min(m - t0));
                    if real == 0 {
                        continue;
                    }
                    // commit_chunk expects the chunk's *stored* rows (blob
                    // geometry); rows past the matched prefix are never
                    // scattered, so zero-padding them is sound
                    let stored = ct.min(total_rows - t0);
                    let mut payload = st.chunk_payload(t0, real);
                    payload.resize(stored * stride, 0);
                    out.push((c, payload));
                }
                Some(out)
            };
            let plan_chunks = self.cfg.plan == PlanMode::Chunk
                && self.cfg.device.models_recompute();
            let local = plan_chunks.then(|| LocalRecompute {
                feed: &mut feed,
                prefill_ms_per_tok: self.cfg.device.prefill_ms_per_tok,
            });
            let fetch = {
                let mut sel: Vec<(usize, &mut Peer)> = self
                    .peers
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| order.contains(i))
                    .collect();
                sel.sort_by_key(|(i, _)| {
                    order.iter().position(|&o| o == *i).unwrap_or(usize::MAX)
                });
                fetch_prefix_multi(
                    &mut sel,
                    &self.planner,
                    &target,
                    total_rows,
                    compressed,
                    ct,
                    m,
                    &hash,
                    dims,
                    local,
                )
            };
            match fetch {
                Some(f) => {
                    self.stats.range_fetches += 1;
                    self.stats.re_plans += f.re_plans;
                    self.stats.peer_failures += f.share_failures;
                    self.stats.replans_on_busy += f.busy_replans;
                    if f.share_failures > 0 {
                        // a claimer failed or had lost its copy mid-fetch:
                        // force the next repair sweep to re-verify this
                        // entry's owners instead of trusting the memo
                        self.verified_owners.remove(&target);
                    }
                    if f.multi_source {
                        self.stats.multi_source_fetches += 1;
                    }
                    self.stats.chunks_fetched += f.chunks_fetched as u64;
                    self.stats.chunks_recomputed += f.chunks_recomputed as u64;
                    if f.chunks_fetched > 0 && f.chunks_recomputed > 0 {
                        self.stats.plan_mixed += 1;
                    }
                    let head_peer = f.head_peer;
                    self.peers[head_peer]
                        .shaper
                        .note_inflated(f.state.payload_bytes(m));
                    // baseline: what the pre-chunking pipeline moved for
                    // this hit — compressed entries fell back to a
                    // full-blob download (head + whole body); uncompressed
                    // is the dedicated-m-row-blob model, same as uploads
                    let lo = BlobLayout::new(&hash, dims.0, dims.2, dims.3)
                        .with_chunk_tokens(ct);
                    let body_total: usize =
                        f.entries.iter().map(|e| e.len as usize).sum();
                    let baseline = if f.compressed {
                        lo.payload_off(total_rows) + body_total
                    } else {
                        lo.blob_len(m)
                    };
                    return Some(Download {
                        wire_bytes: alias_wire + f.wire,
                        saved_bytes: baseline.saturating_sub(f.wire),
                        base: DeltaBase {
                            store_key: target,
                            peer: head_peer,
                            total_rows,
                            compressed: f.compressed,
                            chunk_tokens: Some(ct),
                            chunk_index: f.entries,
                        },
                        state: f.state,
                    });
                }
                None => {
                    // never restore a questionable range: re-fetch the whole
                    // entry, which re-verifies everything from scratch (and
                    // degrades to a miss only if that fails too)
                    log_debug!(
                        "edge-client",
                        "fabric range path failed for {m}-row prefix; full-blob fallback"
                    );
                    self.stats.full_fetch_fallbacks += 1;
                }
            }
        }

        // full-blob path: legacy (pre-chunking) aliases land here directly,
        // the fabric path lands here when its verification fails.  Try the
        // fetch order (claimers, then ring target owners) until one serves
        // a verifiable entry.
        for &i in &order {
            if let Some((state, wire, full)) =
                fetch_full_entry(&mut self.peers[i], &target, m, &hash, dims)
            {
                self.peers[i].shaper.note_inflated(state.payload_bytes(m));
                return Some(Download {
                    base: delta_base_for_entry(target, i, &full),
                    wire_bytes: alias_wire + wire,
                    saved_bytes: 0,
                    state,
                });
            }
        }
        None
    }

    /// GET a donor's cheap token-id header (`tok:<hex>`) from the peers
    /// whose sketch tables advertise it, rotating past dead or evicted
    /// copies.  Returns the header's wire size plus the decoded ids.
    fn fetch_token_header(
        &mut self,
        key: &[u8; KEY_LEN],
        claimers: &[usize],
    ) -> Option<(usize, Vec<u32>)> {
        let tkey = token_store_key(key);
        for &i in claimers {
            if i >= self.peers.len() {
                continue;
            }
            let peer = &mut self.peers[i];
            let got = {
                let Some((conn, shaper)) = peer.conn_parts() else {
                    peer.note_io(Outcome::IoDead);
                    self.stats.peer_failures += 1;
                    continue;
                };
                shaper.shaped_post(|| {
                    let r = conn.get(&tkey);
                    let n = r
                        .as_ref()
                        .map(|o| o.as_ref().map_or(0, |b| b.len()))
                        .unwrap_or(0);
                    (r, n)
                })
            };
            match got {
                Ok(Some(b)) => {
                    peer.note_io(Outcome::IoOk);
                    peer.ledger.bytes_down += b.len() as u64;
                    match decode_token_ids(&b) {
                        Some(ids) => return Some((b.len(), ids)),
                        // unknown header version: the donor is unverifiable,
                        // and every copy stores the same bytes — give up on
                        // this candidate rather than rotating
                        None => return None,
                    }
                }
                Ok(None) => {
                    peer.note_io(Outcome::IoOk);
                    log_debug!(
                        "edge-client",
                        "token header missing on {}; rotating",
                        peer.cfg.addr
                    );
                }
                Err(e) => {
                    log_debug!("edge-client", "token header fetch failed: {e}");
                    peer.mark_dead_conn();
                    peer.note_io(classify_io_err(&e));
                    self.stats.peer_failures += 1;
                }
            }
        }
        None
    }

    /// The semantic tier, engaged ONLY after a total exact-catalog miss
    /// (never on exact hits — the caller guarantees the ordering): sketch
    /// the prompt, rank donor candidates from the per-peer sketch tables
    /// by Hamming distance, then **verify** each candidate by fetching its
    /// cheap token-id header and computing the real longest common token
    /// prefix.  Correctness never rides on the sketch: only the verified
    /// prefix is fetched, and causal attention makes the donor's first
    /// `lcp` rows bit-identical to a local prefill of the same tokens.
    /// A near sketch whose real overlap is below the hit floor is a
    /// *false probe* — one tiny header round trip, charged through the
    /// shaper and counted, never any KV bytes.
    fn semantic_lookup_fetch(
        &mut self,
        tokens: &[u32],
        bd: &mut PhaseBreakdown,
    ) -> Option<Download> {
        let floor = self.cfg.min_hit_tokens.max(1);
        if tokens.len() < floor || self.peers.is_empty() {
            return None;
        }
        // sketch + table scan: pure local compute, microseconds — a
        // genuinely novel prompt whose candidates all exceed the distance
        // bound costs zero wire here.  Attributed to the lookup phase.
        let t0 = std::time::Instant::now();
        let q = sketch_tokens(tokens);
        let mut merged: Vec<(SketchCandidate, Vec<usize>)> = Vec::new();
        for (i, peer) in self.peers.iter().enumerate() {
            let Ok(table) = peer.sketches.lock() else {
                continue;
            };
            for c in table.nearest(q, self.cfg.semantic_k, self.cfg.semantic_dist, floor) {
                match merged.iter_mut().find(|(m, _)| m.record.key == c.record.key) {
                    Some((_, cl)) => cl.push(i),
                    None => merged.push((c, vec![i])),
                }
            }
        }
        // closest sketch first; between equals, the longer donor (more
        // potential overlap per header probe)
        merged.sort_by(|a, b| {
            a.0.distance
                .cmp(&b.0.distance)
                .then(b.0.record.token_len.cmp(&a.0.record.token_len))
        });
        merged.truncate(self.cfg.semantic_k);
        bd.add(Phase::Bloom, t0.elapsed());

        for (cand, claimers) in merged {
            let rec = cand.record;
            self.stats.semantic_probes += 1;
            let t0 = std::time::Instant::now();
            let probe = self.fetch_token_header(&rec.key, &claimers);
            bd.add(Phase::Redis, t0.elapsed());
            let Some((probe_wire, donor)) = probe else {
                continue;
            };
            let lcp = common_prefix_len(tokens, &donor).min(rec.token_len as usize);
            if lcp < floor {
                self.stats.semantic_false_probes += 1;
                log_debug!(
                    "edge-client",
                    "false probe: sketch dist {} but real overlap {lcp} < {floor}",
                    cand.distance
                );
                continue;
            }
            // the same overhead-aware break-even gate the exact path runs,
            // on the *verified* overlap — never on the sketch's promise
            let est_bytes = self.engine.model.config.kv_bytes_per_token() * lcp;
            let link = claimers
                .first()
                .and_then(|&i| self.peers.get(i))
                .map(|p| p.link.clone())
                .unwrap_or_else(|| self.cfg.link.clone());
            if !self
                .cfg
                .fetch_policy
                .should_fetch(&self.cfg.device, &link, lcp, est_bytes)
            {
                self.stats.fetches_declined += 1;
                return None;
            }
            let t0 = std::time::Instant::now();
            let got = self.fetch_entry_rows(
                state_store_key(&rec.key),
                rec.token_len as usize,
                rec.compressed,
                (rec.chunk_tokens > 0).then_some(rec.chunk_tokens as usize),
                lcp,
                claimers,
                tokens,
                probe_wire,
            );
            bd.add(Phase::Redis, t0.elapsed());
            match got {
                Some(d) if d.state.n_tokens == lcp => {
                    self.stats.semantic_hits += 1;
                    self.stats.semantic_tokens_recovered += lcp as u64;
                    self.stats.bytes_saved += d.saved_bytes as u64;
                    return Some(d);
                }
                _ => {
                    // donor evicted or unverifiable mid-fetch; the sketch
                    // was honest (the header proved the overlap), so this
                    // is a peer failure, not a false probe
                    log_debug!(
                        "edge-client",
                        "semantic donor fetch failed; next candidate"
                    );
                }
            }
        }
        None
    }

    /// Probe a peer's keyspace load for the placement policy (`INFO`
    /// `used_bytes:` over the shaped link).  `u64::MAX` marks an
    /// unreachable peer so two-choices routes around it.
    fn probe_used_bytes(&mut self, i: usize) -> u64 {
        let res = {
            let Some((conn, shaper)) = self.peers[i].conn_parts() else {
                self.peers[i].note_io(Outcome::IoDead);
                return u64::MAX;
            };
            shaper.shaped_post(|| {
                let r = conn.info();
                let len = r.as_ref().map(|s| s.len()).unwrap_or(0);
                (r, len)
            })
        };
        match res {
            Ok(info) => {
                self.peers[i].note_io(Outcome::IoOk);
                // piggyback the admission telemetry the same INFO carries:
                // the box's high-water pending depth (absent on servers
                // predating the admission gate — the field is append-only)
                if let Some(pk) = crate::kvstore::client::parse_info_field(&info, "pending_peak")
                {
                    self.peers[i].ledger.peak_pending =
                        self.peers[i].ledger.peak_pending.max(pk as u64);
                }
                crate::kvstore::client::parse_info_used_bytes(&info)
                    .map(|v| v as u64)
                    .unwrap_or(u64::MAX)
            }
            Err(e) => {
                self.peers[i].mark_dead_conn();
                self.peers[i].note_io(classify_io_err(&e));
                self.stats.peer_failures += 1;
                u64::MAX
            }
        }
    }

    /// Ship one prepared request pipeline to `peer` over its pooled
    /// connection.  Returns the replies, or `None` after marking the
    /// connection dead (the caller picks another peer).
    fn send_upload(&mut self, i: usize, reqs: &[Value], wire: usize) -> Option<Vec<Value>> {
        let t0 = std::time::Instant::now();
        let res = {
            let Some((conn, shaper)) = self.peers[i].conn_parts() else {
                self.peers[i].note_io(Outcome::IoDead);
                self.stats.peer_failures += 1;
                return None;
            };
            shaper.shaped(wire, || conn.pipeline_req(reqs))
        };
        let peer = &mut self.peers[i];
        peer.ledger.breakdown.add(Phase::Redis, t0.elapsed());
        match res {
            Ok(replies) => {
                peer.note_io(Outcome::IoOk);
                peer.ledger.bytes_up += wire as u64;
                Some(replies)
            }
            Err(e) => {
                log_debug!("edge-client", "upload to {} failed: {e}", peer.cfg.addr);
                peer.mark_dead_conn();
                peer.note_io(classify_io_err(&e));
                self.stats.peer_failures += 1;
                None
            }
        }
    }

    /// Step 3 (miss path, post-response): publish every range the fabric
    /// does not already have.  One real blob is shipped per prompt — via
    /// `SPLICE` (suffix rows only) when a delta base is known, onto the
    /// base's own peer — and shorter ranges become tiny aliases into it.
    /// Fresh blobs are placed by power-of-two-choices on the peers'
    /// reported `used_bytes`; `cfg.replicas` extra full copies go to
    /// distinct peers so the range survives its primary dying.  Returns
    /// (wire bytes, duration, modelled bytes saved vs full-blob-per-range).
    fn upload_ranges(
        &mut self,
        state: &KvState,
        tokens: &[u32],
        ranges: &[PromptRange],
        skip_up_to: usize,
        prompt_tokens: usize,
        delta_base: Option<&DeltaBase>,
    ) -> (usize, Duration, usize) {
        if self.peers.is_empty() {
            return (0, Duration::ZERO, 0);
        }
        let t0 = std::time::Instant::now();
        let todo: Vec<PromptRange> = {
            // a range that any peer already (probably) holds is not
            // re-published anywhere
            let guards: Vec<_> = self
                .peers
                .iter()
                .map(|p| p.catalog.lock().unwrap())
                .collect();
            ranges
                .iter()
                .filter(|r| {
                    r.token_len > skip_up_to
                        && r.token_len <= prompt_tokens
                        && (self.cfg.partial_matching || r.token_len == prompt_tokens)
                        && !guards.iter().any(|c| c.contains_key(&r.key))
                })
                .cloned()
                .collect()
        };
        if todo.is_empty() {
            return (0, Duration::ZERO, 0);
        }

        let hash = self.engine.model_hash().to_string();
        let compressed = self.cfg.compression == Compression::Deflate;
        // ranges_for returns ascending lengths, so the last entry is longest
        let longest = todo.last().unwrap().clone();
        let n = longest.token_len;
        let ct = self.chunk_tokens_for(n, delta_base);
        let lo = self.blob_layout().with_chunk_tokens(ct);
        let long_key = state_store_key(&longest.key);

        // what the pre-delta pipeline would have shipped: one full nested
        // blob per range (modelled uncompressed)
        let seed_cost: usize = todo.iter().map(|r| lo.blob_len(r.token_len)).sum();

        // shared pipeline tail: the long-range registration plus one tiny
        // alias + registration per shorter range (identical on every peer
        // that receives a copy).  One alias body serves every shorter
        // range and owner — it only names the target entry.
        let alias_blob: SharedBytes =
            encode_range_alias(&long_key, n, compressed, ct).into();
        let alias_len = alias_blob.len();
        let mut tail_reqs: Vec<Value> = Vec::with_capacity(todo.len() * 2 + 1);
        let mut alias_wire = 0usize;
        tail_reqs.push(register_req(&longest.key));
        for r in todo.iter().filter(|r| r.token_len != n) {
            alias_wire += alias_len;
            tail_reqs.push(request_shared(vec![
                SharedBytes::copy_from(b"SET"),
                state_store_key(&r.key).into(),
                alias_blob.clone(),
            ]));
            tail_reqs.push(register_req(&r.key));
        }

        // semantic-tier registration rides the same pipeline tail, so
        // every box that stores a copy also serves verification probes
        // (the cheap token-id header) and advertises the entry in its
        // master sketch log.  A legacy box answers `CAT.SREGISTER` with
        // an in-pipeline error the senders ignore — against it the tier
        // degrades to exact-only, by construction.  Registered for the
        // *longest* range only: a token-prefix LCP against the full entry
        // subsumes every alias prefix.
        let sketch_rec = (self.cfg.semantic && n <= tokens.len()).then(|| SketchRecord {
            key: longest.key,
            sketch: sketch_tokens(&tokens[..n]),
            token_len: n as u32,
            chunk_tokens: ct as u32,
            compressed,
        });
        if let Some(rec) = &sketch_rec {
            let header: SharedBytes = encode_token_ids(&tokens[..n]).into();
            alias_wire += header.len();
            tail_reqs.push(request_shared(vec![
                SharedBytes::copy_from(b"SET"),
                token_store_key(&longest.key).into(),
                header,
            ]));
            let section: SharedBytes = encode_section(std::slice::from_ref(rec)).into();
            alias_wire += section.len();
            tail_reqs.push(request_shared(vec![
                SharedBytes::copy_from(b"CAT.SREGISTER"),
                section,
            ]));
        }

        // SPLICE is chunk-aligned: reuse the base's whole chunks below the
        // matched prefix (their compressed bytes stay server-side and their
        // index entries are copied into the new header); the ragged
        // remainder rides along with the suffix chunks.  Works for deflated
        // bases exactly like raw ones — chunks are independent streams.
        // The splice must land on the base's own peer; fresh blobs go to
        // the placement policy's winner instead.
        let delta = delta_base
            .filter(|b| {
                skip_up_to > 0
                    && b.total_rows >= skip_up_to
                    && b.compressed == compressed
                    && b.chunk_tokens == Some(ct)
                    && b.peer < self.peers.len()
            })
            .map(|b| (b, (skip_up_to / ct).min(b.chunk_index.len())))
            .filter(|(_, k)| *k >= 1);
        // placement targets from the pluggable policy, primary first then
        // the replica successors (ring: the deterministic HRW owner set,
        // zero probe round trips; p2c: successive two-choices used_bytes
        // probes).  The policy is briefly swapped out so its probe closure
        // can borrow the peer table.
        let targets: Vec<usize> = if delta.is_some() && self.cfg.replicas == 0 {
            Vec::new() // primary pinned to the base's peer, nothing to place
        } else {
            // with a pinned splice primary the policy only needs the
            // `replicas` extra copies, not a primary of its own — one
            // fewer draw, two fewer p2c INFO probes
            let want = if delta.is_some() {
                self.cfg.replicas - 1
            } else {
                self.cfg.replicas
            };
            self.refresh_membership();
            let mut policy = std::mem::replace(&mut self.policy, Box::new(Unplaced));
            // placement hashes the *store* key — the identity lookups
            // probe and alias targets name, so owners agree fleet-wide
            let t = policy.place_upload(&long_key, want, &mut |i| {
                self.probe_used_bytes(i)
            });
            self.policy = policy;
            t
        };
        // a splice pins the primary to the base entry's own peer; an empty
        // target set (both p2c probes dead) falls through to the
        // any-live-peer salvage path below rather than dropping the upload
        let primary: Option<usize> = match &delta {
            Some((b, _)) => Some(b.peer),
            None => targets.first().copied(),
        };

        // lazily-built full blob (fresh publishes, replicas, fallbacks);
        // captures no part of self so uploads can borrow self freely
        let compression = self.cfg.compression;
        let mut full_blob: Option<SharedBytes> = None;
        let hash_for_blob = hash.clone();
        let mut mk_full = |state: &KvState| -> SharedBytes {
            full_blob
                .get_or_insert_with(|| {
                    state.serialize_prefix_shared_opts(n, &hash_for_blob, compression, ct)
                })
                .clone()
        };

        // the one full-copy publish shape (fresh primaries, salvage after a
        // dead primary, replicas): SET long_key + the shared alias tail.
        // The blob comes in as a parameter so this closure never borrows
        // `mk_full`, which other paths also call.
        let publish_full_copy =
            |cl: &mut Self, i: usize, replica: bool, blob: SharedBytes| -> usize {
                let blen = blob.len();
                let mut reqs = Vec::with_capacity(tail_reqs.len() + 1);
                reqs.push(request_shared(vec![
                    SharedBytes::copy_from(b"SET"),
                    long_key.clone().into(),
                    blob,
                ]));
                reqs.extend(tail_reqs.iter().cloned());
                if cl.send_upload(i, &reqs, blen + alias_wire).is_none() {
                    return 0;
                }
                cl.peers[i].shaper.note_inflated(state.payload_bytes(n));
                if replica {
                    cl.peers[i].ledger.replica_uploads += 1;
                    cl.stats.replica_uploads += 1;
                } else {
                    cl.peers[i].ledger.uploads += 1;
                }
                cl.peers[i].ledger.placed_entries += 1;
                blen + alias_wire
            };

        // -- primary send (splice base peer or placement winner) ----------
        let mut wire = 0usize;
        // peers that verifiably *stored* a copy — only these get the
        // local-catalog registration below, so a botched publish is
        // re-attempted on a later query instead of poisoning the filter
        let mut uploaded_to: Vec<usize> = Vec::new();
        match (primary, &delta) {
            (Some(primary), Some((b, k))) => {
                let prefix = &b.chunk_index[..*k];
                let (head, tail) =
                    state.serialize_for_splice(n, &hash, compression, ct, prefix);
                let prefix_span: usize = prefix.iter().map(|e| e.len as usize).sum();
                let base_pay = lo.payload_off(b.total_rows);
                let head_wire = head.len() + tail.len();
                let mut reqs = Vec::with_capacity(tail_reqs.len() + 1);
                reqs.push(request_shared(vec![
                    SharedBytes::copy_from(b"SPLICE"),
                    long_key.clone().into(),
                    b.store_key.clone().into(),
                    base_pay.to_string().into_bytes().into(),
                    (base_pay + prefix_span).to_string().into_bytes().into(),
                    head,
                    tail,
                ]));
                reqs.extend(tail_reqs.iter().cloned());
                let send_wire = head_wire + alias_wire;
                if let Some(replies) = self.send_upload(primary, &reqs, send_wire) {
                    self.peers[primary]
                        .shaper
                        .note_inflated((n - k * ct) * lo.token_stride());
                    wire += send_wire;
                    let mut stored = true;
                    if matches!(replies.first(), Some(Value::Error(_))) {
                        // the delta base vanished (evicted) between download
                        // and upload: ship the whole blob after all
                        log_debug!(
                            "edge-client",
                            "splice base gone; falling back to a full upload"
                        );
                        let blob = mk_full(state);
                        let blen = blob.len();
                        let res = match self.peers[primary].conn_parts() {
                            Some((conn, shaper)) => {
                                shaper.shaped(blen, || conn.set_shared(&long_key, blob))
                            }
                            None => Err(anyhow::anyhow!("connection lost")),
                        };
                        match res {
                            Ok(()) => {
                                wire += blen;
                                self.peers[primary].ledger.bytes_up += blen as u64;
                                // the full blob replaced the delta: credit
                                // only the prefix rows the splice would
                                // have left in place — the suffix rows
                                // were already counted above
                                self.peers[primary]
                                    .shaper
                                    .note_inflated(k * ct * lo.token_stride());
                            }
                            Err(_) => {
                                // the aliases went through but the entry
                                // did not: leave the ranges unregistered
                                // locally so a later query republishes
                                self.peers[primary].mark_dead_conn();
                                self.stats.peer_failures += 1;
                                stored = false;
                            }
                        }
                    }
                    if stored {
                        self.peers[primary].ledger.uploads += 1;
                        self.peers[primary].ledger.placed_entries += 1;
                        uploaded_to.push(primary);
                    }
                }
            }
            (Some(primary), None) => {
                let added = publish_full_copy(self, primary, false, mk_full(state));
                if added > 0 {
                    wire += added;
                    uploaded_to.push(primary);
                }
            }
            (None, _) => {}
        }
        if uploaded_to.is_empty() {
            // primary dead, placement found no live probe, or the splice
            // fallback failed: publish the full blob on any other peer
            for i in (0..self.peers.len()).filter(|&i| Some(i) != primary) {
                let added = publish_full_copy(self, i, false, mk_full(state));
                if added > 0 {
                    wire += added;
                    uploaded_to.push(i);
                    break;
                }
            }
        }
        if uploaded_to.is_empty() {
            log_debug!(
                "edge-client",
                "upload failed on every peer (continuing local-only)"
            );
            // `wire` may be non-zero (a splice pipeline that landed on a
            // vanished base) — keep the byte ledger honest regardless
            self.stats.bytes_up += wire as u64;
            return (wire, t0.elapsed(), 0);
        }

        // -- replicas: extra full copies on the remaining policy targets
        // (ring: the key's deterministic replica successors, which is what
        // makes the replica set derivable by any client; p2c: the
        // two-choices picks made above), falling back to the rest of the
        // fleet in index order when a target cannot take its copy
        let mut extra = self.cfg.replicas;
        let mut tried: Vec<usize> = Vec::new();
        for i in targets.iter().copied().chain(0..self.peers.len()) {
            if extra == 0 {
                break;
            }
            if tried.contains(&i) || uploaded_to.contains(&i) {
                continue;
            }
            tried.push(i);
            let added = publish_full_copy(self, i, true, mk_full(state));
            if added > 0 {
                wire += added;
                uploaded_to.push(i);
                extra -= 1;
            }
        }

        // -- ring alias indirection: under deterministic placement every
        // shorter range's alias must ALSO live at *its own* store key's
        // owners — the blob bundle (with its co-located aliases) lives at
        // the longest key's owners, which is not where a catalog-less
        // probe for a shared prefix will look.  With the pointer at the
        // prefix key's own owner, the probe finds the alias there and the
        // fetch follows it to the target key's owners.  Aliases are tens
        // of bytes, so the extra copies are noise next to the blob.
        //
        // Deliberately NOT catalog-registered (no CAT.REGISTER, no local
        // Bloom entry): these copies are probe targets for catalog-less
        // recovery, not claims.  A Bloom claim would make lookups name
        // the alias-only box as a chunk source, planting guaranteed-Nil
        // stripes into every warm partial hit; Bloom discovery keeps
        // flowing from the bundle owners' registrations instead.
        if self.policy.is_deterministic() {
            let mut extras: Vec<(usize, Vec<Value>, usize)> = Vec::new();
            self.refresh_membership();
            for r in todo.iter().filter(|r| r.token_len != n) {
                let skey = state_store_key(&r.key);
                for o in self.policy.owners(&skey, self.cfg.replicas) {
                    if uploaded_to.contains(&o) {
                        continue; // the bundle there already carries the alias
                    }
                    let idx = match extras.iter().position(|(p, ..)| *p == o) {
                        Some(ix) => ix,
                        None => {
                            extras.push((o, Vec::new(), 0));
                            extras.len() - 1
                        }
                    };
                    let slot = &mut extras[idx];
                    slot.2 += alias_len;
                    slot.1.push(request_shared(vec![
                        SharedBytes::copy_from(b"SET"),
                        skey.clone().into(),
                        alias_blob.clone(),
                    ]));
                }
            }
            for (o, reqs, alias_bytes) in extras {
                if self.send_upload(o, &reqs, alias_bytes).is_none() {
                    continue; // a later probe simply misses this owner
                }
                wire += alias_bytes;
            }
        }

        // reflect the published ranges in the local catalog of every peer
        // that received a copy, so this client neither re-uploads nor
        // mis-plans future fetches
        for &i in &uploaded_to {
            let mut cat = self.peers[i].catalog.lock().unwrap();
            for r in &todo {
                cat.register_key(&r.key);
            }
            // mirror the sketch into this client's view of the peer
            // immediately — other clients learn it via CAT.SDELTA sync
            if let Some(rec) = sketch_rec {
                if let Ok(mut t) = self.peers[i].sketches.lock() {
                    t.insert(rec);
                }
            }
        }
        self.stats.bytes_up += wire as u64;
        let saved = seed_cost.saturating_sub(wire);
        self.stats.bytes_saved += saved as u64;
        (wire, t0.elapsed(), saved)
    }

    /// Ring-driven replica repair (post-response, deterministic placement
    /// only): probe the fetched entry's designated owners and re-publish
    /// it to any owner that no longer serves it — e.g. the ring successor
    /// that inherited ownership after a peer death, or an owner that
    /// evicted its copy.  This is how the replication factor is restored
    /// from the ring itself instead of per-entry bookkeeping: any client
    /// that just used an entry can recompute its owner set and heal it.
    ///
    /// The re-publish is **byte-faithful**: it only runs when the whole
    /// entry was restored (`base.total_rows == matched`) and it
    /// re-serializes with the entry's *own* compression and chunk size
    /// (from the download's delta base), so a repaired replica has the
    /// exact chunk geometry the survivors advertise — a multi-source
    /// stripe can mix it with the originals freely.  Repairing a prefix
    /// of a longer entry, or with this client's own codec settings,
    /// would plant a divergent copy whose chunk index disagrees with the
    /// head peer's; those cases are skipped — the timer-gated
    /// [`maybe_repair_sweep`](Self::maybe_repair_sweep) heals them from
    /// the authoritative stored bytes instead.  Bounded to primary +
    /// replicas probes per sweep; a probe
    /// that discovers a dead owner updates membership and the sweep runs
    /// once more against the recomputed owner set.
    fn repair_matched_range(
        &mut self,
        ranges: &[PromptRange],
        matched: usize,
        base: Option<&DeltaBase>,
        state: &KvState,
    ) {
        if matched == 0 || self.peers.is_empty() || !self.policy.is_deterministic() {
            return;
        }
        let Some(b) = base else { return };
        let Some(ct) = b.chunk_tokens else {
            return; // legacy v2 entry: never spliced, never repaired
        };
        if b.total_rows == matched {
            // whole entry restored: a byte-faithful blob re-publish
            let compression = if b.compressed {
                Compression::Deflate
            } else {
                Compression::None
            };
            let store_key = b.store_key.clone();
            let hash = self.engine.model_hash().to_string();
            // the catalog key the entry is announced under (present when
            // the hit range *is* the entry; an alias hit to an
            // exactly-matched entry repairs the data without
            // re-announcing it)
            let catalog_key = ranges
                .iter()
                .find(|r| state_store_key(&r.key) == store_key)
                .map(|r| r.key);
            // serialized lazily: a sweep that finds every owner intact
            // (the steady state) ships nothing
            let mut blob: Option<SharedBytes> = None;
            let mut mk = || {
                blob.get_or_insert_with(|| {
                    state.serialize_prefix_shared_opts(matched, &hash, compression, ct)
                })
                .clone()
            };
            self.repair_sweep(
                &store_key,
                catalog_key.as_ref().map(|k| &k[..]),
                &mut mk,
            );
        } else {
            // alias hit: an m-row prefix cannot re-create the longer
            // entry, but the *pointer* can be re-established at the
            // matched range's own owners — byte-canonical by
            // construction — so catalog-less recovery of this prefix
            // survives an alias owner's death.  Not catalog-registered,
            // like the upload-time alias indirection.
            let Some(range) = ranges.iter().find(|r| r.token_len == matched) else {
                return;
            };
            let skey = state_store_key(&range.key);
            let alias: SharedBytes =
                encode_range_alias(&b.store_key, b.total_rows, b.compressed, ct).into();
            self.repair_sweep(&skey, None, &mut || alias.clone());
        }
    }

    /// The bounded repair sweep shared by the blob and alias repair
    /// branches: probe `store_key`'s owners, re-publish via `mk` where
    /// the copy is missing, and re-sweep once if a probe discovered a
    /// dead owner (membership shifted under us).  A verified-intact
    /// owner set is memoized per store key, so repeat hits in the steady
    /// state pay zero probes — the memo self-invalidates whenever
    /// membership changes the owner set.
    fn repair_sweep(
        &mut self,
        store_key: &[u8],
        catalog_key: Option<&[u8]>,
        mk: &mut dyn FnMut() -> SharedBytes,
    ) {
        for _round in 0..2 {
            self.refresh_membership();
            let owners = self.policy.owners(store_key, self.cfg.replicas);
            if owners.is_empty() {
                return;
            }
            if self.verified_owners.get(store_key) == Some(&owners) {
                return; // steady state: this owner set already verified
            }
            let out = repair_entry(&mut self.peers, &owners, store_key, catalog_key, mk);
            self.stats.repair_republishes += out.republished;
            self.stats.bytes_up += out.wire as u64;
            if out.dead == 0 {
                // a rejected publish (box at its memory limit) leaves the
                // replica missing — don't memoize, so a later hit retries
                if out.rejected == 0 {
                    self.verified_owners.insert(store_key.to_vec(), owners);
                }
                return; // owner set was current; the sweep is authoritative
            }
        }
    }

    /// One timer-gated step of the proactive repair sweep
    /// ([`EdgeClientConfig::repair_sweep`]): SCAN the next slice of the
    /// current box's key space and ring-repair every state entry found.
    /// Full entries re-publish byte-faithfully from the scanned copy;
    /// range-alias pointers re-establish at their own key's owners and are
    /// deliberately not catalog-registered (matching upload-time ring
    /// alias indirection).  The verified-owner memo inside
    /// [`repair_sweep`](Self::repair_sweep) makes steady-state steps
    /// probe-free; a wrapped walk rotates to the next box.  Runs
    /// post-response, never on the query latency path, and only under
    /// deterministic placement (owner sets are derivable).
    fn maybe_repair_sweep(&mut self) {
        const SWEEP_BATCH: usize = 16;
        if self.cfg.repair_sweep.is_zero()
            || self.peers.is_empty()
            || !self.policy.is_deterministic()
            || self.last_sweep.elapsed() < self.cfg.repair_sweep
        {
            return;
        }
        self.last_sweep = std::time::Instant::now();
        let pi = self.sweep_peer % self.peers.len();
        let cursor = self.sweep_cursor;
        let scanned = {
            let peer = &mut self.peers[pi];
            let Some((conn, shaper)) = peer.conn_parts() else {
                peer.note_io(Outcome::IoDead);
                return;
            };
            shaper.shaped(0, || conn.scan_keys(cursor, SWEEP_BATCH))
        };
        let (next, keys) = match scanned {
            Ok(v) => {
                self.peers[pi].note_io(Outcome::IoOk);
                v
            }
            Err(e) => {
                // a legacy box without SCAN answers an error on a healthy
                // connection — rotate to the next box instead of spinning
                // (regular traffic still detects genuinely dead conns)
                log_debug!("edge-client", "repair sweep scan failed: {e}");
                self.sweep_cursor = 0;
                self.sweep_peer = (pi + 1) % self.peers.len();
                return;
            }
        };
        for key in keys {
            // only state entries are ring-placed; token headers and other
            // key families ride along with their bundle's copies
            if !key.starts_with(b"state:") {
                continue;
            }
            let ck: Option<[u8; KEY_LEN]> = std::str::from_utf8(&key[6..])
                .ok()
                .and_then(crate::util::hex::decode)
                .and_then(|v| v.try_into().ok());
            let Some(ck) = ck else {
                continue; // malformed key: not ours to repair
            };
            let blob = {
                let peer = &mut self.peers[pi];
                let Some((conn, shaper)) = peer.conn_parts() else {
                    peer.note_io(Outcome::IoDead);
                    return;
                };
                match shaper.shaped_post(|| {
                    let r = conn.get(&key);
                    let n = r
                        .as_ref()
                        .map(|o| o.as_ref().map_or(0, |b| b.len()))
                        .unwrap_or(0);
                    (r, n)
                }) {
                    Ok(Some(b)) => {
                        peer.note_io(Outcome::IoOk);
                        peer.ledger.bytes_down += b.len() as u64;
                        b
                    }
                    Ok(None) => {
                        peer.note_io(Outcome::IoOk);
                        continue; // evicted between SCAN and GET
                    }
                    Err(e) => {
                        log_debug!("edge-client", "sweep read failed: {e}");
                        self.peers[pi].mark_dead_conn();
                        self.peers[pi].note_io(classify_io_err(&e));
                        return;
                    }
                }
            };
            // alias pointers are repaired key-only (never registered);
            // real entries re-register their catalog key at every healed
            // owner, exactly like the hit-path repair
            if decode_range_alias(&blob).is_some() {
                self.repair_sweep(&key, None, &mut || blob.clone());
            } else {
                self.repair_sweep(&key, Some(&ck[..]), &mut || blob.clone());
            }
        }
        self.sweep_cursor = next;
        if next == 0 {
            // walked the whole box: start over on the next one
            self.sweep_peer = (pi + 1) % self.peers.len();
        }
    }

    /// The full steps-1-to-4 query flow for a structured prompt.
    pub fn query(&mut self, prompt: &Prompt) -> Result<QueryResult> {
        let mut bd = PhaseBreakdown::default();
        self.stats.queries += 1;
        // pick up heartbeat-driven transitions (a heal, a death the sync
        // loop saw first) before the lookup decides who to ask
        self.refresh_membership();
        let inflated0 = self.link_inflated_bytes();
        let overlap0 = self.link_overlap_saved();

        // -- step 1: tokenize -------------------------------------------------
        let t0 = std::time::Instant::now();
        let (tokens, ranges) = self.tokenize_with_ranges(prompt);
        bd.add(Phase::Token, t0.elapsed());
        let full_len = tokens.len();

        // -- step 2: peer-tagged catalog lookup -------------------------------
        let lookup = self.lookup(&ranges, &mut bd);

        // -- step 3: fetch or local prefill ----------------------------------
        let mut matched = 0usize;
        let mut false_positive = false;
        let mut downloaded = 0usize;
        let mut saved = 0usize;
        let mut delta_base: Option<DeltaBase> = None;
        let mut state: Option<KvState> = None;

        if let Some((range, claimers)) = lookup {
            let est_bytes = self.engine.model.config.kv_bytes_per_token() * range.token_len;
            // break-even against the first claimer's link — the one the
            // head (and a single-source fetch) would ride
            let link = claimers
                .first()
                .and_then(|&i| self.peers.get(i))
                .map(|p| p.link.clone())
                .unwrap_or_else(|| self.cfg.link.clone());
            if self.cfg.fetch_policy.should_fetch(
                &self.cfg.device,
                &link,
                range.token_len,
                est_bytes,
            ) {
                match self.try_download(&range, &claimers, &tokens, &mut bd) {
                    Some(d) => {
                        matched = d.state.n_tokens;
                        downloaded = d.wire_bytes;
                        saved += d.saved_bytes;
                        self.stats.bytes_down += d.wire_bytes as u64;
                        delta_base = Some(d.base);
                        state = Some(d.state);
                    }
                    None => {
                        false_positive = true;
                        self.stats.false_positives += 1;
                    }
                }
            } else {
                self.stats.fetches_declined += 1;
            }
        } else if self.cfg.semantic {
            // total exact miss: the semantic tier may still find a
            // paraphrase donor.  Strictly ordered AFTER the exact lookup
            // — an exact hit (even partial) never engages it, so exact
            // workloads see zero behaviour change.
            if let Some(d) = self.semantic_lookup_fetch(&tokens, &mut bd) {
                matched = d.state.n_tokens;
                downloaded = d.wire_bytes;
                saved += d.saved_bytes;
                self.stats.bytes_down += d.wire_bytes as u64;
                delta_base = Some(d.base);
                state = Some(d.state);
            }
        }
        let mut state = state.unwrap_or_else(|| self.engine.fresh_state());

        // first-token logits: prefill the (possibly whole) suffix, or
        // re-derive on a full hit — phase attribution inside first_logits
        let engine = Arc::clone(&self.engine);
        let first =
            engine.first_logits(&mut state, &tokens, &mut self.pacer, &mut bd)?;

        // -- step 4: decode the response --------------------------------------
        let out_tokens = engine.decode_loop(
            &mut state,
            first,
            self.max_new(),
            &mut self.sampler,
            &mut self.pacer,
            &mut bd,
        )?;
        let text = engine.tokenizer.decode(&out_tokens);

        // -- post-response upload (miss/partial path) -------------------------
        let (uploaded, upload_time, upload_saved) = self.upload_ranges(
            &state,
            &tokens,
            &ranges,
            matched,
            full_len,
            delta_base.as_ref(),
        );
        saved += upload_saved;

        // -- ring-driven replica repair (hit path, post-response) -------------
        self.repair_matched_range(&ranges, matched, delta_base.as_ref(), &state);

        // -- proactive repair sweep (timer-gated, post-response) --------------
        self.maybe_repair_sweep();

        let case = Self::classify(&ranges, matched, full_len);
        self.stats.hits_by_case[case.number() - 1] += 1;

        bd.prompt_tokens = full_len;
        bd.reused_tokens = matched;
        bd.state_bytes = downloaded.max(uploaded);
        bd.saved_bytes = saved;
        bd.wire_bytes = downloaded + uploaded;
        bd.inflated_bytes = (self.link_inflated_bytes() - inflated0) as usize;
        bd.overlap_saved = self.link_overlap_saved() - overlap0;

        Ok(QueryResult {
            case,
            matched_tokens: matched,
            prompt_tokens: full_len,
            response_tokens: out_tokens,
            response_text: text,
            breakdown: bd,
            false_positive,
            downloaded_bytes: downloaded,
            uploaded_bytes: uploaded,
            saved_bytes: saved,
            upload_time,
        })
    }

    /// Baseline: bypass the distributed cache entirely (pure local flow).
    pub fn query_local_only(&mut self, prompt: &Prompt) -> Result<QueryResult> {
        let engine = Arc::clone(&self.engine);
        let out = engine.generate(&prompt.full_text(), self.max_new(), &mut self.pacer)?;
        Ok(QueryResult {
            case: HitCase::Miss,
            matched_tokens: 0,
            prompt_tokens: out.prompt_tokens,
            response_tokens: out.tokens.clone(),
            response_text: out.text,
            breakdown: out.breakdown,
            false_positive: false,
            downloaded_bytes: 0,
            uploaded_bytes: 0,
            saved_bytes: 0,
            upload_time: Duration::ZERO,
        })
    }

    pub fn shutdown(mut self) {
        for p in &mut self.peers {
            p.stop_sync();
        }
    }
}

fn register_req(catalog_key: &[u8; crate::catalog::KEY_LEN]) -> Value {
    request_shared(vec![
        SharedBytes::copy_from(b"CAT.REGISTER"),
        catalog_key.to_vec().into(),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cachebox::CacheBox;
    use crate::workload::Generator;

    fn engine() -> Option<Arc<Engine>> {
        let dir = crate::artifacts_dir().join("tiny");
        if !dir.join("meta.json").exists() {
            eprintln!("skipping: artifacts/tiny missing");
            return None;
        }
        Some(Arc::new(Engine::load_preset("tiny").unwrap()))
    }

    fn native_cfg(name: &str, server: Option<String>) -> EdgeClientConfig {
        EdgeClientConfig {
            name: name.into(),
            max_new_tokens: Some(2),
            sync_interval: None,
            ..EdgeClientConfig::native(server)
        }
    }

    #[test]
    fn miss_then_full_hit_same_client() {
        let Some(eng) = engine() else { return };
        let cb = CacheBox::start_local().unwrap();
        let mut c = EdgeClient::new(eng, native_cfg("c1", Some(cb.addr()))).unwrap();
        let p = Generator::new(3).prompt("astronomy", 0, 2);

        let r1 = c.query(&p).unwrap();
        assert_eq!(r1.case, HitCase::Miss);
        assert!(r1.uploaded_bytes > 0, "miss must upload states");

        let r2 = c.query(&p).unwrap();
        assert_eq!(r2.case, HitCase::Full, "identical prompt must fully hit");
        assert!(r2.downloaded_bytes > 0);
        assert_eq!(r2.uploaded_bytes, 0, "nothing new to upload");
        // correctness: identical response via the cache path
        assert_eq!(r1.response_tokens, r2.response_tokens);
        cb.shutdown();
    }

    #[test]
    fn cross_client_sharing_via_sync() {
        let Some(eng) = engine() else { return };
        let cb = CacheBox::start_local().unwrap();
        let mut c1 =
            EdgeClient::new(Arc::clone(&eng), native_cfg("c1", Some(cb.addr()))).unwrap();
        let mut c2 = EdgeClient::new(eng, native_cfg("c2", Some(cb.addr()))).unwrap();
        let p = Generator::new(5).prompt("virology", 0, 2);

        let r1 = c1.query(&p).unwrap();
        assert_eq!(r1.case, HitCase::Miss);

        // client 2 hasn't synced yet: miss (but its upload dedups via server-
        // registered keys only after sync; it may re-upload, which is fine)
        c2.sync_catalog_now().unwrap();
        let r2 = c2.query(&p).unwrap();
        assert_eq!(r2.case, HitCase::Full, "client 2 reuses client 1's state");
        assert_eq!(r1.response_tokens, r2.response_tokens);
        cb.shutdown();
    }

    #[test]
    fn partial_hit_same_domain_different_question() {
        let Some(eng) = engine() else { return };
        let cb = CacheBox::start_local().unwrap();
        let mut c = EdgeClient::new(eng, native_cfg("c", Some(cb.addr()))).unwrap();
        let g = Generator::new(7);
        let p0 = g.prompt("anatomy", 0, 2);
        let p1 = g.prompt("anatomy", 1, 2);
        assert_eq!(p0.examples, p1.examples);

        let r0 = c.query(&p0).unwrap();
        assert_eq!(r0.case, HitCase::Miss);
        let r1 = c.query(&p1).unwrap();
        assert_eq!(
            r1.case,
            HitCase::AllExamples,
            "same-domain question must hit the shared instruction+examples prefix"
        );
        assert!(r1.matched_tokens > 0 && r1.matched_tokens < r1.prompt_tokens);
        // the suffix still had to be prefilled locally
        assert!(r1.breakdown.get(Phase::PDecode) > Duration::ZERO);
        // the partial hit resolved an alias and fetched only the matched
        // rows, not a dedicated full blob
        assert!(r1.saved_bytes > 0, "range download + delta upload must save bytes");
        cb.shutdown();
    }

    #[test]
    fn standalone_mode_without_server() {
        let Some(eng) = engine() else { return };
        let mut c = EdgeClient::new(eng, native_cfg("solo", None)).unwrap();
        let p = Generator::new(9).prompt("marketing", 0, 1);
        let r = c.query(&p).unwrap();
        assert_eq!(r.case, HitCase::Miss);
        assert_eq!(r.uploaded_bytes, 0);
        assert!(!r.response_tokens.is_empty());
    }

    #[test]
    fn false_positive_falls_back_to_local() {
        let Some(eng) = engine() else { return };
        let cb = CacheBox::start_local().unwrap();
        let mut c = EdgeClient::new(eng, native_cfg("c", Some(cb.addr()))).unwrap();
        let p = Generator::new(11).prompt("prehistory", 0, 1);

        // poison the local catalog so every range looks cached
        {
            let (tokens, ranges) = c.tokenize_with_ranges(&p);
            let _ = tokens;
            c.catalog.lock().unwrap().register(&ranges);
        }
        let r = c.query(&p).unwrap();
        assert!(r.false_positive, "GET must come back empty → FP fallback");
        assert_eq!(r.case, HitCase::Miss);
        assert!(!r.response_tokens.is_empty(), "inference still completes");
        assert_eq!(c.stats.false_positives, 1);
        cb.shutdown();
    }

    #[test]
    fn no_catalog_ablation_probes_server() {
        let Some(eng) = engine() else { return };
        let cb = CacheBox::start_local().unwrap();
        let mut cfg = native_cfg("nocat", Some(cb.addr()));
        cfg.use_catalog = false;
        let mut c = EdgeClient::new(eng, cfg).unwrap();
        let p = Generator::new(13).prompt("sociology", 0, 1);
        let r1 = c.query(&p).unwrap();
        assert_eq!(r1.case, HitCase::Miss);
        let r2 = c.query(&p).unwrap();
        assert_eq!(r2.case, HitCase::Full, "EXISTS probing still finds states");
        cb.shutdown();
    }

    #[test]
    fn compression_roundtrips_through_cachebox() {
        let Some(eng) = engine() else { return };
        let cb = CacheBox::start_local().unwrap();
        let mut cfg = native_cfg("comp", Some(cb.addr()));
        cfg.compression = Compression::Deflate;
        let mut c = EdgeClient::new(eng, cfg).unwrap();
        let p = Generator::new(15).prompt("nutrition", 0, 1);
        let r1 = c.query(&p).unwrap();
        let r2 = c.query(&p).unwrap();
        assert_eq!(r2.case, HitCase::Full);
        assert_eq!(r1.response_tokens, r2.response_tokens);
        cb.shutdown();
    }

    #[test]
    fn compressed_partial_hit_uses_range_path() {
        // deflate entries are chunk-compressed (ECS3): an alias hit fetches
        // only the matched chunks — no full-blob fallback — and still
        // reproduces the right state
        let Some(eng) = engine() else { return };
        let cb = CacheBox::start_local().unwrap();
        let mut cfg = native_cfg("comp-partial", Some(cb.addr()));
        cfg.compression = Compression::Deflate;
        let mut c = EdgeClient::new(eng, cfg).unwrap();
        let g = Generator::new(27);
        let p0 = g.prompt("virology", 0, 2);
        let p1 = g.prompt("virology", 1, 2);

        let r0 = c.query(&p0).unwrap();
        assert_eq!(r0.case, HitCase::Miss);
        let r1 = c.query(&p1).unwrap();
        assert_eq!(r1.case, HitCase::AllExamples);
        assert!(r1.matched_tokens > 0 && r1.downloaded_bytes > 0);
        assert_eq!(c.stats.range_fetches, 1, "deflated alias hit must range-fetch");
        assert_eq!(c.stats.full_fetch_fallbacks, 0, "no full-blob fallback");
        assert!(r1.saved_bytes > 0, "range fetch must beat the full-entry model");
        cb.shutdown();
    }

    #[test]
    fn adaptive_chunk_tokens_break_even_shape() {
        // the paper's Wi-Fi 4 + 270M-class stride (6 layers, 1 head, 80
        // dims = 3840 B/token) lands on the old fixed default
        let stride_270m = 2 * 6 * 80 * 4;
        let wifi = LinkModel::wifi4_2g4();
        assert_eq!(
            adaptive_chunk_tokens(&wifi, stride_270m, 117),
            DEFAULT_CHUNK_TOKENS
        );
        // cheap RTT (wired) shrinks chunks; a long-fat link grows them
        let eth = LinkModel::ethernet_1g();
        assert!(adaptive_chunk_tokens(&eth, stride_270m, 117) < DEFAULT_CHUNK_TOKENS);
        let long_fat = LinkModel {
            name: "sat",
            goodput_bps: wifi.goodput_bps,
            rtt: std::time::Duration::from_millis(2000),
            jitter_frac: 0.0,
        };
        assert!(
            adaptive_chunk_tokens(&long_fat, stride_270m, 117) > DEFAULT_CHUNK_TOKENS
        );
        // monotone: fatter strides want smaller chunks, longer entries larger
        let a = adaptive_chunk_tokens(&wifi, stride_270m, 117);
        assert!(adaptive_chunk_tokens(&wifi, stride_270m * 8, 117) <= a);
        assert!(adaptive_chunk_tokens(&wifi, stride_270m, 117 * 16) >= a);
        // always a clamped power of two, even in degenerate corners
        for (stride, rows) in [(1usize, 1usize), (1 << 20, 1), (4, 1 << 20)] {
            let ct = adaptive_chunk_tokens(&wifi, stride, rows);
            assert!((1..=1024).contains(&ct));
            assert!(ct.is_power_of_two());
        }
        // loopback has no BDP: only the fixed per-chunk overhead remains
        let lo = adaptive_chunk_tokens(&LinkModel::loopback(), stride_270m, 117);
        assert!((1..=4).contains(&lo), "{lo}");
    }

    #[test]
    fn negcache_zero_ttl_disables_suppression() {
        use std::time::Instant;
        let probed = Instant::now();
        let now = probed + Duration::from_millis(1);
        // configured TTL (the default 1.5 s) suppresses a fresh miss…
        let ttl = EdgeClientConfig::native(None).probe_negative_ttl;
        assert!(ttl > Duration::ZERO, "default TTL must be non-zero");
        assert!(negcache_suppresses(ttl, probed, now));
        // …and stops suppressing once the entry outlives it
        assert!(!negcache_suppresses(ttl, probed, probed + ttl));
        // a zero TTL never suppresses, even at the exact probe instant —
        // the `--negcache-ms 0` ablation re-probes every cold lookup
        assert!(!negcache_suppresses(Duration::ZERO, probed, probed));
        assert!(!negcache_suppresses(Duration::ZERO, probed, now));
    }

    #[test]
    fn classify_cases() {
        use HitCase::*;
        let meta = ModelMeta::new("x");
        let toks: Vec<u32> = (0..100).collect();
        let ranges = ranges_for(&meta, &toks, &[10, 30, 60, 100]);
        assert_eq!(EdgeClient::classify(&ranges, 0, 100), Miss);
        assert_eq!(EdgeClient::classify(&ranges, 10, 100), Instruction);
        assert_eq!(EdgeClient::classify(&ranges, 30, 100), FirstExample);
        assert_eq!(EdgeClient::classify(&ranges, 60, 100), AllExamples);
        assert_eq!(EdgeClient::classify(&ranges, 100, 100), Full);
    }
}
