//! Fetch and placement policies for the peer fabric.
//!
//! * [`FetchPolicy`] — should a catalog hit trigger a state download?  The
//!   paper always fetches on a (probable) hit and *shows* in Table 2 that
//!   this loses on the high-end device (Redis 2.89 s vs P-decode 2.69 s).
//!   Its §5.3 break-even discussion is turned here into an explicit runtime
//!   policy — [`FetchPolicy::BreakEven`] — evaluated in the ablation bench.
//!   Both variants decide for the *whole* matched range at once; the
//!   per-chunk mixed planner in [`super::plan`] subsumes them (`--plan
//!   chunk`), and this all-or-nothing form is kept as its `--plan range`
//!   ablation baseline.
//! * [`PeerPlanner`] — with N cache boxes instead of one, three decisions
//!   appear that a single-box system never had to make: how to *split* a
//!   matched chunk set across the peers that claim it (goodput-weighted
//!   contiguous stripes, so aggregate download bandwidth scales with peer
//!   count), how to *re-plan* the orphaned chunks when a peer dies
//!   mid-fetch (round-robin over survivors), and where to *place* an upload
//!   (power-of-two-choices on reported `used_bytes` — near-balanced load
//!   for two probes instead of N).  [`PeerPlanner::place`] is the sampling
//!   primitive behind the pluggable `coordinator::placement` policy
//!   (`PowerOfTwoChoices`); the deterministic alternative lives there too
//!   (`RendezvousRing`).

use std::ops::Range;

use crate::devicemodel::DeviceProfile;
use crate::netsim::LinkModel;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchPolicy {
    /// Paper behaviour: a catalog hit always triggers a download.
    Always,
    /// Download only if the modelled transfer time beats the modelled local
    /// prefill time for the tokens the hit would save.
    BreakEven,
}

impl FetchPolicy {
    /// Decide whether to fetch a cached state of `matched_tokens` tokens and
    /// `state_bytes` bytes instead of prefilling those tokens locally.
    pub fn should_fetch(
        self,
        device: &DeviceProfile,
        link: &LinkModel,
        matched_tokens: usize,
        state_bytes: usize,
    ) -> bool {
        match self {
            FetchPolicy::Always => true,
            FetchPolicy::BreakEven => {
                let transfer = link.delay_for(state_bytes, None);
                let prefill = device.prefill_time(matched_tokens);
                transfer < prefill
            }
        }
    }

    /// Smallest matched-token count at which fetching wins on this
    /// device+link (analysis helper; assumes `bytes_per_token` state size).
    ///
    /// Beyond the RTT floor both sides are linear in `n` — transfer is
    /// `rtt + n·bpt/goodput`, prefill is `n·ms_per_tok` — so the predicate
    /// "transfer < prefill" is monotone: once fetching wins it keeps
    /// winning.  A binary search over the same `1..100_000` window the old
    /// linear scan used (returning `usize::MAX` beyond it, where prefill
    /// never catches up) finds the crossing in ~17 model evaluations
    /// instead of up to 100k.
    pub fn break_even_tokens(
        device: &DeviceProfile,
        link: &LinkModel,
        bytes_per_token: usize,
    ) -> usize {
        const LIMIT: usize = 100_000;
        let fetch_wins =
            |n: usize| link.delay_for(n * bytes_per_token, None) < device.prefill_time(n);
        if !fetch_wins(LIMIT - 1) {
            return usize::MAX;
        }
        // invariant: fetch_wins(hi) holds, fetch_wins(lo - 1) does not
        let (mut lo, mut hi) = (1usize, LIMIT - 1);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if fetch_wins(mid) {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo
    }
}

/// Chunk-split, failure re-planning and upload placement for the N-peer
/// cache fabric (see module docs).  Stateless apart from its knobs, so the
/// client and the benches share one implementation.
#[derive(Debug, Clone, Copy)]
pub struct PeerPlanner {
    /// How many re-plan rounds a multi-source fetch may attempt after
    /// share failures before giving up to the full-blob fallback.
    pub max_replan_rounds: usize,
}

impl Default for PeerPlanner {
    fn default() -> Self {
        PeerPlanner { max_replan_rounds: 2 }
    }
}

impl PeerPlanner {
    /// Split `k` chunks into contiguous stripes, one per participant,
    /// proportional to `weights` (link goodputs).  Stripe order follows the
    /// participant order — the head peer is participant 0 and always owns
    /// the leading stripe.  Non-finite or non-positive weights (loopback
    /// links model infinite goodput) degrade the whole split to equal
    /// shares.  Stripes are contiguous so each peer's byte offsets are one
    /// prefix-sum walk of the chunk index, and they always sum to `k`.
    pub fn split_chunks(&self, k: usize, weights: &[f64]) -> Vec<Range<usize>> {
        let n = weights.len();
        if n == 0 {
            return Vec::new();
        }
        let equal = weights.iter().any(|w| !w.is_finite() || *w <= 0.0);
        let total: f64 = if equal {
            n as f64
        } else {
            weights.iter().sum()
        };
        let mut out = Vec::with_capacity(n);
        let mut cum = 0.0;
        let mut prev = 0usize;
        for (i, w) in weights.iter().enumerate() {
            cum += if equal { 1.0 } else { *w };
            let b = if i + 1 == n {
                k
            } else {
                (((k as f64) * cum / total).round() as usize).clamp(prev, k)
            };
            out.push(prev..b);
            prev = b;
        }
        out
    }

    /// Re-plan orphaned chunks onto the surviving peers, round-robin.
    /// `unfed` are chunk ids a failed share left behind; `live` are the
    /// peer slots still worth asking.  Returns one `(peer, chunks)` share
    /// per survivor that got work.
    pub fn reassign(&self, unfed: &[usize], live: &[usize]) -> Vec<(usize, Vec<usize>)> {
        if live.is_empty() || unfed.is_empty() {
            return Vec::new();
        }
        let mut shares: Vec<(usize, Vec<usize>)> =
            live.iter().map(|&p| (p, Vec::new())).collect();
        for (i, &c) in unfed.iter().enumerate() {
            shares[i % live.len()].1.push(c);
        }
        shares.retain(|(_, cs)| !cs.is_empty());
        shares
    }

    /// Upload placement: power-of-two-choices over `candidates`.  Two
    /// distinct peers are sampled and the one whose probed `used_bytes` is
    /// smaller wins — the classic two-choices result gives near-balanced
    /// load without probing the whole fleet.  `probe` returning `u64::MAX`
    /// marks a peer unreachable.  Degenerates to the single candidate (no
    /// probe round trips) when only one peer exists.
    ///
    /// Every random decision — the two samples *and* the equal-load
    /// tie-break — draws from the caller's `rng`, so a seeded caller
    /// replays the exact same placement sequence (benches and tests can
    /// reproduce placements bit-for-bit) and the first-sampled peer gets
    /// no structural bias on ties.
    pub fn place(
        &self,
        rng: &mut Rng,
        candidates: &[usize],
        mut probe: impl FnMut(usize) -> u64,
    ) -> Option<usize> {
        match candidates.len() {
            0 => None,
            1 => Some(candidates[0]),
            n => {
                let a = rng.below(n as u64) as usize;
                let mut b = rng.below((n - 1) as u64) as usize;
                if b >= a {
                    b += 1;
                }
                let (pa, pb) = (candidates[a], candidates[b]);
                let (ua, ub) = (probe(pa), probe(pb));
                if ua == u64::MAX && ub == u64::MAX {
                    return None;
                }
                Some(if ua < ub {
                    pa
                } else if ub < ua {
                    pb
                } else if rng.chance(0.5) {
                    pa
                } else {
                    pb
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_chunks_contiguous_weighted_and_complete() {
        let p = PeerPlanner::default();
        // equal weights: near-even contiguous stripes covering [0, k)
        let s = p.split_chunks(10, &[1.0, 1.0]);
        assert_eq!(s, vec![0..5, 5..10]);
        // weighted: the faster link takes the larger stripe
        let s = p.split_chunks(12, &[3.0, 1.0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].start, 0);
        assert_eq!(s[0].end, s[1].start, "stripes are contiguous");
        assert_eq!(s[1].end, 12, "stripes cover every chunk");
        assert!(s[0].len() > s[1].len(), "weight 3 beats weight 1: {s:?}");
        // infinite goodput (loopback) degrades to equal shares
        let s = p.split_chunks(8, &[f64::INFINITY, 1.0]);
        assert_eq!(s.iter().map(|r| r.len()).collect::<Vec<_>>(), vec![4, 4]);
        // fewer chunks than peers: trailing peers get empty stripes
        let s = p.split_chunks(1, &[1.0, 1.0, 1.0]);
        assert_eq!(s.iter().map(|r| r.len()).sum::<usize>(), 1);
        // degenerate single-peer case: one stripe owning everything
        assert_eq!(p.split_chunks(7, &[1.0]), vec![0..7]);
        assert!(p.split_chunks(7, &[]).is_empty());
    }

    #[test]
    fn reassign_covers_every_orphan_over_survivors() {
        let p = PeerPlanner::default();
        let shares = p.reassign(&[2, 5, 6, 9], &[0, 3]);
        let mut got: Vec<usize> = shares.iter().flat_map(|(_, cs)| cs.clone()).collect();
        got.sort_unstable();
        assert_eq!(got, vec![2, 5, 6, 9], "every orphan reassigned exactly once");
        for (peer, _) in &shares {
            assert!([0, 3].contains(peer));
        }
        // single survivor takes everything; no survivors -> nothing
        let shares = p.reassign(&[1, 2], &[7]);
        assert_eq!(shares, vec![(7, vec![1, 2])]);
        assert!(p.reassign(&[1], &[]).is_empty());
        assert!(p.reassign(&[], &[0]).is_empty());
    }

    #[test]
    fn place_prefers_less_loaded_of_two_choices() {
        let p = PeerPlanner::default();
        let mut rng = Rng::new(7);
        // loads: peer 2 is drastically lighter; over many draws it must win
        // whenever sampled, and a two-choice winner is never the heaviest
        let loads = [900u64, 800, 10];
        let mut wins = [0usize; 3];
        for _ in 0..200 {
            let w = p.place(&mut rng, &[0, 1, 2], |i| loads[i]).unwrap();
            wins[w] += 1;
        }
        assert!(wins[2] > wins[0] && wins[2] > wins[1], "{wins:?}");
        assert!(wins[0] < 40, "heaviest peer must rarely win: {wins:?}");
        // single candidate needs no probe; empty set places nowhere
        let mut probes = 0;
        assert_eq!(
            p.place(&mut rng, &[4], |_| {
                probes += 1;
                0
            }),
            Some(4)
        );
        assert_eq!(probes, 0, "single-peer placement must not probe");
        assert_eq!(p.place(&mut rng, &[], |_| 0), None);
        // both probes dead -> no placement
        assert_eq!(p.place(&mut rng, &[0, 1], |_| u64::MAX), None);
    }

    #[test]
    fn always_always_fetches() {
        let d = DeviceProfile::pi5_4gb();
        let l = LinkModel::wifi4_2g4();
        assert!(FetchPolicy::Always.should_fetch(&d, &l, 1, usize::MAX / 2));
    }

    #[test]
    fn break_even_matches_paper_table2() {
        let l = LinkModel::wifi4_2g4();
        // low-end, paper state sizes: 2.25 MB / 65 tokens — fetch wins big
        let lo = DeviceProfile::pi_zero_2w();
        assert!(FetchPolicy::BreakEven.should_fetch(&lo, &l, 65, 2_250_000));
        // high-end: 9.94 MB / 334 tokens — fetch loses (Table 2: +7 %)
        let hi = DeviceProfile::pi5_4gb();
        assert!(!FetchPolicy::BreakEven.should_fetch(&hi, &l, 334, 9_940_000));
    }

    #[test]
    fn break_even_tokens_ordering() {
        let l = LinkModel::wifi4_2g4();
        let lo = DeviceProfile::pi_zero_2w();
        let hi = DeviceProfile::pi5_4gb();
        // paper state scaling: ~34.5 KB/token (270M), ~29.8 KB/token (1B)
        let be_lo = FetchPolicy::break_even_tokens(&lo, &l, 34_500);
        let be_hi = FetchPolicy::break_even_tokens(&hi, &l, 29_800);
        assert!(be_lo < 20, "low-end breaks even almost immediately: {be_lo}");
        assert!(
            be_hi > 1000,
            "high-end never reasonably breaks even: {be_hi}"
        );
    }

    #[test]
    fn place_sequences_reproducible_under_seed() {
        // a seeded caller replays the exact same placement sequence — the
        // tie-break draws from the caller's rng instead of silently
        // preferring the first sample
        let p = PeerPlanner::default();
        let seq = |seed: u64, load: fn(usize) -> u64| -> Vec<usize> {
            let mut rng = Rng::new(seed);
            (0..128)
                .map(|_| p.place(&mut rng, &[0, 1, 2, 3], load).unwrap())
                .collect()
        };
        assert_eq!(seq(99, |_| 7), seq(99, |_| 7), "same seed, same sequence");
        assert_ne!(seq(99, |_| 7), seq(100, |_| 7), "seed changes the sequence");
        // all-equal loads: ties must spread over the peers, not pile on
        // whichever sample came first
        let ties = seq(5, |_| 0);
        let mut counts = [0usize; 4];
        for &w in &ties {
            counts[w] += 1;
        }
        assert!(
            counts.iter().all(|&c| c > 0),
            "equal-load ties must reach every peer: {counts:?}"
        );
    }

    #[test]
    fn break_even_tokens_matches_linear_scan() {
        // the binary search must agree with the old 1..100_000 linear scan
        // on every device x link x stride combination
        let scan = |device: &DeviceProfile, link: &LinkModel, bpt: usize| -> usize {
            for n in 1..100_000 {
                if link.delay_for(n * bpt, None) < device.prefill_time(n) {
                    return n;
                }
            }
            usize::MAX
        };
        let devices = [
            DeviceProfile::pi_zero_2w(),
            DeviceProfile::pi5_4gb(),
            DeviceProfile::host(),
        ];
        let links = [
            LinkModel::wifi4_2g4(),
            LinkModel::ethernet_1g(),
            LinkModel::loopback(),
        ];
        for d in &devices {
            for l in &links {
                for bpt in [0usize, 512, 29_800, 34_500, 1_000_000] {
                    assert_eq!(
                        FetchPolicy::break_even_tokens(d, l, bpt),
                        scan(d, l, bpt),
                        "device={} link={} bpt={bpt}",
                        d.name,
                        l.name
                    );
                }
            }
        }
    }

    #[test]
    fn ethernet_shifts_break_even() {
        // §5.3: a wired cache box would rescue the high-end case
        let hi = DeviceProfile::pi5_4gb();
        let eth = LinkModel::ethernet_1g();
        assert!(FetchPolicy::BreakEven.should_fetch(&hi, &eth, 334, 9_940_000));
    }
}
