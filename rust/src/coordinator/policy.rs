//! Fetch policies: should a catalog hit trigger a state download?
//!
//! The paper always fetches on a (probable) hit and *shows* in Table 2 that
//! this loses on the high-end device (Redis 2.89 s vs P-decode 2.69 s).  Its
//! §5.3 break-even discussion is turned here into an explicit runtime
//! policy — [`FetchPolicy::BreakEven`] — evaluated in the ablation bench.

use crate::devicemodel::DeviceProfile;
use crate::netsim::LinkModel;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchPolicy {
    /// Paper behaviour: a catalog hit always triggers a download.
    Always,
    /// Download only if the modelled transfer time beats the modelled local
    /// prefill time for the tokens the hit would save.
    BreakEven,
}

impl FetchPolicy {
    /// Decide whether to fetch a cached state of `matched_tokens` tokens and
    /// `state_bytes` bytes instead of prefilling those tokens locally.
    pub fn should_fetch(
        self,
        device: &DeviceProfile,
        link: &LinkModel,
        matched_tokens: usize,
        state_bytes: usize,
    ) -> bool {
        match self {
            FetchPolicy::Always => true,
            FetchPolicy::BreakEven => {
                let transfer = link.delay_for(state_bytes, None);
                let prefill = device.prefill_time(matched_tokens);
                transfer < prefill
            }
        }
    }

    /// Smallest matched-token count at which fetching wins on this
    /// device+link (analysis helper; assumes `bytes_per_token` state size).
    pub fn break_even_tokens(
        device: &DeviceProfile,
        link: &LinkModel,
        bytes_per_token: usize,
    ) -> usize {
        for n in 1..100_000 {
            let transfer = link.delay_for(n * bytes_per_token, None);
            if transfer < device.prefill_time(n) {
                return n;
            }
            // transfer and prefill both linear in n beyond the RTT floor; if
            // prefill hasn't caught up by 100k tokens it never will
        }
        usize::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_always_fetches() {
        let d = DeviceProfile::pi5_4gb();
        let l = LinkModel::wifi4_2g4();
        assert!(FetchPolicy::Always.should_fetch(&d, &l, 1, usize::MAX / 2));
    }

    #[test]
    fn break_even_matches_paper_table2() {
        let l = LinkModel::wifi4_2g4();
        // low-end, paper state sizes: 2.25 MB / 65 tokens — fetch wins big
        let lo = DeviceProfile::pi_zero_2w();
        assert!(FetchPolicy::BreakEven.should_fetch(&lo, &l, 65, 2_250_000));
        // high-end: 9.94 MB / 334 tokens — fetch loses (Table 2: +7 %)
        let hi = DeviceProfile::pi5_4gb();
        assert!(!FetchPolicy::BreakEven.should_fetch(&hi, &l, 334, 9_940_000));
    }

    #[test]
    fn break_even_tokens_ordering() {
        let l = LinkModel::wifi4_2g4();
        let lo = DeviceProfile::pi_zero_2w();
        let hi = DeviceProfile::pi5_4gb();
        // paper state scaling: ~34.5 KB/token (270M), ~29.8 KB/token (1B)
        let be_lo = FetchPolicy::break_even_tokens(&lo, &l, 34_500);
        let be_hi = FetchPolicy::break_even_tokens(&hi, &l, 29_800);
        assert!(be_lo < 20, "low-end breaks even almost immediately: {be_lo}");
        assert!(
            be_hi > 1000,
            "high-end never reasonably breaks even: {be_hi}"
        );
    }

    #[test]
    fn ethernet_shifts_break_even() {
        // §5.3: a wired cache box would rescue the high-end case
        let hi = DeviceProfile::pi5_4gb();
        let eth = LinkModel::ethernet_1g();
        assert!(FetchPolicy::BreakEven.should_fetch(&hi, &eth, 334, 9_940_000));
    }
}
