//! Overhead-aware per-chunk fetch planning (SparKV-style mixed loading).
//!
//! `FetchPolicy::break_even_tokens` makes one all-or-nothing decision per
//! matched range, but ECS3 already gives chunk-granular transfer and the
//! fabric gives per-peer goodput.  This module makes the restore plan
//! per-chunk: for each matched chunk it compares the modelled transfer cost
//! (per-peer goodput/RTT, the chunk's queue position within its stripe, the
//! entry's actual compressed wire bytes) against the local recompute cost
//! (devicemodel prefill rates) and emits **mixed plans** — fetch the
//! expensive-to-recompute chunks from fast peers while the device
//! recomputes the cheap ones locally, overlapped through `StateAssembler`.
//!
//! Two planners share one [`cost_of`] model:
//!
//! * [`plan_exhaustive`] — argmin over all `2^k` fetch/recompute
//!   assignments (`k ≤ 16`).  This is the reference the oracle test suite
//!   pins: whatever assignment the enumeration says is cheapest, the
//!   planner must match.
//! * [`plan_split`] — the *executable* planner.  Causal attention means a
//!   recomputed chunk needs every earlier token's state, so the only plans
//!   the engine can actually run are "recompute the prefix `[0, s)`
//!   locally, fetch the suffix `[s, k)` from peers"; this scans all `k+1`
//!   split points.  For a single link and homogeneous chunks the split
//!   optimum equals the exhaustive optimum (only the *counts* matter);
//!   in general it is the best plan subject to the causality constraint.
//!
//! Cost model, in seconds:
//!
//! * transfer: fetched chunks are striped contiguously across links in
//!   goodput proportion (the same [`PeerPlanner::split_chunks`] discipline
//!   the fabric uses), and a stripe's completion is
//!   `rtt + stripe_bytes / goodput` — the shaper's arrival model for the
//!   stripe's last queued chunk.  Plan transfer cost is the max over
//!   non-empty stripes.
//! * recompute: the device is serial, so `Σ tokens_c · prefill_ms / 1e3`
//!   over recomputed chunks.
//! * total: `max(transfer, recompute)` — the two feeders overlap.
//!
//! The degenerate all-or-nothing decision ([`FetchPolicy::BreakEven`],
//! `--plan range`) is kept as the ablation baseline; `benches/fetch_plan.rs`
//! maps the device×link grid where it is provably wrong.

use crate::netsim::LinkModel;

use super::policy::PeerPlanner;

/// Largest chunk count [`plan_exhaustive`] will enumerate (`2^k` masks).
pub const EXHAUSTIVE_MAX_CHUNKS: usize = 16;

/// Restore-plan granularity (`--plan chunk|range`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanMode {
    /// Ablation: one all-or-nothing decision per matched range (the PR 3
    /// `FetchPolicy` behaviour).
    Range,
    /// Per-chunk mixed plans from the cost model in this module.
    Chunk,
}

impl PlanMode {
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "range" | "binary" => Some(PlanMode::Range),
            "chunk" | "mixed" => Some(PlanMode::Chunk),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PlanMode::Range => "range",
            PlanMode::Chunk => "chunk",
        }
    }
}

/// Per-chunk planner input: what the chunk costs to move vs to redo.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChunkCost {
    /// Bytes actually on the wire for this chunk (the entry's stored,
    /// possibly deflated, chunk length — so per-entry compression ratio is
    /// priced in for free).
    pub wire_bytes: usize,
    /// Prompt tokens this chunk covers (what local prefill must redo).
    pub tokens: usize,
}

/// Per-link planner input, extracted from the fabric's shaped links.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkCost {
    pub goodput_bps: f64,
    pub rtt_s: f64,
}

impl LinkCost {
    pub fn from_link(l: &LinkModel) -> Self {
        LinkCost { goodput_bps: l.goodput_bps, rtt_s: l.rtt.as_secs_f64() }
    }

    /// Queue-depth-aware derating: scale effective goodput down by the
    /// peer's observed/expected service-time ratio
    /// (`PeerLedger::service_slowdown`), so a hot box — one whose shares
    /// complete slower than its link model alone explains — loses planner
    /// share *before* it stalls.  The factor is clamped to `[0.05, 1.0]`:
    /// a slowdown never makes a link look faster than its model, and even
    /// a pathological observation leaves the peer 5% of its goodput so it
    /// keeps receiving (and can shed or recover) rather than being
    /// silently zeroed out of every plan.
    pub fn derated(self, slowdown: f64) -> LinkCost {
        if !slowdown.is_finite() || slowdown <= 0.0 {
            return self;
        }
        let factor = (1.0 / slowdown).clamp(0.05, 1.0);
        LinkCost { goodput_bps: self.goodput_bps * factor, rtt_s: self.rtt_s }
    }
}

/// Where one chunk's rows come from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkSource {
    /// Download from a peer stripe.
    Fetch,
    /// Recompute locally on the (modelled) device.
    Recompute,
}

/// Modelled cost of one assignment, in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanCost {
    /// `max(transfer, recompute)` — the feeders overlap.
    pub total_s: f64,
    /// Completion of the slowest non-empty peer stripe.
    pub transfer_s: f64,
    /// Serial local prefill of the recomputed chunks.
    pub recompute_s: f64,
}

/// A per-chunk restore plan plus its modelled cost.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkPlan {
    /// `sources[c]` is where chunk `c` comes from.
    pub sources: Vec<ChunkSource>,
    pub cost: PlanCost,
}

impl ChunkPlan {
    pub fn fetched(&self) -> usize {
        self.sources.iter().filter(|s| **s == ChunkSource::Fetch).count()
    }

    pub fn recomputed(&self) -> usize {
        self.sources.len() - self.fetched()
    }

    pub fn is_mixed(&self) -> bool {
        self.fetched() > 0 && self.recomputed() > 0
    }

    /// For split plans: the first fetched chunk index `s` (recompute
    /// `[0, s)`, fetch `[s, k)`).  `k` when everything is recomputed.
    pub fn split_point(&self) -> usize {
        self.sources
            .iter()
            .position(|s| *s == ChunkSource::Fetch)
            .unwrap_or(self.sources.len())
    }
}


/// Price one fetch/recompute assignment under the cost model (module docs).
///
/// `sources.len()` must equal `chunks.len()`.  An assignment that fetches
/// anything over an empty link set costs `+inf` transfer.
pub fn cost_of(
    chunks: &[ChunkCost],
    links: &[LinkCost],
    prefill_ms_per_tok: f64,
    sources: &[ChunkSource],
) -> PlanCost {
    assert_eq!(chunks.len(), sources.len(), "one source per chunk");
    let fetch_bytes: Vec<usize> = sources
        .iter()
        .zip(chunks)
        .filter(|(s, _)| **s == ChunkSource::Fetch)
        .map(|(_, c)| c.wire_bytes)
        .collect();
    let transfer_s = if fetch_bytes.is_empty() {
        0.0
    } else if links.is_empty() {
        f64::INFINITY
    } else {
        // Goodput-weighted contiguous stripes — the fabric's split
        // discipline — so a chunk's queue position within its stripe is
        // priced via the stripe's cumulative bytes.
        let weights: Vec<f64> = links.iter().map(|l| l.goodput_bps).collect();
        let stripes = PeerPlanner::default().split_chunks(fetch_bytes.len(), &weights);
        let mut worst = 0.0f64;
        for (link, stripe) in links.iter().zip(&stripes) {
            if stripe.is_empty() {
                continue;
            }
            let bytes: usize = fetch_bytes[stripe.clone()].iter().sum();
            let xfer = bytes as f64 / link.goodput_bps; // inf goodput -> 0
            worst = worst.max(link.rtt_s + xfer);
        }
        worst
    };
    let recompute_tokens: usize = sources
        .iter()
        .zip(chunks)
        .filter(|(s, _)| **s == ChunkSource::Recompute)
        .map(|(_, c)| c.tokens)
        .sum();
    let recompute_s = recompute_tokens as f64 * prefill_ms_per_tok / 1e3;
    PlanCost { total_s: transfer_s.max(recompute_s), transfer_s, recompute_s }
}

fn plan_for(
    chunks: &[ChunkCost],
    links: &[LinkCost],
    prefill_ms_per_tok: f64,
    sources: Vec<ChunkSource>,
) -> ChunkPlan {
    let cost = cost_of(chunks, links, prefill_ms_per_tok, &sources);
    ChunkPlan { sources, cost }
}

/// Argmin over every `2^k` fetch/recompute assignment (`k ≤ 16`; larger
/// inputs delegate to [`plan_split`]).  Ties prefer fewer fetched chunks,
/// then the first assignment in mask order — deterministic, so the oracle
/// suite can replay it.
pub fn plan_exhaustive(
    chunks: &[ChunkCost],
    links: &[LinkCost],
    prefill_ms_per_tok: f64,
) -> ChunkPlan {
    let k = chunks.len();
    if k > EXHAUSTIVE_MAX_CHUNKS {
        return plan_split(chunks, links, prefill_ms_per_tok);
    }
    let mut best: Option<ChunkPlan> = None;
    for mask in 0u32..(1u32 << k) {
        let sources: Vec<ChunkSource> = (0..k)
            .map(|c| {
                if mask & (1 << c) != 0 {
                    ChunkSource::Fetch
                } else {
                    ChunkSource::Recompute
                }
            })
            .collect();
        let cand = plan_for(chunks, links, prefill_ms_per_tok, sources);
        let better = match &best {
            None => true,
            Some(b) => {
                cand.cost.total_s < b.cost.total_s
                    || (cand.cost.total_s == b.cost.total_s && cand.fetched() < b.fetched())
            }
        };
        if better {
            best = Some(cand);
        }
    }
    best.unwrap_or(ChunkPlan {
        sources: Vec::new(),
        cost: PlanCost { total_s: 0.0, transfer_s: 0.0, recompute_s: 0.0 },
    })
}

/// The executable planner: scan every split point `s`, recomputing the
/// prefix `[0, s)` and fetching the suffix `[s, k)` (causal attention
/// forbids recomputing a chunk whose predecessors are absent).  Both
/// extremes are in the scan — `s = 0` is all-fetch, `s = k` is
/// all-recompute — so the split plan never loses to either.  Ties prefer
/// the larger `s` (fewer fetched chunks, fewer wire bytes).
pub fn plan_split(
    chunks: &[ChunkCost],
    links: &[LinkCost],
    prefill_ms_per_tok: f64,
) -> ChunkPlan {
    let k = chunks.len();
    let mut best: Option<ChunkPlan> = None;
    for s in 0..=k {
        let sources: Vec<ChunkSource> = (0..k)
            .map(|c| if c < s { ChunkSource::Recompute } else { ChunkSource::Fetch })
            .collect();
        let cand = plan_for(chunks, links, prefill_ms_per_tok, sources);
        let better = match &best {
            None => true,
            Some(b) => cand.cost.total_s <= b.cost.total_s, // tie -> larger s
        };
        if better {
            best = Some(cand);
        }
    }
    best.expect("k+1 >= 1 candidates")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devicemodel::DeviceProfile;

    fn uniform(k: usize, wire_bytes: usize, tokens: usize) -> Vec<ChunkCost> {
        vec![ChunkCost { wire_bytes, tokens }; k]
    }

    fn wifi() -> LinkCost {
        LinkCost::from_link(&LinkModel::wifi4_2g4())
    }

    #[test]
    fn plan_mode_names_roundtrip() {
        for m in [PlanMode::Range, PlanMode::Chunk] {
            assert_eq!(PlanMode::by_name(m.name()), Some(m));
        }
        assert_eq!(PlanMode::by_name("mixed"), Some(PlanMode::Chunk));
        assert!(PlanMode::by_name("per-token").is_none());
    }

    #[test]
    fn derating_shifts_share_to_survivors() {
        use crate::coordinator::policy::PeerPlanner;
        // Three identical links; peer 1 reports a 4x service-time slowdown
        // (queue building up behind its admission gate).  Its stripe must
        // shrink and the survivors' stripes must grow.
        let links = [wifi(), wifi().derated(4.0), wifi()];
        let weights: Vec<f64> = links.iter().map(|l| l.goodput_bps).collect();
        let stripes = PeerPlanner::default().split_chunks(18, &weights);
        assert_eq!(stripes.len(), 3);
        let hot = stripes[1].len();
        let cold = stripes[0].len().min(stripes[2].len());
        assert!(
            hot < cold,
            "hot peer must get a strictly smaller stripe: {stripes:?}"
        );
        // Coverage is still contiguous and complete.
        assert_eq!(stripes[0].start, 0);
        assert_eq!(stripes[2].end, 18);

        // Derating degrades goodput only — latency is a link property, not
        // a queue property — and is clamped on both sides.
        let base = wifi();
        assert_eq!(base.derated(4.0).rtt_s, base.rtt_s);
        assert_eq!(base.derated(1.0).goodput_bps, base.goodput_bps);
        assert_eq!(base.derated(0.5).goodput_bps, base.goodput_bps); // never faster
        assert!(base.derated(1e9).goodput_bps >= base.goodput_bps * 0.05 * 0.999);
        assert_eq!(base.derated(f64::NAN).goodput_bps, base.goodput_bps);
        assert_eq!(base.derated(-1.0).goodput_bps, base.goodput_bps);
    }

    #[test]
    fn cost_extremes_match_single_feeder() {
        let chunks = uniform(4, 100_000, 32);
        let links = [wifi()];
        let p = 8.0; // ms/tok
        let all_fetch = vec![ChunkSource::Fetch; 4];
        let c = cost_of(&chunks, &links, p, &all_fetch);
        assert_eq!(c.recompute_s, 0.0);
        let expect = 0.270 + 400_000.0 / (30.4e6 / 8.0);
        assert!((c.transfer_s - expect).abs() < 1e-9, "{c:?}");
        assert_eq!(c.total_s, c.transfer_s);
        let all_re = vec![ChunkSource::Recompute; 4];
        let c = cost_of(&chunks, &links, p, &all_re);
        assert_eq!(c.transfer_s, 0.0);
        assert!((c.recompute_s - 128.0 * 8.0 / 1e3).abs() < 1e-12, "{c:?}");
    }

    #[test]
    fn fetch_without_links_is_infinite() {
        let chunks = uniform(2, 1000, 8);
        let c = cost_of(&chunks, &[], 10.0, &[ChunkSource::Fetch, ChunkSource::Recompute]);
        assert!(c.transfer_s.is_infinite());
        let c = cost_of(&chunks, &[], 10.0, &[ChunkSource::Recompute; 2]);
        assert!(c.transfer_s == 0.0 && c.total_s.is_finite());
    }

    #[test]
    fn loopback_plans_all_fetch_on_any_real_device() {
        let chunks = uniform(6, 500_000, 64);
        let links = [LinkCost::from_link(&LinkModel::loopback())];
        for planner in [plan_exhaustive, plan_split] {
            let plan = planner(&chunks, &links, DeviceProfile::pi5_4gb().prefill_ms_per_tok);
            assert_eq!(plan.fetched(), 6, "free wire beats any recompute: {plan:?}");
            assert_eq!(plan.cost.total_s, 0.0);
        }
    }

    #[test]
    fn host_device_plans_all_recompute_under_pure_model() {
        // prefill rate 0 makes recompute free — the *model* says compute
        // everything, which is why callers gate on models_recompute()
        let chunks = uniform(4, 1_000_000, 32);
        let plan = plan_exhaustive(&chunks, &[wifi()], 0.0);
        assert_eq!(plan.recomputed(), 4);
        assert!(!DeviceProfile::host().models_recompute());
        assert!(DeviceProfile::pi5_4gb().models_recompute());
    }

    #[test]
    fn slow_link_fast_device_yields_mixed_plan() {
        // pi5-class prefill (~8 ms/tok) against paper Wi-Fi, long prefix of
        // chunky state: the binary decision is provably wrong here
        let chunks = uniform(8, 1_048_576, 32); // 8 MB total, 256 tokens
        let links = [wifi()];
        let p = DeviceProfile::pi5_4gb().prefill_ms_per_tok;
        let plan = plan_split(&chunks, &links, p);
        let all_fetch = cost_of(&chunks, &links, p, &vec![ChunkSource::Fetch; 8]);
        let all_re = cost_of(&chunks, &links, p, &vec![ChunkSource::Recompute; 8]);
        assert!(plan.is_mixed(), "{plan:?}");
        assert!(plan.cost.total_s < all_fetch.total_s);
        assert!(plan.cost.total_s < all_re.total_s);
    }

    #[test]
    fn split_plan_never_worse_than_either_extreme() {
        let p = 3.7;
        for k in 0..10usize {
            let chunks: Vec<ChunkCost> = (0..k)
                .map(|i| ChunkCost { wire_bytes: 10_000 + 7013 * i, tokens: 16 + i })
                .collect();
            let links = [wifi(), LinkCost { goodput_bps: 1e6, rtt_s: 0.05 }];
            let plan = plan_split(&chunks, &links, p);
            for extreme in [ChunkSource::Fetch, ChunkSource::Recompute] {
                let c = cost_of(&chunks, &links, p, &vec![extreme; k]);
                assert!(
                    plan.cost.total_s <= c.total_s + 1e-12,
                    "k={k} {extreme:?}: {plan:?} vs {c:?}"
                );
            }
        }
    }

    #[test]
    fn exhaustive_matches_split_on_homogeneous_single_link() {
        // with one link and identical chunks only the counts matter, so the
        // causality-constrained split scan reaches the unconstrained optimum
        let links = [wifi()];
        for p in [1.0, 8.0, 50.0, 192.0] {
            for k in 0..=8usize {
                let chunks = uniform(k, 300_000, 24);
                let e = plan_exhaustive(&chunks, &links, p);
                let s = plan_split(&chunks, &links, p);
                assert!(
                    (e.cost.total_s - s.cost.total_s).abs() < 1e-12,
                    "p={p} k={k}: {e:?} vs {s:?}"
                );
            }
        }
    }

    #[test]
    fn plan_split_point_is_prefix_shaped() {
        let chunks = uniform(8, 1_048_576, 32);
        let plan = plan_split(&chunks, &[wifi()], DeviceProfile::pi5_4gb().prefill_ms_per_tok);
        let s = plan.split_point();
        for (c, src) in plan.sources.iter().enumerate() {
            let want = if c < s { ChunkSource::Recompute } else { ChunkSource::Fetch };
            assert_eq!(*src, want, "chunk {c} of split {s}");
        }
        assert_eq!(plan.recomputed(), s);
    }

    #[test]
    fn empty_chunk_set_plans_trivially() {
        let plan = plan_exhaustive(&[], &[wifi()], 8.0);
        assert!(plan.sources.is_empty());
        assert_eq!(plan.cost.total_s, 0.0);
        let plan = plan_split(&[], &[wifi()], 8.0);
        assert!(plan.sources.is_empty());
    }

    #[test]
    fn oversize_exhaustive_delegates_to_split() {
        let chunks = uniform(EXHAUSTIVE_MAX_CHUNKS + 3, 200_000, 16);
        let e = plan_exhaustive(&chunks, &[wifi()], 8.0);
        let s = plan_split(&chunks, &[wifi()], 8.0);
        assert_eq!(e, s);
    }
}
