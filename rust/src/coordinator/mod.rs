//! The distributed prompt-caching coordinator — the paper's system
//! contribution (§3), generalised from one middle node to an N-box **peer
//! fabric**, assembled from the substrate modules:
//!
//! * [`cachebox`] — one middle node of Figure 1: kvstore server + master
//!   catalog in one process; a fabric runs N of them;
//! * [`fabric`] — the peer layer: pooled per-peer connections, peer-tagged
//!   catalogs, and the multi-source chunk fetch that stripes a matched
//!   range across every claiming box and re-plans around mid-stream peer
//!   deaths;
//! * [`client`] — [`client::EdgeClient`], the steps 1–4 inference flow with
//!   partial matching, false-positive fallback and post-response uploads;
//! * [`sync`] — the asynchronous local-catalog synchronization loop
//!   (Figure 2, green arrow), one per peer, with capped backoff for dead
//!   peers;
//! * [`placement`] — the pluggable [`placement::Placement`] policy: where
//!   uploads land, which owners a catalog miss may probe, where repair
//!   re-publishes (deterministic rendezvous-hash ring or load-probing
//!   power-of-two-choices);
//! * [`policy`] — fetch policy and the fabric planner: the paper's
//!   always-fetch-on-hit plus a break-even extension (§5.3 analysis turned
//!   into a runtime policy), and the chunk-split / re-plan /
//!   two-choices-sampling primitives the placement policies build on;
//! * [`plan`] — overhead-aware per-chunk fetch planning: a cost model over
//!   per-peer goodput/RTT and devicemodel prefill rates that emits mixed
//!   fetch/recompute plans per matched chunk (`--plan chunk`), with the
//!   all-or-nothing [`policy::FetchPolicy`] kept as the `--plan range`
//!   ablation;
//! * [`membership`] — the fleet liveness layer: a per-peer
//!   `Up → Suspect → Dead → Recovering` health state machine fed by
//!   heartbeats piggybacked on the sync loop and hot-path I/O outcomes,
//!   plus the [`membership::DeadlineBudget`] that arms socket deadlines on
//!   pooled connections so a stalled peer costs one budget, never a hang.
//!   Since PR 8 the view is *fleet-converged*: SWIM-style
//!   [`membership::MembershipDigest`]s (incarnation-numbered peer states)
//!   ride the catalog-sync wire through each box's gossip blackboard, a
//!   suspected box refutes with a bumped incarnation, and a circumstantial
//!   `Suspect → Dead` is gated behind an indirect probe relayed through a
//!   third box ([`fabric::RelayProber`]).

pub mod cachebox;
pub mod client;
pub mod fabric;
pub mod membership;
pub mod placement;
pub mod plan;
pub mod policy;
pub mod sync;

pub use cachebox::CacheBox;
pub use client::{
    adaptive_chunk_tokens, EdgeClient, EdgeClientConfig, HitCase, QueryResult,
};
pub use fabric::{Peer, PeerConfig, RelayProber};
pub use membership::{
    DeadlineBudget, HealthPolicy, HealthSink, IndirectProbe, Membership,
    MembershipDigest, Outcome, PeerHealth, PeerView,
};
pub use placement::{
    Placement, PlacementKind, PowerOfTwoChoices, RendezvousRing,
};
pub use plan::{ChunkCost, ChunkPlan, ChunkSource, LinkCost, PlanCost, PlanMode};
pub use policy::{FetchPolicy, PeerPlanner};
pub use sync::CatalogSync;
