//! The distributed prompt-caching coordinator — the paper's system
//! contribution (§3), assembled from the substrate modules:
//!
//! * [`cachebox`] — the middle node of Figure 1: kvstore server + master
//!   catalog in one process;
//! * [`client`] — [`client::EdgeClient`], the steps 1–4 inference flow with
//!   partial matching, false-positive fallback and post-response uploads;
//! * [`sync`] — the asynchronous local-catalog synchronization loop
//!   (Figure 2, green arrow);
//! * [`policy`] — fetch policies: the paper's always-fetch-on-hit plus a
//!   break-even extension (§5.3 analysis turned into a runtime policy).

pub mod cachebox;
pub mod client;
pub mod policy;
pub mod sync;

pub use cachebox::CacheBox;
pub use client::{
    adaptive_chunk_tokens, EdgeClient, EdgeClientConfig, HitCase, QueryResult,
};
pub use policy::FetchPolicy;
pub use sync::CatalogSync;
